"""Quickstart: simulate the paper's Fig 6 diamond app in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        critical_path, diamond, node_delays, report_text,
                        summarize)

# Service DAG from paper Fig 6: A → {B, C} → D (C is 2× heavier).
graph = diamond(mi=500.0)

sim = Simulation(
    graph,
    caps=SimCaps(n_clients=32, max_requests=4096, max_cloudlets=4096,
                 max_instances=16, n_vms=4, d_max=2, max_replicas=4),
    params=SimParams(dt=0.05, n_ticks=2400,       # 120 simulated seconds
                     n_clients=20, spawn_rate=2.0,  # Alg 1 client model
                     wait_lo=1.0, wait_hi=3.0, slo_ms=1500.0),
    default_template=InstanceTemplate(mips=11000.0, limit_mips=22000.0),
)

result = sim.run()
report = summarize(sim, result)
print(report_text(report))

# Alg 2: critical path over measured node delays
delays = node_delays(result)
rt, path = critical_path(graph, delays, api=0)
print("\ncritical path:", " → ".join(graph.names[i] for i in path),
      f"(predicted response {rt * 1000:.0f} ms, "
      f"simulated avg {report.avg_response_ms:.0f} ms)")
