"""Streaming telemetry, sampled tracing & phase profiling (DESIGN.md §9).

Three observability surfaces on the paper's SockShop deployment, all
opt-in (``telemetry="stream"``) and provably observation-only — the
golden-matrix digests are bit-identical with telemetry on or off
(tests/test_obs.py):

1. **Live metric stream** — the device seals one metric row per
   ``tel_window_ticks`` window into an on-carry ring and flushes ring
   halves through an ``io_callback`` tap *while the scan runs*; sinks
   render rows as OTel JSON or Prometheus exposition lines.  The same
   tap fires per sweep point during a batched ``run_batch`` sweep (rows
   carry a ``tag`` column), and the streamed windows reconcile exactly
   with each point's end-of-run ``QoSReport``.
2. **Sampled request tracing** — a seeded 1-in-k request sample leaves
   one span per hop in a fixed-capacity ring (exact overflow counter,
   never a silent cap).  Host-side reconstruction links the spans into
   the call tree and reproduces the engine's recorded response time
   with tolerance ZERO, two independent ways: timestamp identity and a
   float64 max-plus (tropical) closure over the span DAG — the same
   Alg 2 recurrence as ``core/critical_path.py``.
3. **Per-phase profiling** (``--profile``) — prefix programs built with
   ``make_tick(stop_after=...)`` attribute wall cost per tick phase and
   per Disruption *stage* (the table feeding DESIGN.md §7's cost
   attribution).

    PYTHONPATH=src python examples/telemetry_study.py
    PYTHONPATH=src python examples/telemetry_study.py --profile
"""
import argparse
import dataclasses

from repro.configs import sockshop
from repro.core import batch_item, summarize
from repro.obs import export, profile, spans


TEL_KW = dict(telemetry="stream", tel_window_ticks=50, tel_windows=4,
              tel_span_k=25, tel_span_cap=2048)


def make_sim(duration_s: float, **kw):
    return sockshop.make_sim(n_clients=80, duration_s=duration_s,
                             seed=11, **TEL_KW, **kw)


def solo_stream(duration_s: float):
    print("=== 1. live metric stream (solo run, OTel JSON) ===")
    sim = make_sim(duration_s)
    with export.collecting() as col:
        sink = export.printer(export.otel_json)
        export.install(sink)
        try:
            res = sim.run()
        finally:
            export.uninstall(sink)
    export.validate_rows(col.rows)
    rep = summarize(sim, res)
    print(f"-> streamed {len(col.rows)} windows live; report agrees: "
          f"tel_windows={rep.tel_windows} tel_spans={rep.tel_spans} "
          f"tel_span_drops={rep.tel_span_drops}")
    return sim, res


def batch_stream(duration_s: float, n_points: int = 3) -> None:
    print("\n=== 2. run_batch: per-point live rows (Prometheus) ===")
    sim = make_sim(duration_s)
    rates = tuple(2.0 * 2 ** b for b in range(n_points))
    points = [dataclasses.replace(sim.params, spawn_rate=r)
              for r in rates]
    with export.collecting() as col:
        sink = export.printer(export.prometheus_line)
        export.install(sink)
        try:
            res = sim.run_batch(points)
        finally:
            export.uninstall(sink)
    export.validate_rows(col.rows)
    for b, (r, p) in enumerate(zip(rates, points)):
        mine = [row for row in col.rows if int(row["tag"]) == b]
        rep = summarize(sim, batch_item(res, b), params=p)
        streamed = int(sum(row["completed"] for row in mine))
        print(f"-> point {b} (spawn_rate={r}): {len(mine)} windows, "
              f"streamed completed {streamed} == report "
              f"{rep.completed_requests}")
        if streamed != rep.completed_requests:
            raise AssertionError(
                f"point {b}: streamed windows sum to {streamed} but the "
                f"QoS report counted {rep.completed_requests}")


def trace_study(sim, res) -> None:
    print("\n=== 3. sampled request traces vs critical path ===")
    d_max = int(sim.app.succ.shape[1])
    checks = spans.verify_traces(res.state, sim.graph, d_max)
    exact = [c for c in checks if c.exact]
    print(f"sampled completed requests reconstructed: {len(checks)} "
          f"({len(exact)} bitwise-exact, tolerance 0)")
    show = max(checks, key=lambda c: c.n_spans, default=None)
    if show is not None:
        roots = spans.trace_tree(spans.spans_of(res.state, show.req),
                                 sim.graph.n_services, d_max)
        print(f"\nrequest {show.req} (api {show.api}, "
              f"{show.n_spans} spans):")
        print(spans.format_trace(roots))
        print(f"engine response  {float(show.response):.6f} s\n"
              f"span-tree        {float(show.tree):.6f} s\n"
              f"tropical closure {float(show.tropical):.6f} s"
              + (f"\ngraph Alg 2      {float(show.graph):.6f} s"
                 if show.graph is not None else ""))


def profile_study(duration_s: float) -> None:
    print("\n=== 4. per-phase cost attribution (prefix programs) ===")
    sim = sockshop.make_sim(
        n_clients=80, duration_s=duration_s, seed=11,
        faults="chaos", replicas=2,
        host_mtbf_s=120.0, host_mttr_s=5.0,
        retry_timeout_s=3.0, retry_budget=2)
    print(profile.format_table(profile.phase_breakdown(sim, reps=3),
                               title="tick phase"))
    print()
    print(profile.format_table(profile.disruption_breakdown(sim, reps=3),
                               title="Disruption stage"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--points", type=int, default=3,
                    help="sweep points in the run_batch section")
    ap.add_argument("--profile", action="store_true",
                    help="also run the (slower) per-phase profiler")
    args = ap.parse_args()
    sim, res = solo_stream(args.duration)
    batch_stream(args.duration, args.points)
    trace_study(sim, res)
    if args.profile:
        profile_study(args.duration)


if __name__ == "__main__":
    main()
