"""Network saturation study on SockShop (DESIGN.md §6).

The paper's uniform-latency transport cannot express network congestion:
transit time is load-independent by construction.  The network fabric mode
can — this example pins SockShop's 10-node cluster to low-bandwidth NICs
and sweeps the offered load (client count) as ONE ``Simulation.run_batch``
call (NIC capacity itself is also sweepable: it travels in ``DynParams``).

Expected output: p95 transit time and NIC utilization rise monotonically
with load until the ingress ports saturate, and the response-time tail
inflates accordingly — the µqSim observation (arXiv:1911.02122) that
communication-layer queueing dominates tail latency at scale.

    PYTHONPATH=src python examples/network_saturation.py \
        --loads 10,25,50,100 --mbps 8
"""
import argparse
import dataclasses

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loads", default="10,25,50,100",
                    help="comma list of client counts (one batched sweep)")
    ap.add_argument("--mbps", type=float, default=8.0,
                    help="per-host NIC capacity, Mbit/s (low on purpose)")
    ap.add_argument("--duration", type=float, default=120.0)
    args = ap.parse_args()
    loads = [int(x) for x in args.loads.split(",") if x]

    # Spread placement: the paper-default most-available policy piles every
    # sockshop instance onto the largest node, making all RPC hops loopback
    # — spreading them across hosts is what creates cross-NIC traffic.
    sim = sockshop.make_sim(
        n_clients=max(loads), duration_s=args.duration,
        network="fabric", nic_egress_mbps=args.mbps,
        nic_ingress_mbps=args.mbps,
        placement_policy=policies.PLACE_SPREAD)
    sweeps = [dataclasses.replace(sim.params, n_clients=nc,
                                  spawn_rate=nc / 10.0) for nc in loads]
    res_b = sim.run_batch(sweeps)

    print(f"# NIC {args.mbps} Mbit/s per host, {args.duration:.0f} s runs "
          f"(batched sweep: compile {res_b.compile_time_s:.1f}s, "
          f"run {res_b.wall_time_s:.1f}s)")
    print(f"{'clients':>8s} {'transits':>9s} {'MB_moved':>9s} "
          f"{'p50_tr_ms':>10s} {'p95_tr_ms':>10s} {'ingress_util':>13s} "
          f"{'p95_resp_ms':>12s}")
    prev = -1.0
    for b, (nc, p) in enumerate(zip(loads, sweeps)):
        rep = summarize(sim, batch_item(res_b, b), params=p)
        mono = "" if rep.transit_p95_ms >= prev else "  (!)"
        prev = rep.transit_p95_ms
        print(f"{nc:8d} {rep.net_transits:9d} {rep.net_bytes_mb:9.1f} "
              f"{rep.transit_p50_ms:10.1f} {rep.transit_p95_ms:10.1f} "
              f"{rep.avg_ingress_util:13.3f} {rep.p95_response_ms:12.1f}"
              f"{mono}")


if __name__ == "__main__":
    main()
