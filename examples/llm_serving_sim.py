"""CloudNativeSim × the LM substrate: capacity-plan an LLM serving fleet.

The closed loop promised in DESIGN.md §3: the service graph models an LLM
inference cluster (router → prefill pool → decode pool → detokenizer);
per-stage cloudlet lengths come from the *roofline cost model of the
assigned architectures* (the same FLOP/byte math as launch/roofline.py),
and the paper's HS autoscaler manages the decode pool under a bursty
diurnal load.

    PYTHONPATH=src python examples/llm_serving_sim.py --arch qwen3-0.6b
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        build_graph, policies, summarize)
from repro.launch.roofline import PEAK_FLOPS, HBM_BW
from repro.models import build_model
from repro.models.common import n_params


def stage_costs_ms(arch: str, prompt_len=1024, gen_len=128, batch=8):
    """Per-request stage service times from the arch's roofline model."""
    cfg = get_config(arch)
    model = build_model(cfg)
    n = n_params(model.schema())
    mfu, mbu = 0.4, 0.6          # achievable fractions on v5e
    # prefill: compute-bound  2·N·prompt FLOPs
    t_prefill = 2 * n * prompt_len / (PEAK_FLOPS * mfu)
    # decode: memory-bound    gen_len × (param bytes / HBM bw) / batch
    t_decode = gen_len * (2 * n / (HBM_BW * mbu)) / batch
    return {"router": 2.0, "prefill": t_prefill * 1e3,
            "decode": t_decode * 1e3, "detok": 1.0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--clients", type=int, default=150)
    ap.add_argument("--duration", type=float, default=600.0)
    args = ap.parse_args()

    costs = stage_costs_ms(args.arch)
    print(f"{args.arch} stage costs (ms/request): "
          + ", ".join(f"{k}={v:.1f}" for k, v in costs.items()))

    # 1 MIPS ≡ 1 ms of stage work → cloudlet length in "ms units".
    graph = build_graph(
        ["router", "prefill", "decode", "detok"],
        {"router": ["prefill"], "prefill": ["decode"],
         "decode": ["detok"]},
        [("POST /generate", "router", 1.0)],
        {k: max(v, 0.5) for k, v in costs.items()},
    )
    caps = SimCaps(n_clients=max(args.clients, 1), max_requests=65536,
                   max_cloudlets=16384, max_instances=64, n_vms=8,
                   d_max=1, max_replicas=12)
    for policy, label in ((policies.SCALE_NONE, "static fleet"),
                          (policies.SCALE_HORIZONTAL, "HS autoscaler")):
        params = SimParams(
            dt=0.05, n_ticks=int(args.duration / 0.05),
            n_clients=args.clients, spawn_rate=args.clients / 60.0,
            wait_lo=2.0, wait_hi=8.0, slo_ms=4000.0,
            scaling_policy=policy, scale_interval=300,
            hs_util_hi=0.6, hs_util_lo=0.1, util_ema=0.05)
        sim = Simulation(
            graph, caps=caps, params=params,
            default_template=InstanceTemplate(
                mips=1000.0, limit_mips=4000.0, replicas=1,
                ram=4096.0, limit_ram=8192.0),
            vm_mips=np.full(8, 64_000.0, np.float32),
            vm_ram=np.full(8, 10_0000.0, np.float32))
        rep = summarize(sim, sim.run())
        print(f"\n=== {label} ({args.arch}) ===")
        print(f"  completed {rep.completed_requests}  "
              f"avg {rep.avg_response_ms:.0f} ms  "
              f"p95 {rep.p95_response_ms:.0f} ms  "
              f"SLO viol {rep.slo_violation_rate:.1%}  "
              f"replicas+{rep.scale_out}/-{rep.scale_in}")


if __name__ == "__main__":
    main()
