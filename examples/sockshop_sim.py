"""SockShop end-to-end: the paper's §6.3 case study via the file registry.

Writes the two registry documents (Fig 3 JSON + YAML) to disk, registers
them, runs the calibrated 600-second experiment at 100 and 300 clients and
compares with the paper's testbed measurements.

    PYTHONPATH=src python examples/sockshop_sim.py
"""
import json
import pathlib
import tempfile

import yaml

from repro.configs import sockshop
from repro.core import summarize

tmp = pathlib.Path(tempfile.mkdtemp(prefix="sockshop_"))
app_json = tmp / "app.json"
inst_yaml = tmp / "instances.yaml"
app_json.write_text(json.dumps(sockshop.app_spec(
    mi_scale=sockshop.CALIBRATED["mi_scale"]), indent=2))
inst_yaml.write_text(yaml.safe_dump(sockshop.instance_spec(
    share=sockshop.CALIBRATED["share"])))
print(f"registry documents written to {tmp}/ (paper Fig 3 formats)")

for n_clients in (100, 300):
    sim = sockshop.make_sim(n_clients=n_clients, duration_s=600.0)
    rep = summarize(sim, sim.run())
    ref = sockshop.TESTBED_MS[n_clients]
    acc = 1 - abs(rep.avg_response_ms - ref) / ref
    print(f"\n=== {n_clients} clients ===")
    print(f"  simulated avg response {rep.avg_response_ms:7.0f} ms")
    print(f"  paper testbed          {ref:7.0f} ms  (accuracy {acc:.1%})")
    print(f"  p95 {rep.p95_response_ms:.0f} ms  qps {rep.qps_mean:.1f}  "
          f"SLO violations {rep.slo_violation_rate:.1%}")
