"""Latency-outlier ejection on heterogeneous hardware (ROADMAP §7.1-a).

NO injected faults: every chaos rate is zeroed, so the Disruption phase
contributes only its resilience machinery.  The "failure" is the
hardware itself — a slow-CPU host class (``--slow-hosts`` of the
10-node SockShop cluster run at ``--cpu-scale`` of full speed via
``Hosts.cpu_scale``, while placement still sees the full requested
milicores — the resource-model asymmetry real schedulers suffer).  With
``replicas=3`` spread across nodes, most services end up with degraded
replicas in the mix; nothing is DOWN, no error is ever raised, so
crash detectors, retries and circuit breakers all stay silent while
every request routed to a slow replica quietly drags the response-time
tail.

Exactly the gray mode latency-outlier ejection targets: the LB tracks a
per-replica latency EMA and ejects a replica whose EMA exceeds
``eject_lat_factor`` × its service's mean over live replicas
(``policies.eject_view``; half-open re-admission after a cooldown keeps
probing, and re-trips while the hardware stays slow).  The study runs
two arms — latency ejection off (``eject_lat_factor=0``) vs on — as one
two-point ``run_batch`` (one compile).  With zero faults the arms
differ purely in *where* requests ran, so the whole effect shows up in
the latency percentiles (and availability stays 1.0 in both).

Reference run (defaults: 80 clients, 120 s, 4/10 nodes at 20% speed)::

    eject  p50_ms  p95_ms  p99_ms  avg_ms ejects readmit failed
      off    1489    4606    6867    1832      0       0      0
       on     802    2823    4908    1164     24      20      0

Latency ejection cuts p95 4606 ms -> 2823 ms with zero failed requests
in either arm — traffic drains to the fast replicas.

    PYTHONPATH=src python examples/hetero_study.py
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize

N_HOSTS = 10        # the paper's cluster (sockshop.make_sim)


def hetero_cpu(n_slow: int, cpu_scale: float) -> np.ndarray:
    """Per-host CPU speed: the LAST ``n_slow`` nodes form the slow class
    (old CPUs, thermal throttling, a noisy neighbor)."""
    scale = np.ones(N_HOSTS, np.float32)
    scale[N_HOSTS - n_slow:] = cpu_scale
    return scale


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=80)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--slow-hosts", type=int, default=4,
                    help="how many of the 10 nodes are the slow class")
    ap.add_argument("--cpu-scale", type=float, default=0.2,
                    help="execution-speed fraction the slow class retains")
    ap.add_argument("--lat-factor", type=float, default=1.5,
                    help="ejection trip: replica latency EMA > factor × "
                         "service mean (the 'on' arm; 'off' uses 0)")
    args = ap.parse_args()

    # faults="chaos" enables the resilience machinery; every *injection*
    # knob is zeroed (inf MTBF, 0 rates), so nothing ever fails — the
    # only asymmetry is hardware speed.  eject_err_thresh > 1 keeps
    # error-based ejection off: the latency signal must do all the work.
    # replicas=3 matters: the healthy replicas must have the headroom to
    # absorb an ejected peer's traffic, or ejection just moves the queue
    # (with 2 replicas it halves a service's capacity and flaps).  The
    # long eject_cooldown_s keeps the slow replica parked between
    # half-open probes instead of re-admitting into the same EMA.
    sim = sockshop.make_sim(
        n_clients=args.clients, duration_s=args.duration, replicas=3,
        share=600.0, placement_policy=policies.PLACE_SPREAD,
        host_cpu_scale=hetero_cpu(args.slow_hosts, args.cpu_scale),
        faults="chaos", host_mtbf_s=float("inf"), inst_kill_rate=0.0,
        nic_degrade_rate=0.0, zone_fault_rate=0.0, zone_slow_rate=0.0,
        zone_partition_rate=0.0, eject_err_thresh=2.0,
        eject_cooldown_s=30.0, cb_err_thresh=2.0)
    base = sim.params

    points = [dataclasses.replace(base, eject_lat_factor=f)
              for f in (0.0, args.lat_factor)]
    res_b = sim.run_batch(points)

    print(f"# sockshop x3 replicas, {args.slow_hosts}/10 nodes at "
          f"{args.cpu_scale:.0%} CPU speed, zero injected faults "
          f"(batched sweep: compile {res_b.compile_time_s:.1f}s, "
          f"run {res_b.wall_time_s:.1f}s)")
    print(f"{'eject':>5s} {'p50_ms':>7s} {'p95_ms':>7s} {'p99_ms':>7s} "
          f"{'avg_ms':>7s} {'ejects':>6s} {'readmit':>7s} {'failed':>6s}")
    reps = []
    for b, p in enumerate(points):
        rep = summarize(sim, batch_item(res_b, b), params=p)
        reps.append(rep)
        on = p.eject_lat_factor > 0
        print(f"{'on' if on else 'off':>5s} {rep.p50_response_ms:7.0f} "
              f"{rep.p95_response_ms:7.0f} {rep.p99_response_ms:7.0f} "
              f"{rep.avg_response_ms:7.0f} {rep.ejections:6d} "
              f"{rep.readmissions:7d} {rep.failed_requests:6d}")
    off, on = reps
    if on.ejections == 0:
        print("# (!) latency ejection never tripped — raise --slow-hosts "
              "or lower --lat-factor")
    elif on.p95_response_ms >= off.p95_response_ms:
        print("# (!) ejection did not improve the p95 tail")
    else:
        print(f"# latency ejection cut p95 "
              f"{off.p95_response_ms:.0f}ms -> {on.p95_response_ms:.0f}ms "
              "by routing around the slow hardware class")


if __name__ == "__main__":
    main()
