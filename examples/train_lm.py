"""End-to-end training driver example: train a ~100M-param LM for a few
hundred steps on the synthetic pipeline and verify the loss drops.

    PYTHONPATH=src python examples/train_lm.py            # tiny, 200 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    losses = main(sys.argv[1:] or
                  ["--preset", "tiny", "--steps", "200",
                   "--ckpt-dir", "/tmp/repro_train_lm"])
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"\nloss {first:.3f} → {last:.3f} "
          f"({'OK: learning' if last < 0.8 * first else 'WARN: flat'})")
