"""Closing the QoS feedback loop on SockShop (DESIGN.md §10).

The observability stack (PR 8) only *watched* the simulation; this study
wires it back into the control plane.  Per-service SLO objectives turn
the streamed latency windows into burn-rate alerts (Google-SRE fast/slow
multi-window rules), and the alerts gate two actuators:

* ``hs_mode="slo_burn"`` — the horizontal autoscaler scales OUT on a
  firing alert (with a stabilization window) and refuses to scale IN
  while any alert is pending or firing, instead of thresholding the
  utilization EMA;
* ``slo_eject_tighten`` — while a service's alert fires, the LB outlier
  ejector trips at a tightened threshold, draining the fail-slow
  replica faster.

Both knobs are traced (``DynParams``), so the util-vs-burn comparison is
ONE ``run_batch`` call — identical chaos schedule, identical load, one
compile.  Under zone fail-slow chaos the utilization signal is a *liar*:
a degraded replica executes fewer MI, so measured util stays low while
latency explodes, and threshold HS either does nothing or scales the
wrong way.  The burn-gated loop watches the SLI itself.

Expected output (default scale): the slo_burn arm ends with a strictly
lower SLO violation rate than the util arm at equal or lower
replica-seconds.

    PYTHONPATH=src python examples/slo_study.py
    PYTHONPATH=src python examples/slo_study.py --duration 20  # toy smoke
"""
import argparse
import dataclasses

import numpy as np

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize
from repro.obs import export

N_HOSTS = 10

# observability + SLO plane: 5 s windows, short lookback 15 s, long
# lookback 60 s, alerts need 0.5 s of sustained burn to fire.
OBS_KW = dict(telemetry="stream", tel_window_ticks=50, tel_windows=4,
              tel_span_k=50, tel_span_cap=1024,
              alerting="burn", slo_budget=0.05,
              slo_short_wins=3, slo_long_wins=12, slo_for_ticks=5,
              slo_stabilize_s=10.0)


def make_sim(duration_s: float, n_clients: int):
    """SockShop x2 replicas under zone fail-slow chaos with HS enabled.

    The chaos plane reuses the gray-failure study's scenario (crash-free,
    episodes degrade a whole 2-host zone to 10 % MIPS); the scaling plane
    runs plain horizontal scaling whose out/in gate is the swept knob.
    """
    zones = (np.arange(N_HOSTS) // 2).astype(np.int32)
    return sockshop.make_sim(
        n_clients=n_clients, duration_s=duration_s, replicas=2,
        share=900.0, seed=11, placement_policy=policies.PLACE_SPREAD,
        scaling_policy=policies.SCALE_HORIZONTAL,
        hs_util_hi=0.5, hs_util_lo=0.05,
        faults="chaos", host_mtbf_s=float("inf"), inst_kill_rate=0.0,
        retry_timeout_s=2.5, retry_budget=2,
        cb_err_thresh=0.5, cb_cooldown_s=5.0, cb_alpha=0.3,
        zone_slow_rate=0.015, host_slow_factor=0.1, host_slow_mttr_s=15.0,
        eject_err_thresh=0.35, eject_cooldown_s=8.0,
        host_zone=zones, **OBS_KW)


def replica_seconds(item, dt: float) -> float:
    """∫ active replicas dt — the cost axis of the comparison."""
    return float(np.asarray(item.trace.active_instances,
                            np.float64).sum()) * dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=240.0)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--points", type=int, default=2,
                    help="kept for smoke-CLI parity; the sweep always "
                         "runs the util and slo_burn arms")
    args = ap.parse_args()

    sim = make_sim(args.duration, args.clients)
    # re-evaluate HS every 5 s (scale_interval is traced, so the
    # override rides the sweep points instead of the Simulation)
    base = dataclasses.replace(sim.params, scale_interval=50)
    # the two control planes; the util arm keeps plain ejection
    # (tighten=1.0 is an exact identity), the burn arm tightens it 2x
    # while alerts fire.
    arms = [("util", dataclasses.replace(base, hs_mode="util",
                                         slo_eject_tighten=1.0)),
            ("slo_burn", dataclasses.replace(base, hs_mode="slo_burn",
                                             slo_eject_tighten=0.3))]
    points = [p for _, p in arms]

    with export.alert_collecting() as alerts:
        res = sim.run_batch(points)
    export.validate_alert_rows(alerts.rows)
    print(f"# sockshop x2 replicas, zone fail-slow chaos, HS on "
          f"(batched sweep: compile {res.compile_time_s:.1f}s, "
          f"run {res.wall_time_s:.1f}s)")

    reps = {}
    print(f"{'hs_mode':>9s} {'viol_rate':>9s} {'repl_sec':>9s} "
          f"{'out':>4s} {'in':>4s} {'fires':>5s} {'firing_s':>8s} "
          f"{'ejects':>6s} {'p95_ms':>8s}")
    for b, (name, p) in enumerate(arms):
        item = batch_item(res, b)
        rep = summarize(sim, item, params=p)
        rs = replica_seconds(item, p.dt)
        reps[name] = (rep, rs)
        print(f"{name:>9s} {rep.slo_violation_rate:9.3f} {rs:9.0f} "
              f"{rep.scale_out:4d} {rep.scale_in:4d} {rep.alert_fires:5d} "
              f"{rep.alert_firing_time_s:8.1f} {rep.ejections:6d} "
              f"{rep.p95_response_ms:8.0f}")
        assert rep.alert_event_drops == 0

    print("\nfirst alert transitions (Prometheus ALERTS convention):")
    for ev in alerts.rows[:6]:
        print(export.prometheus_alert_line(ev).splitlines()[-1])

    (rep_u, rs_u), (rep_b, rs_b) = reps["util"], reps["slo_burn"]
    print(f"\n-> slo_burn vs util: violation rate "
          f"{rep_b.slo_violation_rate:.3f} vs {rep_u.slo_violation_rate:.3f}"
          f", replica-seconds {rs_b:.0f} vs {rs_u:.0f}")
    if args.duration >= 120.0:
        assert rep_b.slo_violation_rate < rep_u.slo_violation_rate, \
            "burn-gated scaling did not reduce the SLO violation rate"
        assert rs_b <= rs_u * 1.001, \
            "burn-gated scaling spent more replica-seconds than util HS"
        print("   burn-gated control wins on both axes.")
    else:
        print("   (toy duration — skipping the win assertions)")


if __name__ == "__main__":
    main()
