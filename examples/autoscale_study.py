"""Scaling-policy study on SockShop: the paper's §6.4 experiment as a
ready-to-edit example (NS vs HS vs VS vs the beyond-paper HYBRID).

Each policy's client-load sweep runs as ONE ``Simulation.run_batch`` —
a single compile + a single device dispatch per policy, however many
load points you ask for.

    PYTHONPATH=src python examples/autoscale_study.py --loads 300,500,1000
"""
import argparse
import dataclasses

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize

POLICIES = [("NS", policies.SCALE_NONE), ("HS", policies.SCALE_HORIZONTAL),
            ("VS", policies.SCALE_VERTICAL), ("HYBRID", policies.SCALE_HYBRID)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loads", default="300,500,1000",
                    help="comma list of client counts (one batched sweep "
                         "per policy)")
    ap.add_argument("--duration", type=float, default=600.0)
    args = ap.parse_args()
    loads = [int(x) for x in args.loads.split(",") if x]

    print(f"{'policy':8s} {'clients':>8s} {'avg_ms':>8s} {'p95_ms':>8s} "
          f"{'SLO_viol':>9s} {'milicores':>10s} {'instances':>10s} "
          f"{'events':>14s}")
    for name, pid in POLICIES:
        sim = sockshop.make_sim(
            n_clients=max(loads), duration_s=args.duration,
            share=4725.0, scaling_policy=pid,
            hs_util_hi=0.03, hs_util_lo=0.002,
            vs_util_hi=0.14, vs_util_lo=0.01,
            idle_mips_frac=0.01, vs_overhead_frac=0.11, util_ema=0.1)
        sweeps = [dataclasses.replace(sim.params, n_clients=nc,
                                      spawn_rate=nc / 30.0) for nc in loads]
        res = sim.run_batch(sweeps)     # whole sweep: one compile/dispatch
        for b, nc in enumerate(loads):
            rep = summarize(sim, batch_item(res, b), params=sweeps[b])
            events = (f"+{rep.scale_out}/-{rep.scale_in}"
                      f"/^{rep.scale_up}/v{rep.scale_down}")
            print(f"{name:8s} {nc:8d} {rep.avg_response_ms:8.0f} "
                  f"{rep.p95_response_ms:8.0f} "
                  f"{rep.slo_violation_rate:9.1%} "
                  f"{rep.avg_milicores:10.1f} {rep.active_instances:10d} "
                  f"{events:>14s}")


if __name__ == "__main__":
    main()
