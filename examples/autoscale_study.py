"""Scaling-policy study on SockShop: the paper's §6.4 experiment as a
ready-to-edit example (NS vs HS vs VS vs the beyond-paper HYBRID).

    PYTHONPATH=src python examples/autoscale_study.py --clients 500
"""
import argparse

from repro.configs import sockshop
from repro.core import policies, summarize

POLICIES = [("NS", policies.SCALE_NONE), ("HS", policies.SCALE_HORIZONTAL),
            ("VS", policies.SCALE_VERTICAL), ("HYBRID", policies.SCALE_HYBRID)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=500)
    ap.add_argument("--duration", type=float, default=600.0)
    args = ap.parse_args()

    print(f"{'policy':8s} {'avg_ms':>8s} {'p95_ms':>8s} {'SLO_viol':>9s} "
          f"{'milicores':>10s} {'instances':>10s} {'events':>14s}")
    for name, pid in POLICIES:
        sim = sockshop.make_sim(
            n_clients=args.clients, duration_s=args.duration,
            share=4725.0, scaling_policy=pid,
            hs_util_hi=0.03, hs_util_lo=0.002,
            vs_util_hi=0.14, vs_util_lo=0.01,
            idle_mips_frac=0.01, vs_overhead_frac=0.11, util_ema=0.1)
        rep = summarize(sim, sim.run())
        events = (f"+{rep.scale_out}/-{rep.scale_in}"
                  f"/^{rep.scale_up}/v{rep.scale_down}")
        print(f"{name:8s} {rep.avg_response_ms:8.0f} "
              f"{rep.p95_response_ms:8.0f} {rep.slo_violation_rate:9.1%} "
              f"{rep.avg_milicores:10.1f} {rep.active_instances:10d} "
              f"{events:>14s}")


if __name__ == "__main__":
    main()
