"""Availability vs blast radius on SockShop (DESIGN.md §7.1).

Second-generation chaos: instead of independent host crashes, faults are
*zone-correlated* — the 10-node cluster is partitioned into failure
domains of ``radius`` hosts, and a firing zone draw throws every host of
the domain into a fail-slow episode at once (MIPS degraded to
``host_slow_factor``).  Crash-stop tooling is blind to this gray mode:
the replicas stay ON, they just crawl, so calls routed to them burn
their full timeout before failing.

The study sweeps the blast radius and, per radius, runs two arms:

* **breaker only** — the per-edge circuit breaker (PR 3) trips when a
  whole edge's error EMA saturates; ``eject_err_thresh`` > 1 disables
  per-replica ejection.
* **breaker + outlier ejection** — the load balancer additionally
  tracks per-replica error EMAs and routes *around* a sick replica
  (``policies.eject_view``) instead of waiting for the whole edge to
  trip, with half-open re-admission after a cooldown.

Every fault/resilience knob travels in ``DynParams`` and the host→zone
table is an ``AppStatic`` leaf, so the full radius × arm grid is ONE
``Simulation.run_batch(points, apps=...)`` call — one compile.

Expected output: the ejection arm sits strictly below the breaker-only
arm at every radius — ejection drains traffic off the slow replicas the
breaker cannot see — and its advantage *widens* with the radius.  Note
the per-host hazard is identical at every radius (each host slows when
its zone fires, at the same rate); what the sweep varies is pure
correlation.  Many 1-host domains keep some replica degraded almost all
the time, while rare 5-host blasts concentrate the same damage into
short windows the resilience machinery rides out, so availability
actually *improves* with radius under a fixed per-zone rate.  A
reference run:

    radius=1 eject=off err=0.209 avail=0.462   eject=on err=0.197 avail=0.497
    radius=2 eject=off err=0.208 avail=0.526   eject=on err=0.167 avail=0.606
    radius=5 eject=off err=0.143 avail=0.641   eject=on err=0.095 avail=0.743

    PYTHONPATH=src python examples/chaos_study.py --radii 1,2,5
"""
import argparse
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize

N_HOSTS = 10        # the paper's cluster (sockshop.make_sim)


def zones(radius: int) -> np.ndarray:
    """Contiguous failure domains of ``radius`` hosts (last one ragged)."""
    return (np.arange(N_HOSTS) // radius).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--radii", default="1,2,5",
                    help="comma list of blast radii (hosts per failure "
                         "domain, 1..10)")
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--zone-rate", type=float, default=0.02,
                    help="fail-slow episode rate per zone, 1/s")
    ap.add_argument("--slow-factor", type=float, default=0.1,
                    help="MIPS fraction a fail-slow host retains")
    ap.add_argument("--slow-mttr", type=float, default=15.0,
                    help="mean fail-slow episode length, seconds")
    ap.add_argument("--timeout", type=float, default=2.5,
                    help="per-attempt RPC timeout, seconds")
    ap.add_argument("--eject-thresh", type=float, default=0.35,
                    help="per-replica error-EMA ejection threshold "
                         "(the 'on' arm; 'off' uses 2.0)")
    args = ap.parse_args()
    radii = [int(x) for x in args.radii.split(",") if x]

    # 2 replicas per service, spread over hosts: a fail-slow zone usually
    # degrades ONE replica of an affected service, which is exactly the
    # asymmetry outlier ejection exploits.  The breaker stays ON in both
    # arms (0.5) — the study isolates what ejection adds on top of it.
    sim = sockshop.make_sim(
        n_clients=args.clients, duration_s=args.duration, replicas=2,
        share=600.0, placement_policy=policies.PLACE_SPREAD,
        faults="chaos", host_mtbf_s=float("inf"), inst_kill_rate=0.0,
        retry_timeout_s=args.timeout, retry_budget=2,
        cb_err_thresh=0.5, cb_cooldown_s=5.0, cb_alpha=0.3,
        zone_slow_rate=args.zone_rate, host_slow_factor=args.slow_factor,
        host_slow_mttr_s=args.slow_mttr, eject_cooldown_s=8.0,
        host_zone=zones(radii[0]))
    base = sim.params

    points, apps, labels = [], [], []
    for r in radii:
        app_r = sim.app._replace(host_zone=jnp.asarray(zones(r), jnp.int32))
        for thresh in (2.0, args.eject_thresh):   # > 1 = ejection off
            points.append(dataclasses.replace(base,
                                              eject_err_thresh=thresh))
            apps.append(app_r)
            labels.append((r, thresh < 1.0))
    res_b = sim.run_batch(points, apps=apps)

    print(f"# sockshop x2 replicas, zone fail-slow rate "
          f"{args.zone_rate}/s, factor {args.slow_factor}, MTTR "
          f"{args.slow_mttr:.0f}s, timeout {args.timeout}s "
          f"(batched sweep: compile {res_b.compile_time_s:.1f}s, "
          f"run {res_b.wall_time_s:.1f}s)")
    print(f"{'radius':>6s} {'eject':>5s} {'avail':>6s} {'err_rate':>8s} "
          f"{'failed':>6s} {'slow_eps':>8s} {'ejects':>6s} {'readmit':>7s} "
          f"{'trips':>5s} {'p95_ms':>8s}")
    flat = {}
    for b, ((r, ej_on), p) in enumerate(zip(labels, points)):
        rep = summarize(sim, batch_item(res_b, b), params=p)
        flat[(r, ej_on)] = rep
        print(f"{r:6d} {'on' if ej_on else 'off':>5s} "
              f"{rep.availability:6.3f} {rep.error_rate:8.3f} "
              f"{rep.failed_requests:6d} {rep.slow_episodes:8d} "
              f"{rep.ejections:6d} {rep.readmissions:7d} "
              f"{rep.breaker_trips:5d} {rep.p95_response_ms:8.0f}")
    worse = [r for r in radii
             if flat[(r, True)].error_rate >= flat[(r, False)].error_rate]
    if worse:
        print(f"# (!) ejection did not reduce error rate at radius={worse}")
    else:
        print("# outlier ejection + breaker dominated breaker-only error "
              "rate at every blast radius")


if __name__ == "__main__":
    main()
