"""Chaos/resilience study on SockShop (DESIGN.md §7).

The fair-weather engine cannot express availability: no host or instance
can fail.  The Disruption phase can — this example spreads a 2-replica
SockShop over the 10-node cluster, sweeps the host-failure rate (MTBF) as
chaos intensity, and runs every point twice: circuit breaker off
(``cb_err_thresh`` > 1 never trips) and on.  All fault knobs travel in
``DynParams``, so the whole grid is ONE ``Simulation.run_batch`` call —
one compile, one device dispatch.

Expected output: error rate rises and availability falls as MTBF shrinks;
with the breaker ON the error-rate curve flattens — tripped edges fail
fast instead of feeding the retry storm, so the overloaded survivors
recover and p95 response (over successful requests) drops too.  A
reference run on this scenario:

    mtbf= 120 cb=off err=0.186 p95=5616ms   cb=on err=0.044 p95=2543ms
    mtbf=  30 cb=off err=0.446 p95=7982ms   cb=on err=0.241 p95=3469ms

    PYTHONPATH=src python examples/chaos_study.py --mtbf 120,60,30
"""
import argparse
import dataclasses

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mtbf", default="120,60,30",
                    help="comma list of host MTBF seconds (chaos intensity; "
                         "'inf' allowed as fault-free baseline)")
    ap.add_argument("--mttr", type=float, default=15.0,
                    help="mean host recovery time, seconds")
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--timeout", type=float, default=2.5,
                    help="per-attempt RPC timeout, seconds")
    ap.add_argument("--budget", type=int, default=2, help="retry budget")
    args = ap.parse_args()
    mtbfs = [float(x) for x in args.mtbf.split(",") if x]

    # 2 replicas per service, spread over hosts: a lone crash degrades a
    # service to its survivor replica instead of blackholing it — the
    # retry-storm-overloads-the-survivor dynamic the breaker protects
    # against.  share=600 sizes the survivor to overload under 2× load.
    sim = sockshop.make_sim(
        n_clients=args.clients, duration_s=args.duration, replicas=2,
        share=600.0, placement_policy=policies.PLACE_SPREAD,
        faults="chaos", retry_timeout_s=args.timeout,
        retry_budget=args.budget, host_mttr_s=args.mttr,
        cb_cooldown_s=5.0, cb_alpha=0.3)
    base = sim.params
    points, labels = [], []
    for mtbf in mtbfs:
        for thresh in (2.0, 0.5):      # > 1 = breaker off; 0.5 = on
            points.append(dataclasses.replace(
                base, host_mtbf_s=mtbf, cb_err_thresh=thresh))
            labels.append((mtbf, thresh < 1.0))
    res_b = sim.run_batch(points)

    print(f"# sockshop x2 replicas, MTTR {args.mttr:.0f}s, timeout "
          f"{args.timeout}s, budget {args.budget} "
          f"(batched sweep: compile {res_b.compile_time_s:.1f}s, "
          f"run {res_b.wall_time_s:.1f}s)")
    print(f"{'mtbf_s':>7s} {'breaker':>7s} {'avail':>6s} {'err_rate':>8s} "
          f"{'failed':>6s} {'retries':>7s} {'trips':>5s} {'failfast':>8s} "
          f"{'p95_ms':>8s} {'mttr_obs':>8s}")
    flat = {}
    for b, ((mtbf, cb_on), p) in enumerate(zip(labels, points)):
        rep = summarize(sim, batch_item(res_b, b), params=p)
        flat[(mtbf, cb_on)] = rep
        print(f"{mtbf:7.0f} {'on' if cb_on else 'off':>7s} "
              f"{rep.availability:6.3f} {rep.error_rate:8.3f} "
              f"{rep.failed_requests:6d} {rep.retries:7d} "
              f"{rep.breaker_trips:5d} {rep.failfast_failures:8d} "
              f"{rep.p95_response_ms:8.0f} {rep.observed_mttr_s:8.1f}")
    worse = [m for m in mtbfs
             if flat[(m, True)].error_rate >= flat[(m, False)].error_rate]
    if worse:
        print(f"# (!) breaker did not reduce error rate at mtbf={worse}")
    else:
        print("# breaker flattened the error-rate curve at every "
              "failure rate")


if __name__ == "__main__":
    main()
