"""Sharding-readiness auditor self-tests (DESIGN.md §8).

Seeded-violation layer: small jaxprs with a planted cross-shard
dependency must be classified gather/all-reduce, purely shard-local
programs must stay clean, and the baseline comparator must catch
growth.  The full golden-combo audit (and its diff against the
committed ``analysis/shard_baseline.json``) runs in the CI simcheck
job (``python -m repro.analysis --only shardability``).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.shardability import (ShardAudit, audit_jaxpr,
                                         baseline_json,
                                         compare_to_baseline, default_spec)

C = 16          # pretend cloudlet-axis extent for these tests
SPEC = {"C": (C,)}


def _audit(fn, *example_args, spec=SPEC):
    closed = jax.make_jaxpr(fn)(*example_args)
    return audit_jaxpr(closed, spec)


# ---------------------------------------------------------------------------
# Classification
# ---------------------------------------------------------------------------

def test_elementwise_on_sharded_axis_is_local():
    rep = _audit(lambda x: x * 2.0 + 1.0, jnp.ones((C,), jnp.float32))
    assert rep.entries == []
    assert rep.n_local == rep.n_total > 0


def test_planted_cross_shard_gather_reported():
    x = jnp.ones((C,), jnp.float32)
    idx = jnp.zeros((C,), jnp.int32)

    # lanes read OTHER lanes of the C-sharded operand: needs a gather
    rep = _audit(lambda t, i: t[i], x, idx)
    assert any(e.cls == "gather" and e.prim == "gather"
               for e in rep.entries)


def test_planted_cross_shard_reduction_reported():
    rep = _audit(lambda x: jnp.sum(x), jnp.ones((C,), jnp.float32))
    assert any(e.cls == "all_reduce" for e in rep.entries)


def test_reduction_over_unsharded_axis_is_local():
    # reducing the UNLABELED trailing axis keeps every lane independent
    rep = _audit(lambda x: jnp.sum(x, axis=1),
                 jnp.ones((C, 5), jnp.float32))
    assert rep.entries == []


def test_scatter_add_into_sharded_target_is_all_reduce():
    tbl = jnp.zeros((C,), jnp.float32)
    ids = jnp.zeros((8,), jnp.int32)
    vals = jnp.ones((8,), jnp.float32)

    rep = _audit(lambda t, i, v: t.at[i].add(v, mode="drop"),
                 tbl, ids, vals)
    assert any(e.cls == "all_reduce" and "scatter" in e.prim
               for e in rep.entries)


def test_cumsum_along_sharded_axis_needs_gather():
    rep = _audit(lambda x: jnp.cumsum(x), jnp.ones((C,), jnp.float32))
    assert any(e.cls == "gather" for e in rep.entries)


# ---------------------------------------------------------------------------
# Spec handling
# ---------------------------------------------------------------------------

def test_extent_collision_rejected():
    with pytest.raises(ValueError, match="labeled both"):
        ShardAudit({"C": (8,), "I": (8,)})


def test_default_spec_separates_axes():
    class Caps:
        max_cloudlets = 96
        max_instances = 12

    spec = default_spec(Caps())
    assert spec["C"] == (96,)
    assert spec["I"] == (12, 13)      # [I] rows and [I+1] accumulators
    ShardAudit(spec)                  # collision-free by construction


# ---------------------------------------------------------------------------
# Baseline comparator
# ---------------------------------------------------------------------------

def _report_for(fn, *example_args):
    closed = jax.make_jaxpr(fn)(*example_args)
    rep = audit_jaxpr(closed, SPEC, combo="test+combo")
    return rep


def test_baseline_roundtrip_is_clean():
    rep = _report_for(lambda x: jnp.sum(x), jnp.ones((C,), jnp.float32))
    baseline = baseline_json([rep])
    assert compare_to_baseline([rep], baseline) == []


def test_baseline_catches_new_cross_shard_eqn():
    clean = _report_for(lambda x: x * 2.0, jnp.ones((C,), jnp.float32))
    baseline = baseline_json([clean])
    grown = _report_for(lambda x: x * jnp.sum(x),
                        jnp.ones((C,), jnp.float32))
    probs = compare_to_baseline([grown], baseline)
    assert probs and any("grew" in p for p in probs)


def test_baseline_catches_missing_combo():
    rep = _report_for(lambda x: jnp.sum(x), jnp.ones((C,), jnp.float32))
    probs = compare_to_baseline([rep], {"combos": {}})
    assert probs and any("no committed shardability baseline" in p
                         for p in probs)


def test_committed_baseline_covers_golden_combos():
    import json

    from repro.analysis.simcheck import GOLDEN_COMBOS, SHARD_BASELINE_PATH

    doc = json.loads(SHARD_BASELINE_PATH.read_text())
    for net, fl in GOLDEN_COMBOS:
        assert f"{net}+{fl}" in doc["combos"]


def test_report_json_and_phase_table_shapes():
    rep = _report_for(lambda x: jnp.sum(x), jnp.ones((C,), jnp.float32))
    doc = rep.to_json()
    assert doc["combo"] == "test+combo"
    assert doc["n_total"] == rep.n_local + len(rep.entries)
    assert all(isinstance(n, int) for n in doc["cross_shard"].values())
    table = rep.phase_table()
    assert all(set(v) == {"gather", "all_reduce"} for v in table.values())
