"""Fault injection & resilience (DESIGN.md §7): the Disruption tick phase,
retry/breaker semantics, and the faults="none" bit-identity guarantee.

Pinned contracts:

 * ``faults="none"`` (the default) compiles the exact pre-faults program:
   the golden digests captured before the network fabric landed (and
   re-pinned by tests/test_network.py) still hold bit for bit;
 * a mass-kill wave frees its pool slots and respawns the retries in the
   SAME tick through the two-scatter spawn path, without leaking ``n_exec``
   or dropping a retry;
 * retry-budget exhaustion propagates to the owning request as a failed
   completion, counted exactly once;
 * chaos conservation: every spawned cloudlet is finished, in flight, or a
   counted failed attempt;
 * fault rates sweep through ``run_batch`` with no recompile and bit-match
   solo runs;
 * the circuit breaker trips on a dead edge, fails fast while open, and
   HS scale-out respawns replicas off down hosts.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        batch_item, build_app, build_graph, diamond,
                        linear_chain, summarize)
from repro.core.faults import disruption
from repro.core.types import (CL_EXEC, CL_FREE, CL_WAITING, DynParams,
                              INST_DOWN, INST_DRAIN, INST_ON, zeros_state)

from test_network import GOLDEN, _digest_f32, _diamond_sim

i32, f32 = jnp.int32, jnp.float32


# ---------------------------------------------------------------------------
# faults="none": bit-identical to the pre-faults engine
# ---------------------------------------------------------------------------

def test_faults_none_bit_identical_golden():
    """The default mode (faults="none" is what _diamond_sim builds) still
    reproduces the pre-fabric golden digests after the resilience columns
    and state joined the pytrees."""
    sim, _ = _diamond_sim()
    assert sim.params.faults == "none"
    res = sim.run()
    st = res.state
    assert _digest_f32(st.requests.response) == GOLDEN["diamond_resp"]
    assert int(st.counters.completed) == GOLDEN["diamond_completed"]
    assert int(st.counters.spawned) == GOLDEN["diamond_spawned"]
    assert _digest_f32(res.trace.used_mips) == \
        GOLDEN["diamond_trace_used_mips"]
    # the fault state exists but never moves in faults="none" mode
    assert int(np.asarray(st.fault.host_up).sum()) == sim.caps.n_vms
    assert int(st.fstats.failed_attempts) == 0
    assert int(st.fstats.retries) == 0
    assert int(np.asarray(st.requests.failed).sum()) == 0


def test_faults_param_validated():
    sim, params = _diamond_sim()
    bad = dataclasses.replace(params, faults="mayhem")
    with pytest.raises(ValueError, match="none.*chaos|chaos.*none"):
        Simulation(diamond(mi=400.0), caps=sim.caps, params=bad)


def test_run_batch_rejects_faults_mode_sweep():
    sim, params = _diamond_sim()
    with pytest.raises(ValueError, match="structural"):
        sim.run_batch([params, dataclasses.replace(params, faults="chaos")])


# ---------------------------------------------------------------------------
# Disruption phase unit semantics (direct call on a crafted state)
# ---------------------------------------------------------------------------

def _crafted(C=64, retry_budget=2, host_mtbf_s=1e-9):
    """A full pool of EXEC cloudlets on one instance of one service, and a
    fault schedule that crashes every host on the next sample."""
    g = linear_chain(1, mi=100.0)
    app = build_app(g, n_hosts=2)
    caps = SimCaps(n_clients=4, max_requests=max(C, 4), max_cloudlets=C,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=0.1, n_ticks=1, faults="chaos",
                       retry_budget=retry_budget, host_mtbf_s=host_mtbf_s,
                       host_mttr_s=float("inf"))
    dyn = DynParams.from_params(params)
    state = zeros_state(caps, params, jax.random.PRNGKey(0), n_services=1,
                        n_edges=int(app.n_edges))
    inst = state.instances._replace(
        status=state.instances.status.at[0].set(INST_ON),
        service=state.instances.service.at[0].set(0),
        vm=state.instances.vm.at[0].set(0),
        host=state.instances.host.at[0].set(0),
        mips=state.instances.mips.at[0].set(1000.0),
        n_exec=state.instances.n_exec.at[0].set(C),
    )
    sched = state.sched._replace(
        inst_of_rank=state.sched.inst_of_rank.at[0, 0].set(0),
        svc_replicas=state.sched.svc_replicas.at[0].set(1))
    cl = state.cloudlets.with_cols(
        status=CL_EXEC, req=jnp.arange(C, dtype=i32), service=0, inst=0,
        wait_ticks=0, depth=0, src_host=-1, attempt=0, edge=0, src_inst=-1,
        length=100.0, rem=50.0, arrival=0.0, start=0.0, rem_bytes=0.0)
    req = state.requests._replace(
        count=jnp.asarray(C, i32),
        api=state.requests.api.at[:C].set(0),
        arrival=state.requests.arrival.at[:C].set(0.0),
        outstanding=state.requests.outstanding.at[:C].set(1),
        spawned=state.requests.spawned.at[:C].set(1))
    state = state._replace(instances=inst, sched=sched, cloudlets=cl,
                           requests=req,
                           time=jnp.asarray(1.0, f32))
    return state, app, caps, params, dyn


def test_mass_kill_recycles_slots_in_one_tick():
    """A host crash fails a FULL pool of executing cloudlets; every one is
    within its retry budget, so the wave frees C slots and respawns C
    retries in the same Disruption pass — zero drops, zero n_exec leak."""
    C = 64
    state, app, caps, params, dyn = _crafted(C=C, retry_budget=2)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    out = disruption(state, app, caps, params, dyn, k1, k2, None)
    cl_status = np.asarray(out.cloudlets.status)
    # every slot was freed AND re-filled by its retry (WAITING, attempt 1)
    assert (cl_status == CL_WAITING).all()
    assert (np.asarray(out.cloudlets.attempt) == 1).all()
    assert int(out.fstats.failed_attempts) == C
    assert int(out.fstats.retries) == C
    assert int(out.counters.spawned) == C        # retry spawns counted
    assert int(out.counters.dropped_cloudlets) == 0
    # the crashed instance is DOWN with a zeroed execution count
    assert int(np.asarray(out.instances.status)[0]) == INST_DOWN
    assert int(np.asarray(out.instances.n_exec)[0]) == 0
    # outstanding untouched: a retry replaces its attempt
    assert (np.asarray(out.requests.outstanding)[:C] == 1).all()
    assert int(np.asarray(out.requests.failed).sum()) == 0
    assert int(out.fstats.host_crashes) == caps.n_vms


def test_budget_exhausted_wave_fails_requests_exactly_once():
    """retry_budget=0: the same wave becomes C permanent failures — slots
    free, outstanding drains, every request is marked failed once."""
    C = 32
    state, app, caps, params, dyn = _crafted(C=C, retry_budget=0)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    out = disruption(state, app, caps, params, dyn, k1, k2, None)
    assert (np.asarray(out.cloudlets.status) == CL_FREE).all()
    assert int(out.fstats.retries) == 0
    assert int(out.fstats.failed_attempts) == C
    assert (np.asarray(out.requests.outstanding)[:C] == 0).all()
    assert (np.asarray(out.requests.failed)[:C] == 1).all()
    # finish was scatter-maxed with the failure time → response ≥ 0 later
    assert (np.asarray(out.requests.finish)[:C] >= 1.0 - 1e-6).all()


# ---------------------------------------------------------------------------
# engine-level chaos semantics
# ---------------------------------------------------------------------------

def _chaos_sim(**over):
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=512,
                   max_instances=8, n_vms=4, d_max=2, max_replicas=2)
    kw = dict(dt=0.05, n_ticks=600, n_clients=12, spawn_rate=5.0,
              wait_lo=0.5, wait_hi=1.5, seed=3, faults="chaos",
              host_mtbf_s=20.0, host_mttr_s=5.0, retry_timeout_s=3.0,
              retry_budget=2, inst_kill_rate=0.01)
    kw.update(over)
    params = SimParams(**kw)
    tmpl = InstanceTemplate(mips=8000.0, limit_mips=16000.0, replicas=2)
    return Simulation(diamond(mi=400.0), caps=caps, params=params,
                      default_template=tmpl,
                      vm_mips=np.full(4, 64000.0, np.float32)), params


def test_chaos_conservation_and_availability():
    """Acceptance: a deterministic seeded chaos run reports availability
    < 1 and retries > 0, and the chaos conservation law holds — every
    spawned cloudlet is finished, in flight, or a counted failed attempt
    (no n_exec leak through the failure waves)."""
    sim, _ = _chaos_sim()
    res = sim.run()
    st = res.state
    rep = summarize(sim, res)
    assert rep.host_crashes > 0
    assert rep.retries > 0
    assert rep.failed_requests > 0
    assert 0.0 <= rep.availability < 1.0
    assert rep.error_rate > 0.0
    assert rep.retry_amplification > 1.0
    assert rep.observed_mttr_s > 0.0

    spawned = int(st.counters.spawned)
    finished = int(st.counters.finished)
    in_flight = int((np.asarray(st.cloudlets.status) != CL_FREE).sum())
    assert spawned == finished + in_flight + int(st.fstats.failed_attempts)
    # n_exec matches the pool exactly after hundreds of failure waves
    cl_inst = np.asarray(st.cloudlets.inst)
    cl_st = np.asarray(st.cloudlets.status)
    I = st.instances.status.shape[0]
    expect = np.bincount(cl_inst[cl_st == CL_EXEC], minlength=I)[:I]
    np.testing.assert_array_equal(expect,
                                  np.asarray(st.instances.n_exec))
    # outstanding ≥ 0 and sums to the in-flight pool
    out = np.asarray(st.requests.outstanding)[:int(st.requests.count)]
    assert (out >= 0).all()
    assert out.sum() == in_flight
    # failed completions counted exactly once
    resp = np.asarray(st.requests.response)
    assert int(st.counters.completed) == int((resp >= 0).sum())
    failed = np.asarray(st.requests.failed)
    assert set(np.unique(failed)) <= {0, 1}
    assert int(st.fstats.failed_requests) == \
        int(((resp >= 0) & (failed > 0)).sum())


def test_chaos_deterministic_given_seed():
    sim1, _ = _chaos_sim()
    sim2, _ = _chaos_sim()
    r1, r2 = sim1.run(), sim2.run()
    np.testing.assert_array_equal(np.asarray(r1.state.requests.response),
                                  np.asarray(r2.state.requests.response))
    assert int(r1.state.fstats.failed_attempts) == \
        int(r2.state.fstats.failed_attempts)


def test_fault_rates_sweep_via_run_batch_bitmatch_solo():
    """Chaos intensity sweeps through DynParams: one compile, and every
    point bit-matches its solo run — failures, retries and all."""
    sim, base = _chaos_sim(n_ticks=300)
    sweeps = [dataclasses.replace(base, host_mtbf_s=m, inst_kill_rate=k)
              for m, k in ((60.0, 0.0), (20.0, 0.01), (8.0, 0.05))]
    res_b = sim.run_batch(sweeps)
    for b, p in enumerate(sweeps):
        solo = Simulation(
            sim.graph, caps=sim.caps, params=p,
            default_template=InstanceTemplate(mips=8000.0,
                                              limit_mips=16000.0,
                                              replicas=2),
            vm_mips=np.full(4, 64000.0, np.float32)).run()
        item = batch_item(res_b, b)
        np.testing.assert_array_equal(
            np.asarray(item.state.requests.response),
            np.asarray(solo.state.requests.response))
        for field in ("failed_attempts", "retries", "host_crashes",
                      "failed_requests"):
            assert int(getattr(item.state.fstats, field)) == \
                int(getattr(solo.state.fstats, field)), (b, field)
    # more chaos → more failures across the sweep
    fails = [int(batch_item(res_b, b).state.fstats.failed_attempts)
             for b in range(3)]
    assert fails[0] < fails[-1]


def test_breaker_trips_open_and_fails_fast():
    """All hosts die at t≈0 and never recover: calls time out, the
    error-rate EMA saturates, the breaker trips and subsequent calls fail
    fast.  With the threshold above 1 the breaker never engages."""
    caps = SimCaps(n_clients=8, max_requests=512, max_cloudlets=256,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=1)
    base = dict(dt=0.05, n_ticks=400, n_clients=8, spawn_rate=20.0,
                wait_lo=0.3, wait_hi=0.8, seed=0, faults="chaos",
                host_mtbf_s=1e-4, host_mttr_s=float("inf"),
                retry_timeout_s=0.5, retry_budget=1, cb_cooldown_s=2.0)
    on = SimParams(cb_err_thresh=0.3, **base)
    off = SimParams(cb_err_thresh=2.0, **base)
    g = linear_chain(1, mi=200.0)
    rep_on = None
    for params, name in ((on, "on"), (off, "off")):
        sim = Simulation(g, caps=caps, params=params)
        rep = summarize(sim, sim.run())
        assert rep.availability == 0.0, name     # nothing can ever succeed
        assert rep.failed_requests > 0, name
        if name == "on":
            rep_on = rep
            assert rep.breaker_trips > 0
            assert rep.failfast_failures > 0
        else:
            assert rep.breaker_trips == 0
            assert rep.failfast_failures == 0
            # fail-fast spares the doomed retries the full timeout ladder
            assert rep.retries > rep_on.retries


def test_hs_scale_out_respawns_off_down_hosts():
    """Permanent host crashes + HS scaling: replicas are only ever placed
    on up hosts, so no ON instance ends the run on a down host."""
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=512,
                   max_instances=16, n_vms=4, d_max=2, max_replicas=4)
    params = SimParams(dt=0.05, n_ticks=600, n_clients=16, spawn_rate=10.0,
                       wait_lo=0.3, wait_hi=0.8, seed=5, faults="chaos",
                       host_mtbf_s=40.0, host_mttr_s=float("inf"),
                       retry_timeout_s=2.0, scaling_policy=1,
                       scale_interval=20, hs_util_hi=0.4, hs_util_lo=0.01)
    sim = Simulation(diamond(mi=400.0), caps=caps, params=params,
                     default_template=InstanceTemplate(mips=1000.0,
                                                       limit_mips=2000.0),
                     vm_mips=np.full(4, 64000.0, np.float32))
    res = sim.run()
    st = res.state
    up = np.asarray(st.fault.host_up)
    assert up.sum() < len(up)                    # some hosts really died
    assert int(st.counters.scale_out) > 0        # HS really respawned
    on = np.asarray(st.instances.status) == INST_ON
    hosts = np.asarray(st.instances.host)
    assert on.any()
    assert (up[hosts[on]] == 1).all()


# ---------------------------------------------------------------------------
# multi-API edge tables (zeros_state default sizing regression)
# ---------------------------------------------------------------------------

def _two_api_graph(mi=300.0):
    return build_graph(["front", "back"], {"front": ["back"]},
                       [("GET /a", "front", 1.0), ("GET /b", "front", 1.0)],
                       {"front": mi, "back": mi})


def test_zeros_state_default_edge_table_covers_all_apis():
    """Regression: the default n_edges undersized the retry/breaker tables
    for multi-API graphs (client→entry ids run to S*d_max + n_apis - 1),
    aliasing breaker state through clamped gathers."""
    g = _two_api_graph()
    app = build_app(g, n_hosts=2)
    caps = SimCaps(n_clients=4, max_requests=64, max_cloudlets=64,
                   max_instances=4, n_vms=2, d_max=1)
    params = SimParams(faults="chaos")
    state = zeros_state(caps, params, jax.random.PRNGKey(0),
                        n_services=g.n_services, n_apis=g.n_apis)
    E = state.fault.edge_open_until.shape[0]
    assert int(app.n_edges) == g.n_services * 1 + 2
    assert E >= int(app.n_edges)
    # an undersized table (the old single-API default) is rejected at
    # trace time instead of silently aliasing the last edge
    small = zeros_state(caps, params, jax.random.PRNGKey(0),
                        n_services=g.n_services)  # n_apis defaults to 1
    assert small.fault.edge_open_until.shape[0] == int(app.n_edges) - 1
    dyn = DynParams.from_params(params)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="undersized"):
        disruption(small, app, caps, params, dyn, k1, k2, None)


def test_two_api_chaos_run_keeps_breaker_edges_distinct():
    """Engine-level 2-API chaos run: edge ids stay in range, conservation
    holds, and the per-edge breaker state is genuinely per-edge (the
    second API's entry edge no longer aliases out of bounds)."""
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=512,
                   max_instances=8, n_vms=4, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=500, n_clients=12, spawn_rate=5.0,
                       wait_lo=0.5, wait_hi=1.5, seed=3, faults="chaos",
                       host_mtbf_s=20.0, host_mttr_s=5.0,
                       retry_timeout_s=3.0, retry_budget=2)
    sim = Simulation(_two_api_graph(), caps=caps, params=params,
                     default_template=InstanceTemplate(mips=8000.0,
                                                       limit_mips=16000.0,
                                                       replicas=2),
                     vm_mips=np.full(4, 64000.0, np.float32))
    assert int(sim.app.n_edges) == \
        sim.graph.n_services * sim.graph.d_max + 2
    res = sim.run()
    st = res.state
    E = st.fault.edge_open_until.shape[0]
    assert E == int(sim.app.n_edges)
    edges = np.asarray(st.cloudlets.edge)
    active = np.asarray(st.cloudlets.status) != CL_FREE
    assert (edges[active] >= 0).all() and (edges[active] < E).all()
    spawned = int(st.counters.spawned)
    in_flight = int(active.sum())
    assert spawned == int(st.counters.finished) + in_flight \
        + int(st.fstats.failed_attempts)
    # both APIs really generated traffic
    api = np.asarray(st.requests.api)[:int(st.requests.count)]
    assert set(np.unique(api)) == {0, 1}


# ---------------------------------------------------------------------------
# HS scale-in must not drain DOWN replicas (chaos-mode regression)
# ---------------------------------------------------------------------------

def _scale_in_state(statuses):
    """One service with len(statuses) ranked replicas in the given INST_*
    states (instance slot = rank)."""
    from repro.core.scaling import _scale_in
    n = len(statuses)
    caps = SimCaps(n_clients=4, max_requests=16, max_cloudlets=32,
                   max_instances=max(n, 2), n_vms=2, d_max=1,
                   max_replicas=max(n, 2))
    params = SimParams(faults="chaos")
    state = zeros_state(caps, params, jax.random.PRNGKey(0), n_services=1)
    inst = state.instances._replace(
        status=state.instances.status.at[:n].set(jnp.asarray(statuses, i32)),
        service=state.instances.service.at[:n].set(0),
        vm=state.instances.vm.at[:n].set(0),
        host=state.instances.host.at[:n].set(0),
        mips=state.instances.mips.at[:n].set(1000.0))
    sched = state.sched._replace(
        inst_of_rank=state.sched.inst_of_rank.at[0, :n].set(
            jnp.arange(n, dtype=i32)),
        svc_replicas=state.sched.svc_replicas.at[0].set(n))
    vms = state.vms._replace(
        mips=state.vms.mips.at[0].set(64000.0),
        mips_used=state.vms.mips_used.at[0].set(n * 1000.0))
    return _scale_in, state._replace(instances=inst, sched=sched, vms=vms)


def test_scale_in_skips_down_newest_replica():
    """Regression: the newest rank is DOWN (chaos killed it) — scale-in
    must NOT flip it to DRAIN (that steals its restart path and lets the
    VM share release twice via drain_dies + drain_done).  With an older ON
    replica available it drains that one and compacts the rank table."""
    _scale_in, state = _scale_in_state([INST_ON, INST_ON, INST_DOWN])
    out = _scale_in(state, 0)
    status = np.asarray(out.instances.status)
    assert status[2] == INST_DOWN                 # untouched, restartable
    assert status[1] == INST_DRAIN                # newest ON rank drains
    assert status[0] == INST_ON
    iof = np.asarray(out.sched.inst_of_rank)[0]
    assert iof[0] == 0 and iof[1] == 2 and iof[2] == -1  # table compacted
    assert int(out.sched.svc_replicas[0]) == 2
    assert int(out.counters.scale_in) == 1


def test_scale_in_skips_entirely_when_only_rank0_is_on():
    """Newest replica DOWN and only rank 0 ON: scale-in is a no-op (rank 0
    is never drained) — previously the DOWN replica was drained."""
    _scale_in, state = _scale_in_state([INST_ON, INST_DOWN])
    out = _scale_in(state, 0)
    np.testing.assert_array_equal(np.asarray(out.instances.status),
                                  np.asarray(state.instances.status))
    np.testing.assert_array_equal(np.asarray(out.sched.inst_of_rank),
                                  np.asarray(state.sched.inst_of_rank))
    assert int(out.sched.svc_replicas[0]) == 2
    assert int(out.counters.scale_in) == 0


def test_scale_in_all_on_unchanged_behavior():
    """faults="none" invariant: with every ranked replica ON the guarded
    scale-in behaves exactly like the old newest-rank drain."""
    _scale_in, state = _scale_in_state([INST_ON, INST_ON, INST_ON])
    out = _scale_in(state, 0)
    status = np.asarray(out.instances.status)
    assert status[2] == INST_DRAIN and status[1] == INST_ON
    iof = np.asarray(out.sched.inst_of_rank)[0]
    assert iof[0] == 0 and iof[1] == 1 and iof[2] == -1
    assert int(out.sched.svc_replicas[0]) == 2
    assert int(out.counters.scale_in) == 1


# ---------------------------------------------------------------------------
# per-edge timeout table (mirrors the per-edge retry resolver)
# ---------------------------------------------------------------------------

def _slow_service_sim(api_timeouts=None, n_ticks=300):
    """A single slow service (≈0.5 s execution) with no injected faults:
    only timeouts can fail attempts.  retry_budget=0 makes every timeout a
    permanent failure, so failed_requests counts timeout hits."""
    g = build_graph(["s0"], {}, [("api", "s0", 1.0)], {"s0": 500.0},
                    len_std={"s0": 0.0}, api_timeouts=api_timeouts)
    caps = SimCaps(n_clients=8, max_requests=256, max_cloudlets=128,
                   max_instances=2, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=0.05, n_ticks=n_ticks, n_clients=8,
                       spawn_rate=10.0, wait_lo=0.5, wait_hi=1.0, seed=0,
                       faults="chaos", host_mtbf_s=float("inf"),
                       inst_kill_rate=0.0, retry_budget=0,
                       retry_timeout_s=float("inf"))
    return Simulation(g, caps=caps, params=params,
                      default_template=InstanceTemplate(mips=1000.0,
                                                        limit_mips=1000.0))


def test_per_edge_timeout_overrides_run_wide_default():
    """A 0.2 s timeout on the client→entry edge fails the ≈0.5 s calls even
    though the run-wide retry_timeout_s is inf; without the per-edge entry
    nothing ever times out."""
    sim_tight = _slow_service_sim(api_timeouts={"api": 0.2})
    rep_tight = summarize(sim_tight, sim_tight.run())
    sim_loose = _slow_service_sim()
    rep_loose = summarize(sim_loose, sim_loose.run())
    assert rep_loose.failed_requests == 0
    assert rep_loose.availability == 1.0
    assert rep_tight.failed_requests > 0
    assert rep_tight.availability < 1.0


def test_timeout_spec_keys_resolve_like_retries():
    """Registry spec: service "timeouts" maps and API "timeout" scalars
    land on the same edge-id layout as the retry table."""
    from repro.core.registry import graph_from_spec
    spec = {
        "services": [
            {"name": "a", "mi": 100, "calls": ["b"],
             "retries": {"b": 5}, "timeouts": {"b": 1.5}},
            {"name": "b", "mi": 100},
        ],
        "apis": [{"name": "GET /x", "entry": "a", "retries": 3,
                  "timeout": 2.5}],
    }
    g = graph_from_spec(spec)
    app = build_app(g)
    S, D = g.n_services, g.d_max
    et = np.asarray(app.edge_timeout)
    er = np.asarray(app.edge_retry)
    # call edge a→b is row 0 slot 0
    assert er[0 * D + 0] == 5 and et[0 * D + 0] == pytest.approx(1.5)
    # client→entry edge of api 0 sits after the S*D call edges
    assert er[S * D + 0] == 3 and et[S * D + 0] == pytest.approx(2.5)
    # unlisted edges fall back to the run-wide defaults (-1 sentinel)
    assert er[1 * D + 0] == -1 and et[1 * D + 0] == -1.0


# ---------------------------------------------------------------------------
# gray failures: fail-slow hosts, failure domains, outlier ejection (§7.1)
# ---------------------------------------------------------------------------

def test_faults_none_gray_tables_zero_width():
    """faults="none" pays zero bytes for resilience state: every per-edge
    and gray-failure column is zero-width; only host_up/nic_ok stay [H]
    (scaling and placement read them unconditionally)."""
    caps = SimCaps(n_clients=4, max_requests=16, max_cloudlets=16,
                   max_instances=4, n_vms=3, d_max=1)
    st = zeros_state(caps, SimParams(), jax.random.PRNGKey(0))
    f = st.fault
    assert f.host_up.shape == (3,) and f.nic_ok.shape == (3,)
    for name in ("edge_open_until", "edge_err_ema", "edge_succ",
                 "host_slow", "nic_factor", "inst_err_ema", "inst_lat_ema",
                 "inst_eject_until", "inst_succ", "inst_lat_sum"):
        assert getattr(f, name).shape == (0,), name
    assert f.zone_cut.shape == (0, 0)
    # chaos sizes the edge tables through the one shared resolver
    from repro.core.types import edge_table_size
    g = linear_chain(2, mi=100.0)
    app = build_app(g, n_hosts=3)
    chaos = zeros_state(caps, SimParams(faults="chaos"),
                        jax.random.PRNGKey(0), app=app)
    assert int(app.n_edges) == edge_table_size(g.n_services, g.d_max,
                                               g.n_apis)
    assert chaos.fault.edge_open_until.shape == (int(app.n_edges),)
    assert chaos.fault.host_slow.shape == (3,)
    assert chaos.fault.nic_factor.shape == (3,)
    assert chaos.fault.zone_cut.shape == (3, 3)
    assert chaos.fault.inst_err_ema.shape == (4,)
    assert chaos.fault.inst_eject_until.shape == (4,)


def test_build_app_zone_defaults_and_validation():
    g = linear_chain(1, mi=100.0)
    app = build_app(g, n_hosts=3)          # default: one zone per host
    np.testing.assert_array_equal(np.asarray(app.host_zone), [0, 1, 2])
    app2 = build_app(g, host_zone=[0, 0, 1, 1])
    assert int(app2.n_hosts) == 4
    with pytest.raises(ValueError):
        build_app(g, n_hosts=2, host_zone=[0, 0, 1])   # length mismatch
    with pytest.raises(ValueError):
        build_app(g, host_zone=[0, 5])                 # zone id out of range


def test_registry_zones_spec_maps_hosts_to_domains():
    from repro.core.registry import register
    spec = {"services": [{"name": "a", "mi": 100}],
            "apis": [{"name": "GET /x", "entry": "a"}],
            "zones": [0, 0, 1, 1]}
    caps = SimCaps(n_clients=4, max_requests=16, max_cloudlets=32,
                   max_instances=4, n_vms=4, d_max=1)
    sim = register(spec, caps=caps, params=SimParams(faults="chaos"))
    np.testing.assert_array_equal(np.asarray(sim.app.host_zone),
                                  [0, 0, 1, 1])


def _zone_state(host_zone, **pover):
    """Empty chaos-mode state over a zoned cluster (one host per list
    entry), ready for direct disruption calls."""
    g = linear_chain(1, mi=100.0)
    app = build_app(g, host_zone=host_zone)
    H = len(host_zone)
    caps = SimCaps(n_clients=4, max_requests=8, max_cloudlets=16,
                   max_instances=4, n_vms=H, d_max=1, max_replicas=1)
    kw = dict(dt=0.1, n_ticks=1, faults="chaos",
              host_mtbf_s=float("inf"), host_mttr_s=float("inf"),
              inst_kill_rate=0.0)
    kw.update(pover)
    params = SimParams(**kw)
    dyn = DynParams.from_params(params)
    state = zeros_state(caps, params, jax.random.PRNGKey(0), app=app)
    return state, app, caps, params, dyn


def test_zone_fault_downs_the_whole_zone_atomically():
    """One firing zone draw crashes every host of the zone in the same
    tick while the other zone stays up (host MTBF is inf, so only the
    zone draw can down anything).  Seed picked so zone 0's uniform falls
    below p=0.5 and zone 1's above."""
    import math
    state, app, caps, params, dyn = _zone_state(
        [0, 0, 1, 1], zone_fault_rate=math.log(2.0) / 0.1)  # p_tick = 0.5
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    out = disruption(state, app, caps, params, dyn, k1, k2, None)
    np.testing.assert_array_equal(np.asarray(out.fault.host_up),
                                  [0, 0, 1, 1])
    assert int(out.fstats.zone_faults) == 1
    assert int(out.fstats.host_crashes) == 2


def test_partition_cuts_zone_pair_then_heals():
    """A partition draw cuts the zone pair symmetrically (never the
    diagonal); with the rate off and a tiny MTTR the next draw heals it."""
    state, app, caps, params, dyn = _zone_state(
        [0, 0, 1, 1], zone_partition_rate=1e9,
        zone_partition_mttr_s=float("inf"))
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    out = disruption(state, app, caps, params, dyn, k1, k2, None)
    zc = np.asarray(out.fault.zone_cut)
    assert zc[0, 1] == 1 and zc[1, 0] == 1
    assert zc.diagonal().sum() == 0
    assert zc.sum() == 2                       # exactly the one used pair
    assert int(out.fstats.partitions) == 1
    heal = dataclasses.replace(params, zone_partition_rate=0.0,
                               zone_partition_mttr_s=1e-9)
    out2 = disruption(out, app, caps, heal, DynParams.from_params(heal),
                      k1, k2, None)
    assert np.asarray(out2.fault.zone_cut).sum() == 0


def test_partition_stalls_cross_zone_transfer_without_crashing():
    """A cut zone pair zeroes the transfer's water-fill capacity: the
    payload makes no progress but nothing crashes, and the same transfer
    arrives normally once the pair heals."""
    from repro.core import network as netmod
    g = linear_chain(1, mi=100.0)
    app = build_app(g, host_zone=[0, 0, 1, 1])
    caps = SimCaps(n_clients=4, max_requests=8, max_cloudlets=8,
                   max_instances=4, n_vms=4, d_max=1, max_replicas=1)
    params = SimParams(dt=0.1, n_ticks=1, network="fabric", faults="chaos")
    dyn = DynParams.from_params(params)
    state = zeros_state(caps, params, jax.random.PRNGKey(0), app=app)
    inst = state.instances._replace(
        status=state.instances.status.at[0].set(INST_ON),
        service=state.instances.service.at[0].set(0),
        vm=state.instances.vm.at[0].set(2),
        host=state.instances.host.at[0].set(2),      # zone 1
        mips=state.instances.mips.at[0].set(1000.0))
    first = jnp.arange(caps.max_cloudlets) == 0
    from repro.core.types import CL_TRANSIT
    cl = state.cloudlets.with_cols(
        status=jnp.where(first, CL_TRANSIT, CL_FREE),
        inst=jnp.where(first, 0, -1),
        req=jnp.where(first, 0, -1),
        service=0, depth=0, attempt=0, edge=0, src_inst=-1,
        src_host=jnp.where(first, 0, -1),            # zone 0 → cross-zone
        length=100.0, rem=100.0, arrival=0.0, start=-1.0,
        rem_bytes=jnp.where(first, 1.0, 0.0))
    cut = state.fault.zone_cut.at[0, 1].set(1).at[1, 0].set(1)
    st = state._replace(instances=inst, cloudlets=cl,
                        fault=state.fault._replace(zone_cut=cut))
    out = netmod.transit(st, caps, params, dyn, app)
    assert int(np.asarray(out.cloudlets.status)[0]) == CL_TRANSIT
    assert float(np.asarray(out.cloudlets.rem_bytes)[0]) == 1.0
    healed = st._replace(fault=st.fault._replace(
        zone_cut=jnp.zeros_like(cut)))
    out2 = netmod.transit(healed, caps, params, dyn, app)
    assert int(np.asarray(out2.cloudlets.status)[0]) == CL_WAITING
    assert float(np.asarray(out2.cloudlets.rem_bytes)[0]) == 0.0


def test_fail_slow_host_degrades_only_execution_rate():
    """A host in a fail-slow episode runs its instances' cloudlets at
    host_slow_factor × MIPS; a healthy twin state finishes the same work
    proportionally faster (allocation/util untouched — only the rate)."""
    from repro.core.scheduler import execute
    g = linear_chain(1, mi=100.0)
    app = build_app(g, n_hosts=2)
    caps = SimCaps(n_clients=4, max_requests=8, max_cloudlets=8,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=0.1, n_ticks=1, faults="chaos",
                       host_slow_factor=0.25)
    dyn = DynParams.from_params(params)
    state = zeros_state(caps, params, jax.random.PRNGKey(0), app=app)
    inst = state.instances._replace(
        status=state.instances.status.at[0].set(INST_ON),
        service=state.instances.service.at[0].set(0),
        vm=state.instances.vm.at[0].set(0),
        host=state.instances.host.at[0].set(0),
        mips=state.instances.mips.at[0].set(1000.0),
        n_exec=state.instances.n_exec.at[0].set(1))
    first = jnp.arange(caps.max_cloudlets) == 0
    cl = state.cloudlets.with_cols(
        status=jnp.where(first, CL_EXEC, CL_FREE),
        inst=jnp.where(first, 0, -1), req=jnp.where(first, 0, -1),
        service=0, depth=0, attempt=0, edge=0, src_inst=-1, src_host=-1,
        length=1000.0, rem=1000.0, arrival=0.0, start=0.0, rem_bytes=0.0)
    healthy = state._replace(instances=inst, cloudlets=cl)
    slowed = healthy._replace(fault=healthy.fault._replace(
        host_slow=healthy.fault.host_slow.at[0].set(1)))
    rem_h = float(np.asarray(
        execute(healthy, app, caps, params, dyn)[0].cloudlets.rem)[0])
    rem_s = float(np.asarray(
        execute(slowed, app, caps, params, dyn)[0].cloudlets.rem)[0])
    # healthy burns 1000 MIPS × dt = 100 MI; slowed burns a quarter of it
    assert rem_h == pytest.approx(900.0)
    assert rem_s == pytest.approx(975.0)


def _eject_state(**pover):
    """Two ON replicas of one service; every pooled cloudlet is EXEC on
    replica 0 and past its timeout, so replica 0 is the outlier."""
    C = 8
    g = linear_chain(1, mi=100.0)
    app = build_app(g, n_hosts=2)
    caps = SimCaps(n_clients=4, max_requests=8, max_cloudlets=C,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=2)
    kw = dict(dt=0.1, n_ticks=1, faults="chaos", retry_budget=0,
              host_mtbf_s=float("inf"), host_mttr_s=float("inf"),
              inst_kill_rate=0.0, retry_timeout_s=1.0,
              cb_alpha=0.9, eject_err_thresh=0.3, eject_cooldown_s=5.0)
    kw.update(pover)
    params = SimParams(**kw)
    dyn = DynParams.from_params(params)
    state = zeros_state(caps, params, jax.random.PRNGKey(0), app=app)
    inst = state.instances._replace(
        status=state.instances.status.at[:2].set(INST_ON),
        service=state.instances.service.at[:2].set(0),
        vm=state.instances.vm.at[:2].set(jnp.asarray([0, 1], i32)),
        host=state.instances.host.at[:2].set(jnp.asarray([0, 1], i32)),
        mips=state.instances.mips.at[:2].set(1000.0),
        n_exec=state.instances.n_exec.at[0].set(C))
    sched = state.sched._replace(
        inst_of_rank=state.sched.inst_of_rank.at[0, :2].set(
            jnp.asarray([0, 1], i32)),
        svc_replicas=state.sched.svc_replicas.at[0].set(2))
    cl = state.cloudlets.with_cols(
        status=CL_EXEC, req=jnp.arange(C, dtype=i32), service=0, inst=0,
        wait_ticks=0, depth=0, src_host=-1, attempt=0, edge=0, src_inst=-1,
        length=100.0, rem=50.0, arrival=0.0, start=0.0, rem_bytes=0.0)
    req = state.requests._replace(
        count=jnp.asarray(C, i32),
        outstanding=state.requests.outstanding.at[:C].set(1),
        spawned=state.requests.spawned.at[:C].set(1))
    state = state._replace(instances=inst, sched=sched, cloudlets=cl,
                           requests=req, time=jnp.asarray(10.0, f32))
    return state, app, caps, params, dyn


def test_outlier_ejection_and_readmission_round_trip():
    """Replica 0 times out a full wave → its error EMA trips the ejector;
    the dispatch view compacts it out while replica 1 keeps serving.
    After the cooldown a clean probe re-admits it with reset EMAs."""
    from repro.core import policies
    state, app, caps, params, dyn = _eject_state()
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    out = disruption(state, app, caps, params, dyn, k1, k2, None)
    t = float(out.time)
    ej = np.asarray(out.fault.inst_eject_until)
    assert ej[0] > t                      # sick replica ejected
    assert ej[1] == 0.0                   # healthy replica untouched
    assert int(out.fstats.ejections) == 1
    assert int(np.asarray(out.instances.status)[0]) == INST_ON  # not DOWN
    # the LB view routes around it without shrinking the rank table
    iof_eff, n_ok = policies.eject_view(out.sched,
                                        out.fault.inst_eject_until, out.time)
    assert np.asarray(iof_eff)[0, :2].tolist() == [1, -1]
    assert int(n_ok[0]) == 1
    # half-open probe after the cooldown: clean traffic re-admits it
    st2 = out._replace(fault=out.fault._replace(
        inst_eject_until=out.fault.inst_eject_until.at[0].set(5.0),
        inst_succ=out.fault.inst_succ.at[0].set(3)))
    out2 = disruption(st2, app, caps, params, dyn, k1, k2, None)
    assert float(np.asarray(out2.fault.inst_eject_until)[0]) == 0.0
    assert int(out2.fstats.readmissions) == 1
    assert float(np.asarray(out2.fault.inst_err_ema)[0]) == 0.0
    iof_eff2, n_ok2 = policies.eject_view(
        out2.sched, out2.fault.inst_eject_until, out2.time)
    np.testing.assert_array_equal(np.asarray(iof_eff2)[0, :2], [0, 1])
    assert int(n_ok2[0]) == 2


def test_ejection_spares_the_last_admissible_replica():
    """Single-replica service: the outlier wants out but the last-replica
    guard refuses — ejecting it would leave nothing to route to (that is
    the edge breaker's job, not the LB's)."""
    state, app, caps, params, dyn = _eject_state()
    from repro.core.types import INST_FREE
    inst = state.instances._replace(
        status=state.instances.status.at[1].set(INST_FREE))
    sched = state.sched._replace(
        inst_of_rank=state.sched.inst_of_rank.at[0, 1].set(-1),
        svc_replicas=state.sched.svc_replicas.at[0].set(1))
    state = state._replace(instances=inst, sched=sched)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    out = disruption(state, app, caps, params, dyn, k1, k2, None)
    assert float(np.asarray(out.fault.inst_eject_until)[0]) == 0.0
    assert int(out.fstats.ejections) == 0


def test_eject_view_identity_when_nothing_ejected():
    from repro.core import policies
    state, app, caps, params, dyn = _eject_state()
    iof_eff, n_ok = policies.eject_view(
        state.sched, state.fault.inst_eject_until, state.time)
    np.testing.assert_array_equal(np.asarray(iof_eff),
                                  np.asarray(state.sched.inst_of_rank))
    np.testing.assert_array_equal(np.asarray(n_ok),
                                  np.asarray(state.sched.svc_replicas))


def test_conservation_under_fail_slow_and_partition_chaos():
    """Gray-failure campaign point vs a calm point, one compile via
    run_batch (every gray knob travels in DynParams): the conservation
    law holds through fail-slow episodes, zone-slow draws and partitions,
    and the gray chaos visibly hurts the workload."""
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=512,
                   max_instances=8, n_vms=4, d_max=2, max_replicas=2)
    gray = SimParams(dt=0.05, n_ticks=600, n_clients=12, spawn_rate=5.0,
                     wait_lo=0.5, wait_hi=1.5, seed=3, faults="chaos",
                     network="fabric", host_mtbf_s=float("inf"),
                     inst_kill_rate=0.0, retry_timeout_s=2.0,
                     retry_budget=2, host_slow_mtbf_s=5.0,
                     host_slow_mttr_s=2.0, host_slow_factor=0.2,
                     zone_slow_rate=0.1, zone_partition_rate=0.2,
                     zone_partition_mttr_s=1.0)
    calm = dataclasses.replace(gray, host_slow_mtbf_s=float("inf"),
                               zone_slow_rate=0.0, zone_partition_rate=0.0)
    tmpl = InstanceTemplate(mips=8000.0, limit_mips=16000.0, replicas=2)
    sim = Simulation(diamond(mi=400.0), caps=caps, params=gray,
                     default_template=tmpl,
                     vm_mips=np.full(4, 64000.0, np.float32),
                     host_zone=np.asarray([0, 0, 1, 1], np.int32))
    res_b = sim.run_batch([gray, calm])
    it_g, it_c = batch_item(res_b, 0), batch_item(res_b, 1)
    rep_g = summarize(sim, it_g, params=gray)
    rep_c = summarize(sim, it_c, params=calm)
    assert rep_g.slow_episodes > 0 and rep_g.slow_time_s > 0.0
    assert rep_g.partitions > 0
    assert rep_g.zone_faults > 0
    assert rep_c.slow_episodes == 0 and rep_c.slow_time_s == 0.0
    assert rep_c.partitions == 0 and rep_c.zone_faults == 0
    # gray failure hurts: slower responses or failed attempts appear
    assert (rep_g.avg_response_ms > rep_c.avg_response_ms
            or int(it_g.state.fstats.failed_attempts)
            > int(it_c.state.fstats.failed_attempts))
    for st in (it_g.state, it_c.state):
        spawned = int(st.counters.spawned)
        finished = int(st.counters.finished)
        in_flight = int((np.asarray(st.cloudlets.status) != CL_FREE).sum())
        assert spawned == finished + in_flight \
            + int(st.fstats.failed_attempts)
        cl_inst = np.asarray(st.cloudlets.inst)
        cl_st = np.asarray(st.cloudlets.status)
        I = st.instances.status.shape[0]
        expect = np.bincount(cl_inst[cl_st == CL_EXEC], minlength=I)[:I]
        np.testing.assert_array_equal(expect,
                                      np.asarray(st.instances.n_exec))


def test_recovery_restores_availability():
    """Crash/recover churn with quick MTTR: recoveries are observed and a
    healthy fraction of requests still completes successfully."""
    sim, _ = _chaos_sim(host_mtbf_s=20.0, host_mttr_s=2.0,
                        inst_mttr_s=0.5, inst_kill_rate=0.0,
                        retry_timeout_s=3.0)
    res = sim.run()
    rep = summarize(sim, res)
    assert rep.host_crashes > 0
    assert int(res.state.fstats.host_recoveries) > 0
    assert rep.availability > 0.2
    assert rep.observed_mttr_s > 0.0
