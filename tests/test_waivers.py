"""Waiver-file tests (DESIGN.md §8): a waiver is a dated loan against
the analyzers — matching suppresses, expiry and staleness both fail."""
import datetime

import pytest

from repro.analysis.waivers import Waiver, apply_waivers, load_waivers

TODAY = datetime.date(2026, 8, 8)


def _w(rule, site="", expires=datetime.date(2026, 12, 31),
       reason="tracked in #1"):
    return Waiver(rule=rule, site=site, reason=reason, expires=expires)


# ---------------------------------------------------------------------------
# apply_waivers
# ---------------------------------------------------------------------------

def test_matching_waiver_suppresses_finding():
    findings = [("donation", "[uniform+none] donation: 3/9 not donated"),
                ("f64", "[uniform+none] f64: widening")]
    surviving, probs = apply_waivers(findings, [_w("donation")], today=TODAY)
    assert surviving == ["[uniform+none] f64: widening"]
    assert probs == []


def test_site_pins_waiver_to_one_finding():
    findings = [("dup-scatter", "FAIL pool.py:26 ..."),
                ("dup-scatter", "FAIL scheduler.py:99 ...")]
    surviving, probs = apply_waivers(
        findings, [_w("dup-scatter", site="pool.py:26")], today=TODAY)
    assert surviving == ["FAIL scheduler.py:99 ..."]
    assert probs == []


def test_expired_waiver_fails_and_finding_survives():
    findings = [("donation", "donation: not donated")]
    surviving, probs = apply_waivers(
        findings, [_w("donation", expires=datetime.date(2026, 1, 1))],
        today=TODAY)
    assert surviving == ["donation: not donated"]
    assert len(probs) == 1 and "expired 2026-01-01" in probs[0]


def test_unused_waiver_fails():
    surviving, probs = apply_waivers([], [_w("oob-gather")], today=TODAY)
    assert surviving == []
    assert len(probs) == 1 and "matched no finding" in probs[0]


# ---------------------------------------------------------------------------
# load_waivers
# ---------------------------------------------------------------------------

def test_load_waivers_parses_toml(tmp_path):
    p = tmp_path / "waivers.toml"
    p.write_text(
        '[[waiver]]\n'
        'rule = "donation"\n'
        'site = "pool.py:111"\n'
        'reason = "tracked in #42"\n'
        'expires = 2026-12-31\n')
    ws = load_waivers(p)
    assert ws == [Waiver("donation", "pool.py:111", "tracked in #42",
                         datetime.date(2026, 12, 31))]


def test_load_waivers_missing_key_raises(tmp_path):
    p = tmp_path / "waivers.toml"
    p.write_text('[[waiver]]\nrule = "donation"\nexpires = 2026-12-31\n')
    with pytest.raises(ValueError, match="missing required"):
        load_waivers(p)


def test_load_waivers_bad_expiry_type_raises(tmp_path):
    p = tmp_path / "waivers.toml"
    p.write_text('[[waiver]]\nrule = "x"\nreason = "y"\n'
                 'expires = "2026-12-31"\n')
    with pytest.raises(ValueError, match="TOML date"):
        load_waivers(p)


def test_load_waivers_missing_file_is_empty(tmp_path):
    assert load_waivers(tmp_path / "absent.toml") == []


def test_committed_waiver_file_is_currently_empty():
    # Acceptance bar for this PR: every site proven/declared, ZERO
    # non-expiring waivers.  If this fails, someone added a waiver —
    # make sure it carries a real reason and a near expiry.
    assert load_waivers() == []
