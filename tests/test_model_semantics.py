"""Deep model-semantics tests.

1. MoE sort-based dispatch == brute-force dense mixture oracle (when
   capacity is not binding), and degrades gracefully (drops) when it is.
2. Step-by-step decode == teacher-forced forward logits — the strongest
   end-to-end consistency check of the KV-cache / SSM-state machinery.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.common import initialize
from repro.models.moe import MoECfg, moe_apply, moe_schema


# ---------------------------------------------------------------------------
# 1. MoE dispatch vs oracle
# ---------------------------------------------------------------------------

def _moe_oracle(p, x, cfg: MoECfg):
    """Dense mixture: run EVERY expert on every token, combine top-k."""
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # all-experts forward [E, n, d]
    h = jax.nn.silu(jnp.einsum("nd,edf->enf", xf, p["gate"])) \
        * jnp.einsum("nd,edf->enf", xf, p["up"])
    ye = jnp.einsum("enf,efd->end", h, p["down"])      # [E, n, d]
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for k in range(cfg.top_k):
        sel = jnp.take_along_axis(
            ye, top_e[None, :, k, None], axis=0)[0]     # [n, d]
        out = out + top_p[:, k, None] * sel.astype(jnp.float32)
    return out.reshape(B, T, d).astype(x.dtype)


@pytest.mark.parametrize("E,K,norm", [(8, 2, True), (16, 4, False)])
def test_moe_matches_dense_oracle(E, K, norm, rng):
    d, f = 32, 64
    cfg = MoECfg(n_experts=E, top_k=K, d_expert=f, capacity_factor=8.0,
                 norm_topk=norm)   # capacity never binds
    params = initialize(moe_schema(d, cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.normal(size=(2, 24, d)), jnp.float32)
    got = moe_apply(params, x, cfg)
    want = _moe_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_graceful(rng):
    d, f = 16, 32
    tight = MoECfg(n_experts=4, top_k=2, d_expert=f, capacity_factor=0.25)
    params = initialize(moe_schema(d, tight), jax.random.PRNGKey(1))
    x = jnp.asarray(rng.normal(size=(1, 64, d)), jnp.float32)
    out = moe_apply(params, x, tight)
    assert np.isfinite(np.asarray(out)).all()
    # dropped tokens contribute zero, so the output norm shrinks vs
    # an uncapped run — but never explodes
    loose = dc.replace(tight, capacity_factor=8.0)
    out_loose = moe_apply(params, x, loose)
    assert (float(jnp.abs(out).mean())
            <= float(jnp.abs(out_loose).mean()) + 1e-5)


# ---------------------------------------------------------------------------
# 2. decode == teacher-forced forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-130m",
                                  "qwen3-moe-30b-a3b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward(arch):
    rng = np.random.default_rng(7)   # local: independent of test order
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # capacity drops depend on how many tokens route together, so the
        # teacher-forced forward (T tokens/batch) and decode (1 token)
        # only agree when capacity never binds — that's the semantics
        # under test here, not the (documented) drop behaviour.
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, T = 1, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)

    # teacher-forced forward logits (no remat, full precision path)
    h = model.hidden_states(params, tokens=tokens, remat=False)
    fwd_logits = model.logits(params, h) if hasattr(model, "logits") else None
    if fwd_logits is None:
        from repro.models.common import unembed
        fwd_logits = unembed(h, params["head"])

    # step-by-step decode with state threading
    state = model.init_decode_state(B, T + 2)
    dec = []
    step = jax.jit(model.decode_step)
    for t in range(T):
        lg, state = step(params, tokens[:, t:t + 1], state)
        dec.append(np.asarray(lg[:, 0]))
    dec = np.stack(dec, axis=1)            # [B, T, V]

    a = np.asarray(jax.nn.softmax(jnp.asarray(dec), -1))
    b = np.asarray(jax.nn.softmax(fwd_logits, -1))
    diff = np.abs(a - b).max()
    # SSM/hybrid: the chunked-scan forward and the sequential decode
    # accumulate differently in bf16 → allow a slightly wider band and
    # near-total (not bitwise) argmax agreement.
    ssm = cfg.family in ("ssm", "hybrid")
    assert diff < (5e-2 if ssm else 2e-2), (arch, diff)
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    # untrained smoke models have near-flat softmax → argmax is a
    # tie-break; require strong but not bitwise agreement for ssm/hybrid
    assert agree >= (0.8 if ssm else 1.0), (arch, agree)
