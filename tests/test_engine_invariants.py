"""End-to-end engine invariants (conservation laws + hypothesis sweeps).

These are the system-level properties the tensor-DES must satisfy for any
configuration: cloudlet conservation, request accounting, capacity limits,
and monotonicity of the usage history.
"""
import numpy as np

from _hyp import given, settings, st  # skips gracefully without hypothesis

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        diamond, linear_chain, star, summarize)


def _run(graph, caps, params, tmpl=None):
    sim = Simulation(graph, caps=caps, params=params, default_template=tmpl)
    return sim, sim.run()


@given(
    seed=st.integers(min_value=0, max_value=2 ** 16),
    n_clients=st.integers(min_value=1, max_value=24),
    mi=st.floats(min_value=50.0, max_value=2000.0),
    topology=st.sampled_from(["chain", "diamond", "star"]),
)
@settings(max_examples=12, deadline=None)
def test_conservation_laws(seed, n_clients, mi, topology):
    g = {"chain": lambda: linear_chain(3, mi=mi),
         "diamond": lambda: diamond(mi=mi),
         "star": lambda: star(4, mi=mi)}[topology]()
    caps = SimCaps(n_clients=32, max_requests=4096, max_cloudlets=2048,
                   max_instances=16, n_vms=4, d_max=max(g.d_max, 1),
                   max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=600, n_clients=n_clients,
                       spawn_rate=4.0, wait_lo=0.5, wait_hi=2.0, seed=seed)
    sim, res = _run(g, caps, params,
                    InstanceTemplate(mips=8000.0, limit_mips=8000.0))
    st_ = res.state
    cls = np.asarray(st_.cloudlets.status)
    in_flight = int((cls != 0).sum())
    spawned = int(st_.counters.spawned)
    finished = int(st_.counters.finished)
    # Conservation: every spawned cloudlet is finished or still in flight.
    assert spawned == finished + in_flight
    # Request accounting: outstanding == in-flight cloudlets per request.
    out = np.asarray(st_.requests.outstanding)
    n = int(st_.requests.count)
    assert (out[:n] >= 0).all()
    assert out[:n].sum() == in_flight
    # Completed requests have response ≥ 0 and finish ≥ arrival.
    resp = np.asarray(st_.requests.response)[:n]
    arr = np.asarray(st_.requests.arrival)[:n]
    fin = np.asarray(st_.requests.finish)[:n]
    done = resp >= 0
    assert (fin[done] >= arr[done] - 1e-5).all()
    assert np.allclose(resp[done], fin[done] - arr[done], atol=1e-4)
    # Counter bookkeeping matches the pool.
    assert int(st_.counters.completed) == int(done.sum())


def test_capacity_is_never_oversubscribed():
    """Instance usage ≤ allocation; VM allocations ≤ VM capacity."""
    g = diamond(mi=300.0)
    caps = SimCaps(n_clients=64, max_requests=8192, max_cloudlets=4096,
                   max_instances=32, n_vms=4, d_max=2, max_replicas=4)
    params = SimParams(dt=0.05, n_ticks=800, n_clients=50, spawn_rate=10.0,
                       wait_lo=0.5, wait_hi=1.5, scaling_policy=1,
                       scale_interval=40)
    sim, res = _run(g, caps, params,
                    InstanceTemplate(mips=1000.0, limit_mips=4000.0))
    inst = res.state.instances
    used = np.asarray(inst.used_mips)
    alloc = np.asarray(inst.mips)
    assert (used <= alloc * (1 + 1e-4) + 1e-3).all()
    vms = res.state.vms
    assert (np.asarray(vms.mips_used) <= np.asarray(vms.mips) + 1e-3).all()
    assert (np.asarray(vms.ram_used) <= np.asarray(vms.ram) + 1e-3).all()
    assert (np.asarray(vms.mips_used) >= -1e-3).all()


def test_overload_sheds_into_waiting_queue_not_crash():
    """Saturated system: waiting queue grows, nothing is lost."""
    g = linear_chain(2, mi=5000.0)
    caps = SimCaps(n_clients=32, max_requests=2048, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=0.05, n_ticks=400, n_clients=32, spawn_rate=50.0,
                       wait_lo=0.1, wait_hi=0.2)
    sim, res = _run(g, caps, params,
                    InstanceTemplate(mips=500.0, limit_mips=500.0))
    st_ = res.state
    spawned = int(st_.counters.spawned)
    finished = int(st_.counters.finished)
    in_flight = int((np.asarray(st_.cloudlets.status) != 0).sum())
    assert spawned == finished + in_flight
    assert in_flight > 0          # genuinely backlogged
    rep = summarize(sim, res)
    assert rep.cloudlets_dropped >= 0  # drops are counted, not crashes


def test_space_shared_cap_limits_concurrency():
    g = linear_chain(1, mi=2000.0)
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=256,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=0.05, n_ticks=300, n_clients=16, spawn_rate=100.0,
                       wait_lo=0.1, wait_hi=0.2, max_concurrent=2)
    sim = Simulation(g, caps=caps, params=params,
                     default_template=InstanceTemplate(mips=1000.0,
                                                       limit_mips=1000.0))
    res = sim.run()
    # n_exec per instance never exceeds the cap
    assert int(np.asarray(res.state.instances.n_exec).max()) <= 2
    tr = res.trace_np()
    assert tr["n_exec"].max() <= 2 * 1  # one instance
    assert tr["n_waiting"].max() > 0    # the rest queue up


def test_deterministic_given_seed():
    g = diamond(mi=400.0)
    caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=300, n_clients=10, spawn_rate=5.0,
                       wait_lo=0.5, wait_hi=1.5, seed=123)
    _, r1 = _run(g, caps, params)
    _, r2 = _run(g, caps, params)
    np.testing.assert_array_equal(np.asarray(r1.state.requests.response),
                                  np.asarray(r2.state.requests.response))
    assert int(r1.state.counters.spawned) == int(r2.state.counters.spawned)
