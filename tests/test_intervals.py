"""Index-safety verifier self-tests (DESIGN.md §8).

Seeded-violation layer for the interval pass: each rule is fed a small
jaxpr containing a deliberate hazard and must fire — plus acceptance
tests proving the escape hatches (declared collisions, proven-unique
index vectors) do NOT fire.  The full golden-combo proof runs in the CI
simcheck job (``python -m repro.analysis --only intervals``); this file
keeps the analyzer honest on inputs where the verdict is known by
construction.
"""
import jax
import jax.numpy as jnp

from repro.analysis.intervals import (analyze_jaxpr, from_concrete, ival,
                                      top_for, verify_combo)
from repro.core import pool


def _sites(fn, seeds, *example_args):
    closed = jax.make_jaxpr(fn)(*example_args)
    sites, _outs, _it = analyze_jaxpr(closed, list(seeds))
    return sites


# ---------------------------------------------------------------------------
# Seeded violations: bounds rules
# ---------------------------------------------------------------------------

def test_oob_gather_flagged():
    tbl = jnp.zeros((8,), jnp.float32)
    idx = jnp.zeros((4,), jnp.int32)

    def f(t, i):
        # promise_in_bounds makes an unproven index undefined behaviour
        return t.at[i].get(mode="promise_in_bounds")

    bad = _sites(f, [ival(0.0, 1.0), ival(0, 9)], tbl, idx)
    assert any(not s.ok and s.rule == "oob-gather" for s in bad)

    ok = _sites(f, [ival(0.0, 1.0), ival(0, 7)], tbl, idx)
    assert all(s.ok and s.bounds == "in-bounds" for s in ok)


def test_oob_dynamic_slice_flagged():
    tbl = jnp.zeros((8,), jnp.float32)

    def f(t, start):
        return jax.lax.dynamic_slice(t, (start,), (2,))

    # start ∈ [0, 7] but the window needs start ≤ 6: wrong-window read
    bad = _sites(f, [ival(0.0, 1.0), ival(0, 7)], tbl, jnp.int32(0))
    assert any(not s.ok and s.rule == "oob-dslice" for s in bad)

    ok = _sites(f, [ival(0.0, 1.0), ival(0, 6)], tbl, jnp.int32(0))
    assert all(s.ok for s in ok)


def test_oob_scatter_flagged_unless_dropped():
    tbl = jnp.zeros((8,), jnp.float32)
    idx = jnp.arange(4, dtype=jnp.int32)
    val = jnp.ones((4,), jnp.float32)

    def clipped(t, i, v):
        return t.at[i].set(v, mode="clip", unique_indices=True)

    # mode="clip" lands OOB writes in the WRONG slot — a violation
    bad = _sites(clipped, [ival(0.0, 1.0), ival(0, 9), ival(1.0, 1.0)],
                 tbl, idx, val)
    assert any(not s.ok and s.rule == "oob-scatter" for s in bad)

    def dropped(t, i, v):
        return t.at[i].set(v, mode="drop", unique_indices=True)

    # mode="drop" discards OOB writes — sound, reported as 'drop'
    ok = _sites(dropped, [ival(0.0, 1.0), ival(0, 9), ival(1.0, 1.0)],
                tbl, idx, val)
    assert all(s.ok for s in ok)
    assert any(s.bounds == "drop" for s in ok)


# ---------------------------------------------------------------------------
# Seeded violations: duplicate-freedom rules
# ---------------------------------------------------------------------------

def test_duplicate_index_scatter_flagged():
    tbl = jnp.zeros((8,), jnp.float32)
    idx = jnp.zeros((4,), jnp.int32)
    val = jnp.ones((4,), jnp.float32)

    def f(t, i, v):
        return t.at[i].set(v, mode="drop")

    # idx ∈ [0, 7] with no uniqueness evidence: last-write-wins races
    bad = _sites(f, [ival(0.0, 1.0), ival(0, 7), ival(1.0, 1.0)],
                 tbl, idx, val)
    assert any(not s.ok and s.rule == "dup-scatter" and s.dups == "DUP"
               for s in bad)


def test_proven_unique_scatter_accepted():
    tbl = jnp.zeros((8,), jnp.float32)
    idx = jnp.arange(4, dtype=jnp.int32)
    val = jnp.ones((4,), jnp.float32)

    def f(t, i, v):
        return t.at[i].set(v, mode="drop")

    # the index SEED carries the pairwise-distinct tag (what the
    # prefix-sum slot compaction establishes): accepted without a flag
    ok = _sites(f, [ival(0.0, 1.0), ival(0, 7, unique=True),
                    ival(1.0, 1.0)], tbl, idx, val)
    assert any(s.ok and s.dups == "unique(proven)" for s in ok)


def test_constant_index_scatter_accepted():
    tbl = jnp.zeros((8,), jnp.float32)
    val = jnp.ones((4,), jnp.float32)
    idx = jnp.asarray([0, 2, 4, 6], jnp.int32)

    def f(t, v):
        return t.at[idx].set(v, mode="drop")

    ok = _sites(f, [ival(0.0, 1.0), ival(1.0, 1.0)], tbl, val)
    assert any(s.ok and s.dups in ("unique(const)", "unique(jnp)")
               for s in ok)


def test_declared_segment_sum_collision_accepted():
    data = jnp.ones((16,), jnp.float32)
    ids = jnp.zeros((16,), jnp.int32)

    def f(d, i):
        return pool.segment_sum(d, i, 4)

    # ids may repeat AND stray out of range: the collide("segment_sum")
    # scope + mode="drop" make the site acceptable by declaration
    sites = _sites(f, [ival(0.0, 1.0), ival(-1, 99)], data, ids)
    scatters = [s for s in sites if s.kind.startswith("scatter")]
    assert scatters
    assert all(s.ok for s in scatters)
    assert any(s.dups == "declared-collide" for s in scatters)


def test_undeclared_segment_sum_equivalent_flagged():
    # The SAME computation without the collide() declaration must fail —
    # the declaration is load-bearing, not decorative.
    data = jnp.ones((16,), jnp.float32)
    ids = jnp.zeros((16,), jnp.int32)

    def f(d, i):
        idx = jnp.where(i >= 0, i, 4)
        return jnp.zeros((4,), d.dtype).at[idx].add(d, mode="drop")

    sites = _sites(f, [ival(0.0, 1.0), ival(-1, 99)], data, ids)
    assert any(not s.ok and s.rule == "dup-scatter" for s in sites)


# ---------------------------------------------------------------------------
# Seed helpers
# ---------------------------------------------------------------------------

def test_from_concrete_tracks_uniqueness():
    v = from_concrete(jnp.asarray([3, 1, 2], jnp.int32))
    assert (v.lo, v.hi, v.unique) == (1.0, 3.0, True)
    w = from_concrete(jnp.asarray([1, 1, 2], jnp.int32))
    assert not w.unique


def test_top_for_is_dtype_wide():
    t = top_for(jax.ShapeDtypeStruct((4,), jnp.int32))
    assert t.lo == float(jnp.iinfo(jnp.int32).min)
    assert t.hi == float(jnp.iinfo(jnp.int32).max)
    b = top_for(jax.ShapeDtypeStruct((4,), jnp.bool_))
    assert (b.lo, b.hi) == (0.0, 1.0)


# ---------------------------------------------------------------------------
# One full-combo proof (the other combos run in the CI simcheck job)
# ---------------------------------------------------------------------------

def test_verify_combo_uniform_none_fully_proven():
    rep = verify_combo("uniform", "none")
    assert rep.violations == []
    assert rep.induction_fails == []
    assert rep.unknown_prims == {}
    assert all(s.ok for s in rep.sites)
    # every site is attributed to a real tick phase by named_scope
    assert all(s.phase != "?" for s in rep.sites)
