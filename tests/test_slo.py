"""SLO alerting tests (DESIGN.md §10).

Five contracts:

* **observation-only** — turning ``alerting="burn"`` ON (objectives
  disabled) reproduces the golden-matrix digests bitwise in every mode
  combo: the SIXTH golden combo, stacked on top of the telemetry fifth;
* **burn-rate math** — device-side f32 rule evaluation matches a
  host-side float64 oracle over crafted SLI windows with decisive
  margins (no f32-rounding knife edges);
* **state machine** — pending → firing → resolved round-trips on a
  crafted condition sequence, with ``for_ticks`` hysteresis and exact
  one-shot fire/resolve counting;
* **streamed == aggregate** — ALERTS transition rows drained during
  ``run_batch`` reconcile exactly with each point's QoSReport counters;
* **feedback gating** — ``hs_mode="slo_burn"`` scales out only on
  firing alerts (never when objectives are disabled).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        diamond, policies)
from repro.core.engine import batch_item
from repro.core.qos import summarize
from repro.core.types import (ALERT_FIRING, ALERT_INACTIVE, ALERT_PENDING,
                              ALERT_RESOLVED, DynParams, validate_alerting)
from repro.obs import export
from repro.obs import slo as slomod

from test_layouts import MATRIX_GOLDEN, MODES, matrix_sim
from test_network import _digest_f32

# observation-only telemetry + alerting riders for the golden scenario:
# objectives stay DISABLED (slo_budget=0.0 default) so the rule
# conditions are constant-false and nothing feeds back.
ALERT_KW = dict(telemetry="stream", tel_window_ticks=16, tel_windows=8,
                tel_span_k=4, tel_span_cap=256, alerting="burn")

# an always-burning variant: slo_ms=1.0 makes every completion an SLO
# miss (frac = 1.0), budget 0.05 → burn 20 ≥ both thresholds.
HOT_KW = dict(ALERT_KW, slo_budget=0.05, slo_ms=1.0,
              slo_short_wins=2, slo_long_wins=4, slo_for_ticks=2)


# ---------------------------------------------------------------------------
# Sixth golden combo: alerting ON (objectives off) keeps every digest
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("network,faults", MODES)
def test_alerting_on_bit_identical_golden(network, faults):
    """The Alerting stage rides the carry in every mode combo without
    perturbing a single simulated bit while no objective is enabled —
    the burn conditions are constant-false, the feedback multipliers
    exact identities."""
    sim = matrix_sim(network, faults, **ALERT_KW)
    res = sim.run()
    st = res.state
    want = MATRIX_GOLDEN[(network, faults)]
    assert _digest_f32(st.requests.response) == want["resp"]
    assert int(st.counters.completed) == want["completed"]
    assert int(st.counters.spawned) == want["spawned"]
    assert int(st.counters.finished) == want["finished"]
    assert _digest_f32(res.trace.used_mips) == want["used_mips"]
    assert int(st.net.transits) == want["transits"]
    assert int(st.fstats.failed_attempts) == want["failed_attempts"]
    assert int(st.fstats.retries) == want["retries"]
    # ...and the alert plane stayed silent: no objective, no transitions
    rep = summarize(sim, res)
    assert (rep.alert_fires, rep.alert_resolves,
            rep.alert_event_drops) == (0, 0, 0)
    assert rep.alert_firing_time_s == 0.0


def test_alerting_off_is_zero_width():
    sim = matrix_sim("uniform", "none", n_ticks=64)
    res = sim.run()
    al = res.state.alerts
    assert al.astate.size == 0 and al.sli_win.size == 0
    assert al.ev_time.size == 0
    rep = summarize(sim, res)
    assert (rep.alert_fires, rep.alert_resolves,
            rep.alert_event_drops) == (0, 0, 0)


# ---------------------------------------------------------------------------
# Burn-rate math vs a host-side float64 oracle
# ---------------------------------------------------------------------------

def _oracle_rules(sli_win, w_closed, budget, params):
    """Mirror of evaluate_rules in plain float64 numpy: iterate window
    ids m in [w_closed - n, w_closed), read ring slot m % L."""
    sli = np.asarray(sli_win, np.float64)
    L, S, _ = sli.shape

    def frac(n):
        out = np.zeros(S)
        for s in range(S):
            good = bad = 0.0
            for m in range(max(0, w_closed - n), w_closed):
                good += sli[m % L, s, 0]
                bad += sli[m % L, s, 1]
            out[s] = bad / max(good + bad, 1.0)
        return out

    b = np.asarray(budget, np.float64)
    active = b > 0
    safe = np.maximum(b, 1e-9)
    burn1 = frac(1) / safe
    burn_s = frac(params.slo_short_wins) / safe
    burn_l = frac(params.slo_long_wins) / safe
    fast = active & (burn_s >= params.slo_fast_burn) \
        & (burn1 >= params.slo_fast_burn)
    slow = active & (burn_l >= params.slo_slow_burn) \
        & (burn_s >= params.slo_slow_burn)
    return np.stack([fast, slow], axis=1)


def test_burn_rules_match_float64_oracle():
    """Device f32 rule conditions == host f64 oracle over crafted SLI
    rings: full burn, partial burn landing between the two thresholds,
    recovered services, empty windows, disabled objectives, and a
    partially-filled ring (w_closed < L)."""
    params = SimParams(telemetry="stream", alerting="burn",
                       slo_budget=0.05, slo_short_wins=2, slo_long_wins=4,
                       slo_fast_burn=14.4, slo_slow_burn=6.0)
    dyn = DynParams.from_params(params)
    L, S = 6, 5
    rng = np.random.RandomState(11)
    for w_closed in (0, 1, 3, 6, 11):
        sli = np.zeros((L, S, 2), np.float32)
        for m in range(max(0, w_closed - L), w_closed):
            # decisive margins only: frac per (window, service) is one of
            # {0, 0.5, 1} — burn {0, 10, 20} vs thresholds 14.4 / 6.0
            kind = rng.randint(0, 3, size=S)
            n = rng.randint(1, 40, size=S).astype(np.float32)
            sli[m % L, :, 0] = np.where(kind == 0, n,
                                        np.where(kind == 1, n, 0.0))
            sli[m % L, :, 1] = np.where(kind == 0, 0.0,
                                        np.where(kind == 1, n, n))
        budget = np.array([0.05, 0.05, 0.0, -1.0, 0.05], np.float32)
        got = np.asarray(slomod.evaluate_rules(
            jnp.asarray(sli), jnp.int32(w_closed), jnp.asarray(budget),
            params, dyn))
        want = _oracle_rules(sli, w_closed, budget, params)
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"w_closed={w_closed}")
        # disabled objectives can never fire
        assert not got[2].any() and not got[3].any()


def test_lookback_frac_ring_wraparound():
    """Slot contents older than the lookback are excluded even after the
    ring wraps: window ids resolve to the LARGEST m < w_closed at each
    slot, so a 1-window lookback reads exactly the newest window."""
    L, S = 3, 1
    sli = np.zeros((L, S, 2), np.float32)
    # windows 2,3,4 live in slots 2,0,1; window 4 (slot 1) is all-bad,
    # the older two all-good
    sli[2, 0] = (10.0, 0.0)     # window 2
    sli[0, 0] = (10.0, 0.0)     # window 3
    sli[1, 0] = (0.0, 10.0)     # window 4
    f1 = float(slomod._lookback_frac(jnp.asarray(sli), jnp.int32(5), 1)[0])
    f3 = float(slomod._lookback_frac(jnp.asarray(sli), jnp.int32(5), 3)[0])
    assert f1 == 1.0
    assert abs(f3 - 10.0 / 30.0) < 1e-6


# ---------------------------------------------------------------------------
# State machine: pending → firing → resolved with for_ticks hysteresis
# ---------------------------------------------------------------------------

def _drive(conds, for_ticks):
    st = jnp.full((1,), ALERT_INACTIVE, jnp.int32)
    pend = jnp.zeros((1,), jnp.int32)
    out = []
    for c in conds:
        st, pend = slomod.step_machine(st, pend,
                                       jnp.asarray([c]), for_ticks)
        out.append(int(st[0]))
    return out


def test_state_machine_round_trip():
    # for_ticks=3: two pending ticks, fire on the third held tick, stay
    # firing under the condition, resolve one tick after it clears, then
    # back to inactive.
    assert _drive([1, 1, 1, 1, 0, 0], for_ticks=3) == [
        ALERT_PENDING, ALERT_PENDING, ALERT_FIRING, ALERT_FIRING,
        ALERT_RESOLVED, ALERT_INACTIVE]


def test_state_machine_hysteresis_resets_on_gap():
    # a gap during pending resets the held counter — the alert never
    # fires on intermittent flapping shorter than for_ticks.
    assert _drive([1, 1, 0, 1, 1, 0, 1], for_ticks=3) == [
        ALERT_PENDING, ALERT_PENDING, ALERT_INACTIVE,
        ALERT_PENDING, ALERT_PENDING, ALERT_INACTIVE, ALERT_PENDING]


def test_state_machine_for_ticks_one_fires_immediately():
    assert _drive([1, 0, 1], for_ticks=1) == [
        ALERT_FIRING, ALERT_RESOLVED, ALERT_FIRING]


def test_state_machine_refire_after_resolve():
    # resolved is a one-tick state; a re-burn restarts the full
    # hysteresis from pending.
    assert _drive([1, 1, 0, 0, 1, 1], for_ticks=2) == [
        ALERT_PENDING, ALERT_FIRING, ALERT_RESOLVED, ALERT_INACTIVE,
        ALERT_PENDING, ALERT_FIRING]


# ---------------------------------------------------------------------------
# End-to-end: a hot run fires, resolves at run end, and reconciles
# ---------------------------------------------------------------------------

def test_hot_run_fires_and_reports():
    """slo_ms=1.0 turns every completion into an SLO miss: the fast rule
    must fire on the entry service and the report's firing time must
    equal firing_ticks * dt to the float."""
    sim = matrix_sim("uniform", "none", **HOT_KW)
    res = sim.run()
    rep = summarize(sim, res)
    assert rep.alert_fires > 0
    assert rep.alert_event_drops == 0
    al = res.state.alerts
    assert rep.alert_firing_time_s == pytest.approx(
        float(np.asarray(al.firing_ticks).sum()) * sim.params.dt)
    # drained events replay the exact transition counts
    rows = slomod.drain_events(al)
    export.validate_alert_rows(rows)
    assert sum(r["state"] == "firing" for r in rows) == rep.alert_fires
    assert sum(r["state"] == "resolved" for r in rows) == rep.alert_resolves
    # exposition formats render without error
    for r in rows[:4]:
        assert "ALERTS{" in export.prometheus_alert_line(r)
        assert export.otel_alert_event(r)


def test_event_ring_overflow_counts_drops_exactly():
    sim = matrix_sim("uniform", "none", **dict(HOT_KW, slo_event_cap=2))
    res = sim.run()
    al = res.state.alerts
    n = int(np.asarray(al.ev_n)[0])
    drops = int(np.asarray(al.ev_drops)[0])
    transitions = int(np.asarray(al.fires).sum()
                      + np.asarray(al.resolves).sum())
    assert n == 2                        # full, never overwritten
    assert drops > 0
    # every transition either landed in the ring or was counted dropped
    # (pending/inactive transitions also occupy the ring, so >=)
    assert n + drops >= transitions
    assert summarize(sim, res).alert_event_drops == drops


def test_run_batch_alert_rows_match_reports():
    """Per sweep point, streamed ALERTS transition rows reconcile
    EXACTLY with the point's QoSReport fire/resolve counters."""
    base = matrix_sim("uniform", "none", **HOT_KW)
    points = [dataclasses.replace(base.params, spawn_rate=r)
              for r in (3.0, 5.0, 8.0)]
    with export.alert_collecting() as col:
        res = base.run_batch(points)
    rows = col.rows
    export.validate_alert_rows(rows)
    assert rows, "hot scenario streamed no alert transitions"
    for b, p in enumerate(points):
        mine = [r for r in rows if int(r["tag"]) == b]
        rep = summarize(base, batch_item(res, b), params=p)
        assert rep.alert_event_drops == 0
        assert sum(r["state"] == "firing" for r in mine) == rep.alert_fires
        assert sum(r["state"] == "resolved" for r in mine) \
            == rep.alert_resolves
        assert rep.alert_fires > 0


# ---------------------------------------------------------------------------
# Feedback gating: hs_mode="slo_burn" scales out only on firing alerts
# ---------------------------------------------------------------------------

def _burn_sim(**over):
    # the golden scenario starts AT the replica cap, so feedback tests
    # use their own sim: 1 replica per service with headroom to 4.
    caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                   max_instances=16, n_vms=4, d_max=2, max_replicas=4)
    kw = dict(dt=0.05, n_ticks=300, n_clients=12, spawn_rate=5.0,
              wait_lo=0.5, wait_hi=1.5, seed=3, net_latency_s=0.05,
              scaling_policy=policies.SCALE_HORIZONTAL, scale_interval=20,
              hs_mode="slo_burn", slo_stabilize_s=2.0, **HOT_KW)
    kw.update(over)
    return Simulation(diamond(mi=400.0), caps=caps, params=SimParams(**kw),
                      default_template=InstanceTemplate(
                          mips=8000.0, limit_mips=16000.0, replicas=1),
                      vm_mips=np.full(4, 64000.0, np.float32))


def test_slo_burn_autoscaler_scales_out_on_firing():
    res = _burn_sim().run()
    assert int(res.state.counters.scale_out) > 0


def test_slo_burn_autoscaler_idle_without_objectives():
    # objectives disabled → alerts never fire → the burn gate never
    # scales out (the util path would have, under the same load)
    res = _burn_sim(slo_budget=0.0, slo_ms=1000.0).run()
    assert int(res.state.counters.scale_out) == 0


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_validate_alerting_rejects_bad_configs():
    with pytest.raises(ValueError, match="telemetry"):
        validate_alerting(SimParams(alerting="burn"))
    with pytest.raises(ValueError, match="alerting"):
        validate_alerting(SimParams(alerting="sometimes"))
    with pytest.raises(ValueError, match="hs_mode"):
        validate_alerting(SimParams(hs_mode="vibes"))
    with pytest.raises(ValueError, match="slo_long_wins"):
        validate_alerting(SimParams(telemetry="stream", alerting="burn",
                                    slo_short_wins=4, slo_long_wins=2))
    with pytest.raises(ValueError, match="slo_burn"):
        validate_alerting(SimParams(hs_mode="slo_burn"))
