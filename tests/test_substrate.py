"""Substrate tests: optimizer, checkpoint/restart, elastic reshard,
gradient compression, deterministic data pipeline, sharding resolver."""
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.ckpt.checkpoint import CheckpointManager, load_checkpoint, \
    save_checkpoint
from repro.data.synthetic import SyntheticLM
from repro.dist import sharding as shd
from repro.dist.compression import compress_decompress, ef_compress, ef_init
from repro.launch.train import PRESETS
from repro.models import build_model
from repro.train.optimizer import (AdamWCfg, adamw_init, adamw_update,
                                   clip_by_global_norm, lr_schedule)
from repro.train.train_step import make_train_step


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    cfg = AdamWCfg(lr=0.1, weight_decay=0.0, warmup_steps=1,
                   total_steps=1000, clip_norm=100.0)
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_weight_decay_shrinks_without_gradient():
    params = {"w": jnp.ones(4) * 2.0}
    cfg = AdamWCfg(lr=0.1, weight_decay=0.5, warmup_steps=1, total_steps=100)
    state = adamw_init(params)
    p1, _, _ = adamw_update(params, {"w": jnp.zeros(4)}, state, cfg)
    assert float(p1["w"][0]) < 2.0


def test_lr_schedule_shape():
    cfg = AdamWCfg(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6          # warmup rises
    assert abs(lrs[10] - 1.0) < 0.01              # peak after warmup
    assert lrs[100] == pytest.approx(0.1, rel=0.05)  # decays to floor


def test_grad_clip():
    g = {"a": jnp.ones(100) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint / restart / elastic
# ---------------------------------------------------------------------------

def _tiny_state():
    cfg = PRESETS["tiny"]
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def test_checkpoint_roundtrip_bf16():
    cfg, model, params = _tiny_state()
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        path = pathlib.Path(d) / "ck.npz"
        save_checkpoint(path, {"p": params, "o": opt}, step=7)
        back = load_checkpoint(path, {"p": params, "o": opt})
    for a, b in zip(jax.tree_util.tree_leaves(back["p"]),
                    jax.tree_util.tree_leaves(params)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_manager_keeps_last_k_and_restores_latest():
    cfg, model, params = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in (10, 20, 30):
            scaled = jax.tree_util.tree_map(
                lambda x: x * (step / 10.0), params)
            mgr.save({"p": scaled}, step, blocking=True)
        files = sorted(pathlib.Path(d).glob("step_*.npz"))
        assert len(files) == 2                      # pruned to keep=2
        restored, step = mgr.restore_latest({"p": params})
        assert step == 30
        a = jax.tree_util.tree_leaves(restored["p"])[0]
        b = jax.tree_util.tree_leaves(params)[0]
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32) * 3.0,
                                   rtol=2e-2)


def test_crash_resume_is_bit_exact():
    """Train 6 steps straight vs 3 + checkpoint + restore + 3."""
    cfg = PRESETS["tiny"]
    model = build_model(cfg)
    opt_cfg = AdamWCfg(lr=1e-3, warmup_steps=2, total_steps=10)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=2)

    def run(params, opt, start, end):
        for s in range(start, end):
            params, opt, _ = step_fn(params, opt, data.batch(s))
        return params, opt

    p0 = model.init_params(jax.random.PRNGKey(0))
    o0 = adamw_init(p0)
    pa, oa = run(p0, o0, 0, 6)

    pb, ob = run(p0, o0, 0, 3)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save({"p": pb, "o": ob}, 2, blocking=True)
        restored, step = mgr.restore_latest({"p": pb, "o": ob})
    pc, oc = run(restored["p"], restored["o"], step + 1, 6)

    for a, b in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_compression_error_bounded(rng):
    x = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    y = compress_decompress(x)
    err = np.abs(np.asarray(x - y))
    scale = np.abs(np.asarray(x)).max() / 127
    assert err.max() <= scale * 1.01


def test_error_feedback_reduces_bias(rng):
    g = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32)) * 1e-3
    grads = {"w": g}
    ef = ef_init(grads)
    total_plain = np.zeros(2048, np.float32)
    total_ef = np.zeros(2048, np.float32)
    for _ in range(50):
        total_plain += np.asarray(compress_decompress(g))
        c, ef = ef_compress(grads, ef)
        total_ef += np.asarray(c["w"])
    true = np.asarray(g) * 50
    assert np.abs(total_ef - true).mean() <= \
        np.abs(total_plain - true).mean() + 1e-6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_step_indexed():
    ds = SyntheticLM(vocab=1000, seq_len=64, global_batch=4, seed=3)
    b1 = ds.batch(17)
    b2 = ds.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert (np.asarray(b1["labels"])[:, -1] == -1).all()
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["labels"])[:, :-1],
                                  np.asarray(b1["tokens"])[:, 1:])


# ---------------------------------------------------------------------------
# sharding resolver
# ---------------------------------------------------------------------------

def test_resolver_divisibility_and_uniqueness():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a 16x16 mesh by resolving against axis sizes via a real mesh is
    # overkill on 1 device; instead exercise the guards with 1-sized axes:
    # every rule fails divisibility unless dim % 1 == 0 (always true), so
    # uniqueness is the interesting part here.
    spec = shd.resolve(mesh, (64, 64), ("heads", "mlp"), shd.PARAM_RULES)
    # both want "model"; only the first gets it
    assert spec == PartitionSpec("model", None) or \
        spec == PartitionSpec(*spec)  # structural sanity
    assert spec[0] == "model" and spec[1] is None

    # non-divisible dims replicate (simulate with a 2-ary axis)
    mesh2 = jax.make_mesh((1,), ("model",))
    spec2 = shd.resolve(mesh2, (7,), ("vocab",), shd.PARAM_RULES)
    assert spec2[0] == "model"  # 7 % 1 == 0 → allowed on size-1 axis


def test_resolver_batch_multi_axis():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    spec = shd.resolve(mesh, (256, 4096), ("batch", "seq"), shd.ACT_RULES)
    assert spec[0] == ("pod", "data")
    assert spec[1] is None
