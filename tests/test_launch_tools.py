"""Launch-layer unit tests: HLO collective parser, registry files,
train/serve drivers (tiny presets), roofline model-flops math."""
import numpy as np

from repro.launch.hlo_analysis import parse_collectives


HLO = """
HloModule jit_step, entry_computation_layout={...}

%fused (p: f32[128,256]) -> f32[128,256] {
  %ag = f32[1024,256]{1,0} all-gather(f32[128,256]{1,0} %p), dimensions={0}
  ROOT %c = f32[128,256]{1,0} copy(%p)
}

ENTRY %main {
  %p0 = bf16[512,512]{1,0} parameter(0)
  %ar = bf16[512,512]{1,0} all-reduce(bf16[512,512]{1,0} %p0), to_apply=%add
  %ag2 = bf16[512,1024]{1,0} all-gather(bf16[512,512]{1,0} %p0), dimensions={1}
  %rs = f32[64,512]{1,0} reduce-scatter(f32[512,512]{1,0} %x), dimensions={0}
  %a2a = f32[512,512]{1,0} all-to-all(f32[512,512]{1,0} %x)
  %cp = u32[16]{0} collective-permute(u32[16]{0} %y)
  %ars = bf16[512,512]{1,0} all-reduce-start(bf16[512,512]{1,0} %p0)
  %ard = bf16[512,512]{1,0} all-reduce-done(bf16[512,512]{1,0} %ars)
}
"""


def test_parse_collectives_counts_and_bytes():
    out = parse_collectives(HLO)
    # all-reduce: plain (512·512·2) + start (512·512·2); -done excluded
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 2 * 512 * 512 * 2
    # all-gather: fused f32[1024,256] + entry bf16[512,1024]
    assert out["all-gather"]["count"] == 2
    assert out["all-gather"]["bytes"] == 1024 * 256 * 4 + 512 * 1024 * 2
    assert out["reduce-scatter"]["bytes"] == 64 * 512 * 4
    assert out["all-to-all"]["bytes"] == 512 * 512 * 4
    assert out["collective-permute"]["bytes"] == 16 * 4
    assert out["total_bytes"] == sum(
        out[k]["bytes"] for k in ("all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute"))


def test_parse_collectives_ignores_non_collectives():
    out = parse_collectives("%x = f32[8,8] add(f32[8,8] %a, f32[8,8] %b)")
    assert out["total_bytes"] == 0


def test_train_driver_loss_drops(tmp_path):
    from repro.launch.train import main
    losses = main(["--preset", "tiny", "--steps", "100", "--batch", "4",
                   "--seq", "64", "--lr", "3e-3", "--log-every", "100"])
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2


def test_train_driver_resume(tmp_path):
    from repro.launch.train import main
    d = str(tmp_path / "ck")
    l1 = main(["--preset", "tiny", "--steps", "20", "--batch", "2",
               "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "10",
               "--log-every", "100"])
    # resume continues from step 20 checkpoint → runs 10 more
    l2 = main(["--preset", "tiny", "--steps", "30", "--batch", "2",
               "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "10",
               "--log-every", "100"])
    assert len(l2) == 10                    # resumed at step 20


def test_serve_driver_waves():
    from repro.launch.serve import main
    outs = main(["--preset", "tiny", "--requests", "5", "--batch-slots", "2",
                 "--prompt-len", "4", "--gen-len", "6", "--max-seq", "16"])
    assert len(outs) == 5
    assert all(len(o) == 6 for o in outs)


def test_roofline_model_flops():
    from repro.launch.roofline import model_flops, _active_fraction
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("qwen3-0.6b")
    train = next(s for s in SHAPES if s.name == "train_4k")
    decode = next(s for s in SHAPES if s.name == "decode_32k")
    mf_train = model_flops(cfg, train)
    # 0.596B params × 6 × 1.05M tokens ≈ 3.75e15
    assert 1e15 < mf_train < 1e16
    mf_dec = model_flops(cfg, decode)
    assert mf_dec < mf_train / 1000
    # MoE active fraction strictly below 1 and sane
    moe = get_config("qwen3-moe-30b-a3b")
    f = _active_fraction(moe)
    assert 0.05 < f < 0.5
