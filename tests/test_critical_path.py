"""Critical-path analysis (Alg 2) — tropical closure vs DP oracle, and
engine response times vs the analytic critical path on deterministic runs.
"""
import numpy as np
import pytest

from _hyp import given, settings, st  # skips gracefully without hypothesis

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        build_graph, critical_path, diamond, linear_chain,
                        path_delay, response_times)
from repro.core.critical_path import response_times_batched


def _random_dag(rng, n, p=0.35):
    """Random DAG via upper-triangular edges + random delays."""
    names = [f"s{i}" for i in range(n)]
    calls = {}
    for i in range(n):
        dst = [names[j] for j in range(i + 1, n) if rng.random() < p]
        if dst:
            calls[names[i]] = dst
    delays = rng.uniform(0.5, 5.0, size=n)
    mi = {nm: 100.0 for nm in names}
    g = build_graph(names, calls, [("api", names[0], 1.0)], mi)
    return g, delays


@given(st.integers(min_value=2, max_value=12),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=60, deadline=None)
def test_tropical_matches_dp_oracle(n, seed):
    rng = np.random.default_rng(seed)
    g, delays = _random_dag(rng, n)
    rt_trop = response_times(g, delays)[0]
    rt_dp, path = critical_path(g, delays, 0)
    assert np.isclose(rt_trop, rt_dp, rtol=1e-5), (rt_trop, rt_dp)
    # Eq 5: the returned CP's delay equals the response time
    assert np.isclose(path_delay(path, delays), rt_dp, rtol=1e-6)
    # path must start at the API entry and follow real edges
    assert path[0] == int(g.api_entry[0])
    adj = g.adjacency()
    for u, v in zip(path, path[1:]):
        assert adj[u, v]


def test_batched_matches_single():
    rng = np.random.default_rng(7)
    g, _ = _random_dag(rng, 8, p=0.5)
    delays = rng.uniform(0.1, 3.0, size=(5, g.n_services)).astype(np.float32)
    batched = response_times_batched(g, delays)
    for b in range(5):
        single = response_times(g, delays[b])
        np.testing.assert_allclose(batched[b], single, rtol=1e-5)


def test_diamond_critical_path_picks_heavier_branch():
    g = diamond(mi=100.0)  # C = 2×mi, so A→C→D is critical
    delays = np.array([1.0, 1.0, 2.0, 1.0])
    rt, path = critical_path(g, delays, 0)
    assert [g.names[i] for i in path] == ["A", "C", "D"]
    assert rt == pytest.approx(4.0)


def test_engine_response_matches_critical_path_deterministic():
    """Deterministic single request: engine response == Alg 2 prediction
    (execution delays + per-hop dispatch latency)."""
    n, mi, mips, dt = 4, 800.0, 1600.0, 0.05
    g = linear_chain(n, mi=mi)
    g.len_std[:] = 0.0   # deterministic lengths
    caps = SimCaps(n_clients=1, max_requests=8, max_cloudlets=64,
                   max_instances=8, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=dt, n_ticks=400, n_clients=1, spawn_rate=100.0,
                       wait_lo=100.0, wait_hi=101.0, num_limit=1)
    sim = Simulation(g, caps=caps, params=params,
                     default_template=InstanceTemplate(mips=mips,
                                                       limit_mips=mips))
    res = sim.run()
    resp = np.asarray(res.state.requests.response)
    resp = resp[resp >= 0]
    assert len(resp) == 1
    exec_time = n * mi / mips              # 4 × 0.5 s
    # children dispatch at the next tick boundary after the parent finishes:
    # per-hop latency in [0, dt]; root dispatches in its spawn tick.
    lo = exec_time
    hi = exec_time + n * dt + 1e-3
    assert lo - 1e-3 <= resp[0] <= hi, (resp[0], lo, hi)
    # Alg 2 on measured node delays reproduces the engine response
    from repro.core import node_delays
    rt, path = critical_path(g, node_delays(res), 0)
    assert len(path) == n
    assert rt == pytest.approx(float(resp[0]), rel=0.02)
    # critical_len recorded on the request equals the chain depth
    assert int(res.state.requests.critical_len[0]) == n
