"""Network fabric (DESIGN.md §6): link_share kernel, transit semantics,
and the uniform-mode bit-identity guarantee.

Pinned contracts:

 * the ``link_share`` Pallas kernel (interpret mode) bit-matches its jnp
   oracle, and the oracle satisfies the max-min fairness properties on
   hand-built and randomized port topologies (never oversubscribes a port,
   exact water levels on small cases);
 * ``network="uniform"`` builds the exact pre-PR program: run()/run_batch()
   responses, counters and traces are bit-identical to digests captured at
   the commit before the fabric landed;
 * fabric-mode conservation: every spawned transfer either arrives or is
   still in flight; loopback hops never touch a NIC;
 * a low-bandwidth sockshop sweep shows monotonically increasing p95
   transit time with offered load (the saturation scenario the uniform
   model cannot express).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        batch_item, diamond, summarize)
from repro.core.types import CL_TRANSIT
from repro.kernels.link_share import link_share_pallas, link_share_ref

i32, f32 = jnp.int32, jnp.float32


# ---------------------------------------------------------------------------
# link_share: kernel vs oracle, max-min fairness properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C,H,seed,iters", [
    (64, 4, 0, 4), (300, 7, 1, 4),     # C not a bc multiple → padding path
    (1024, 16, 2, 8), (8, 2, 3, 1),
])
def test_link_share_kernel_bitmatches_ref(C, H, seed, iters):
    r = np.random.default_rng(seed)
    src = np.asarray(r.integers(-1, H, C), np.int32)
    dst = np.asarray(r.integers(0, H, C), np.int32)
    active = r.random(C) < 0.6
    cap_e = jnp.asarray(r.uniform(1.0, 50.0, H), f32)
    cap_i = jnp.asarray(r.uniform(1.0, 50.0, H), f32)
    got = link_share_pallas(jnp.asarray(src), jnp.asarray(dst),
                            jnp.asarray(active), cap_e, cap_i,
                            iters=iters, bc=256, interpret=True)
    want = link_share_ref(jnp.asarray(src), jnp.asarray(dst),
                          jnp.asarray(active), cap_e, cap_i, iters=iters)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", range(4))
def test_link_share_never_oversubscribes(seed):
    r = np.random.default_rng(seed)
    C, H = 256, 5
    src = np.asarray(r.integers(-1, H, C), np.int32)
    dst = np.asarray(r.integers(0, H, C), np.int32)
    active = r.random(C) < 0.7
    cap_e = np.asarray(r.uniform(0.5, 20.0, H), np.float32)
    cap_i = np.asarray(r.uniform(0.5, 20.0, H), np.float32)
    rate = np.asarray(link_share_ref(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(active),
        jnp.asarray(cap_e), jnp.asarray(cap_i), iters=4))
    assert (rate >= 0).all()
    assert (rate[~active] == 0).all()
    for h in range(H):
        used_e = rate[active & (src == h)].sum()
        used_i = rate[active & (dst == h)].sum()
        assert used_e <= cap_e[h] * (1 + 1e-4), h
        assert used_i <= cap_i[h] * (1 + 1e-4), h


def test_link_share_exact_waterfill_small():
    """Hand-checked two-level max-min case.

    Host 0 egress cap 10 carries transfers A, B; B also rides into host 1
    whose ingress cap is 2.  Max-min: B is bottlenecked at 2 (level 1),
    then A takes the residual 8 (level 2).
    """
    src = jnp.asarray([0, 0], i32)
    dst = jnp.asarray([2, 1], i32)
    active = jnp.asarray([True, True])
    cap_e = jnp.asarray([10.0, 100.0, 100.0], f32)
    cap_i = jnp.asarray([100.0, 2.0, 100.0], f32)
    rate = np.asarray(link_share_ref(src, dst, active, cap_e, cap_i,
                                     iters=4))
    np.testing.assert_allclose(rate, [8.0, 2.0], rtol=1e-5)


def test_link_share_client_uploads_share_ingress():
    """Three client (src=-1) transfers into one host split its ingress
    evenly — no egress constraint applies."""
    src = jnp.asarray([-1, -1, -1], i32)
    dst = jnp.asarray([0, 0, 0], i32)
    active = jnp.ones(3, bool)
    cap_e = jnp.asarray([5.0], f32)
    cap_i = jnp.asarray([9.0], f32)
    rate = np.asarray(link_share_ref(src, dst, active, cap_e, cap_i,
                                     iters=4))
    np.testing.assert_allclose(rate, [3.0, 3.0, 3.0], rtol=1e-5)


def test_link_share_many_levels_is_conservative():
    """More bottleneck levels than freeze rounds: the allocation must stay
    feasible (the final fill never oversubscribes)."""
    H = 8
    # one transfer per (host h egress → host h+1 ingress), capacities
    # descending so every round freezes exactly one level
    src = jnp.asarray(list(range(H - 1)), i32)
    dst = jnp.asarray(list(range(1, H)), i32)
    active = jnp.ones(H - 1, bool)
    cap = np.linspace(1.0, 10.0, H).astype(np.float32)
    rate = np.asarray(link_share_ref(src, dst, active, jnp.asarray(cap),
                                     jnp.asarray(cap), iters=2))
    for h in range(H - 1):
        assert rate[h] <= cap[h] * (1 + 1e-4)


# ---------------------------------------------------------------------------
# uniform degenerate mode: bit-identical to the pre-PR engine
# ---------------------------------------------------------------------------

def _digest_f32(x) -> int:
    a = np.ascontiguousarray(np.asarray(x, np.float32))
    return int(a.view(np.uint32).astype(np.uint64).sum())


def _digest_i32(x) -> int:
    a = np.ascontiguousarray(np.asarray(x, np.int32))
    return int(a.astype(np.int64).sum())


def _diamond_sim():
    caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=300, n_clients=12, spawn_rate=5.0,
                       wait_lo=0.5, wait_hi=1.5, scaling_policy=1,
                       scale_interval=40, net_latency_s=0.05, seed=3)
    return Simulation(diamond(mi=400.0), caps=caps, params=params), params


# Digests captured at commit db98924 (the commit before the network fabric),
# by running these exact scenarios and summing the bit patterns of the
# outputs — see the capture script quoted in the PR description.
GOLDEN = dict(
    diamond_resp=1610947120196,
    diamond_completed=16,
    diamond_spawned=240,
    diamond_trace_completed=16,
    diamond_trace_used_mips=348533711833,
    diamond_scale_out=5,
    batch_resp=(1621571898612, 1610947120196, 1625837432215),
)


def test_uniform_mode_bit_identical_to_pre_fabric_run():
    sim, _ = _diamond_sim()
    res = sim.run()
    st = res.state
    assert _digest_f32(st.requests.response) == GOLDEN["diamond_resp"]
    assert int(st.counters.completed) == GOLDEN["diamond_completed"]
    assert int(st.counters.spawned) == GOLDEN["diamond_spawned"]
    assert _digest_i32(res.trace.completed) == \
        GOLDEN["diamond_trace_completed"]
    assert _digest_f32(res.trace.used_mips) == \
        GOLDEN["diamond_trace_used_mips"]
    assert int(st.counters.scale_out) == GOLDEN["diamond_scale_out"]
    # the fabric state exists but never moves in uniform mode
    assert int(st.net.transits) == 0
    assert float(np.asarray(st.net.bytes_in).sum()) == 0.0
    assert int(np.asarray(res.trace.n_transit).sum()) == 0


def test_uniform_mode_bit_identical_run_batch():
    sim, params = _diamond_sim()
    sweeps = [dataclasses.replace(params, n_clients=nc)
              for nc in (6, 12, 16)]
    res_b = sim.run_batch(sweeps)
    for b, want in enumerate(GOLDEN["batch_resp"]):
        item = batch_item(res_b, b)
        assert _digest_f32(item.state.requests.response) == want, b


# ---------------------------------------------------------------------------
# fabric-mode engine semantics
# ---------------------------------------------------------------------------

def _fabric_sim(mbps: float, n_ticks: int = 300, seed: int = 3,
                n_clients: int = 12):
    caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=n_ticks, n_clients=n_clients,
                       spawn_rate=5.0, wait_lo=0.5, wait_hi=1.5, seed=seed,
                       network="fabric", nic_egress_mbps=mbps,
                       nic_ingress_mbps=mbps)
    tmpl = InstanceTemplate(mips=8000.0, limit_mips=16000.0)
    vm_mips = np.full(2, 64000.0, np.float32)
    return Simulation(diamond(mi=400.0), caps=caps, params=params,
                      default_template=tmpl, vm_mips=vm_mips)


def test_fabric_transfer_conservation():
    sim = _fabric_sim(50.0)
    res = sim.run()
    st = res.state
    in_flight = int(np.asarray(
        (st.cloudlets.status == CL_TRANSIT)).sum())
    # histogram counts exactly the arrived transfers
    assert int(np.asarray(st.net.hist).sum()) == int(st.net.transits)
    assert int(st.net.transits) > 0
    # bytes only move through the fabric while transfers are in flight
    assert float(np.asarray(st.net.bytes_in).sum()) > 0
    # requests complete despite transit (the phase delivers)
    assert int(st.counters.completed) > 0
    # in-flight leftovers are bounded by the pool
    assert 0 <= in_flight <= st.cloudlets.status.shape[0]


def test_fabric_loopback_beats_cross_host():
    """All instances on one VM → every hop is loopback: no NIC bytes, no
    transits except the client→entry uploads."""
    caps = SimCaps(n_clients=8, max_requests=256, max_cloudlets=256,
                   max_instances=8, n_vms=1, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=200, n_clients=6, spawn_rate=5.0,
                       wait_lo=0.5, wait_hi=1.5, seed=0,
                       network="fabric", nic_egress_mbps=100.0,
                       nic_ingress_mbps=100.0)
    sim = Simulation(diamond(mi=200.0), caps=caps, params=params,
                     default_template=InstanceTemplate(mips=8000.0,
                                                       limit_mips=16000.0),
                     vm_mips=np.full(1, 64000.0, np.float32))
    res = sim.run()
    st = res.state
    assert int(st.counters.completed) > 0
    # every fabric transfer is a client upload: zero egress anywhere
    assert float(np.asarray(st.net.bytes_out).sum()) == 0.0
    # client uploads did arrive through the ingress port
    assert float(np.asarray(st.net.bytes_in).sum()) > 0.0
    # derived hops took the loopback fast path: transits == root arrivals
    assert int(st.net.transits) <= int(st.requests.count) + 1


def test_fabric_low_bandwidth_increases_transit_p95():
    reps = {}
    for mbps in (100.0, 2.0):
        sim = _fabric_sim(mbps)
        reps[mbps] = summarize(sim, sim.run())
    assert reps[2.0].transit_p95_ms > reps[100.0].transit_p95_ms
    assert reps[2.0].avg_ingress_util > reps[100.0].avg_ingress_util


def test_fabric_saturation_p95_monotone_with_load():
    """Acceptance scenario: low-bandwidth sockshop sweep — p95 transit time
    rises monotonically with offered load (a saturation curve the uniform
    latency model cannot produce).  Spread placement puts services on
    different hosts so RPC edges actually cross NICs."""
    from repro.configs.sockshop import make_sim
    from repro.core import policies
    sim = make_sim(n_clients=96, duration_s=40.0, seed=0,
                   network="fabric", nic_egress_mbps=8.0,
                   nic_ingress_mbps=8.0,
                   placement_policy=policies.PLACE_SPREAD)
    base = sim.params
    sweeps = [dataclasses.replace(base, n_clients=nc, spawn_rate=nc / 10.0)
              for nc in (8, 32, 96)]
    res_b = sim.run_batch(sweeps)
    p95 = []
    for b, p in enumerate(sweeps):
        rep = summarize(sim, batch_item(res_b, b), params=p)
        p95.append(rep.transit_p95_ms)
    assert all(b >= a for a, b in zip(p95, p95[1:])), p95
    assert p95[-1] > p95[0], p95


def test_fabric_nic_bandwidth_sweepable_via_dynparams():
    """run_batch sweeps NIC capacity without recompiling; each point
    matches its solo run bit for bit."""
    sim = _fabric_sim(100.0)
    base = sim.params
    sweeps = [dataclasses.replace(base, nic_egress_mbps=m,
                                  nic_ingress_mbps=m)
              for m in (100.0, 4.0)]
    res_b = sim.run_batch(sweeps)
    for b, p in enumerate(sweeps):
        caps = sim.caps
        solo = Simulation(sim.graph, caps=caps, params=p,
                          default_template=InstanceTemplate(
                              mips=8000.0, limit_mips=16000.0),
                          vm_mips=np.full(2, 64000.0, np.float32)).run()
        item = batch_item(res_b, b)
        np.testing.assert_array_equal(
            np.asarray(item.state.requests.response),
            np.asarray(solo.state.requests.response))
        assert int(item.state.net.transits) == int(solo.state.net.transits)


def test_fabric_round_robin_uses_all_replicas():
    """Regression: the spawn-time cursor advance must not be repeated at
    dispatch (a double step of +2 per RPC pins a 2-replica service to one
    replica forever) — both replicas must see traffic."""
    from repro.core import linear_chain
    caps = SimCaps(n_clients=8, max_requests=512, max_cloudlets=256,
                   max_instances=8, n_vms=4, d_max=1, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=300, n_clients=8, spawn_rate=10.0,
                       wait_lo=0.3, wait_hi=0.6, seed=0,
                       network="fabric", nic_egress_mbps=1000.0,
                       nic_ingress_mbps=1000.0)
    from repro.core import policies
    sim = Simulation(linear_chain(2, mi=500.0), caps=caps, params=params,
                     default_template=InstanceTemplate(
                         mips=4000.0, limit_mips=8000.0, replicas=2),
                     vm_mips=np.full(4, 64000.0, np.float32),
                     placement_policy=policies.PLACE_SPREAD)
    res = sim.run()
    st = res.state
    busy = np.asarray(st.instances.busy_ticks)
    svc = np.asarray(st.instances.service)
    assert int(st.counters.completed) > 10
    for s in (0, 1):
        replicas_busy = busy[svc == s]
        assert len(replicas_busy) == 2
        # round-robin must spread executions over BOTH replicas
        assert (replicas_busy > 0).all(), (s, busy, svc)


def test_egress_shaping_bw_starved_instance_slows_transit():
    """PR-2 follow-up (§6): with ``egress_shaping=True`` an instance's
    concurrent transfers share its own ``Instances.bw`` allowance, so a
    bw-starved instance's transit time rises even on amply-provisioned
    NICs; shaping off (the default, PR-2 program) is unaffected."""
    def run_one(shaping: bool, bw: float):
        caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                       max_instances=8, n_vms=2, d_max=2, max_replicas=2)
        params = SimParams(dt=0.05, n_ticks=300, n_clients=12,
                           spawn_rate=5.0, wait_lo=0.5, wait_hi=1.5, seed=3,
                           network="fabric", nic_egress_mbps=1000.0,
                           nic_ingress_mbps=1000.0, egress_shaping=shaping)
        from repro.core import policies
        sim = Simulation(diamond(mi=400.0), caps=caps, params=params,
                         default_template=InstanceTemplate(
                             mips=8000.0, limit_mips=16000.0, bw=bw),
                         vm_mips=np.full(2, 64000.0, np.float32),
                         placement_policy=policies.PLACE_SPREAD)
        return summarize(sim, sim.run())

    rep_off = run_one(False, 0.5)
    rep_on = run_one(True, 0.5)
    rep_on_fat = run_one(True, 1000.0)
    assert rep_on.net_transits > 0
    # the starved instances' hops cross the fabric slower under shaping
    assert rep_on.avg_transit_ms > 2.0 * rep_off.avg_transit_ms
    # with ample instance bw the clamp never binds: same as shaping off
    assert abs(rep_on_fat.avg_transit_ms - rep_off.avg_transit_ms) < 1e-3


def test_network_param_validated():
    sim, params = _diamond_sim()
    bad = dataclasses.replace(params, network="mesh")
    with pytest.raises(ValueError, match="uniform.*fabric|fabric.*uniform"):
        Simulation(diamond(mi=400.0), caps=sim.caps, params=bad)
