"""Shared test fixtures. NOTE: never set XLA_FLAGS device-count here —
smoke tests and benches must see the single real CPU device; only
launch/dryrun.py fakes 512 devices (and only in its own process)."""
import os

# Keep test-time compilation lean and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
