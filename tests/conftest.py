"""Shared test fixtures. NOTE: never set XLA_FLAGS device-count here —
smoke tests and benches must see the single real CPU device; only
launch/dryrun.py fakes 512 devices (and only in its own process)."""
import os

# Keep test-time compilation lean and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

# REPRO_STRICT_PROMOTION=1 runs the whole session under JAX's strict
# dtype-promotion regime: any implicit cross-kind promotion (the classic
# leak is a weak Python float widening an i32/u8 operand) becomes a
# TypePromotionError instead of a silent upcast the jaxpr lint would
# have to chase.  CI's simcheck job sets it for the core-sim modules;
# locally it is opt-in because third-party test deps may not be strict.
if os.environ.get("REPRO_STRICT_PROMOTION"):
    import jax

    jax.config.update("jax_numpy_dtype_promotion", "strict")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
