"""File registry (paper Fig 3), SockShop config, scaling/migration
behaviours, and kernel-path equivalence of the engine tick."""
import json

import numpy as np
import yaml

from repro.configs import sockshop
from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        diamond, policies, register, summarize)
from repro.core.types import INST_ON


def test_registry_json_yaml_roundtrip(tmp_path):
    app = tmp_path / "app.json"
    inst = tmp_path / "instances.yaml"
    app.write_text(json.dumps(sockshop.app_spec()))
    inst.write_text(yaml.safe_dump(sockshop.instance_spec(share=800.0)))
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=2048,
                   max_instances=32, n_vms=4, d_max=5, max_replicas=2)
    params = SimParams(dt=0.1, n_ticks=300, n_clients=10, spawn_rate=2.0,
                       wait_lo=2.0, wait_hi=6.0)
    sim = register(str(app), str(inst), caps=caps, params=params)
    assert sim.graph.n_services == 13
    assert sim.graph.n_apis == 5
    # YAML requests.share becomes the instance MIPS
    assert float(np.asarray(sim.app.tmpl_mips)[0]) == 800.0
    rep = summarize(sim, sim.run())
    assert rep.completed_requests > 0


def test_sockshop_graph_structure():
    sim = sockshop.make_sim(n_clients=10, duration_s=30.0)
    g = sim.graph
    # paper Fig 8: POST /orders triggers the deep shipping chain
    orders = g.service_id("orders")
    chains = g.chains_from(orders)
    leaves = {c[-1] for c in chains}
    assert g.service_id("queue-master") in leaves
    assert g.depth >= 3


def test_hs_scales_out_under_load():
    sim = sockshop.make_sim(n_clients=300, duration_s=120.0,
                            scaling_policy=policies.SCALE_HORIZONTAL,
                            share=400.0, hs_util_hi=0.5, hs_util_lo=0.05,
                            util_ema=0.2)
    res = sim.run()
    assert int(res.state.counters.scale_out) > 0
    on = np.asarray(res.state.instances.status) == INST_ON
    assert on.sum() > 13          # replicas were added


def test_vs_raises_mips_under_load():
    sim = sockshop.make_sim(n_clients=300, duration_s=120.0,
                            scaling_policy=policies.SCALE_VERTICAL,
                            share=400.0, vs_util_hi=0.5, vs_util_lo=0.05,
                            util_ema=0.2)
    res = sim.run()
    assert int(res.state.counters.scale_up) > 0
    inst = res.state.instances
    on = np.asarray(inst.status) == INST_ON
    assert (np.asarray(inst.mips)[on] > np.asarray(
        inst.request_mips)[on] + 1).any()


def test_migration_moves_instance():
    g = diamond(mi=500.0)
    caps = SimCaps(n_clients=8, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=3, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=400, n_clients=8, spawn_rate=8.0,
                       wait_lo=0.5, wait_hi=1.0, migration_enabled=True,
                       mig_vm_util_hi=0.5, scale_interval=20)
    # most-available placement stacks all four instances on VM0 (its free
    # capacity stays the largest throughout) → 90 % allocation pressure;
    # VM1 is the only target that ends up cooler than the source
    # (anti-ping-pong hysteresis in placement.migrate)
    sim = Simulation(g, caps=caps, params=params,
                     default_template=InstanceTemplate(mips=900.0,
                                                       limit_mips=900.0),
                     vm_mips=np.array([4000.0, 1250.0, 1200.0], np.float32),
                     vm_ram=np.array([4096.0, 4096.0, 4096.0], np.float32))
    res = sim.run()
    assert int(res.state.counters.migrations) > 0
    vms = np.asarray(res.state.instances.vm)
    on = np.asarray(res.state.instances.status) == INST_ON
    assert len(set(vms[on].tolist())) > 1


def test_engine_kernel_path_matches_ref_path():
    g = diamond(mi=400.0)
    caps = SimCaps(n_clients=8, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    base = dict(dt=0.05, n_ticks=300, n_clients=6, spawn_rate=4.0,
                wait_lo=0.5, wait_hi=1.5, seed=11)
    tmpl = InstanceTemplate(mips=4000.0, limit_mips=8000.0)
    r_ref = Simulation(g, caps=caps, params=SimParams(**base),
                       default_template=tmpl).run()
    r_krn = Simulation(g, caps=caps,
                       params=SimParams(use_pallas_tick=True, **base),
                       default_template=tmpl).run()
    np.testing.assert_allclose(
        np.asarray(r_ref.state.requests.response),
        np.asarray(r_krn.state.requests.response), rtol=1e-5, atol=1e-5)
    assert int(r_ref.state.counters.finished) == \
        int(r_krn.state.counters.finished)
