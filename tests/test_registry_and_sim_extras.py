"""File registry (paper Fig 3), SockShop config, scaling/migration
behaviours, build-time bounds validation, and kernel-path equivalence
of the engine tick."""
import json

import jax.numpy as jnp
import numpy as np
import pytest
import yaml

from repro.configs import sockshop
from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        diamond, policies, register, summarize)
from repro.core.app import validate_app
from repro.core.graph import build_graph
from repro.core.types import INST_ON


def test_registry_json_yaml_roundtrip(tmp_path):
    app = tmp_path / "app.json"
    inst = tmp_path / "instances.yaml"
    app.write_text(json.dumps(sockshop.app_spec()))
    inst.write_text(yaml.safe_dump(sockshop.instance_spec(share=800.0)))
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=2048,
                   max_instances=32, n_vms=4, d_max=5, max_replicas=2)
    params = SimParams(dt=0.1, n_ticks=300, n_clients=10, spawn_rate=2.0,
                       wait_lo=2.0, wait_hi=6.0)
    sim = register(str(app), str(inst), caps=caps, params=params)
    assert sim.graph.n_services == 13
    assert sim.graph.n_apis == 5
    # YAML requests.share becomes the instance MIPS
    assert float(np.asarray(sim.app.tmpl_mips)[0]) == 800.0
    rep = summarize(sim, sim.run())
    assert rep.completed_requests > 0


def test_sockshop_graph_structure():
    sim = sockshop.make_sim(n_clients=10, duration_s=30.0)
    g = sim.graph
    # paper Fig 8: POST /orders triggers the deep shipping chain
    orders = g.service_id("orders")
    chains = g.chains_from(orders)
    leaves = {c[-1] for c in chains}
    assert g.service_id("queue-master") in leaves
    assert g.depth >= 3


def test_hs_scales_out_under_load():
    sim = sockshop.make_sim(n_clients=300, duration_s=120.0,
                            scaling_policy=policies.SCALE_HORIZONTAL,
                            share=400.0, hs_util_hi=0.5, hs_util_lo=0.05,
                            util_ema=0.2)
    res = sim.run()
    assert int(res.state.counters.scale_out) > 0
    on = np.asarray(res.state.instances.status) == INST_ON
    assert on.sum() > 13          # replicas were added


def test_vs_raises_mips_under_load():
    sim = sockshop.make_sim(n_clients=300, duration_s=120.0,
                            scaling_policy=policies.SCALE_VERTICAL,
                            share=400.0, vs_util_hi=0.5, vs_util_lo=0.05,
                            util_ema=0.2)
    res = sim.run()
    assert int(res.state.counters.scale_up) > 0
    inst = res.state.instances
    on = np.asarray(inst.status) == INST_ON
    assert (np.asarray(inst.mips)[on] > np.asarray(
        inst.request_mips)[on] + 1).any()


def test_migration_moves_instance():
    g = diamond(mi=500.0)
    caps = SimCaps(n_clients=8, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=3, d_max=2, max_replicas=2)
    params = SimParams(dt=0.05, n_ticks=400, n_clients=8, spawn_rate=8.0,
                       wait_lo=0.5, wait_hi=1.0, migration_enabled=True,
                       mig_vm_util_hi=0.5, scale_interval=20)
    # most-available placement stacks all four instances on VM0 (its free
    # capacity stays the largest throughout) → 90 % allocation pressure;
    # VM1 is the only target that ends up cooler than the source
    # (anti-ping-pong hysteresis in placement.migrate)
    sim = Simulation(g, caps=caps, params=params,
                     default_template=InstanceTemplate(mips=900.0,
                                                       limit_mips=900.0),
                     vm_mips=np.array([4000.0, 1250.0, 1200.0], np.float32),
                     vm_ram=np.array([4096.0, 4096.0, 4096.0], np.float32))
    res = sim.run()
    assert int(res.state.counters.migrations) > 0
    vms = np.asarray(res.state.instances.vm)
    on = np.asarray(res.state.instances.status) == INST_ON
    assert len(set(vms[on].tolist())) > 1


# ---------------------------------------------------------------------------
# Build-time bounds validation (DESIGN.md §8): every id table the jitted
# tick indexes with is range-checked BEFORE tracing, with errors naming
# the offending entry.
# ---------------------------------------------------------------------------

_TINY_CAPS = SimCaps(n_clients=8, max_requests=128, max_cloudlets=128,
                     max_instances=8, n_vms=2, d_max=2, max_replicas=2)
_TINY_PARAMS = SimParams(dt=0.05, n_ticks=4, n_clients=4, spawn_rate=4.0,
                         wait_lo=0.1, wait_hi=0.3)


def _tiny_app():
    sim = Simulation(diamond(mi=200.0), caps=_TINY_CAPS,
                     params=_TINY_PARAMS)
    return sim.app


def test_register_rejects_replica_overflow():
    inst = sockshop.instance_spec(share=800.0)
    inst["instances"][0]["replicas"] = 99
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=2048,
                   max_instances=32, n_vms=4, d_max=5, max_replicas=2)
    with pytest.raises(ValueError, match="declares replicas=99"):
        register(sockshop.app_spec(), inst, caps=caps)


def test_register_rejects_zone_count_mismatch():
    spec = sockshop.app_spec()
    spec["zones"] = [0, 0, 1]          # 3 entries for a 4-host cluster
    caps = SimCaps(n_clients=16, max_requests=1024, max_cloudlets=2048,
                   max_instances=32, n_vms=4, d_max=5, max_replicas=2)
    with pytest.raises(ValueError, match='"zones" lists 3 entries'):
        register(spec, sockshop.instance_spec(share=800.0), caps=caps)


def test_build_rejects_out_of_range_host_zone():
    with pytest.raises(ValueError, match="host_zone"):
        Simulation(diamond(mi=200.0), caps=_TINY_CAPS,
                   params=_TINY_PARAMS,
                   host_zone=np.asarray([0, 7], np.int32))


def test_build_rejects_out_degree_beyond_d_max():
    caps = SimCaps(n_clients=8, max_requests=128, max_cloudlets=128,
                   max_instances=8, n_vms=2, d_max=1, max_replicas=2)
    # diamond's entry fans out to two callees: out-degree 2 > d_max=1
    with pytest.raises(ValueError, match="out-degree"):
        Simulation(diamond(mi=200.0), caps=caps, params=_TINY_PARAMS)


def test_build_accepts_chain_deeper_than_d_max():
    # d_max caps succ-table WIDTH (out-degree), not chain depth: a
    # linear depth-3 chain with d_max=1 is legal (cf. test_critical_path
    # which runs one through the engine) and must pass validation.
    names = ["a", "b", "c", "d"]
    chain = build_graph(names,
                        {"a": ["b"], "b": ["c"], "c": ["d"], "d": []},
                        [("api", "a", 1.0)],
                        {n: 200.0 for n in names},
                        {n: 20.0 for n in names})
    caps = SimCaps(n_clients=8, max_requests=128, max_cloudlets=128,
                   max_instances=8, n_vms=2, d_max=1, max_replicas=2)
    sim = Simulation(chain, caps=caps, params=_TINY_PARAMS)
    validate_app(sim.app, caps)         # no exception


def test_validate_app_rejects_out_of_range_succ_id():
    app = _tiny_app()
    succ = np.asarray(app.succ).copy()
    succ[0, 0] = 99
    with pytest.raises(ValueError, match="succ table ids"):
        validate_app(app._replace(succ=jnp.asarray(succ)), _TINY_CAPS)


def test_validate_app_rejects_call_graph_cycle():
    app = _tiny_app()
    succ = np.asarray(app.succ).copy()
    entry = int(np.asarray(app.api_entry).max())
    succ[entry, 0] = entry              # entry service calls itself
    with pytest.raises(ValueError, match="cycle"):
        validate_app(app._replace(succ=jnp.asarray(succ)), _TINY_CAPS)


def test_validate_app_rejects_undersized_edge_table():
    app = _tiny_app()
    with pytest.raises(ValueError, match="edge tables"):
        validate_app(app._replace(edge_retry=app.edge_retry[:-1]),
                     _TINY_CAPS)


def test_validate_app_rejects_api_without_entry():
    app = _tiny_app()
    with pytest.raises(ValueError, match="no entry service"):
        validate_app(
            app._replace(api_entry=jnp.full_like(app.api_entry, -1)),
            _TINY_CAPS)


def test_engine_kernel_path_matches_ref_path():
    g = diamond(mi=400.0)
    caps = SimCaps(n_clients=8, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    base = dict(dt=0.05, n_ticks=300, n_clients=6, spawn_rate=4.0,
                wait_lo=0.5, wait_hi=1.5, seed=11)
    tmpl = InstanceTemplate(mips=4000.0, limit_mips=8000.0)
    r_ref = Simulation(g, caps=caps, params=SimParams(**base),
                       default_template=tmpl).run()
    r_krn = Simulation(g, caps=caps,
                       params=SimParams(use_pallas_tick=True, **base),
                       default_template=tmpl).run()
    np.testing.assert_allclose(
        np.asarray(r_ref.state.requests.response),
        np.asarray(r_krn.state.requests.response), rtol=1e-5, atol=1e-5)
    assert int(r_ref.state.counters.finished) == \
        int(r_krn.state.counters.finished)
