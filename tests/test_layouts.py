"""Mode-keyed pool column registry (DESIGN.md §2.2): PoolLayout resolution,
named-accessor ↔ raw-column round trips, and the four-combo golden matrix
pinning bit-identity through the layout refactor.

Pinned contracts:

 * `resolve_layout` gives each mode combination exactly the columns its
   enabled tick phases declared — the default run carries no fabric or
   resilience columns;
 * every named accessor reads the same storage its layout index points at,
   in every mode; absent columns raise KeyError on read and are skipped on
   write (mode-agnostic spawn sites);
 * all four `network` × `faults` combos reproduce the golden digests
   captured at the commit BEFORE the registry refactor (PR 3 program) —
   shrinking the pool must not move a single bit;
 * `run_batch` sweeps bit-match solo runs in the fullest mode
   (fabric + chaos);
 * the fused finish kernel (interpret mode) agrees with its jnp oracle
   when fed through the pool-level wrapper under both the minimal and the
   full layout.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        batch_item, diamond, resolve_layout)
from repro.core.types import (CL_F_FIELDS, CL_I_FIELDS, Cloudlets,
                              PoolLayout, zeros_state)
from repro.kernels.cloudlet_step import (cloudlet_finish_pool,
                                         cloudlet_finish_ref)

i32, f32 = jnp.int32, jnp.float32

MODES = [("uniform", "none"), ("uniform", "chaos"),
         ("fabric", "none"), ("fabric", "chaos")]


def _params_for(network: str, faults: str, **over) -> SimParams:
    kw = dict(network=network, faults=faults)
    kw.update(over)
    return SimParams(**kw)


# ---------------------------------------------------------------------------
# layout resolution: phases declare columns, modes enable phases
# ---------------------------------------------------------------------------

def test_default_layout_is_minimal():
    L = resolve_layout(SimParams())
    assert L.i_fields == ("status", "req", "service", "inst",
                          "wait_ticks", "depth")
    assert L.f_fields == ("length", "rem", "arrival", "start")


def test_mode_columns_appear_only_with_their_phase():
    for network, faults in MODES:
        L = resolve_layout(_params_for(network, faults))
        assert ("src_host" in L) == (network == "fabric")
        assert ("rem_bytes" in L) == (network == "fabric")
        for col in ("attempt", "edge", "src_inst"):
            assert (col in L) == (faults == "chaos"), (network, faults, col)
    # egress shaping is a Transit sub-feature: src_inst joins the layout
    # in fabric mode even without chaos
    L = resolve_layout(_params_for("fabric", "none", egress_shaping=True))
    assert "src_inst" in L and "attempt" not in L
    # ... but shaping outside fabric mode changes nothing (the clamp only
    # exists inside the Transit phase)
    assert resolve_layout(_params_for("uniform", "none",
                                      egress_shaping=True)) == \
        resolve_layout(_params_for("uniform", "none"))


def test_layout_storage_order_follows_registry():
    """Column storage order is the registry order restricted to the active
    set, so the full layout is exactly the pre-refactor fixed layout."""
    full = resolve_layout(_params_for("fabric", "chaos",
                                      egress_shaping=True))
    assert full.i_fields == CL_I_FIELDS
    assert full.f_fields == CL_F_FIELDS
    for network, faults in MODES:
        L = resolve_layout(_params_for(network, faults))
        assert L.i_fields == tuple(n for n in CL_I_FIELDS if n in L)
        assert L.f_fields == tuple(n for n in CL_F_FIELDS if n in L)


@pytest.mark.parametrize("network,faults", MODES)
def test_accessor_roundtrip_every_column_every_mode(network, faults):
    """Named accessor ↔ raw column round trip: every registered column of
    every mode's layout reads exactly its storage slice; absent columns
    raise on read and are skipped on write."""
    params = _params_for(network, faults)
    caps = SimCaps(n_clients=4, max_requests=16, max_cloudlets=32,
                   max_instances=4, n_vms=2, d_max=2)
    state = zeros_state(caps, params, jax.random.PRNGKey(0), n_services=3)
    cl = state.cloudlets
    L = cl.layout
    r = np.random.default_rng(7)
    cl = cl.replace(
        ints=jnp.asarray(r.integers(-2, 9, cl.ints.shape), i32),
        flts=jnp.asarray(r.normal(size=cl.flts.shape), f32))
    for name in L.i_fields:
        np.testing.assert_array_equal(np.asarray(getattr(cl, name)),
                                      np.asarray(cl.ints[:, L.i(name)]))
    for name in L.f_fields:
        np.testing.assert_array_equal(np.asarray(getattr(cl, name)),
                                      np.asarray(cl.flts[:, L.f(name)]))
    for name in CL_I_FIELDS + CL_F_FIELDS:
        if name in L:
            continue
        with pytest.raises(KeyError, match=name):
            getattr(cl, name)
        # writes of absent-but-registered columns are skipped in place
        same = cl.with_cols(**{name: 0})
        np.testing.assert_array_equal(np.asarray(same.ints),
                                      np.asarray(cl.ints))
        np.testing.assert_array_equal(np.asarray(same.flts),
                                      np.asarray(cl.flts))
    with pytest.raises(TypeError, match="unknown"):
        cl.with_cols(not_a_column=1)


def test_layout_is_static_aux_data():
    """The layout rides pytrees as aux data: tree_map preserves it and two
    states of the same mode share one (hashable) layout object."""
    params = _params_for("fabric", "chaos")
    caps = SimCaps(n_clients=4, max_requests=16, max_cloudlets=32,
                   max_instances=4, n_vms=2, d_max=2)
    state = zeros_state(caps, params, jax.random.PRNGKey(0))
    mapped = jax.tree_util.tree_map(lambda x: x, state)
    assert mapped.cloudlets.layout is state.cloudlets.layout
    assert isinstance(state.cloudlets.layout, PoolLayout)
    assert hash(resolve_layout(params)) == hash(state.cloudlets.layout)


# ---------------------------------------------------------------------------
# golden matrix: all four mode combos bit-identical through the refactor
# ---------------------------------------------------------------------------

from test_network import _digest_f32  # one digest scheme for all goldens


def matrix_sim(network: str, faults: str, **overrides):
    """The golden-matrix scenario; ``overrides`` lets observation-only
    knobs (telemetry, tests/test_obs.py) ride the same pinned digests."""
    caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=4, d_max=2, max_replicas=2)
    kw = dict(dt=0.05, n_ticks=300, n_clients=12, spawn_rate=5.0,
              wait_lo=0.5, wait_hi=1.5, seed=3,
              network=network, faults=faults)
    if network == "fabric":
        kw.update(nic_egress_mbps=50.0, nic_ingress_mbps=50.0)
    else:
        kw.update(net_latency_s=0.05)
    if faults == "chaos":
        kw.update(host_mtbf_s=20.0, host_mttr_s=5.0, retry_timeout_s=3.0,
                  retry_budget=2, inst_kill_rate=0.01)
    kw.update(overrides)
    params = SimParams(**kw)
    tmpl = InstanceTemplate(mips=8000.0, limit_mips=16000.0, replicas=2)
    return Simulation(diamond(mi=400.0), caps=caps, params=params,
                      default_template=tmpl,
                      vm_mips=np.full(4, 64000.0, np.float32))


# Captured at commit 50ee839 (PR 3, fixed 10-int/5-float layout) by running
# matrix_sim for every combo and digesting the outputs — the layout
# refactor must keep every mode combo bit-identical.
MATRIX_GOLDEN = {
    ("uniform", "none"): dict(resp=1306795296637, completed=157,
                              spawned=794, finished=789,
                              used_mips=353555764098, transits=0,
                              failed_attempts=0, retries=0),
    ("uniform", "chaos"): dict(resp=1530248430121, completed=54,
                               spawned=1002, finished=296,
                               used_mips=346459279954, transits=0,
                               failed_attempts=517, retries=388),
    ("fabric", "none"): dict(resp=1292572014442, completed=163,
                             spawned=830, finished=822,
                             used_mips=355715694613, transits=606,
                             failed_attempts=0, retries=0),
    ("fabric", "chaos"): dict(resp=1477918938445, completed=78,
                              spawned=803, finished=626,
                              used_mips=348111040792, transits=289,
                              failed_attempts=80, retries=79),
}


@pytest.mark.parametrize("network,faults", MODES)
def test_mode_matrix_bit_identical_golden(network, faults):
    res = matrix_sim(network, faults).run()
    st = res.state
    want = MATRIX_GOLDEN[(network, faults)]
    assert _digest_f32(st.requests.response) == want["resp"]
    assert int(st.counters.completed) == want["completed"]
    assert int(st.counters.spawned) == want["spawned"]
    assert int(st.counters.finished) == want["finished"]
    assert _digest_f32(res.trace.used_mips) == want["used_mips"]
    assert int(st.net.transits) == want["transits"]
    assert int(st.fstats.failed_attempts) == want["failed_attempts"]
    assert int(st.fstats.retries) == want["retries"]


def test_fabric_chaos_sweep_bitmatches_solo():
    """run_batch under the fullest layout (fabric + chaos): every sweep
    point still bit-matches its solo run after the refactor."""
    sim = matrix_sim("fabric", "chaos")
    base = sim.params
    sweeps = [dataclasses.replace(base, host_mtbf_s=m, nic_egress_mbps=b,
                                  nic_ingress_mbps=b)
              for m, b in ((60.0, 50.0), (15.0, 10.0))]
    res_b = sim.run_batch(sweeps)
    for b, p in enumerate(sweeps):
        solo = Simulation(
            sim.graph, caps=sim.caps, params=p,
            default_template=InstanceTemplate(mips=8000.0,
                                              limit_mips=16000.0,
                                              replicas=2),
            vm_mips=np.full(4, 64000.0, np.float32)).run()
        item = batch_item(res_b, b)
        np.testing.assert_array_equal(
            np.asarray(item.state.requests.response),
            np.asarray(solo.state.requests.response))
        assert int(item.state.net.transits) == int(solo.state.net.transits)
        assert int(item.state.fstats.failed_attempts) == \
            int(solo.state.fstats.failed_attempts)


# ---------------------------------------------------------------------------
# fused finish kernel through the pool wrapper: minimal vs full layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lname,network,faults", [
    ("minimal", "uniform", "none"),
    ("full", "fabric", "chaos"),
])
def test_finish_kernel_pool_wrapper_both_layouts(lname, network, faults):
    """cloudlet_finish_pool slices the kernel inputs through the layout:
    the interpret-mode kernel must agree with the jnp oracle fed the same
    columns, for both the minimal and the full layout."""
    layout = resolve_layout(_params_for(network, faults))
    C, I, R = 256, 8, 64
    r = np.random.default_rng(11)
    ints = np.zeros((C, len(layout.i_fields)), np.int32)
    flts = np.zeros((C, len(layout.f_fields)), np.float32)
    cols = dict(
        status=r.choice([0, 1, 2], size=C, p=[0.3, 0.2, 0.5]),
        req=r.integers(-1, R, C), inst=r.integers(-1, I, C),
        depth=r.integers(0, 6, C),
        rem=r.uniform(0.1, 500.0, C), arrival=r.uniform(0.0, 10.0, C),
        start=r.uniform(-1.0, 12.0, C))
    for n, v in cols.items():
        if n in layout.i_fields:
            ints[:, layout.i(n)] = v
        else:
            flts[:, layout.f(n)] = v
    cl = Cloudlets(jnp.asarray(ints), jnp.asarray(flts), layout)
    rate = jnp.asarray(r.uniform(0.0, 300.0, C), f32)
    reqf = jnp.asarray(r.uniform(0.0, 12.0, R), f32)
    reqc = jnp.asarray(r.integers(0, 4, R), i32)
    reqo = jnp.asarray(r.integers(0, 8, R), i32)
    time, dt = 12.5, 0.25
    got = cloudlet_finish_pool(cl, rate, time, dt, reqf, reqc, reqo,
                               n_inst=I, use_pallas=True, interpret=True)
    want = cloudlet_finish_ref(
        jnp.asarray(cols["status"], i32), jnp.asarray(cols["rem"], f32),
        jnp.asarray(cols["inst"], i32), jnp.asarray(cols["req"], i32),
        jnp.asarray(cols["arrival"], f32), jnp.asarray(cols["start"], f32),
        jnp.asarray(cols["depth"], i32), rate, time, dt,
        reqf, reqc, reqo, n_inst=I)
    for name in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            err_msg=f"{lname}: {name}")
