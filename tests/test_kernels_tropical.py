"""Pallas tropical-matmul kernel vs pure-jnp oracle (interpret mode).

Sweeps shapes (aligned + ragged via the padding wrapper) and dtypes, as
required for every kernel in this repo.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tropical import ref
from repro.kernels.tropical.kernel import tropical_matmul_pallas
from repro.kernels.tropical.ops import tropical_closure, tropical_matmul


def _rand(rng, shape, dtype, density=0.7):
    x = rng.normal(size=shape).astype(dtype) * 3.0
    mask = rng.random(size=shape) < density
    return np.where(mask, x, -np.inf).astype(dtype)


@pytest.mark.parametrize("dtype", [np.float32])
@pytest.mark.parametrize("B,M,K,N,bm,bn,bk", [
    (1, 128, 128, 128, 128, 128, 128),
    (2, 256, 128, 128, 128, 128, 128),
    (1, 128, 256, 384, 128, 128, 128),
    (3, 256, 256, 256, 128, 128, 64),
    (1, 128, 128, 128, 64, 64, 32),
])
def test_kernel_matches_ref_aligned(B, M, K, N, bm, bn, bk, dtype, rng):
    x = _rand(rng, (B, M, K), dtype)
    a = _rand(rng, (B, K, N), dtype)
    got = tropical_matmul_pallas(jnp.asarray(x), jnp.asarray(a),
                                 bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.tropical_matmul(jnp.asarray(x), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("M,K,N", [(5, 7, 3), (130, 64, 257), (1, 1, 1),
                                   (127, 129, 128)])
def test_ops_padding_ragged_shapes(M, K, N, rng):
    x = _rand(rng, (M, K), np.float32)
    a = _rand(rng, (K, N), np.float32)
    got = tropical_matmul(jnp.asarray(x), jnp.asarray(a), use_pallas=True,
                          interpret=True)
    want = ref.tropical_matmul(jnp.asarray(x), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_closure_longest_path_vs_numpy_dp(rng):
    n = 24
    # random DAG (upper triangular), weights on edges
    w = rng.uniform(0.1, 2.0, size=(n, n)).astype(np.float32)
    mask = np.triu(rng.random((n, n)) < 0.3, k=1)
    a = np.where(mask, w, -np.inf).astype(np.float32)
    got = np.asarray(tropical_closure(jnp.asarray(a)))
    # Floyd-Warshall-style DP oracle (longest path, DAG-safe)
    dp = np.where(np.eye(n, dtype=bool), 0.0, -np.inf)
    dp = np.maximum(dp, a)
    for k in range(n):
        dp = np.maximum(dp, dp[:, k:k + 1] + dp[k:k + 1, :])
    np.testing.assert_allclose(got, dp, rtol=1e-5)


def test_closure_interpret_kernel_path(rng):
    n = 12
    mask = np.triu(rng.random((n, n)) < 0.4, k=1)
    a = np.where(mask, rng.uniform(0.5, 1.5, (n, n)),
                 -np.inf).astype(np.float32)
    got = tropical_closure(jnp.asarray(a), use_pallas=True, interpret=True)
    want = tropical_closure(jnp.asarray(a), use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_identity_is_neutral(rng):
    x = _rand(rng, (1, 128, 128), np.float32)
    eye = ref.tropical_identity(128)[None]
    got = tropical_matmul_pallas(jnp.asarray(x), jnp.asarray(eye),
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got)[0], x[0], rtol=1e-6)
