"""SSD kernel chain: sequential oracle == chunked ref == Pallas kernel
(interpret), across shapes/dtypes; decode step consistency with the scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import (ssd, ssd_chunked_ref,
                                    ssd_decode_step, ssd_ref)


def _mk(rng, B, T, H, P, G, N, dtype=np.float32):
    x = rng.normal(size=(B, T, H, P)).astype(dtype)
    dt = rng.uniform(0.05, 0.3, size=(B, T, H)).astype(dtype)
    A = -rng.uniform(0.5, 2.0, size=(H,)).astype(dtype)
    Bm = rng.normal(size=(B, T, G, N)).astype(dtype) / np.sqrt(N)
    Cm = rng.normal(size=(B, T, G, N)).astype(dtype) / np.sqrt(N)
    D = rng.normal(size=(H,)).astype(dtype)
    return tuple(jnp.asarray(a) for a in (x, dt, A, Bm, Cm, D))


@pytest.mark.parametrize("B,T,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 32, 32),
    (1, 96, 3, 8, 3, 64, 32),      # H == G (no grouping)
])
def test_chunked_ref_matches_sequential(B, T, H, P, G, N, chunk, rng):
    x, dt, A, Bm, Cm, D = _mk(rng, B, T, H, P, G, N)
    want = ssd_ref(x, dt, A, Bm, Cm, D)
    got = ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,T,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 16, 32),
    (2, 128, 4, 32, 2, 32, 64),
])
def test_kernel_matches_sequential(B, T, H, P, G, N, chunk, rng):
    x, dt, A, Bm, Cm, D = _mk(rng, B, T, H, P, G, N)
    want = ssd_ref(x, dt, A, Bm, Cm, D)
    got = ssd(x, dt, A, Bm, Cm, D, chunk=chunk, impl="kernel",
              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_kernel_ragged_T_pads(rng):
    x, dt, A, Bm, Cm, D = _mk(rng, 1, 50, 2, 8, 1, 16)
    want = ssd_ref(x, dt, A, Bm, Cm, D)
    got = ssd(x, dt, A, Bm, Cm, D, chunk=32, impl="kernel", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_bf16_inputs(rng):
    x, dt, A, Bm, Cm, D = _mk(rng, 1, 64, 2, 16, 1, 16)
    xb = x.astype(jnp.bfloat16)
    got = ssd(xb, dt, A, Bm, Cm, D, chunk=32, impl="kernel", interpret=True)
    want = ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_decode_step_matches_scan_suffix(rng):
    """Running T decode steps must equal the parallel scan output."""
    B, T, H, P, G, N = 1, 16, 2, 8, 1, 8
    x, dt, A, Bm, Cm, D = _mk(rng, B, T, H, P, G, N)
    want = ssd_ref(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((B, H, N, P), jnp.float32)
    outs = []
    for t in range(T):
        h, y = ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        outs.append(y)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_gradients_flow_through_kernel(rng):
    x, dt, A, Bm, Cm, D = _mk(rng, 1, 64, 2, 8, 1, 8)

    def loss(x, Bm, Cm):
        return jnp.sum(ssd(x, dt, A, Bm, Cm, D, chunk=32, impl="kernel",
                           interpret=True) ** 2)

    def loss_ref(x, Bm, Cm):
        return jnp.sum(ssd_ref(x, dt, A, Bm, Cm, D) ** 2)

    gk = jax.grad(loss, argnums=(0, 1, 2))(x, Bm, Cm)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, Bm, Cm)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
