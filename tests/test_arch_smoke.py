"""Per-architecture smoke tests: reduced same-family config, one forward +
one gradient step + one decode step on CPU; asserts shapes and no NaNs.

The FULL configs are exercised only by the dry-run (ShapeDtypeStructs,
no allocation) — see launch/dryrun.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

B, T = 2, 32


def _batch(cfg, rng):
    if cfg.family == "encdec":
        return {
            "frames": jnp.asarray(
                rng.normal(size=(B, cfg.n_frames, cfg.d_model)),
                jnp.bfloat16),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "embeds": jnp.asarray(rng.normal(size=(B, T, cfg.d_model)),
                                  jnp.bfloat16),
            "positions": jnp.asarray(
                np.broadcast_to(np.arange(T, dtype=np.int32), (3, B, T))),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, batch)))(params)
    assert np.isfinite(float(loss)), arch
    # plausible CE at init: close to ln(vocab)
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)
    assert any(float(jnp.abs(l.astype(jnp.float32)).max()) > 0
               for l in leaves), "all-zero gradients"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    state = model.init_decode_state(B, 16)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, state2 = jax.jit(model.decode_step)(params, tokens, state)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(state2.pos) == 1
    # a second step must also be well-formed (state threading works)
    logits3, state3 = jax.jit(model.decode_step)(params, tokens, state2)
    assert int(state3.pos) == 2
    assert np.isfinite(np.asarray(logits3)).all()


def test_full_configs_have_exact_assigned_dims():
    """The full configs must match the assignment verbatim."""
    expect = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
                cfg.d_ff if cfg.moe is None or cfg.family == "hybrid"
                else cfg.moe.d_expert, cfg.vocab) == (L, d, h, kv, ff, v), arch
    # MoE structure checks from the assignment
    q2 = get_config("qwen2-moe-a2.7b").moe
    assert (q2.n_experts, q2.top_k, q2.n_shared) == (60, 4, 4)
    q3 = get_config("qwen3-moe-30b-a3b").moe
    assert (q3.n_experts, q3.top_k) == (128, 8)
    jb = get_config("jamba-1.5-large-398b")
    assert (jb.moe.n_experts, jb.moe.top_k, jb.attn_period) == (16, 2, 8)
    assert get_config("mamba2-130m").mamba.d_state == 128
