"""Request Generator validation against the paper's closed forms (Eqs 1–4).

This is the test-suite version of §6.2 / Fig 9: the simulated client count,
QPS and cumulative request curves must match N(t), λ(t), R(t).
"""
import numpy as np
import pytest

from repro.core import (SimCaps, SimParams, Simulation, linear_chain,
                        qps_analytic, total_requests_analytic)


def _run_generator(n_clients, spawn_rate, p, n_ticks=3000, dt=0.1, seed=0):
    g = linear_chain(1, mi=1.0)  # trivial service so requests drain instantly
    caps = SimCaps(n_clients=n_clients, max_requests=200_000,
                   max_cloudlets=4096, max_instances=4, n_vms=2,
                   d_max=1, max_replicas=1)
    params = SimParams(dt=dt, n_ticks=n_ticks, n_clients=n_clients,
                       spawn_rate=spawn_rate, wait_lo=p[0], wait_hi=p[1],
                       seed=seed)
    sim = Simulation(g, caps=caps, params=params)
    res = sim.run()
    tr = res.trace_np()
    return sim, res, tr


@pytest.mark.parametrize("n_clients,v,p", [
    (100, 1.0, (4.0, 6.0)),
    (50, 2.0, (2.0, 6.0)),
])
def test_client_ramp_matches_eq1(n_clients, v, p):
    _, _, tr = _run_generator(n_clients, v, p)
    t = np.arange(len(tr["active_clients"])) * 0.1
    expect = np.minimum(n_clients, np.floor(v * t) + 1)
    got = tr["active_clients"]
    # Eq 1 with the +1 discretization of "clients activate at ramp rate v"
    assert np.abs(got - expect).max() <= 1


def test_qps_converges_to_eq3():
    n_clients, v, p = 100, 1.0, (4.0, 6.0)
    _, _, tr = _run_generator(n_clients, v, p, n_ticks=6000)
    qps = tr["generated"] / 0.1
    # steady state after ramp (Nc/v = 100 s → tick 1000); average over tail
    steady = qps[2000:].mean()
    expect = qps_analytic(np.array([1e9]), SimParams(
        n_clients=n_clients, spawn_rate=v, wait_lo=p[0], wait_hi=p[1]))[0]
    # paper Fig 9b: oscillatory convergence around 2Nc/(p0+p1) = 20
    assert abs(steady - expect) / expect < 0.08, (steady, expect)


def test_total_requests_piecewise_eq4():
    n_clients, v, p = 80, 1.0, (4.0, 6.0)
    sim, res, tr = _run_generator(n_clients, v, p, n_ticks=4000)
    t = (np.arange(len(tr["generated"])) + 1) * 0.1
    total = np.cumsum(tr["generated"])
    # Eq 4 models the renewal process; each client additionally fires
    # immediately on activation (Locust semantics), adding +N(t).
    expect = total_requests_analytic(t, sim.params) + \
        np.minimum(n_clients, np.floor(v * t) + 1)
    tail = t > 30.0
    rel = np.abs(total[tail] - expect[tail]) / np.maximum(expect[tail], 1.0)
    assert rel.mean() < 0.05, rel.mean()
    assert rel.max() < 0.15
    # curvature check: ramp segment superlinear, steady segment linear
    ramp_end = int(n_clients / v / 0.1)
    mid = total[ramp_end // 2]
    assert mid < expect[ramp_end] * 0.65  # t²/ramp² = 0.25 ≪ 0.65 at halfway


def test_num_limit_respected():
    g = linear_chain(1, mi=1.0)
    caps = SimCaps(n_clients=32, max_requests=4096, max_cloudlets=1024,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=1)
    params = SimParams(dt=0.1, n_ticks=500, n_clients=32, spawn_rate=100.0,
                       wait_lo=0.2, wait_hi=0.4, num_limit=100)
    sim = Simulation(g, caps=caps, params=params)
    res = sim.run()
    assert int(res.state.requests.count) == 100
