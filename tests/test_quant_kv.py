"""int8 KV cache (§Perf change #3): decode outputs must track the bf16
cache closely, and multi-step state threading must stay consistent."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def test_int8_kv_decode_matches_bf16():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    cfg_q = dc.replace(cfg, kv_dtype="int8")
    model = build_model(cfg)
    model_q = build_model(cfg_q)
    params = model.init_params(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    B, S = 2, 16
    st = model.init_decode_state(B, S)
    st_q = model_q.init_decode_state(B, S)
    assert st_q.layers.k.dtype == jnp.int8
    step = jax.jit(model.decode_step)
    step_q = jax.jit(model_q.decode_step)
    # Quantization perturbs next-token probabilities by up to QTOL; exact
    # argmax equality is only meaningful when the bf16 winner leads by
    # more than that (random-init logits are near-flat, so unmargined
    # argmax flips on ~1e-3 ties — seen at steps 0 and 3 of this seed).
    QTOL = 1e-2
    for t in range(6):
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        lg, st = step(params, tok, st)
        lg_q, st_q = step_q(params, tok, st_q)
        a = np.asarray(jax.nn.softmax(lg[:, 0], -1))
        b = np.asarray(jax.nn.softmax(lg_q[:, 0], -1))
        # distributions agree closely
        assert np.abs(a - b).max() < QTOL, t
        srt = np.sort(a, axis=-1)
        decisive = (srt[:, -1] - srt[:, -2]) > 2 * QTOL
        for i in range(B):
            if decisive[i]:
                # a clear winner must survive quantization exactly
                assert a[i].argmax() == b[i].argmax(), (t, i)
            else:
                # near-tie: the bf16 winner must stay near-maximal
                assert b[i, a[i].argmax()] >= b[i].max() - 2 * QTOL, (t, i)
    assert int(st_q.pos) == 6


def test_int8_cache_is_half_the_bytes():
    cfg = get_config("granite-20b")
    model = build_model(cfg)
    model_q = build_model(dc.replace(cfg, kv_dtype="int8"))
    bf = model.init_decode_state(4, 128, abstract_only=True)
    q = model_q.init_decode_state(4, 128, abstract_only=True)

    def nbytes(tree):
        return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(tree))

    ratio = nbytes(q.layers) / nbytes(bf.layers)
    assert 0.5 < ratio < 0.54          # 1 byte + scale overhead vs 2 bytes
