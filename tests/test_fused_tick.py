"""Fused one-pass tick vs per-field seed references.

Three contracts of the single-pass tick refactor are pinned here:

 * the stacked-scatter spawn writer (pool.scatter_pool) bit-matches a
   per-field scatter reference on randomized pools,
 * the extended finish-reduction kernel (interpret mode) bit-matches the
   single-pass jnp reference, which itself bit-matches a per-field
   seed-style reference (separate _segsum per statistic),
 * prefix-sum segment_rank equals the retired sort-based ranking,
 * Simulation.run_batch equals N independent runs, point for point.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (InstanceTemplate, SimCaps, SimParams, Simulation,
                        batch_item, diamond, linear_chain, resolve_layout)
from repro.core.pool import (assign_free_slots, scatter_pool, segment_rank,
                             segment_rank_sorted)
from repro.core.types import Cloudlets
from repro.kernels.cloudlet_step import cloudlet_finish_ref
from repro.kernels.cloudlet_step.kernel import cloudlet_finish_pallas

i32, f32 = jnp.int32, jnp.float32

# Mode-keyed layouts (DESIGN.md §2.2): the spawn writer must behave
# identically on the minimal default layout and the everything-enabled one.
LAYOUTS = {
    "minimal": resolve_layout(SimParams()),
    "full": resolve_layout(SimParams(network="fabric", faults="chaos",
                                     egress_shaping=True)),
}


# ---------------------------------------------------------------------------
# stacked-scatter spawn path vs per-field reference
# ---------------------------------------------------------------------------

def _per_field_spawn(ints, flts, asg, int_cols, flt_cols):
    """Seed-style reference: one scatter per field column."""
    C = ints.shape[0]
    K = asg.dst.shape[0]
    dst = jnp.where(asg.live, asg.dst, C)
    for j, col in enumerate(int_cols):
        v = jnp.broadcast_to(jnp.asarray(col, ints.dtype), (K,))
        ints = ints.at[dst, j].set(v, mode="drop")
    for j, col in enumerate(flt_cols):
        v = jnp.broadcast_to(jnp.asarray(col, flts.dtype), (K,))
        flts = flts.at[dst, j].set(v, mode="drop")
    return ints, flts


@pytest.mark.parametrize("lname", sorted(LAYOUTS))
@pytest.mark.parametrize("C,M,seed", [(64, 16, 0), (256, 300, 1),
                                      (1024, 512, 2), (33, 7, 3)])
def test_scatter_pool_bitmatches_per_field(C, M, seed, lname, rng):
    layout = LAYOUTS[lname]
    r = np.random.default_rng(seed)
    ints = jnp.asarray(r.integers(-1, 5, size=(C, len(layout.i_fields))),
                       i32)
    flts = jnp.asarray(r.normal(size=(C, len(layout.f_fields))), f32)
    cl = Cloudlets(ints, flts, layout)
    free = jnp.asarray(r.random(C) < 0.5)
    valid = jnp.asarray(r.random(M) < 0.7)
    asg = assign_free_slots(free, valid)
    K = asg.dst.shape[0]
    length = jnp.asarray(r.uniform(1, 100, K), f32)
    # the full vocabulary is always passed — columns outside the layout
    # must be skipped, so spawn sites stay mode-agnostic
    cols = dict(
        status=1, req=jnp.asarray(r.integers(0, 99, K), i32),
        service=jnp.asarray(r.integers(0, 9, K), i32), inst=-1,
        wait_ticks=0, depth=jnp.asarray(r.integers(0, 4, K), i32),
        src_host=jnp.asarray(r.integers(-1, 4, K), i32),
        attempt=jnp.asarray(r.integers(0, 3, K), i32),
        edge=jnp.asarray(r.integers(-1, 12, K), i32),
        src_inst=jnp.asarray(r.integers(-1, 6, K), i32),
        length=length, rem=length,
        arrival=jnp.asarray(r.uniform(0, 10, K), f32), start=-1.0,
        rem_bytes=jnp.asarray(r.uniform(0, 1, K), f32))
    int_cols = tuple(cols[n] for n in layout.i_fields)
    flt_cols = tuple(cols[n] for n in layout.f_fields)

    got = scatter_pool(cl, asg, **cols)
    wi, wf = _per_field_spawn(ints, flts, asg, int_cols, flt_cols)
    np.testing.assert_array_equal(np.asarray(got.ints), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(got.flts), np.asarray(wf))
    assert got.layout is layout
    with pytest.raises(TypeError, match="missing"):
        scatter_pool(cl, asg, **{k: v for k, v in cols.items()
                                 if k != "rem"})
    with pytest.raises(TypeError, match="unknown"):
        scatter_pool(cl, asg, bogus_col=0, **cols)


# ---------------------------------------------------------------------------
# extended finish-reduction kernel vs single-pass jnp reference
# ---------------------------------------------------------------------------

def _mk_finish_pool(r, C, I, R):
    status = jnp.asarray(r.choice([0, 1, 2], size=C, p=[0.3, 0.2, 0.5]), i32)
    rem = jnp.asarray(r.uniform(0.1, 500.0, C), f32)
    inst = np.asarray(r.integers(0, I, C), np.int32)
    inst[r.random(C) < 0.05] = -1
    req = np.asarray(r.integers(0, R, C), np.int32)
    req[r.random(C) < 0.05] = -1
    arrival = jnp.asarray(r.uniform(0.0, 10.0, C), f32)
    start = np.asarray(r.uniform(0.0, 12.0, C), np.float32)
    start[r.random(C) < 0.3] = -1.0
    depth = jnp.asarray(r.integers(0, 6, C), i32)
    rate = jnp.asarray(r.uniform(0.0, 300.0, C), f32)
    req_finish = jnp.asarray(r.uniform(0.0, 12.0, R), f32)
    req_crit = jnp.asarray(r.integers(0, 4, R), i32)
    req_out = jnp.asarray(r.integers(0, 8, R), i32)
    return (status, rem, jnp.asarray(inst), jnp.asarray(req), arrival,
            jnp.asarray(start), depth, rate, req_finish, req_crit, req_out)


def _per_field_finish_reference(args, I):
    """Seed-style reference: one _segsum-style scatter per statistic."""
    (status, rem, inst, req, arrival, start, depth, rate,
     req_finish, req_crit, req_out) = args
    time, dt = 12.5, 0.25
    R = req_finish.shape[0]
    execm = status == 2
    prog = rate * dt
    fin = execm & (rem <= prog) & (rate > 0)
    tfin = jnp.where(fin, jnp.clip(time + rem / jnp.maximum(rate, 1e-9),
                                   time, time + dt), 0.0)
    consumed = jnp.where(execm, jnp.minimum(prog, rem), 0.0)
    new_rem = jnp.where(execm, jnp.maximum(rem - prog, 0.0), rem)

    def seg(data, idx, n):
        return jnp.zeros((n,), data.dtype).at[idx].add(data, mode="drop")

    iidx = jnp.where(execm & (inst >= 0), inst, I)
    started = jnp.maximum(start, arrival)
    cols = [consumed / dt, fin.astype(f32),
            jnp.where(fin, tfin - arrival, 0.0),
            jnp.where(fin, tfin - started, 0.0),
            jnp.where(fin, started - arrival, 0.0)]
    inst_acc = jnp.stack([seg(c, iidx, I + 1) for c in cols], axis=1)
    ridx = jnp.where(fin & (req >= 0), req, R)
    return (new_rem, fin, tfin, consumed, inst_acc,
            req_finish.at[ridx].max(tfin, mode="drop"),
            req_crit.at[ridx].max(depth + 1, mode="drop"),
            req_out.at[ridx].add(-fin.astype(i32), mode="drop"))


@pytest.mark.parametrize("C,I,R,bc", [
    (256, 8, 32, 64),
    (1000, 33, 2000, 256),     # C not a bc multiple → padding path; R > C
    (512, 16, 64, 512),
])
def test_finish_kernel_matches_refs(C, I, R, bc):
    r = np.random.default_rng(C + I)
    args = _mk_finish_pool(r, C, I, R)
    time, dt = 12.5, 0.25
    got = cloudlet_finish_pallas(*args[:8], time, dt, *args[8:],
                                 n_inst=I, bc=bc, interpret=True)
    ref = cloudlet_finish_ref(*args[:8], time, dt, *args[8:], n_inst=I)
    want = _per_field_finish_reference(args, I)
    names = ("new_rem", "fin", "tfin", "consumed", "inst_acc",
             "req_finish", "req_crit", "req_out")
    for name, g, rf, w in zip(names, got, ref, want):
        # kernel vs single-pass jnp reference: bit-exact
        np.testing.assert_array_equal(np.asarray(g), np.asarray(rf),
                                      err_msg=f"kernel vs ref: {name}")
        # single-pass reference vs per-field seed reference: bit-exact
        np.testing.assert_array_equal(np.asarray(rf), np.asarray(w),
                                      err_msg=f"ref vs per-field: {name}")


# ---------------------------------------------------------------------------
# prefix-sum segment rank vs the retired sort-based ranking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,n_seg,block,seed", [
    (1, 1, 128, 0), (48, 8, 16, 1), (300, 5, 128, 2),
    (1024, 64, 128, 3), (777, 3, 256, 4),
])
def test_segment_rank_matches_sorted(n, n_seg, block, seed):
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, n_seg, n), i32)
    mask = jnp.asarray(r.random(n) < 0.6)
    got = segment_rank(keys, mask, n_seg, block=block)
    want = segment_rank_sorted(keys, mask, n_seg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# run_batch ≡ N × run
# ---------------------------------------------------------------------------

def test_run_batch_matches_solo_runs():
    g = diamond(mi=400.0)
    caps = SimCaps(n_clients=16, max_requests=512, max_cloudlets=512,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    base = SimParams(dt=0.05, n_ticks=200, n_clients=10, spawn_rate=5.0,
                     wait_lo=0.5, wait_hi=1.5, seed=123)
    sim = Simulation(g, caps=caps, params=base)
    sweeps = [dataclasses.replace(base, n_clients=nc, hs_util_hi=th)
              for nc, th in [(4, 0.8), (8, 0.5), (10, 0.8), (16, 0.3)]]
    res_b = sim.run_batch(sweeps)
    for b, p in enumerate(sweeps):
        solo = Simulation(g, caps=caps, params=p).run()
        item = batch_item(res_b, b)
        np.testing.assert_array_equal(
            np.asarray(item.state.requests.response),
            np.asarray(solo.state.requests.response))
        assert int(item.state.counters.spawned) == \
            int(solo.state.counters.spawned)
        assert int(item.state.counters.completed) == \
            int(solo.state.counters.completed)
        np.testing.assert_array_equal(np.asarray(item.trace.completed),
                                      np.asarray(solo.trace.completed))


def test_run_batch_hoisted_scaling_matches_solo_runs():
    """Scaling-enabled sweep: exercises the hoisted-cond batch program
    (scan outside, vmap inside, real lax.cond on the shared cadence)."""
    g = diamond(mi=300.0)
    caps = SimCaps(n_clients=32, max_requests=1024, max_cloudlets=512,
                   max_instances=16, n_vms=4, d_max=2, max_replicas=4)
    base = SimParams(dt=0.05, n_ticks=250, n_clients=20, spawn_rate=10.0,
                     wait_lo=0.5, wait_hi=1.5, scaling_policy=1,
                     scale_interval=40, seed=7)
    tmpl = InstanceTemplate(mips=1000.0, limit_mips=4000.0)
    sim = Simulation(g, caps=caps, params=base, default_template=tmpl)
    sweeps = [dataclasses.replace(base, n_clients=nc, hs_util_hi=th)
              for nc, th in [(8, 0.6), (20, 0.4), (32, 0.2)]]
    res_b = sim.run_batch(sweeps)
    any_scaled = False
    for b, p in enumerate(sweeps):
        solo = Simulation(g, caps=caps, params=p,
                          default_template=tmpl).run()
        item = batch_item(res_b, b)
        np.testing.assert_array_equal(
            np.asarray(item.state.requests.response),
            np.asarray(solo.state.requests.response))
        assert int(item.state.counters.scale_out) == \
            int(solo.state.counters.scale_out)
        any_scaled |= int(solo.state.counters.scale_out) > 0
    assert any_scaled  # the sweep genuinely triggered HS events


def test_run_batch_rejects_structural_sweeps():
    g = diamond(mi=300.0)
    caps = SimCaps(n_clients=8, max_requests=128, max_cloudlets=128,
                   max_instances=4, n_vms=2, d_max=2, max_replicas=2)
    base = SimParams(dt=0.05, n_ticks=50, n_clients=8, spawn_rate=5.0)
    sim = Simulation(g, caps=caps, params=base)
    with pytest.raises(ValueError, match="structural"):
        sim.run_batch([base, dataclasses.replace(base, scaling_policy=1)])
    with pytest.raises(ValueError, match="structural"):
        sim.run_batch([dataclasses.replace(base, max_concurrent=2)])


def test_run_batch_capped_dispatch_path():
    """Sweep max_concurrent (the prefix-sum ranking path) under vmap."""
    g = linear_chain(1, mi=2000.0)
    caps = SimCaps(n_clients=16, max_requests=256, max_cloudlets=128,
                   max_instances=4, n_vms=2, d_max=1, max_replicas=1)
    base = SimParams(dt=0.05, n_ticks=150, n_clients=16, spawn_rate=100.0,
                     wait_lo=0.1, wait_hi=0.2, max_concurrent=2)
    sim = Simulation(g, caps=caps,
                     default_template=InstanceTemplate(mips=1000.0,
                                                       limit_mips=1000.0),
                     params=base)
    sweeps = [dataclasses.replace(base, max_concurrent=m) for m in (1, 2, 3)]
    res_b = sim.run_batch(sweeps)
    for b, p in enumerate(sweeps):
        solo = Simulation(g, caps=caps, params=p,
                          default_template=InstanceTemplate(
                              mips=1000.0, limit_mips=1000.0)).run()
        item = batch_item(res_b, b)
        assert int(np.asarray(item.state.instances.n_exec).max()) <= \
            p.max_concurrent
        np.testing.assert_array_equal(
            np.asarray(item.state.requests.response),
            np.asarray(solo.state.requests.response))
