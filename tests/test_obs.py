"""Observability tests (DESIGN.md §9).

Four contracts:

* **observation-only** — turning ``telemetry="stream"`` ON reproduces
  the golden-matrix digests bitwise in every mode combo (the fifth
  golden combo of the matrix; the OFF direction is pinned by
  ``test_layouts.test_mode_matrix_bit_identical_golden``, whose goldens
  predate observability and are unchanged);
* **exact overflow accounting** — the span ring never silently caps:
  ``span_n + span_drops`` equals the number of spans the run *wanted*
  to record, to the unit;
* **trace reconstruction** — a sampled request's span tree reproduces
  the engine's recorded response with tolerance ZERO, both by timestamp
  identity and by the tropical (max-plus) closure over the span DAG
  (``core/critical_path.py``'s Alg 2 at span granularity);
* **streamed == aggregate** — rows streamed through the io_callback
  exporter during ``run_batch`` reconcile exactly with the end-of-run
  ``QoSReport`` per sweep point.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import SimParams, Simulation
from repro.core.engine import batch_item
from repro.core.qos import summarize
from repro.obs import export
from repro.obs import spans as spans_mod
from repro.obs import telemetry as telmod

from test_layouts import MATRIX_GOLDEN, MODES, matrix_sim
from test_network import _digest_f32


def _d_max(sim: Simulation) -> int:
    return int(sim.app.succ.shape[1])


# ---------------------------------------------------------------------------
# Observation-only: telemetry ON keeps every golden digest (fifth combo)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("network,faults", MODES)
def test_telemetry_on_bit_identical_golden(network, faults):
    """The chunked scan-of-scan + ring writes + span sampling must not
    perturb a single simulated bit: every MATRIX_GOLDEN digest (pinned
    before observability existed) must hold with telemetry streaming."""
    sim = matrix_sim(network, faults, telemetry="stream",
                     tel_window_ticks=16, tel_windows=8,
                     tel_span_k=4, tel_span_cap=256)
    with export.collecting() as col:
        res = sim.run()
    st = res.state
    want = MATRIX_GOLDEN[(network, faults)]
    assert _digest_f32(st.requests.response) == want["resp"]
    assert int(st.counters.completed) == want["completed"]
    assert int(st.counters.spawned) == want["spawned"]
    assert int(st.counters.finished) == want["finished"]
    assert _digest_f32(res.trace.used_mips) == want["used_mips"]
    assert int(st.net.transits) == want["transits"]
    assert int(st.fstats.failed_attempts) == want["failed_attempts"]
    assert int(st.fstats.retries) == want["retries"]
    # ...and the observation stream itself is well-formed: one row per
    # closed window (300 ticks / 16 = 18), schema-valid.
    rows = col.rows
    assert len(rows) == 300 // 16
    export.validate_rows(rows)


# ---------------------------------------------------------------------------
# Span ring: exact overflow accounting
# ---------------------------------------------------------------------------

def test_span_ring_overflow_counts_drops_exactly():
    """tel_span_k=1 samples EVERY request, so the run wants one span per
    finished cloudlet; a tiny ring must fill to capacity and count every
    rejected span — spans kept + spans dropped == cloudlets finished."""
    cap = 8
    sim = matrix_sim("uniform", "none", telemetry="stream",
                     tel_window_ticks=16, tel_windows=8,
                     tel_span_k=1, tel_span_cap=cap)
    res = sim.run()
    tel = res.state.telemetry
    span_n = int(np.asarray(tel.span_n)[0])
    drops = int(np.asarray(tel.span_drops)[0])
    finished = int(res.state.counters.finished)
    assert finished > cap                    # scenario actually overflows
    assert span_n == cap                     # full, never overwritten
    assert drops == finished - cap           # every drop counted, exactly
    # the report surfaces the same numbers
    rep = summarize(sim, res)
    assert rep.tel_spans == cap
    assert rep.tel_span_drops == drops


def test_span_tick_cap_exact_accounting():
    """``tel_span_tick_cap`` bounds the per-tick staging build (the ring
    capacity otherwise re-inflates it); a generous budget is bitwise
    identical to uncapped, and a starved one still conserves
    kept + dropped == finished — drops are counted, never silent."""
    kw = dict(telemetry="stream", tel_window_ticks=16, tel_windows=8,
              tel_span_k=1, tel_span_cap=2048)
    base = matrix_sim("uniform", "none", **kw).run()
    roomy = matrix_sim("uniform", "none", tel_span_tick_cap=512,
                       **kw).run()       # 512 = pool size: can't bind
    for f in ("span_i", "span_f", "span_n", "span_drops"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base.state.telemetry, f)),
            np.asarray(getattr(roomy.state.telemetry, f)))
    tight = matrix_sim("uniform", "none", tel_span_tick_cap=1,
                       **kw).run()       # ≤ 1 span staged per tick
    tel = tight.state.telemetry
    span_n = int(np.asarray(tel.span_n)[0])
    drops = int(np.asarray(tel.span_drops)[0])
    finished = int(tight.state.counters.finished)
    assert span_n + drops == finished    # conservation survives the cap
    assert span_n < int(np.asarray(base.state.telemetry.span_n)[0])
    assert span_n <= 300                 # matrix_sim runs 300 ticks


# ---------------------------------------------------------------------------
# Trace reconstruction: span tree == engine response, tolerance 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("network,faults", [("uniform", "none"),
                                            ("fabric", "chaos")])
def test_sampled_trace_reproduces_response_exactly(network, faults):
    """Every completed, retry-free, non-failed sampled request's span
    tree must reproduce the engine's response bitwise — both the
    timestamp identity and the tropical closure (TraceCheck.exact)."""
    sim = matrix_sim(network, faults, telemetry="stream",
                     tel_window_ticks=16, tel_windows=8,
                     tel_span_k=2, tel_span_cap=1024)
    res = sim.run()
    checks = spans_mod.verify_traces(res.state, sim.graph, _d_max(sim))
    eligible = [c for c in checks if not c.failed and c.retry_free]
    assert len(eligible) >= 5, "scenario produced too few sampled traces"
    for c in eligible:
        assert c.tree == c.response, \
            f"req {c.req}: tree {c.tree!r} != response {c.response!r}"
        assert c.tropical == c.response, \
            f"req {c.req}: tropical {c.tropical!r} != {c.response!r}"
        assert c.exact
        # graph-level Alg 2 is f32-approximate — consistency only
        if c.graph is not None:
            np.testing.assert_allclose(float(c.graph), float(c.response),
                                       rtol=1e-5, atol=1e-5)


def test_trace_tree_shape_matches_graph():
    """A sampled diamond-request's tree has the entry as root and every
    span parented by a span whose finish equals its arrival bitwise."""
    sim = matrix_sim("fabric", "chaos", telemetry="stream",
                     tel_window_ticks=16, tel_windows=8,
                     tel_span_k=1, tel_span_cap=2048)
    res = sim.run()
    checks = spans_mod.verify_traces(res.state, sim.graph, _d_max(sim))
    full = [c for c in checks
            if not c.failed and c.retry_free and c.n_spans >= 4]
    assert full, "no fully-fanned diamond trace sampled"
    req = full[0].req
    roots = spans_mod.trace_tree(spans_mod.spans_of(res.state, req),
                                 sim.graph.n_services, _d_max(sim))
    assert len(roots) == 1                   # single client→entry root
    for s in spans_mod._all_spans(roots):
        if s.parent is not None:
            assert np.float32(s.parent.finish) == np.float32(s.arrival)
    assert spans_mod.format_trace(roots)     # renders without error


# ---------------------------------------------------------------------------
# run_batch: streamed rows reconcile with QoSReport per sweep point
# ---------------------------------------------------------------------------

def test_run_batch_streamed_rows_match_reports():
    """Each sweep point's streamed windows must cover the whole run
    (n_ticks a multiple of the window) and their sums/finals equal the
    point's QoSReport aggregates computed from the final state."""
    base = matrix_sim("fabric", "chaos", telemetry="stream", n_ticks=128,
                      tel_window_ticks=8, tel_windows=4,
                      tel_span_k=2, tel_span_cap=512)
    points = [dataclasses.replace(base.params, spawn_rate=r)
              for r in (3.0, 5.0, 8.0)]
    with export.collecting() as col:
        res = base.run_batch(points)
    rows = col.rows
    export.validate_rows(rows)
    n_windows = 128 // 8
    for b, p in enumerate(points):
        mine = [r for r in rows if int(r["tag"]) == b]
        assert len(mine) == n_windows, \
            f"point {b}: {len(mine)} rows streamed, want {n_windows}"
        item = batch_item(res, b)
        rep = summarize(base, item, params=p)
        # windowed counters sum to the run totals
        assert int(sum(r["completed"] for r in mine)) \
            == rep.completed_requests
        assert int(sum(r["generated"] for r in mine)) \
            == rep.generated_requests
        # cumulative gauges: the last window reports the final state
        last = max(mine, key=lambda r: r["window"])
        assert int(last["failed_attempts"]) \
            == int(item.state.fstats.failed_attempts)
        assert int(last["retries"]) == rep.retries
        assert int(last["spans"]) == rep.tel_spans
        assert int(last["span_drops"]) == rep.tel_span_drops
        assert rep.tel_windows == n_windows
        # per-tick trace cross-check: window sums == trace sums
        tr = np.asarray(item.trace.completed)
        assert int(sum(r["completed"] for r in mine)) == int(tr.sum())


def test_solo_run_flushes_live_and_drains_tail():
    """A solo run whose tick count is NOT flush-aligned still delivers
    every closed window: chunk flushes live + end-of-run drain."""
    sim = matrix_sim("uniform", "none", telemetry="stream", n_ticks=100,
                     tel_window_ticks=8, tel_windows=4,
                     tel_span_k=4, tel_span_cap=128)
    with export.collecting() as col:
        sim.run()
    rows = col.rows
    # 100 ticks / 8 = 12 closed windows; chunk = 8*2 = 16 ticks → 6
    # live flushes deliver 12 rows... all of them here; the drain covers
    # whatever the final partial chunk sealed.
    assert len(rows) == 100 // 8
    export.validate_rows(rows)
    assert [int(r["window"]) for r in
            sorted(rows, key=lambda r: r["window"])] == list(range(12))


def test_telemetry_off_streams_nothing():
    sim = matrix_sim("uniform", "none", n_ticks=64)
    with export.collecting() as col:
        res = sim.run()
    assert col.rows == []
    assert res.state.telemetry.ring.size == 0
    rep = summarize(sim, res)
    assert (rep.tel_windows, rep.tel_spans, rep.tel_span_drops) == (0, 0, 0)


def test_flush_ticks_and_window_validation():
    from repro.core.types import validate_telemetry
    assert telmod.flush_ticks(SimParams(tel_window_ticks=16,
                                        tel_windows=8)) == 64
    with pytest.raises(ValueError, match="even"):
        validate_telemetry(SimParams(telemetry="stream", tel_windows=3))
    with pytest.raises(ValueError, match="'none' or 'stream'"):
        validate_telemetry(SimParams(telemetry="sometimes"))
