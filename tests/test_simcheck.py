"""simcheck self-tests (DESIGN.md §8).

Three layers:

* **golden topology** — the four mode combos' RNG stream-derivation
  trees pinned under digests, so a widened split or reordered fold_in
  fails here before it silently perturbs every seeded experiment
  (`jax.random.split` is not prefix-stable);
* **seeded violations** — each analyzer rule is fed a deliberately
  broken input and must fire: a checker that cannot catch its own
  seeded bug is decoration;
* **layout properties** — reading a column absent from a mode's layout
  raises (never silently aliases another column), for every combo.

The recompile sentinel's full warm/count pass runs in the CI simcheck
job (`python -m repro.analysis`), not here — this file only proves the
counter counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import checkify, enable_x64

from repro.analysis import jaxpr_lint, layout_check, recompile, streams
from repro.analysis.simcheck import check_streams, run_simcheck
from repro.core.pool import SlotAssignment, scatter_pool
from repro.core.types import PHASE_COLUMNS, _layout_for

# Pinned stream-derivation topologies (see analysis/streams.py).  If an
# engine change legitimately rewires a stream tree, re-pin via
#   python -c "from repro.analysis.simcheck import check_streams; \
#              print(check_streams()['digests'])"
# and say so in the commit — this is the seeded-run compatibility break.
GOLDEN_STREAM_DIGESTS = {
    "uniform+none": "63d3efb9556990fb",
    "uniform+chaos": "ef15e81868ba91e7",
    "fabric+none": "3c57f57cd8b23c38",
    "fabric+chaos": "bceab1a96eb2745f",
    # telemetry is observation-only: NO tick RNG consumed, so the fifth
    # combo's topology is pinned EQUAL to fabric+chaos (PR 8)
    "fabric+chaos+telemetry": "bceab1a96eb2745f",
    # alerting is pure arithmetic over sealed SLI windows: same pin (PR 9)
    "fabric+chaos+alerting": "bceab1a96eb2745f",
}


# ---------------------------------------------------------------------------
# Golden topology + clean integration
# ---------------------------------------------------------------------------

def test_stream_topology_matches_golden():
    res = check_streams()
    assert res["problems"] == []
    assert res["digests"] == GOLDEN_STREAM_DIGESTS


def test_layout_and_streams_sections_clean():
    report = run_simcheck(only={"layout", "streams"})
    assert report.ok, report.problems


def test_lint_combo_clean_uniform_none():
    assert jaxpr_lint.lint_combo("uniform", "none") == []


# ---------------------------------------------------------------------------
# Seeded violations: jaxpr lint
# ---------------------------------------------------------------------------

def test_lint_catches_f64_in_hot_loop():
    def leaky(x):
        return (x.astype(jnp.float64) * 2.0).astype(jnp.float32)

    with enable_x64():
        closed = jax.make_jaxpr(leaky)(jnp.ones((4,), jnp.float32))
    probs = jaxpr_lint.lint_jaxpr(closed, in_loop=True)
    assert any(p.startswith("f64:") for p in probs)
    # ...and the rule is waivable by id
    assert jaxpr_lint.lint_jaxpr(closed, in_loop=True,
                                 waive={"f64"}) == []


def test_lint_catches_callback_in_hot_loop():
    def chatty(x):
        jax.debug.print("x={x}", x=x)
        return x + 1.0

    closed = jax.make_jaxpr(chatty)(jnp.float32(0.0))
    probs = jaxpr_lint.lint_jaxpr(closed, in_loop=True)
    assert any(p.startswith("callback:") for p in probs)


def test_lint_ignores_cold_code():
    # Same callback OUTSIDE any loop: in_loop=False keeps it legal.
    def chatty(x):
        jax.debug.print("x={x}", x=x)
        return x + 1.0

    closed = jax.make_jaxpr(chatty)(jnp.float32(0.0))
    assert jaxpr_lint.lint_jaxpr(closed, in_loop=False) == []


def test_donation_check_catches_undonated_carry():
    def bump(st):
        return jax.tree_util.tree_map(
            lambda x: x + jnp.ones((), x.dtype), st)

    state = {"a": jnp.zeros((4,), jnp.float32),
             "b": jnp.zeros((2,), jnp.int32)}
    undonated = jax.jit(bump).lower(state)
    probs = jaxpr_lint.check_donation(undonated)
    assert probs and probs[0].startswith("donation:")
    assert jaxpr_lint.check_donation(undonated, waive={"donation"}) == []

    donated = jax.jit(bump, donate_argnums=0).lower(state)
    assert jaxpr_lint.check_donation(donated) == []


# ---------------------------------------------------------------------------
# Seeded violations: layout-access checker
# ---------------------------------------------------------------------------

def test_layout_checker_catches_undeclared_access():
    # Strip 'wait_ticks' from Dispatch's declaration: the replay still
    # reads it (the real layout is untouched), so the access is now
    # undeclared and must fail.
    perturbed = dict(PHASE_COLUMNS)
    perturbed["Dispatch"] = tuple(
        c for c in PHASE_COLUMNS["Dispatch"] if c != "wait_ticks")
    probs = layout_check.check_layout_access(phase_columns=perturbed)
    assert any("undeclared" in p and "wait_ticks" in p
               and "'Dispatch'" in p for p in probs)


def test_layout_checker_catches_stale_declaration():
    perturbed = dict(PHASE_COLUMNS)
    perturbed["Execute"] = PHASE_COLUMNS["Execute"] + ("ghost_col",)
    probs = layout_check.check_layout_access(phase_columns=perturbed)
    assert any("ever touches" in p and "ghost_col" in p for p in probs)


def test_layout_checker_clean_on_real_registry():
    assert layout_check.check_layout_access() == []


# ---------------------------------------------------------------------------
# Layout property: absent-column reads raise under every mode combo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("network,faults,egress,telemetry",
                         layout_check.COMBOS)
def test_absent_column_read_raises(network, faults, egress, telemetry):
    full = _layout_for("fabric", "chaos", True)
    layout = _layout_for(network, faults, egress, telemetry)
    for col in full.i_fields:
        if col not in layout.i_fields:
            with pytest.raises(KeyError):
                layout.i(col)
    for col in full.f_fields:
        if col not in layout.f_fields:
            with pytest.raises(KeyError):
                layout.f(col)
    with pytest.raises(KeyError):
        layout.i("definitely_not_a_column")
    with pytest.raises(KeyError):
        layout.f("definitely_not_a_column")


# ---------------------------------------------------------------------------
# Seeded violations: RNG stream auditor
# ---------------------------------------------------------------------------

def test_streams_catch_key_reuse():
    key = jax.random.PRNGKey(0)
    with streams.recording() as rec:
        rec.register(key, "root")
        streams.split(key, names=("a", "b"))
        streams.split(key, names=("a", "b"))   # identical derivation
    probs = streams.audit_events(rec)
    assert any("key reuse" in p for p in probs)


def test_streams_catch_path_collision():
    key = jax.random.PRNGKey(0)
    with streams.recording() as rec:
        rec.register(key, "root")
        streams.fold_in(key, 1, name="x")
        streams.fold_in(key, 2, name="x")      # distinct stream, same name
    probs = streams.audit_events(rec)
    assert any("path collision" in p for p in probs)


def test_streams_catch_unnamed_derivation():
    key = jax.random.PRNGKey(0)
    with streams.recording() as rec:
        rec.register(key, "root")
        orphan = jax.random.fold_in(key, 7)    # raw call — unwrapped site
        streams.split(orphan, names=("a", "b"))
    probs = streams.audit_events(rec)
    assert any("unnamed stream" in p for p in probs)


def test_streams_validate_names():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        streams.split(key, 3, names=("a", "b"))
    with pytest.raises(ValueError):
        streams.split(key, names=("a", "a"))


def test_streams_are_transparent_outside_recording():
    key = jax.random.PRNGKey(0)
    named = streams.split(key, 3, names=("a", "b", "c"))
    raw = jax.random.split(key, 3)
    assert (jax.numpy.asarray(named) == jax.numpy.asarray(raw)).all()
    assert (streams.fold_in(key, 5, name="x")
            == jax.random.fold_in(key, 5)).all()


# ---------------------------------------------------------------------------
# Seeded violation: recompile counter
# ---------------------------------------------------------------------------

def test_compile_counter_counts_cache_misses():
    with recompile.count_backend_compiles() as hits:
        for i in range(3):
            # a fresh function object per iteration defeats the jit
            # cache — exactly the closure bug the sentinel hunts
            jax.jit(lambda x, _i=i: x + _i)(jnp.float32(0.0))
    assert hits[0] >= 3


def test_compile_counter_silent_on_cache_hits():
    f = jax.jit(lambda x: x * 2.0)
    f(jnp.float32(1.0))                        # warm
    with recompile.count_backend_compiles() as hits:
        for s in range(5):
            f(jnp.float32(s))                  # value changes, shape fixed
    assert hits[0] == 0


# ---------------------------------------------------------------------------
# REPRO_CHECKED=1: checkify lowering of declared-disjoint sites
# ---------------------------------------------------------------------------

def test_checked_mode_is_value_neutral(monkeypatch):
    # The checkify asserts must not perturb the simulation: same seeds,
    # same results, checked or not.
    monkeypatch.delenv("REPRO_CHECKED", raising=False)
    res0 = layout_check._tiny_sim("fabric", "chaos", False, False).run()
    monkeypatch.setenv("REPRO_CHECKED", "1")
    res1 = layout_check._tiny_sim("fabric", "chaos", False, False).run()
    np.testing.assert_array_equal(
        np.asarray(res0.state.requests.response),
        np.asarray(res1.state.requests.response))
    assert int(res0.state.counters.finished) == \
        int(res1.state.counters.finished)


def _forged_scatter(dst):
    """scatter_pool call with a hand-forged (invalid) slot assignment."""
    sim = layout_check._tiny_sim("uniform", "none", False, False)
    cl = sim.init_state().cloudlets
    cols = {n: 0 for n in cl.layout.columns}
    i32 = jnp.int32
    asg = SlotAssignment(dst=jnp.asarray(dst, i32),
                         src=jnp.arange(len(dst), dtype=i32),
                         live=jnp.ones((len(dst),), bool),
                         n_assigned=jnp.asarray(len(dst), i32),
                         n_dropped=jnp.asarray(0, i32))
    return cl, lambda c: scatter_pool(c, asg, **cols)


def test_checked_mode_catches_duplicate_slots(monkeypatch):
    monkeypatch.setenv("REPRO_CHECKED", "1")
    cl, fn = _forged_scatter([3, 3])    # two live lanes, one slot
    err, _out = checkify.checkify(fn, errors=checkify.user_checks)(cl)
    with pytest.raises(Exception, match="duplicate destination slot"):
        err.throw()


def test_checked_mode_catches_oob_live_destination(monkeypatch):
    monkeypatch.setenv("REPRO_CHECKED", "1")
    cl, fn = _forged_scatter([-5])      # live lane below the pool
    err, _out = checkify.checkify(fn, errors=checkify.user_checks)(cl)
    with pytest.raises(Exception, match="destination out of range"):
        err.throw()


def test_unchecked_mode_traces_no_asserts(monkeypatch):
    # Without REPRO_CHECKED the same forged call is assert-free (the
    # production program carries zero checkify overhead).
    monkeypatch.delenv("REPRO_CHECKED", raising=False)
    cl, fn = _forged_scatter([3, 3])
    err, _out = checkify.checkify(fn, errors=checkify.user_checks)(cl)
    assert err.get() is None
