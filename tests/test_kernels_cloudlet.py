"""Fused cloudlet-tick kernel vs oracle, including hypothesis sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # skips gracefully without hypothesis

from repro.kernels.cloudlet_step import cloudlet_step, cloudlet_step_ref
from repro.kernels.cloudlet_step.kernel import cloudlet_step_pallas


def _mk(rng, C, I):
    status = rng.choice([0, 1, 2], size=C, p=[0.3, 0.2, 0.5]).astype(np.int32)
    rem = rng.uniform(0.1, 500.0, size=C).astype(np.float32)
    inst = rng.integers(0, I, size=C).astype(np.int32)
    inst[rng.random(C) < 0.05] = -1
    rate = rng.uniform(0.0, 300.0, size=C).astype(np.float32)
    return (jnp.asarray(status), jnp.asarray(rem), jnp.asarray(inst),
            jnp.asarray(rate))


@pytest.mark.parametrize("C,I,bc", [(256, 8, 64), (1024, 33, 256),
                                    (4096, 100, 4096)])
def test_kernel_matches_ref(C, I, bc, rng):
    status, rem, inst, rate = _mk(rng, C, I)
    time, dt = 12.5, 0.25
    got = cloudlet_step_pallas(status, rem, inst, rate, time, dt,
                               n_inst=I, bc=bc, interpret=True)
    want = cloudlet_step_ref(status, rem, inst, rate, time, dt, I)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 10_000), dt=st.floats(0.01, 1.0))
@settings(max_examples=20, deadline=None)
def test_ops_dispatch_property(seed, dt):
    rng = np.random.default_rng(seed)
    status, rem, inst, rate = _mk(rng, 512, 16)
    got = cloudlet_step(status, rem, inst, rate, 3.0, dt, 16,
                        use_pallas=True, interpret=True)
    want = cloudlet_step_ref(status, rem, inst, rate, 3.0, dt, 16)
    new_rem, fin, tfin, consumed, used = (np.asarray(x) for x in got)
    wrem, wfin, wtfin, wcons, wused = (np.asarray(x) for x in want)
    np.testing.assert_allclose(new_rem, wrem, rtol=2e-5, atol=1e-4)
    np.testing.assert_array_equal(fin, wfin)
    np.testing.assert_allclose(used, wused, rtol=1e-5, atol=1e-5)
    # physical invariants
    assert (new_rem >= 0).all()
    exec_mask = np.asarray(status) == 2
    assert (consumed[exec_mask]
            <= np.asarray(rate)[exec_mask] * dt + 1e-5).all()
    assert not fin[~exec_mask].any()
    assert (tfin[fin] >= 3.0).all() and (tfin[fin] <= 3.0 + dt + 1e-6).all()
