"""Property tests for the free-slot allocator (core/pool.py).

These are the invariants the whole tensor-DES rests on: every assignment
targets a genuinely free slot, slots are unique, FCFS rank order is
respected, and overflow is counted — never silent.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # skips gracefully without hypothesis

from repro.core.pool import assign_free_slots, segment_rank


@given(
    free=st.lists(st.booleans(), min_size=1, max_size=64),
    want=st.lists(st.booleans(), min_size=1, max_size=96),
)
@settings(max_examples=200, deadline=None)
def test_assign_free_slots_invariants(free, want):
    free = np.array(free)
    want = np.array(want)
    asg = assign_free_slots(jnp.asarray(free), jnp.asarray(want))
    dst = np.asarray(asg.dst)
    src = np.asarray(asg.src)
    live = np.asarray(asg.live)
    n_assigned = int(asg.n_assigned)
    n_dropped = int(asg.n_dropped)

    assert n_assigned == min(free.sum(), want.sum(), len(live))
    assert n_dropped == want.sum() - n_assigned
    assert live.sum() == n_assigned
    # live ranks are a prefix
    assert np.all(live[:n_assigned]) and not live[n_assigned:].any()
    # destinations: unique, genuinely free, in ascending slot order
    d = dst[:n_assigned]
    assert len(np.unique(d)) == n_assigned
    assert free[d].all()
    assert np.all(np.diff(d) > 0) if n_assigned > 1 else True
    # sources: exactly the first n_assigned valid descriptors, in order
    expect_src = np.flatnonzero(want)[:n_assigned]
    assert np.array_equal(src[:n_assigned], expect_src)


@given(
    data=st.data(),
    n=st.integers(min_value=1, max_value=48),
    n_seg=st.integers(min_value=1, max_value=8),
)
@settings(max_examples=150, deadline=None)
def test_segment_rank_matches_oracle(data, n, n_seg):
    keys = np.array(data.draw(st.lists(
        st.integers(min_value=0, max_value=n_seg - 1),
        min_size=n, max_size=n)))
    mask = np.array(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    got = np.asarray(segment_rank(jnp.asarray(keys), jnp.asarray(mask), n_seg))
    # oracle: FCFS rank within segment over masked elements, slot order
    counts = {}
    for i in range(n):
        if mask[i]:
            k = int(keys[i])
            expect = counts.get(k, 0)
            counts[k] = expect + 1
            assert got[i] == expect, (i, keys, mask, got)
        else:
            assert got[i] == n


def test_assign_respects_k_static():
    free = jnp.ones(16, bool)
    want = jnp.ones(16, bool)
    asg = assign_free_slots(free, want, k_static=4)
    assert int(asg.n_assigned) == 4
    assert int(asg.n_dropped) == 12
