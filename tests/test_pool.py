"""Property tests for the free-slot allocator (core/pool.py).

These are the invariants the whole tensor-DES rests on: every assignment
targets a genuinely free slot, slots are unique, FCFS rank order is
respected, and overflow is counted — never silent.
"""
import jax.numpy as jnp
import numpy as np

from _hyp import given, settings, st  # skips gracefully without hypothesis

from repro.core.pool import assign_free_slots, segment_rank


@given(
    free=st.lists(st.booleans(), min_size=1, max_size=64),
    want=st.lists(st.booleans(), min_size=1, max_size=96),
)
@settings(max_examples=200, deadline=None)
def test_assign_free_slots_invariants(free, want):
    free = np.array(free)
    want = np.array(want)
    asg = assign_free_slots(jnp.asarray(free), jnp.asarray(want))
    dst = np.asarray(asg.dst)
    src = np.asarray(asg.src)
    live = np.asarray(asg.live)
    n_assigned = int(asg.n_assigned)
    n_dropped = int(asg.n_dropped)

    assert n_assigned == min(free.sum(), want.sum(), len(live))
    assert n_dropped == want.sum() - n_assigned
    assert live.sum() == n_assigned
    # live ranks are a prefix
    assert np.all(live[:n_assigned]) and not live[n_assigned:].any()
    # destinations: unique, genuinely free, in ascending slot order
    d = dst[:n_assigned]
    assert len(np.unique(d)) == n_assigned
    assert free[d].all()
    assert np.all(np.diff(d) > 0) if n_assigned > 1 else True
    # sources: exactly the first n_assigned valid descriptors, in order
    expect_src = np.flatnonzero(want)[:n_assigned]
    assert np.array_equal(src[:n_assigned], expect_src)


@given(
    data=st.data(),
    n=st.integers(min_value=1, max_value=48),
    n_seg=st.integers(min_value=1, max_value=8),
)
@settings(max_examples=150, deadline=None)
def test_segment_rank_matches_oracle(data, n, n_seg):
    keys = np.array(data.draw(st.lists(
        st.integers(min_value=0, max_value=n_seg - 1),
        min_size=n, max_size=n)))
    mask = np.array(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    got = np.asarray(segment_rank(jnp.asarray(keys), jnp.asarray(mask), n_seg))
    # oracle: FCFS rank within segment over masked elements, slot order
    counts = {}
    for i in range(n):
        if mask[i]:
            k = int(keys[i])
            expect = counts.get(k, 0)
            counts[k] = expect + 1
            assert got[i] == expect, (i, keys, mask, got)
        else:
            assert got[i] == n


def test_assign_respects_k_static():
    free = jnp.ones(16, bool)
    want = jnp.ones(16, bool)
    asg = assign_free_slots(free, want, k_static=4)
    assert int(asg.n_assigned) == 4
    assert int(asg.n_dropped) == 12


def test_recycling_waves_with_overflow_accounting():
    """Spawn waves against a pool that keeps freeing slots: every wave's
    assignments + drops must add up, freed slots must be reused (FCFS by
    slot index), and the cumulative drop count never goes backwards."""
    C = 32
    r = np.random.default_rng(7)
    free = np.ones(C, bool)
    total_assigned = total_dropped = 0
    occupied = set()
    for wave in range(20):
        want = r.random(40) < r.uniform(0.2, 0.9)
        asg = assign_free_slots(jnp.asarray(free), jnp.asarray(want))
        n_a, n_d = int(asg.n_assigned), int(asg.n_dropped)
        assert n_a + n_d == int(want.sum())
        assert n_a <= free.sum()
        dst = np.asarray(asg.dst)[:n_a]
        # every destination was genuinely free, and is the lowest-index
        # run of free slots (recycled slots come back in slot order)
        assert free[dst].all()
        expect = np.flatnonzero(free)[:n_a]
        assert np.array_equal(np.sort(dst), expect)
        free[dst] = False
        occupied.update(dst.tolist())
        total_assigned += n_a
        total_dropped += n_d
        # free a random subset (the "finished queue" folding + slot free)
        done = [s for s in list(occupied) if r.random() < 0.4]
        for s in done:
            occupied.discard(s)
            free[s] = True
    # the pool was oversubscribed at least once over 20 waves
    assert total_dropped > 0
    assert total_assigned > C        # slots were genuinely recycled


def test_pool_full_drops_everything_then_recovers():
    free = np.zeros(8, bool)
    want = np.ones(5, bool)
    asg = assign_free_slots(jnp.asarray(free), jnp.asarray(want))
    assert int(asg.n_assigned) == 0
    assert int(asg.n_dropped) == 5
    assert not bool(np.asarray(asg.live).any())
    free[3] = True                      # one slot frees up
    asg = assign_free_slots(jnp.asarray(free), jnp.asarray(want))
    assert int(asg.n_assigned) == 1
    assert int(asg.n_dropped) == 4
    assert int(np.asarray(asg.dst)[0]) == 3


def test_segment_rank_large_segment_count_fallback():
    """num_segments big enough to blow the blocked count-matrix budget
    (n_blocks × (S+1) > 2²⁴) must take the sort-based O(n)-memory path and
    still agree with the oracle."""
    from repro.core.pool import segment_rank_sorted

    n = 256
    n_seg = (1 << 23) + 11               # 2 blocks × (S+1) > 2²⁴
    r = np.random.default_rng(11)
    # cluster keys so ranks actually exceed 0 within segments
    keys = np.asarray(r.integers(0, 50, n), np.int32)
    keys[::7] = n_seg - 3                # exercise the huge-id range too
    mask = r.random(n) < 0.7
    got = np.asarray(segment_rank(jnp.asarray(keys), jnp.asarray(mask),
                                  n_seg, block=128))
    want = np.asarray(segment_rank_sorted(jnp.asarray(keys),
                                          jnp.asarray(mask), n_seg))
    np.testing.assert_array_equal(got, want)
    # sanity: the masked ranks are FCFS within their segment
    counts = {}
    for i in range(n):
        if mask[i]:
            k = int(keys[i])
            assert got[i] == counts.get(k, 0)
            counts[k] = counts.get(k, 0) + 1
        else:
            assert got[i] == n
