"""Flash-attention kernel vs pure-jnp oracle — shape/dtype sweep in
interpret mode, plus gradient wiring (custom_vjp) checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention, attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _mk(rng, B, Hq, Hkv, Tq, Tk, D, dtype):
    q = rng.normal(size=(B, Hq, Tq, D)).astype(dtype)
    k = rng.normal(size=(B, Hkv, Tk, D)).astype(dtype)
    v = rng.normal(size=(B, Hkv, Tk, D)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,bq,bk", [
    (1, 2, 2, 128, 128, 64, 64, 64),       # MHA square
    (1, 4, 2, 128, 128, 64, 64, 64),       # GQA group=2
    (2, 8, 1, 128, 256, 32, 64, 128),      # MQA, Tq<Tk (chunked prefill)
    (1, 4, 4, 256, 128, 32, 128, 64),      # Tq>Tk (some rows fully masked)
])
def test_kernel_vs_ref_f32(B, Hq, Hkv, Tq, Tk, D, bq, bk, rng):
    q, k, v = _mk(rng, B, Hq, Hkv, Tq, Tk, D, np.float32)
    got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=True)
    # rows with no visible keys are ref-nan / kernel-zero; compare the rest
    off = Tk - Tq
    visible = (np.arange(Tq) + off) >= 0
    np.testing.assert_allclose(np.asarray(got)[:, :, visible],
                               np.asarray(want)[:, :, visible],
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,rtol", [(np.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_kernel_dtypes(dtype, rtol, rng):
    q, k, v = _mk(rng, 1, 4, 2, 128, 128, 64, np.float32)
    q, k, v = (x.astype(dtype) for x in (q, k, v))
    got = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=rtol, atol=rtol)


@pytest.mark.parametrize("Tq,Tk", [(100, 100), (77, 200), (130, 130)])
def test_ops_padding_ragged(Tq, Tk, rng):
    q, k, v = _mk(rng, 1, 2, 2, Tq, Tk, 32, np.float32)
    got = attention(q, k, v, impl="flash", interpret=True, bq=64, bk=64)
    want = attention_ref(q, k, v, causal=True)
    off = Tk - Tq
    visible = (np.arange(Tq) + off) >= 0
    np.testing.assert_allclose(np.asarray(got)[:, :, visible],
                               np.asarray(want)[:, :, visible],
                               rtol=2e-5, atol=2e-5)


def test_noncausal_matches_ref(rng):
    q, k, v = _mk(rng, 1, 2, 2, 64, 128, 32, np.float32)
    got = flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64,
                                 interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_gradients_match_ref(rng):
    q, k, v = _mk(rng, 1, 2, 1, 64, 64, 32, np.float32)

    def loss_flash(q, k, v):
        return jnp.sum(attention(q, k, v, impl="flash", interpret=True,
                                 bq=64, bk=64) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
