"""Optional-hypothesis shim.

Property-test modules import ``given / settings / st`` from here instead of
from ``hypothesis`` directly: when hypothesis is installed they get the real
thing; when it is not, ``@given(...)`` turns into a graceful per-test skip
(``pytest.importorskip`` semantics) while the modules' plain pytest tests
keep running.  Dev installs get hypothesis via requirements-dev.txt.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import (HealthCheck, given, settings,  # noqa: F401
                            strategies as st)
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(_f):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(_f)
        return deco

    def settings(*_a, **_k):
        def deco(f):
            return f
        return deco

    class HealthCheck:  # noqa: D401 — attribute access only
        all = staticmethod(lambda: ())

    class _Strategies:
        """Strategy stubs: evaluated only at decoration time, never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
