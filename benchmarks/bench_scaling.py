"""Paper Fig 11 — scaling-policy demonstration (§6.4).

NS / HS / VS on the SockShop configuration at 300 / 500 / 1000 clients,
reporting average per-instance CPU usage in milicores.  The paper's claims:

  * HS uses ≈ 70 % fewer milicores per instance than NS (scale-out spreads
    the same work over 2–4 replicas),
  * VS uses ≈ 10–15 % more than NS (raised limits let saturated instances
    consume beyond their original share),
  * usage grows with client load for every policy.

Absolute milicores are reported in paper units via a single conversion
constant fitted on NS@300 (the paper's own unit anchor, 104.76 mc).

Each policy's load series runs as ONE ``Simulation.run_batch`` — a single
compile and a single device dispatch for the whole sweep (the policy
selector is a static knob, so policies stay separate compilations).  The
``sweep8`` section demonstrates the batched-sweep speedup the engine
refactor targets: an 8-point HS load sweep vs one solo run.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import sockshop
from repro.core import batch_item, policies, summarize
from repro.core.types import INST_ON

from .common import emit, header

LOADS = [300, 500, 1000]
POLICIES = [("NS", policies.SCALE_NONE), ("HS", policies.SCALE_HORIZONTAL),
            ("VS", policies.SCALE_VERTICAL)]
PAPER = {  # milicores from §6.4
    ("NS", 300): 104.76, ("HS", 300): 31.52, ("VS", 300): 115.77,
    ("NS", 500): 174.24, ("HS", 500): 52.52, ("VS", 500): 192.99,
    ("NS", 1000): 348.52, ("HS", 1000): 97.74, ("VS", 1000): 399.77,
}

# Deviation from §6.3 (documented in EXPERIMENTS.md): the paper's NS
# series is linear through 1000 clients, which implies an unsaturated
# cluster — so Fig 11 runs with share=4725 (util ≈ 0.8 at the hottest
# service under 1000 clients).  Thresholds sized so HS spreads every
# busy service over ~3.3 replicas (the paper's constant HS ratio) and
# VS's resize churn surcharge reproduces its constant ≈ +11 %.
FIG11_KNOBS = dict(
    share=4725.0, hs_util_hi=0.03, hs_util_lo=0.002,
    vs_util_hi=0.14, vs_util_lo=0.01, vs_up_factor=1.5, vs_down_factor=0.8,
    util_ema=0.1, idle_mips_frac=0.01, vs_overhead_frac=0.11,
)


def _per_inst_milicores(item):
    st = item.state
    on = np.asarray(st.instances.status) == INST_ON
    usage = np.asarray(st.instances.usage_sum)  # ∫ used_mips dt
    return float((usage[on] / float(st.time)).mean()), int(on.sum())


def run_policy_sweep(policy_id: int, loads, duration_s: float = 600.0,
                     seed: int = 0):
    """One batched run over the load series for a single (static) policy.

    The Simulation is sized for the largest load; per-point client counts
    and spawn rates travel through the traced DynParams sweep, so the
    whole series is one compile + one dispatch.
    """
    sim = sockshop.make_sim(
        n_clients=max(loads), duration_s=duration_s,
        scaling_policy=policy_id, seed=seed, **FIG11_KNOBS)
    sweeps = [dataclasses.replace(sim.params, n_clients=nc,
                                  spawn_rate=nc / 30.0) for nc in loads]
    res = sim.run_batch(sweeps)
    cells = {}
    for b, nc in enumerate(loads):
        item = batch_item(res, b)
        mc, n_on = _per_inst_milicores(item)
        rep = summarize(sim, item, params=sweeps[b])
        cells[nc] = (mc, rep, n_on)
    return cells, res


def sweep8_demo(n_points: int = 8, duration_s: float = 600.0):
    """Batched-sweep economics: n-point HS load sweep as ONE compile +
    ONE dispatch vs solo runs.

    Two ratios are emitted.  ``batch_over_solo`` (< 3× target) measures
    how close the whole sweep gets to a single run's wall time — it
    reaches the target when the sweep points can actually run in
    parallel (an accelerator, or ≥ n_points CPU cores; on a 2-core CI
    container the compute floor is n_points/2 × solo).
    ``batch_over_sequential`` compares against the real alternative —
    n_points separate runs.  (Sequential runs share one compilation too,
    via the compiled-executable cache; the batch's edge is a single
    device dispatch and one Python-free sweep, not compile count.)
    """
    import os
    loads = [int(x) for x in np.linspace(200, 1100, n_points)]
    sim = sockshop.make_sim(n_clients=max(loads), duration_s=duration_s,
                            scaling_policy=policies.SCALE_HORIZONTAL,
                            **FIG11_KNOBS)
    solo = sim.run()          # compiles + runs the single-point program
    solo_wall = sim.run().wall_time_s  # warm second run (stable number)
    solo_wall = min(solo_wall, solo.wall_time_s)
    sweeps = [dataclasses.replace(sim.params, n_clients=int(nc),
                                  spawn_rate=float(nc) / 30.0)
              for nc in loads]
    res = sim.run_batch(sweeps)
    ratio = res.wall_time_s / max(solo_wall, 1e-9)
    seq_ratio = res.wall_time_s / max(n_points * solo_wall, 1e-9)
    ncpu = os.cpu_count() or 1
    emit(f"fig11/sweep8/points", n_points, "", f"loads={loads}")
    emit(f"fig11/sweep8/solo_wall_s", f"{solo_wall:.2f}", "",
         f"solo_compile_s={solo.compile_time_s:.1f}")
    emit(f"fig11/sweep8/batch_wall_s", f"{res.wall_time_s:.2f}",
         "< 3x solo", f"compile_s={res.compile_time_s:.1f} (one compile)")
    emit(f"fig11/sweep8/batch_over_solo", f"{ratio:.2f}",
         "< 3.0" if ncpu >= n_points else "",
         f"cpu_count={ncpu} (needs >= {n_points} parallel lanes)")
    # the < targets assume the sweep points can run in parallel — on a
    # host with fewer lanes than points they are not reachable, so don't
    # print a permanently-failing reference there
    seq_target = "< 1.0" if ncpu >= n_points else ""
    emit(f"fig11/sweep8/batch_over_sequential", f"{seq_ratio:.2f}",
         seq_target,
         "one dispatch for the whole sweep (sequential runs share one "
         "compile via the executable cache)")
    return ratio, seq_ratio


def main():
    header("Fig 11: scaling policies — per-instance milicores "
           "(one run_batch per policy)")
    raw = {}
    for name, pid in POLICIES:
        cells, res = run_policy_sweep(pid, LOADS)
        emit(f"fig11/{name}/sweep_wall_s", f"{res.wall_time_s:.2f}", "",
             f"compile_s={res.compile_time_s:.1f} points={len(LOADS)}")
        for nc, cell in cells.items():
            raw[(name, nc)] = cell[0]
            raw[(name, nc, "meta")] = (cell[1], cell[2])
    # one unit anchor: paper NS@300
    k = PAPER[("NS", 300)] / raw[("NS", 300)]
    for name, pid in POLICIES:
        for nc in LOADS:
            mc = raw[(name, nc)] * k
            rep, n_on = raw[(name, nc, "meta")]
            emit(f"fig11/{name}/clients={nc}/milicores", f"{mc:.2f}",
                 f"{PAPER[(name, nc)]:.2f}",
                 f"instances={n_on} scale_out={rep.scale_out} "
                 f"scale_up={rep.scale_up}")
    for nc in LOADS:
        hs_vs_ns = 1.0 - raw[("HS", nc)] / raw[("NS", nc)]
        vs_vs_ns = raw[("VS", nc)] / raw[("NS", nc)] - 1.0
        paper_hs = 1.0 - PAPER[("HS", nc)] / PAPER[("NS", nc)]
        paper_vs = PAPER[("VS", nc)] / PAPER[("NS", nc)] - 1.0
        emit(f"fig11/clients={nc}/HS_reduction", f"{hs_vs_ns:.3f}",
             f"{paper_hs:.3f}")
        emit(f"fig11/clients={nc}/VS_increase", f"{vs_vs_ns:.3f}",
             f"{paper_vs:.3f}")
    sweep8_demo()


if __name__ == "__main__":
    main()
