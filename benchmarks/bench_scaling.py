"""Paper Fig 11 — scaling-policy demonstration (§6.4).

NS / HS / VS on the SockShop configuration at 300 / 500 / 1000 clients,
reporting average per-instance CPU usage in milicores.  The paper's claims:

  * HS uses ≈ 70 % fewer milicores per instance than NS (scale-out spreads
    the same work over 2–4 replicas),
  * VS uses ≈ 10–15 % more than NS (raised limits let saturated instances
    consume beyond their original share),
  * usage grows with client load for every policy.

Absolute milicores are reported in paper units via a single conversion
constant fitted on NS@300 (the paper's own unit anchor, 104.76 mc).
"""
from __future__ import annotations

import numpy as np

from repro.configs import sockshop
from repro.core import policies, summarize
from repro.core.types import INST_ON

from .common import emit, header

LOADS = [300, 500, 1000]
POLICIES = [("NS", policies.SCALE_NONE), ("HS", policies.SCALE_HORIZONTAL),
            ("VS", policies.SCALE_VERTICAL)]
PAPER = {  # milicores from §6.4
    ("NS", 300): 104.76, ("HS", 300): 31.52, ("VS", 300): 115.77,
    ("NS", 500): 174.24, ("HS", 500): 52.52, ("VS", 500): 192.99,
    ("NS", 1000): 348.52, ("HS", 1000): 97.74, ("VS", 1000): 399.77,
}


def run_cell(policy_id: int, n_clients: int, seed: int = 0):
    # Deviation from §6.3 (documented in EXPERIMENTS.md): the paper's NS
    # series is linear through 1000 clients, which implies an unsaturated
    # cluster — so Fig 11 runs with share=4725 (util ≈ 0.8 at the hottest
    # service under 1000 clients).  Thresholds sized so HS spreads every
    # busy service over ~3.3 replicas (the paper's constant HS ratio) and
    # VS's resize churn surcharge reproduces its constant ≈ +11 %.
    sim = sockshop.make_sim(
        n_clients=n_clients, duration_s=600.0, share=4725.0,
        scaling_policy=policy_id, seed=seed,
        hs_util_hi=0.03, hs_util_lo=0.002,
        vs_util_hi=0.14, vs_util_lo=0.01,
        vs_up_factor=1.5, vs_down_factor=0.8,
        util_ema=0.1,
        idle_mips_frac=0.01, vs_overhead_frac=0.11,
    )
    res = sim.run()
    rep = summarize(sim, res)
    st = res.state
    on = np.asarray(st.instances.status) == INST_ON
    usage = np.asarray(st.instances.usage_sum)  # ∫ used_mips dt
    sim_t = float(st.time)
    per_inst = usage[on] / sim_t
    return float(per_inst.mean()), rep, int(on.sum())


def main():
    header("Fig 11: scaling policies — per-instance milicores")
    raw = {}
    for name, pid in POLICIES:
        for nc in LOADS:
            raw[(name, nc)], rep, n_on = run_cell(pid, nc)
            raw[(name, nc, "meta")] = (rep, n_on)
    # one unit anchor: paper NS@300
    k = PAPER[("NS", 300)] / raw[("NS", 300)]
    for name, pid in POLICIES:
        for nc in LOADS:
            mc = raw[(name, nc)] * k
            rep, n_on = raw[(name, nc, "meta")]
            emit(f"fig11/{name}/clients={nc}/milicores", f"{mc:.2f}",
                 f"{PAPER[(name, nc)]:.2f}",
                 f"instances={n_on} scale_out={rep.scale_out} "
                 f"scale_up={rep.scale_up}")
    for nc in LOADS:
        hs_vs_ns = 1.0 - raw[("HS", nc)] / raw[("NS", nc)]
        vs_vs_ns = raw[("VS", nc)] / raw[("NS", nc)] - 1.0
        paper_hs = 1.0 - PAPER[("HS", nc)] / PAPER[("NS", nc)]
        paper_vs = PAPER[("VS", nc)] / PAPER[("NS", nc)] - 1.0
        emit(f"fig11/clients={nc}/HS_reduction", f"{hs_vs_ns:.3f}",
             f"{paper_hs:.3f}")
        emit(f"fig11/clients={nc}/VS_increase", f"{vs_vs_ns:.3f}",
             f"{paper_vs:.3f}")


if __name__ == "__main__":
    main()
