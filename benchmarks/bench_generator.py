"""Paper Fig 9 — Request Generator verification (§6.2).

Runs the four (N_c, v, p) configurations of the figure and reports the
relative error of the simulated client-count / QPS / total-request curves
against the closed forms (Eqs 1, 3, 4).
"""
from __future__ import annotations

import numpy as np

from repro.core import (SimCaps, SimParams, Simulation, linear_chain,
                        qps_analytic, total_requests_analytic)

from .common import emit, header

# Fig 9: four configs; ramp knee at N_c/v ≈ 100 s as highlighted in the text.
CONFIGS = [
    dict(n_clients=200, spawn_rate=2.0, p=(2.0, 6.0)),
    dict(n_clients=200, spawn_rate=2.0, p=(3.0, 5.0)),
    dict(n_clients=100, spawn_rate=1.0, p=(3.0, 5.0)),
    dict(n_clients=100, spawn_rate=1.0, p=(2.0, 6.0)),
]


def run_one(cfg, n_ticks=4000, dt=0.1, seed=0):
    g = linear_chain(1, mi=1.0)
    caps = SimCaps(n_clients=cfg["n_clients"], max_requests=400_000,
                   max_cloudlets=4096, max_instances=4, n_vms=2, d_max=1,
                   max_replicas=1)
    params = SimParams(dt=dt, n_ticks=n_ticks, n_clients=cfg["n_clients"],
                       spawn_rate=cfg["spawn_rate"], wait_lo=cfg["p"][0],
                       wait_hi=cfg["p"][1], seed=seed)
    sim = Simulation(g, caps=caps, params=params)
    res = sim.run()
    tr = res.trace_np()
    t = (np.arange(n_ticks) + 1) * dt

    # Eq 1 — client ramp
    exp_n = np.minimum(cfg["n_clients"], np.floor(cfg["spawn_rate"] * t) + 1)
    err_n = np.abs(tr["active_clients"] - exp_n).max()

    # Eq 3 — steady-state QPS
    ramp_ticks = int(cfg["n_clients"] / cfg["spawn_rate"] / dt)
    qps = tr["generated"] / dt
    steady = qps[min(2 * ramp_ticks, n_ticks - 500):].mean()
    exp_qps = qps_analytic(np.array([1e9]), params)[0]
    err_qps = abs(steady - exp_qps) / exp_qps

    # Eq 4 — cumulative requests (+N(t): clients fire on activation)
    total = np.cumsum(tr["generated"])
    exp_total = total_requests_analytic(t, params) + exp_n
    sel = t > 5.0
    err_total = (np.abs(total[sel] - exp_total[sel])
                 / np.maximum(exp_total[sel], 1.0)).mean()
    return err_n, steady, exp_qps, err_qps, err_total, res


def main():
    header("Fig 9: request generator vs Eqs 1/3/4")
    for i, cfg in enumerate(CONFIGS):
        err_n, qps, exp_qps, err_qps, err_total, res = run_one(cfg)
        tag = (f"Nc={cfg['n_clients']}_v={cfg['spawn_rate']}"
               f"_p={cfg['p'][0]}-{cfg['p'][1]}")
        emit(f"fig9/{tag}/eq1_max_client_err", f"{err_n:.0f}",
             "0 (exact ramp)")
        emit(f"fig9/{tag}/eq3_qps", f"{qps:.2f}", f"{exp_qps:.2f}",
             f"rel_err={err_qps:.3f}")
        emit(f"fig9/{tag}/eq4_total_rel_err", f"{err_total:.4f}", "<0.1")
        emit(f"fig9/{tag}/wall_s", f"{res.wall_time_s:.2f}")


if __name__ == "__main__":
    main()
