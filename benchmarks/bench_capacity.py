"""Paper Table 2 — simulation-capacity test (§6.1).

Four cases × two parameter sets, mirroring the paper's object counts
(requests / services / instances / cloudlets) on a single machine.  The
paper's engine is a Java event heap; ours is a vectorized tensor DES, so
we report wall time (excluding one-off XLA compilation, which is also
reported) against the paper's published runtimes.

Case structure (paper's counts; our topology interpretation in brackets):
  1: 1 service × 10³ instances, 10⁵/10⁶ requests → 1 cloudlet per request
  2: 5×10³/5×10⁴ parallel services (star fan-out), 10³ requests
     → 5×10⁶/5×10⁷ cloudlets [paper lists "instances 1" = 1 replica/service]
  3: 10²/10³ services × 3 replicas, 10⁴ requests
  4: 5×10³ services × 3 replicas, 10³/10⁴ requests
"""
from __future__ import annotations

import numpy as np

from repro.core import SimCaps, SimParams, Simulation, InstanceTemplate
from repro.core.graph import build_graph

from .common import emit, header

PAPER_S = {  # running time in seconds from Table 2
    ("case1", 0): 1.95, ("case1", 1): 13.29,
    ("case2", 0): 0.84, ("case2", 1): 2.73,
    ("case3", 0): 0.94, ("case3", 1): 1.40,
    ("case4", 0): 4.58, ("case4", 1): 9.56,
}


def flat_services(n: int, mi: float) -> "ServiceGraph":
    """n independent services, one API entering all of them (star without
    a gateway node — fan-out happens at request generation)."""
    names = [f"s{i}" for i in range(n)]
    return build_graph(names, {}, [("api", names[0], 1.0)],
                       {nm: mi for nm in names}, d_max=1)


def build_case(n_requests, n_services, replicas, fanout=1,
               use_pallas_interpret=False, network=False, faults=False,
               chaos2=False, telemetry=False, slo=False):
    """Build a capacity Simulation sized to the Table 2 object counts;
    returns (sim, meta) where meta records the sizing decisions.

    ``network=True`` runs the same case with the network fabric enabled
    (DESIGN.md §6) on amply-provisioned NICs: the Transit phase executes
    every tick (client→entry payloads cross host ingress ports) without
    starving the workload, so the wall-time delta is the phase's overhead.

    ``faults=True`` enables the Disruption phase (DESIGN.md §7) with mild
    chaos (long MTBF, quick MTTR, retries on): the full failure/retry/
    breaker machinery runs every tick without collapsing throughput, so
    the wall-time delta is the phase's overhead (target ≤ 1.3×).

    ``chaos2=True`` layers the second-generation gray-failure machinery
    (§7.1) on top of ``faults``: fail-slow episodes, NIC brownout spread,
    zone-correlated draws over a 4-domain cluster, partition draws and
    per-replica outlier ejection all sample every tick, so the delta over
    the fault-free case prices the FULL chaos surface (same ≤ 1.3×
    target, tracked as ``<tag>+chaos2``).

    ``telemetry=True`` streams observability (DESIGN.md §9): per-window
    metric rows flushed through the io_callback tap every 16 ticks plus
    1-in-100 span sampling — the delta over the telemetry-off case is
    the observation cost (target ≤ 1.05×, tracked as ``<tag>+obs``).

    ``slo=True`` (implies ``telemetry``) additionally compiles the
    Alerting stage (DESIGN.md §10) with an ENABLED run-wide objective, so
    the SLI accumulate, window seal, burn rules, state machine and event
    ring all execute every tick — the delta over ``+obs`` is the alert
    plane's cost (tracked as ``<tag>+slo``, target ≤ 1.1× telemetry-off:
    the SLI scatter-add is real per-tick pool work, not pure
    observation).
    """
    mi = 50.0
    if fanout > 1:
        graph = flat_services(n_services, mi)
        api_entries = [[f"s{i}" for i in range(n_services)]]
    else:
        graph = flat_services(n_services, mi)
        api_entries = None

    n_inst = n_services * replicas
    n_vms = max(n_inst // 64, 4)
    dt = 0.5
    fanout = max(fanout, 1)
    avg_wait_ticks = 4.0 / dt

    # Admission sizing: pick k_fire (requests admitted per tick) so the
    # active-cloudlet pool holds ~2 ticks of arrivals with 2× head-room,
    # then give the run enough ticks to admit everything + drain.
    target_ticks = 500
    k_fire = max(int(np.ceil(n_requests / target_ticks)), 1)
    if 5 * k_fire * fanout > 2 * (1 << 18):
        k_fire = max(2 * (1 << 18) // (5 * fanout), 1)
    pool = int(min(max(4 * k_fire * fanout, 1 << 12), 1 << 18))
    nc = int(min(max(k_fire * avg_wait_ticks, 64), 1 << 16))
    fire_rate = min(k_fire, nc / avg_wait_ticks)       # requests per tick
    n_ticks = int(n_requests / fire_rate * 1.25) + 60
    duration = n_ticks * dt

    caps = SimCaps(
        n_clients=nc,
        max_requests=n_requests + nc + 8,
        max_cloudlets=pool,
        max_instances=n_inst,
        n_vms=n_vms,
        d_max=1,
        max_replicas=replicas,
        k_fire=k_fire,
    )
    fault_kw = dict(
        faults="chaos", host_mtbf_s=duration * 2.0, host_mttr_s=2 * dt,
        inst_kill_rate=0.0, retry_timeout_s=20 * duration, retry_budget=2,
    ) if (faults or chaos2) else {}
    if chaos2:
        # mild gray chaos: every §7.1 stream samples each tick without
        # collapsing throughput (rates sized to a handful of episodes)
        fault_kw.update(
            host_slow_mtbf_s=duration, host_slow_mttr_s=4 * dt,
            host_slow_factor=0.5, nic_degrade_spread=0.2,
            zone_slow_rate=1.0 / duration,
            zone_partition_rate=1.0 / duration,
            zone_partition_mttr_s=4 * dt,
            eject_err_thresh=0.8, eject_cooldown_s=4 * dt)
    tel_kw = dict(
        telemetry="stream", tel_window_ticks=16, tel_windows=8,
        tel_span_k=100, tel_span_cap=4096,
        # staging budget: ~15 sampled finishers/tick expected at case1b —
        # without it the 4096-slot ring re-inflates the per-tick span
        # build the rank compaction exists to avoid
        tel_span_tick_cap=64,
    ) if (telemetry or slo) else {}
    if slo:
        tel_kw.update(alerting="burn", slo_budget=0.05,
                      slo_short_wins=2, slo_long_wins=4,
                      slo_for_ticks=2, slo_event_cap=256)
    params = SimParams(
        dt=dt, n_ticks=n_ticks, n_clients=nc,
        spawn_rate=nc / 5.0, wait_lo=2.0, wait_hi=6.0,
        num_limit=n_requests, seed=0,
        use_pallas_tick=use_pallas_interpret,
        pallas_interpret=use_pallas_interpret,
        network="fabric" if network else "uniform",
        # ample per-host NICs: the phase runs, the workload doesn't starve
        nic_egress_mbps=10_000.0, nic_ingress_mbps=10_000.0,
        **fault_kw, **tel_kw,
    )
    # Instance speed: each tick's per-instance batch drains in ~0.4 ticks,
    # keeping residence ≈ 2 ticks and utilization < 1 (no blow-up).
    a_i = fire_rate * fanout / n_inst        # cloudlet arrivals/inst/tick
    mips = max(a_i, 0.4) * mi / (0.4 * dt)
    tmpl = InstanceTemplate(mips=mips, limit_mips=2 * mips,
                            ram=1.0, limit_ram=2.0, bw=100.0,
                            replicas=replicas)
    vm_mips = np.full(n_vms, 2.0 * mips * n_inst / n_vms + 1e4, np.float32)
    vm_ram = np.full(n_vms, 1e9, np.float32)
    host_zone = (np.arange(n_vms, dtype=np.int32) % 4 if chaos2 else None)
    sim = Simulation(graph, caps=caps, params=params, default_template=tmpl,
                     vm_mips=vm_mips, vm_ram=vm_ram,
                     api_entries=api_entries, host_zone=host_zone)
    meta = dict(n_requests=n_requests, n_services=n_services,
                replicas=replicas, n_instances=n_inst, n_ticks=n_ticks,
                pool=pool, k_fire=k_fire)
    return sim, meta


# Table 2 case registry: tag → (n_requests, n_services, replicas,
# cloudlets_per_request, fanout)
CASES = {
    "case1a": (10 ** 5, 1, 1000, 1, 1),
    "case1b": (10 ** 6, 1, 1000, 1, 1),
    "case2a": (10 ** 3, 5 * 10 ** 3, 1, 5 * 10 ** 3, 5 * 10 ** 3),
    "case2b": (10 ** 3, 5 * 10 ** 4, 1, 5 * 10 ** 4, 5 * 10 ** 4),
    "case3a": (10 ** 4, 10 ** 2, 3, 10 ** 2, 10 ** 2),
    "case3b": (10 ** 4, 10 ** 3, 3, 10 ** 3, 10 ** 3),
    "case4a": (10 ** 3, 5 * 10 ** 3, 3, 5 * 10 ** 3, 5 * 10 ** 3),
    "case4b": (10 ** 4, 5 * 10 ** 3, 3, 5 * 10 ** 3, 5 * 10 ** 3),
}


def perf_record(tag: str, backend: str = "jnp", scale: float = 1.0,
                network: bool = False, faults: bool = False,
                chaos2: bool = False, telemetry: bool = False,
                slo: bool = False) -> dict:
    """One BENCH_perf.json record: wall seconds + ticks/sec for a Table 2
    case.  ``scale`` shrinks the request count (pallas-interpret runs are
    orders of magnitude slower than compiled backends).  ``network=True``
    re-runs the case with the fabric's Transit phase on (case tagged
    ``<tag>+net``), ``faults=True`` with the Disruption phase on
    (``<tag>+faults``), ``chaos2=True`` with the full gray-failure
    surface on (``<tag>+chaos2``), ``telemetry=True`` with streaming
    observability on (``<tag>+obs``), ``slo=True`` with burn-rate
    alerting on top (``<tag>+slo``), so each phase's overhead is
    tracked PR-over-PR."""
    n_requests, n_services, replicas, cpr, fanout = CASES[tag]
    n_requests = max(int(n_requests * scale), 100)
    sim, meta = build_case(n_requests, n_services, replicas, fanout,
                           use_pallas_interpret=(backend
                                                 == "pallas-interpret"),
                           network=network, faults=faults, chaos2=chaos2,
                           telemetry=telemetry, slo=slo)
    res = sim.run()
    suffix = ("+net" if network else "") \
        + ("+chaos2" if chaos2 else ("+faults" if faults else "")) \
        + ("+slo" if slo else ("+obs" if telemetry else ""))
    return dict(
        case=tag + suffix, backend=backend, scale=scale,
        requests=int(res.state.requests.count),
        cloudlets=int(res.state.counters.spawned),
        n_services=n_services, n_instances=meta["n_instances"],
        n_ticks=meta["n_ticks"],
        wall_s=round(res.wall_time_s, 4),
        compile_s=round(res.compile_time_s, 4),
        ticks_per_s=round(meta["n_ticks"] / max(res.wall_time_s, 1e-9), 2),
        paper_s=PAPER_S.get((tag[:-1], 0 if tag.endswith("a") else 1)),
    )


def bytes_per_tick(tag: str, network: bool = False,
                   faults: bool = False, chaos2: bool = False) -> float:
    """Per-tick "bytes accessed" of the compiled scan (XLA cost_analysis)
    for a Table 2 case — the footprint metric behind the mode-keyed pool
    layout (DESIGN.md §2.2): wall clocks drift on shared containers, but
    the compiled program's byte traffic is deterministic, so the reclaim
    from dropping disabled-phase columns is tracked PR-over-PR without
    timing noise.  Compiles (cached) but never executes the case."""
    from repro.core.types import DynParams

    n_requests, n_services, replicas, cpr, fanout = CASES[tag]
    sim, meta = build_case(n_requests, n_services, replicas, fanout,
                           network=network, faults=faults, chaos2=chaos2)
    state = sim.init_state()
    dyn = DynParams.from_params(sim.params)
    compiled, _ = sim._get_compiled(state, dyn)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # older jax returns one dict per device
        ca = ca[0]
    return float(ca.get("bytes accessed", -1.0)) / meta["n_ticks"]


def run_case(tag, n_requests, n_services, replicas, cloudlets_per_req,
             paper_s, fanout=1):
    """Run one Table 2 case and emit the CSV rows."""
    sim, meta = build_case(n_requests, n_services, replicas, fanout)
    res = sim.run()
    st = res.state
    n_inst = meta["n_instances"]
    emit(f"table2/{tag}/requests", int(st.requests.count), n_requests)
    emit(f"table2/{tag}/cloudlets", int(st.counters.spawned),
         cloudlets_per_req * n_requests)
    emit(f"table2/{tag}/finished", int(st.counters.finished), "",
         f"dropped={int(st.counters.dropped_cloudlets)}")
    emit(f"table2/{tag}/wall_s", f"{res.wall_time_s:.2f}", f"{paper_s:.2f}",
         f"compile_s={res.compile_time_s:.1f} "
         f"services={n_services} instances={n_inst}")
    return res


def main():
    header("Table 2: capacity test (wall seconds, compile excluded)")
    # cases 1: requests-dominated; 2: services-dominated star fan-out;
    # 3: balanced 1:3 service:instance ratio; 4: high-instance scenarios
    for tag, (n_requests, n_services, replicas, cpr, fanout) in CASES.items():
        paper = PAPER_S[(tag[:-1], 0 if tag.endswith("a") else 1)]
        run_case(tag, n_requests, n_services, replicas, cpr, paper,
                 fanout=fanout)


if __name__ == "__main__":
    main()
