"""Shared benchmark helpers: CSV emission in the repo-wide format.

Every benchmark prints ``name,value,paper_reference,derived`` rows so
``benchmarks/run.py`` can aggregate one table per paper table/figure.
"""
from __future__ import annotations

import sys
import time


def emit(name: str, value, reference="", derived=""):
    print(f"{name},{value},{reference},{derived}")
    sys.stdout.flush()


def header(title: str):
    print(f"# === {title} ===")
    print("name,value,paper_reference,derived")
    sys.stdout.flush()


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
