"""Aggregate benchmark runner — one section per paper table/figure plus
the kernel micro-benchmarks.  Prints ``name,value,paper_reference,derived``
CSV rows (see common.emit).

    PYTHONPATH=src python -m benchmarks.run [--skip capacity,...]

``--perf-json`` additionally writes the machine-readable perf-trajectory
file (BENCH_perf.json): wall seconds and ticks/sec for the requested
Table 2 capacity cases on the jnp path plus a scaled-down
pallas-interpret case, so the hot-path trend is tracked across PRs, and
bytes/tick per mode (default / +net / +faults) from XLA cost_analysis —
the timing-noise-free footprint metric behind the mode-keyed pool layout
(DESIGN.md §2.2).

    PYTHONPATH=src python -m benchmarks.run --only perf \
        --perf-json BENCH_perf.json --perf-cases case1b,case2b
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def write_perf_json(path: str, cases, repeats: int = 2) -> None:
    """Best-of-N wall times per case (the capacity numbers are wall-clock
    on a shared machine; best-of is the stable statistic).  The
    ``seed_baseline_wall_s`` block of an existing file is carried over and
    speedups recomputed, so regeneration preserves the cross-PR trend."""
    import os

    import jax

    from . import bench_capacity

    baselines = {}
    bytes_baseline = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            baselines = prev.get("seed_baseline_wall_s", {})
            bytes_baseline = prev.get("pr3_bytes_per_tick", {})
        except (OSError, ValueError):
            pass

    records = []
    for tag in cases:
        best = None
        for _ in range(max(repeats, 1)):
            rec = bench_capacity.perf_record(tag, backend="jnp")
            if best is None or rec["wall_s"] < best["wall_s"]:
                best = rec
        records.append(best)
        print(f"# perf {tag}: {best['wall_s']:.2f}s "
              f"({best['ticks_per_s']:.0f} ticks/s, best of {repeats})")
    # Per-phase overhead ratios on case1b — each variant re-runs the same
    # case with one more phase compiled in, and the wall ratio over the
    # phase-off run prices that phase's per-tick cost:
    #   +net    Transit (amply-provisioned NICs)           target ≤ 1.3×
    #   +faults Disruption, mild chaos (DESIGN.md §7)      target ≤ 1.3×
    #   +chaos2 FULL §7.1 gray surface on top              target ≤ 1.3×
    #   +obs    streaming telemetry (§9)                   target ≤ 1.05×
    #   +slo    burn-rate Alerting on top, objectives ON   target ≤ 1.1×
    #           (its [C,2] SLI scatter-add is real per-tick pool work
    #           the pure-observation budget doesn't cover)
    # Baseline and variant repeats are INTERLEAVED (base, variant, base,
    # variant, …) and each side takes its own best: the container's wall
    # clock drifts several percent over the minutes a sequential protocol
    # spans (case2b churns 50M cloudlets between the one-off baseline and
    # the variants), which used to swamp the small ratios.  The
    # interleaved baseline rides along as ``base_wall_s``.
    variants = [
        ("net", dict(network=True), "net_overhead_ratio", "network-off"),
        ("faults", dict(faults=True), "faults_overhead_ratio",
         "fault-free"),
        ("chaos2", dict(chaos2=True), "chaos2_overhead_ratio",
         "fault-free"),
        ("obs", dict(telemetry=True), "obs_overhead_ratio",
         "telemetry-off"),
        ("slo", dict(slo=True), "slo_overhead_ratio", "telemetry-off"),
    ]
    if "case1b" in cases:
        for name, kw, ratio_key, vs in variants:
            best, base_wall = None, float("inf")
            for _ in range(max(repeats, 1)):
                base_wall = min(base_wall, bench_capacity.perf_record(
                    "case1b", backend="jnp")["wall_s"])
                rec = bench_capacity.perf_record("case1b", backend="jnp",
                                                 **kw)
                if best is None or rec["wall_s"] < best["wall_s"]:
                    best = rec
            best[ratio_key] = round(best["wall_s"] / max(base_wall, 1e-9),
                                    3)
            best["base_wall_s"] = round(base_wall, 4)
            records.append(best)
            print(f"# perf case1b+{name}: {best['wall_s']:.2f}s "
                  f"({best[ratio_key]}x of {vs}, interleaved base "
                  f"{base_wall:.2f}s)")
    # interpret-mode kernel trend on a scaled-down case (interpret is
    # orders of magnitude slower — the trend matters, not the magnitude)
    rec = bench_capacity.perf_record("case1a", backend="pallas-interpret",
                                     scale=0.01)
    records.append(rec)
    print(f"# perf case1a/pallas-interpret(x0.01): {rec['wall_s']:.2f}s")
    for rec in records:
        base = baselines.get(rec["case"])
        if base and rec["backend"] == "jnp" and rec.get("scale", 1.0) == 1.0:
            rec["speedup_vs_seed"] = round(base / rec["wall_s"], 2)
    # batched-sweep economics (see bench_scaling.sweep8_demo docstring)
    from . import bench_scaling
    ratio, seq_ratio = bench_scaling.sweep8_demo(duration_s=120.0)
    records.append({
        "case": "sweep8_hs", "backend": "jnp",
        "batch_over_solo": round(ratio, 3),
        "batch_over_sequential": round(seq_ratio, 3),
        "cpu_count": os.cpu_count(),
    })
    doc = {
        "generated_unix": int(time.time()),
        "jax_backend": jax.default_backend(),
        "records": records,
    }
    # bytes/tick per mode from XLA cost_analysis — NOT wall clock (container
    # walls drift within a session); this is the stable footprint metric
    # for the mode-keyed pool layout (DESIGN.md §2.2).  The PR-3 baseline
    # (fixed 10-int/5-float layout) is carried over so the reclaim ratio
    # stays comparable across regenerations.
    if "case1b" in cases:
        bpt = {}
        for mode_tag, kw in (("case1b", {}),
                             ("case1b+net", dict(network=True)),
                             ("case1b+faults", dict(faults=True)),
                             ("case1b+chaos2", dict(chaos2=True))):
            bpt[mode_tag] = round(
                bench_capacity.bytes_per_tick("case1b", **kw), 1)
            base = bytes_baseline.get(mode_tag)
            ratio = f" ({bpt[mode_tag] / base - 1.0:+.1%} vs pr3)" \
                if base else ""
            print(f"# bytes/tick {mode_tag}: {bpt[mode_tag]:.0f}{ratio}")
        doc["bytes_per_tick"] = bpt
        if bytes_baseline:
            doc["pr3_bytes_per_tick"] = bytes_baseline
            doc["bytes_reclaim_vs_pr3"] = {
                k: round(1.0 - v / bytes_baseline[k], 4)
                for k, v in bpt.items() if bytes_baseline.get(k)}
    if baselines:
        doc["seed_baseline_wall_s"] = baselines
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", default="",
                    help="comma list: capacity,generator,response,scaling,"
                         "kernels,perf")
    ap.add_argument("--only", default="")
    ap.add_argument("--perf-json", default="",
                    help="path for the machine-readable perf records "
                         "(enables the perf section)")
    ap.add_argument("--perf-cases", default="case1b,case2b",
                    help="Table 2 cases to time for --perf-json")
    ap.add_argument("--perf-repeats", type=int, default=2)
    args = ap.parse_args()
    skip = set(filter(None, args.skip.split(",")))
    only = set(filter(None, args.only.split(",")))

    from . import (bench_capacity, bench_generator, bench_kernels,
                   bench_response, bench_scaling)
    sections = [
        ("generator", bench_generator.main),   # Fig 9
        ("capacity", bench_capacity.main),     # Table 2
        ("response", bench_response.main),     # Fig 10
        ("scaling", bench_scaling.main),       # Fig 11
        ("kernels", bench_kernels.main),
    ]
    if args.perf_json:
        cases = [c for c in args.perf_cases.split(",") if c]
        sections.append(
            ("perf", lambda: write_perf_json(args.perf_json, cases,
                                             args.perf_repeats)))
    failed = []
    for name, fn in sections:
        if name in skip or (only and name not in only):
            print(f"# --- skipping {name} ---")
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failed.append(name)
            print(f"# !!! section {name} FAILED")
            traceback.print_exc()
        print(f"# --- {name} done in {time.perf_counter() - t0:.1f}s ---",
              flush=True)
    if failed:
        sys.exit(f"failed sections: {failed}")


if __name__ == "__main__":
    main()
