"""Aggregate benchmark runner — one section per paper table/figure plus
the kernel micro-benchmarks.  Prints ``name,value,paper_reference,derived``
CSV rows (see common.emit).

    PYTHONPATH=src python -m benchmarks.run [--skip capacity,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", default="",
                    help="comma list: capacity,generator,response,scaling,"
                         "kernels")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    skip = set(filter(None, args.skip.split(",")))
    only = set(filter(None, args.only.split(",")))

    from . import (bench_capacity, bench_generator, bench_kernels,
                   bench_response, bench_scaling)
    sections = [
        ("generator", bench_generator.main),   # Fig 9
        ("capacity", bench_capacity.main),     # Table 2
        ("response", bench_response.main),     # Fig 10
        ("scaling", bench_scaling.main),       # Fig 11
        ("kernels", bench_kernels.main),
    ]
    failed = []
    for name, fn in sections:
        if name in skip or (only and name not in only):
            print(f"# --- skipping {name} ---")
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failed.append(name)
            print(f"# !!! section {name} FAILED")
            traceback.print_exc()
        print(f"# --- {name} done in {time.perf_counter() - t0:.1f}s ---",
              flush=True)
    if failed:
        sys.exit(f"failed sections: {failed}")


if __name__ == "__main__":
    main()
