"""Paper Fig 10 — SockShop response-time accuracy (§6.3).

Runs the calibrated SockShop simulation at 100..300 clients and reports
accuracy = 1 - |sim - testbed| / testbed against the paper's *published*
testbed measurements (749 ms @ 100 clients, 2574 ms @ 300).  The figure's
intermediate bars carry no numeric labels, so 150/200/250 are reported as
predictions without a reference (the simulated curve is convex, as PS
queueing theory dictates near saturation — a linear interpolation of the
endpoints would be a fabricated reference).  The paper claims
94.53–99.46 % accuracy; our acceptance bar is min accuracy ≥ 94.5 % over
the published points.

``--calibrate`` re-runs the 2-knob secant fit (mi_scale on the congestion
gap, net_latency on the level) instead of using the frozen constants.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.configs import sockshop
from repro.core import summarize

from .common import emit, header

CLIENTS = [100, 150, 200, 250, 300]


def run_point(nc, **kw):
    sim = sockshop.make_sim(n_clients=nc, duration_s=600.0, **kw)
    rep = summarize(sim, sim.run())
    return rep


def calibrate(max_iter=8):
    """2-knob secant: mi_scale fits R300-R100; net_latency fits R100."""
    target_gap = sockshop.TESTBED_MS[300] - sockshop.TESTBED_MS[100]

    def gap(mi):
        r = [run_point(nc, mi_scale=mi, net_latency_s=0.0).avg_response_ms
             for nc in (100, 300)]
        return r[1] - r[0], r[0]

    b0, b1 = 1.0, 1.05
    g0, _ = gap(b0)
    g1, _ = gap(b1)
    for _ in range(max_iter):
        if abs(g1 - target_gap) / target_gap < 0.03:
            break
        b2 = float(np.clip(b1 + (target_gap - g1) * (b1 - b0)
                           / max(g1 - g0, 1e-6), 0.5, 1.5))
        b0, g0, b1 = b1, g1, b2
        g1, _ = gap(b1)
    _, r100 = gap(b1)
    # per-hop latency shifts every config equally; solve linearly then refine
    lat = max((sockshop.TESTBED_MS[100] - r100) / 1000.0 / 1.5, 0.0)
    for _ in range(4):
        r = run_point(100, mi_scale=b1, net_latency_s=lat).avg_response_ms
        if (abs(r - sockshop.TESTBED_MS[100])
                / sockshop.TESTBED_MS[100] < 0.015):
            break
        lat = max(lat + (sockshop.TESTBED_MS[100] - r) / 1000.0 / 1.5, 0.0)
    return dict(mi_scale=b1, net_latency_s=lat)


def main():
    header("Fig 10: SockShop response-time accuracy vs testbed")
    kw = {}
    if "--calibrate" in sys.argv:
        kw = calibrate()
        emit("fig10/calibrated_mi_scale", f"{kw['mi_scale']:.4f}")
        emit("fig10/calibrated_net_latency_s", f"{kw['net_latency_s']:.4f}")
    accs = []
    for nc in CLIENTS:
        rep = run_point(nc, **kw)
        if nc in (100, 300):                      # published values
            ref = sockshop.TESTBED_MS[nc]
            acc = 1.0 - abs(rep.avg_response_ms - ref) / ref
            accs.append(acc)
            emit(f"fig10/clients={nc}/avg_response_ms",
                 f"{rep.avg_response_ms:.0f}", f"{ref:.0f}",
                 f"accuracy={acc:.4f}")
        else:                                     # unlabeled bars: predict
            emit(f"fig10/clients={nc}/avg_response_ms",
                 f"{rep.avg_response_ms:.0f}", "n/a (unpublished bar)",
                 "prediction")
    emit("fig10/min_accuracy", f"{min(accs):.4f}", ">=0.9453 (paper)")
    emit("fig10/max_accuracy", f"{max(accs):.4f}", "<=0.9946 (paper)")
    assert min(accs) >= 0.945, "accuracy gate failed"


if __name__ == "__main__":
    main()
