"""Kernel micro-benchmarks: ref-path wall time (CPU) + interpret-mode
validation status for each Pallas kernel.  Real-TPU timings are N/A in
this container; the kernels' roofline behaviour is covered by §Roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cloudlet_step import cloudlet_step, cloudlet_step_ref
from repro.kernels.flash_attention import attention, attention_ref
from repro.kernels.ssd_scan import ssd, ssd_ref
from repro.kernels.tropical import tropical_matmul
from repro.kernels.tropical.ref import tropical_matmul as tropical_ref

from .common import emit, header


def _time(fn, *args, iters=5):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # µs


def main():
    header("kernel micro-benchmarks (CPU ref path, µs/call)")
    rng = np.random.default_rng(0)

    # tropical: 512×512 closure-sized matmul
    a = jnp.asarray(np.where(rng.random((1, 512, 512)) < 0.5,
                             rng.normal(size=(1, 512, 512)), -np.inf),
                    jnp.float32)
    us = _time(jax.jit(lambda x: tropical_matmul(x, x, use_pallas=False)), a)
    chk = np.allclose(
        np.asarray(tropical_matmul(a, a, use_pallas=True, interpret=True)),
        np.asarray(tropical_ref(a, a)), rtol=1e-6)
    emit("kernels/tropical_512", f"{us:.0f}", "",
         f"interpret_matches_ref={chk}")

    # flash attention: B1 H8 T1024 D64
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    us = _time(jax.jit(lambda q: attention_ref(q, q, q)), q)
    got = attention(q, q, q, impl="flash", interpret=True, bq=128, bk=128)
    chk = np.allclose(np.asarray(got, np.float32),
                      np.asarray(attention_ref(q, q, q), np.float32),
                      rtol=3e-2, atol=3e-2)
    emit("kernels/flash_attention_1k", f"{us:.0f}", "",
         f"interpret_matches_ref={chk}")

    # ssd: B1 T512 H4 P32 N32
    x = jnp.asarray(rng.normal(size=(1, 512, 4, 32)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.2, (1, 512, 4)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, 4), jnp.float32)
    B = jnp.asarray(rng.normal(size=(1, 512, 1, 32)) / 6, jnp.float32)
    us = _time(jax.jit(lambda x: ssd(x, dt, A, B, B, impl="chunked",
                                     chunk=64)), x)
    got = ssd(x, dt, A, B, B, impl="kernel", interpret=True, chunk=64)
    chk = np.allclose(np.asarray(got), np.asarray(ssd_ref(x, dt, A, B, B)),
                      rtol=1e-3, atol=1e-3)
    emit("kernels/ssd_512", f"{us:.0f}", "", f"interpret_matches_ref={chk}")

    # cloudlet step: C=65536 pool
    C, I = 65536, 512
    status = jnp.asarray(rng.choice([0, 1, 2], C, p=[.3, .2, .5]), jnp.int32)
    rem = jnp.asarray(rng.uniform(1, 500, C), jnp.float32)
    inst = jnp.asarray(rng.integers(0, I, C), jnp.int32)
    rate = jnp.asarray(rng.uniform(0, 300, C), jnp.float32)
    us = _time(jax.jit(lambda s, r, i, ra: cloudlet_step_ref(
        s, r, i, ra, 1.0, 0.5, I)), status, rem, inst, rate)
    got = cloudlet_step(status[:4096], rem[:4096], inst[:4096], rate[:4096],
                        1.0, 0.5, I, use_pallas=True, interpret=True)
    want = cloudlet_step_ref(status[:4096], rem[:4096], inst[:4096],
                             rate[:4096], 1.0, 0.5, I)
    chk = all(np.allclose(np.asarray(g, np.float32),
                          np.asarray(w, np.float32), rtol=2e-5, atol=1e-4)
              for g, w in zip(got, want))
    emit("kernels/cloudlet_step_64k", f"{us:.0f}", "",
         f"interpret_matches_ref={chk}")


if __name__ == "__main__":
    main()
