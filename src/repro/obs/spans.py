"""Host-side trace reconstruction for the sampled request set (§9).

Each finished cloudlet of a sampled request left one span in the span
ring: (req, service, inst, host, src_host, edge, attempt, wait_ticks)
ints and (arrival, start, finish) f32 timestamps.  This module rebuilds
the per-request span *tree* (parentage is encoded in the edge id:
``edge = parent_service * d_max + slot`` for call edges,
``edge = S * d_max + api`` for the client→entry root) and cross-checks
the end-to-end latency three ways:

1. **Timestamp identity** — ``f32(max span finish) - f32(root arrival)``
   recomputes exactly the engine's ``response = finish - arrival``
   (finish is the scatter-max of span finishes), so for a successful
   request with all spans recorded the reconstruction is *bitwise*
   equal.
2. **Tropical closure over the span DAG** — per-span sojourn delays
   (f64 diffs of f32 timestamps: exact) closed with the same max-plus
   squaring as ``kernels/tropical`` / ``core/critical_path.py`` (Alg 2),
   mirrored here in NumPy float64 because sojourn diffs need more
   mantissa than the f32 device kernel carries.  Derive hands each
   child ``arrival = parent finish`` bitwise, so every root→leaf path
   telescopes and the closure reproduces the response exactly for
   retry-free traces (a retry re-arrives at its respawn time, which
   breaks the telescoping — those traces are flagged, not asserted).
3. **Graph-level Alg 2** — when each service ran exactly once, the
   per-service sojourns feed ``critical_path.response_times`` directly
   (f32 kernel: approximate consistency, not bitwise).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.critical_path import response_times
from ..core.graph import ServiceGraph
from ..core.types import TEL_SPAN_F_COLUMNS, TEL_SPAN_I_COLUMNS, SimState

NEG_INF = -np.inf


@dataclasses.dataclass
class Span:
    """One hop of a sampled request (a finished cloudlet)."""

    req: int
    service: int
    inst: int
    host: int
    src_host: int
    edge: int
    attempt: int
    wait_ticks: int
    arrival: np.float32
    start: np.float32
    finish: np.float32
    parent: Optional["Span"] = None
    children: List["Span"] = dataclasses.field(default_factory=list)

    @property
    def sojourn(self) -> float:
        """Queue-wait + exec + transit, exact in float64."""
        return float(np.float64(self.finish) - np.float64(self.arrival))

    @property
    def exec_s(self) -> float:
        if self.start < 0:
            return 0.0
        return float(np.float64(self.finish) - np.float64(self.start))


def spans_np(state: SimState) -> Dict[str, np.ndarray]:
    """Recorded spans as named columns, trimmed to ``span_n``."""
    tel = state.telemetry
    n = int(np.asarray(tel.span_n)[0]) if tel.span_n.size else 0
    si = np.asarray(tel.span_i)[:n]
    sf = np.asarray(tel.span_f)[:n]
    out = {c: si[:, j] for j, c in enumerate(TEL_SPAN_I_COLUMNS)}
    out.update({c: sf[:, j] for j, c in enumerate(TEL_SPAN_F_COLUMNS)})
    return out


def spans_of(state: SimState, req: Optional[int] = None) -> List[Span]:
    """Materialize :class:`Span` objects (optionally one request's)."""
    cols = spans_np(state)
    n = len(cols["req"])
    out = []
    for i in range(n):
        if req is not None and int(cols["req"][i]) != req:
            continue
        out.append(Span(
            req=int(cols["req"][i]), service=int(cols["service"][i]),
            inst=int(cols["inst"][i]), host=int(cols["host"][i]),
            src_host=int(cols["src_host"][i]), edge=int(cols["edge"][i]),
            attempt=int(cols["attempt"][i]),
            wait_ticks=int(cols["wait_ticks"][i]),
            arrival=np.float32(cols["arrival"][i]),
            start=np.float32(cols["start"][i]),
            finish=np.float32(cols["finish"][i])))
    return out


def sampled_requests(state: SimState) -> np.ndarray:
    """Request ids with at least one recorded span."""
    return np.unique(spans_np(state)["req"])


def trace_tree(spans: List[Span], n_services: int, d_max: int
               ) -> List[Span]:
    """Link spans into call trees; returns the roots.

    Parentage: a call edge ``e < S*d_max`` was spawned by service
    ``e // d_max``; ``e >= S*d_max`` is the client→entry root edge.
    The ``edge`` column is chaos-mode only — when absent (−1) every
    other span is a parent candidate.  Within the candidates the parent
    is the span whose ``finish`` equals the child's ``arrival`` bitwise
    (Derive hands successors ``arrival = parent tfin`` exactly;
    ``finish > arrival`` strictly, so timestamp links cannot cycle) —
    falling back to the sole candidate when timestamps are ambiguous.
    """
    roots = []
    by_service: Dict[int, List[Span]] = {}
    for s in spans:
        by_service.setdefault(s.service, []).append(s)
    for s in spans:
        if s.edge >= n_services * d_max:
            roots.append(s)              # client→entry edge
            continue
        if s.edge >= 0:
            cands = by_service.get(s.edge // d_max, [])
        else:                            # no edge column: match any span
            cands = [p for p in spans if p is not s]
        exact = [p for p in cands if p is not s
                 and np.float32(p.finish) == np.float32(s.arrival)]
        parent = exact[0] if exact else (
            cands[0] if s.edge >= 0 and len(cands) == 1 else None)
        if parent is None:
            roots.append(s)
        else:
            s.parent = parent
            parent.children.append(s)
    return roots


def _all_spans(roots: List[Span]) -> List[Span]:
    out, stack = [], list(roots)
    while stack:
        s = stack.pop()
        out.append(s)
        stack.extend(s.children)
    return out


def tree_latency(roots: List[Span]) -> np.float32:
    """Timestamp identity: f32(max finish) − f32(root arrival).

    Bitwise equal to the engine's recorded response for a successful
    request whose spans were all recorded (the engine's finish is the
    scatter-max of exactly these tfin values).
    """
    spans = _all_spans(roots)
    fin = np.float32(max(np.float32(s.finish) for s in spans))
    arr = np.float32(min(np.float32(s.arrival) for s in roots))
    return np.float32(fin - arr)


def np_tropical_closure(a: np.ndarray, depth: int) -> np.ndarray:
    """Max-plus closure by repeated squaring — the float64 host mirror
    of ``kernels/tropical`` (same (I ⊕ A)^(2^⌈log₂ d⌉) recurrence; f64
    because exact sojourn diffs exceed the f32 kernel's mantissa)."""
    n = a.shape[0]
    m = np.maximum(a, np.where(np.eye(n, dtype=bool), 0.0, NEG_INF))
    for _ in range(max(1, int(np.ceil(np.log2(max(depth, 2)))))):
        m = np.max(m[:, :, None] + m[None, :, :], axis=1)
    return m


def tropical_latency(roots: List[Span]) -> np.float32:
    """Alg 2 over the trace's own span DAG: close the parent→child
    delay matrix (``A[i, j] = sojourn(j)``) and take
    ``sojourn(root) + max(D*[root], 0)`` — exactly
    ``critical_path.response_times`` at span granularity."""
    spans = _all_spans(roots)
    n = len(spans)
    idx = {id(s): i for i, s in enumerate(spans)}
    a = np.full((n, n), NEG_INF)
    for s in spans:
        for c in s.children:
            a[idx[id(s)], idx[id(c)]] = c.sojourn
    d_star = np_tropical_closure(a, depth=n)
    best = NEG_INF
    for r in roots:
        i = idx[id(r)]
        best = max(best, r.sojourn + max(float(d_star[i].max()), 0.0))
    return np.float32(best)


def graph_latency(roots: List[Span], graph: ServiceGraph, api: int
                  ) -> Optional[np.float32]:
    """Graph-level Alg 2 (``critical_path.response_times``) fed with
    per-service sojourns — only defined when every service in the trace
    ran exactly once (f32 kernel: consistency check, not bitwise)."""
    spans = _all_spans(roots)
    per_svc: Dict[int, List[Span]] = {}
    for s in spans:
        per_svc.setdefault(s.service, []).append(s)
    if any(len(v) != 1 for v in per_svc.values()):
        return None
    delays = np.zeros(graph.n_services, np.float64)
    for svc, (s,) in per_svc.items():
        delays[svc] = s.sojourn
    rt = response_times(graph, delays)
    return np.float32(rt[api])


@dataclasses.dataclass
class TraceCheck:
    """One sampled request's reconstruction vs the engine's record."""

    req: int
    api: int
    n_spans: int
    retry_free: bool       # all attempts 0 → telescoping sums are exact
    failed: bool           # request completed as failed (chaos mode)
    response: np.float32   # engine-recorded response time
    tree: np.float32       # timestamp identity (bitwise when complete)
    tropical: np.float32   # span-DAG tropical closure (exact retry-free)
    graph: Optional[np.float32]  # graph-level Alg 2 (approximate)

    @property
    def exact(self) -> bool:
        return (not self.failed and self.retry_free
                and self.tree == self.response
                and self.tropical == self.response)


def verify_traces(state: SimState, graph: ServiceGraph, d_max: int
                  ) -> List[TraceCheck]:
    """Reconstruct every completed sampled request and compare its span
    tree's latency against the engine's response (see module doc for
    which comparisons are bitwise)."""
    req = state.requests
    response = np.asarray(req.response)
    api = np.asarray(req.api)
    failed_col = np.asarray(req.failed)
    out = []
    for r in sampled_requests(state):
        r = int(r)
        if response[r] < 0:              # still open at end of run
            continue
        spans = spans_of(state, r)
        roots = trace_tree(spans, graph.n_services, d_max)
        if not roots:
            continue
        out.append(TraceCheck(
            req=r, api=int(api[r]), n_spans=len(spans),
            retry_free=all(s.attempt == 0 for s in spans),
            failed=bool(failed_col[r]) if failed_col.size else False,
            response=np.float32(response[r]),
            tree=tree_latency(roots),
            tropical=tropical_latency(roots),
            graph=graph_latency(roots, graph, int(api[r]))))
    return out


def format_trace(roots: List[Span], indent: int = 0) -> str:
    """Render a span tree, one hop per line (example/debug output)."""
    lines = []
    for s in sorted(roots, key=lambda x: float(x.arrival)):
        lines.append(
            f"{'  ' * indent}svc={s.service} inst={s.inst} "
            f"host={s.host} attempt={s.attempt} "
            f"wait={s.wait_ticks}t arr={float(s.arrival):.4f} "
            f"fin={float(s.finish):.4f} sojourn={s.sojourn:.4f}s")
        if s.children:
            lines.append(format_trace(s.children, indent + 1))
    return "\n".join(lines)
