"""Opt-in observability for the tensor DES (DESIGN.md §9).

Three coordinated pieces, all default-off and bit-identical when off:

- :mod:`.telemetry` — device-side metric-row ring + sampled span ring,
  double-buffered io_callback flush (the paper's Exporter, §3.1).
- :mod:`.export` — host-side exporter registry rendering OTel /
  Prometheus-style rows live during runs.
- :mod:`.spans` — host-side trace-tree reconstruction for the seeded
  1-in-k request sample, cross-checked against the tropical-closure
  critical path (paper §4.3.2).
- :mod:`.profile` — per-phase wall/cost attribution via prefix programs
  (ROADMAP item b).
- :mod:`.slo` — per-service SLO objectives, multi-window burn-rate
  alerting, and the alert state machine feeding the control plane
  (DESIGN.md §10).

Submodules import lazily: ``profile`` imports ``core.engine`` (which
itself imports ``obs.telemetry``), so an eager package import would
cycle.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("telemetry", "export", "spans", "profile", "slo")

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
