"""SLO objectives + multi-window burn-rate alerting (DESIGN.md §10).

The Alerting tick stage turns the paper's QoS metrics into an in-sim
feedback signal: per-service latency SLIs accumulate on the telemetry
window cadence, Google-SRE-style short/long burn-rate rules evaluate over
the closed windows, and a per-(service, rule) state machine
(inactive → pending → firing → resolved, with ``for_ticks`` hysteresis)
carries `AlertState` tensors on the scan carry.  Firing alerts gate the
``hs_mode="slo_burn"`` autoscaler (scaling.py) and tighten LB outlier
ejection (faults.py).

Everything here is pure recording-rule math: the stage consumes NO tick
RNG (simcheck pins the stream digest equal to the alert-free program) and
only ever re-reads pool columns other phases already carry, so no mode's
layout grows.  With every objective disabled (budget ≤ 0 after the
per-service fallback) the rule conditions are constant-false and the
carried tensors stay zero — the sixth golden combo is bit-identical by
construction.

Alert transitions append into a fixed ring (exact drop counting, the span
discipline) and drain host-side at end of run through `export.py`'s alert
sinks — no second io_callback in the hot loop.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.types import (ALERT_FIRING, ALERT_INACTIVE, ALERT_PENDING,
                          ALERT_RESOLVED, ALERT_RULES, ALERT_STATES,
                          AlertState, SimParams, SimState)

N_RULES = len(ALERT_RULES)


def enabled(params: SimParams) -> bool:
    """True when the Alerting stage is compiled into the tick."""
    return params.telemetry == "stream" and params.alerting == "burn"


def objectives(app, dyn):
    """Resolve per-service (target_ms, budget): AppStatic overrides where
    declared (> 0), run-wide traced defaults otherwise.  A service whose
    resolved budget is ≤ 0 has no objective — its rules never fire."""
    target_ms = jnp.where(app.slo_target_ms > 0, app.slo_target_ms,
                          dyn.slo_ms)
    budget = jnp.where(app.slo_budget > 0, app.slo_budget, dyn.slo_budget)
    return target_ms, budget


def _lookback_frac(sli_win: jnp.ndarray, w_closed: jnp.ndarray,
                   n: int) -> jnp.ndarray:
    """Per-service bad-completion fraction over the last ``n`` CLOSED
    windows of the [L, S, 2] SLI ring (0 where no completions landed)."""
    L = sli_win.shape[0]
    idx = jnp.arange(L, dtype=jnp.int32)
    # window id currently stored at ring slot p: the largest m < w_closed
    # with m % L == p (negative = slot never written)
    m = w_closed - 1 - ((w_closed - 1 - idx) % L)
    mask = ((m >= w_closed - n) & (m >= 0)).astype(jnp.float32)   # [L]
    good = jnp.sum(sli_win[:, :, 0] * mask[:, None], axis=0)      # [S]
    bad = jnp.sum(sli_win[:, :, 1] * mask[:, None], axis=0)       # [S]
    return bad / jnp.maximum(good + bad, 1.0)


def evaluate_rules(sli_win: jnp.ndarray, w_closed: jnp.ndarray,
                   budget: jnp.ndarray, params: SimParams, dyn):
    """Burn-rate rule conditions, [S, N_RULES] bool.

    Rule 0 (fast / page): burn over the short lookback AND over the last
    single window both ≥ ``slo_fast_burn``.  Rule 1 (slow / ticket): burn
    over the long lookback AND over the short lookback both ≥
    ``slo_slow_burn``.  The second clause of each pair is the SRE
    "still-burning" guard that stops alerts from trailing long after the
    incident ended.  Services with budget ≤ 0 are objective-free.
    """
    active = budget > 0
    safe_budget = jnp.maximum(budget, 1e-9)
    frac1 = _lookback_frac(sli_win, w_closed, 1)
    frac_s = _lookback_frac(sli_win, w_closed, params.slo_short_wins)
    frac_l = _lookback_frac(sli_win, w_closed, params.slo_long_wins)
    burn1, burn_s, burn_l = (f / safe_budget for f in (frac1, frac_s, frac_l))
    fast = active & (burn_s >= dyn.slo_fast_burn) & (burn1 >= dyn.slo_fast_burn)
    slow = active & (burn_l >= dyn.slo_slow_burn) & (burn_s >= dyn.slo_slow_burn)
    return jnp.stack([fast, slow], axis=1)


def step_machine(astate: jnp.ndarray, pending: jnp.ndarray,
                 cond: jnp.ndarray, for_ticks: int):
    """One tick of the per-(service, rule) alert state machine.

    ``held`` counts consecutive ticks (including this one) the condition
    has held; FIRING needs ``held >= for_ticks``.  RESOLVED is a one-tick
    state entered from FIRING when the condition clears.
    """
    held = jnp.where(cond,
                     jnp.where(astate == ALERT_PENDING, pending, 0) + 1, 0)
    firing_now = astate == ALERT_FIRING
    new_state = jnp.where(
        firing_now,
        jnp.where(cond, ALERT_FIRING, ALERT_RESOLVED),
        jnp.where(cond & (held >= for_ticks), ALERT_FIRING,
                  jnp.where(cond, ALERT_PENDING, ALERT_INACTIVE)))
    new_pending = jnp.where(new_state == ALERT_PENDING, held, 0)
    return new_state, new_pending


def firing_mask(alerts: AlertState) -> jnp.ndarray:
    """[S] bool — any rule firing for the service."""
    return (alerts.astate == ALERT_FIRING).any(axis=1)


def active_mask(alerts: AlertState) -> jnp.ndarray:
    """[S] bool — any rule pending or firing (burn-mode scale-in guard)."""
    return ((alerts.astate == ALERT_PENDING)
            | (alerts.astate == ALERT_FIRING)).any(axis=1)


def alert_step(state: SimState, info, params: SimParams, dyn,
               app) -> SimState:
    """The Alerting tick stage: accumulate SLIs from this tick's finished
    hops, seal the SLI window on the telemetry cadence, evaluate the burn
    rules over closed windows, advance the state machines, and append
    transitions into the event ring.  Runs right after the span pass
    (post-Execute) so it sees the same FinishInfo."""
    al = state.alerts
    cl = state.cloudlets
    i32, f32 = jnp.int32, jnp.float32
    S = al.sli_acc.shape[0]

    target_ms, budget = objectives(app, dyn)

    # --- SLI accumulate: (good, bad) completions per service this tick --
    fin = info.fin & (info.pre_service >= 0)
    svc = jnp.where(fin, info.pre_service, S)       # S = drop lane
    svc_safe = jnp.clip(info.pre_service, 0, S - 1)
    arrival = cl.flts[:, cl.layout.f("arrival")]
    sojourn_ms = (info.tfin - arrival) * 1000.0
    bad = fin & (sojourn_ms > target_ms[svc_safe])
    # one [C,2] scatter-add, not two [C] ones — CPU scatters serialize
    gb = jnp.stack([(fin & ~bad).astype(f32), bad.astype(f32)], axis=1)
    acc = al.sli_acc + jnp.zeros((S, 2), f32).at[svc].add(gb, mode="drop")

    # --- window seal: same cadence as the telemetry metric ring ---------
    L = al.sli_win.shape[0]
    Wt = params.tel_window_ticks
    due = (state.tick % Wt) == (Wt - 1)
    w = al.win[0]
    slot = w % L
    sli_win = al.sli_win.at[slot].set(
        jnp.where(due, acc, al.sli_win[slot]))
    acc = jnp.where(due, jnp.zeros_like(acc), acc)
    w_closed = w + due.astype(i32)

    # --- burn rules + state machine -------------------------------------
    cond = evaluate_rules(sli_win, w_closed, budget, params, dyn)
    st0 = al.astate
    st1, pending1 = step_machine(st0, al.pending, cond, params.slo_for_ticks)
    fired = (st1 == ALERT_FIRING) & (st0 != ALERT_FIRING)
    resolved = st1 == ALERT_RESOLVED        # only reachable from FIRING

    # --- transition events into the append-until-full ring --------------
    changed = (st1 != st0).reshape(-1)                       # [S*NR]
    svc_id = jnp.repeat(jnp.arange(S, dtype=i32), N_RULES)
    rule_id = jnp.tile(jnp.arange(N_RULES, dtype=i32), S)
    AP = al.ev_time.shape[0]
    rank = jnp.cumsum(changed.astype(i32)) - 1
    dst = al.ev_n[0] + rank
    keep = changed & (dst < AP)
    idx = jnp.where(keep, dst, AP)          # AP = discard sentinel
    t_now = state.time + dyn.dt
    return state._replace(alerts=al._replace(
        sli_win=sli_win,
        sli_acc=acc,
        win=al.win + due.astype(i32),
        astate=st1,
        pending=pending1,
        fires=al.fires + fired.astype(i32),
        resolves=al.resolves + resolved.astype(i32),
        firing_ticks=al.firing_ticks + (st1 == ALERT_FIRING).astype(i32),
        ev_time=al.ev_time.at[idx].set(
            jnp.full((S * N_RULES,), t_now, f32), mode="drop"),
        ev_service=al.ev_service.at[idx].set(svc_id, mode="drop"),
        ev_rule=al.ev_rule.at[idx].set(rule_id, mode="drop"),
        ev_state=al.ev_state.at[idx].set(
            st1.reshape(-1).astype(i32), mode="drop"),
        ev_n=al.ev_n + jnp.sum(keep.astype(i32)),
        ev_drops=al.ev_drops + (jnp.sum(changed.astype(i32))
                                - jnp.sum(keep.astype(i32))),
    ))


# --------------------------------------------------------------------------
# Host-side end-of-run drain (no second io_callback in the hot loop)
# --------------------------------------------------------------------------

def drain_events(alerts: AlertState, tags=None) -> list:
    """Materialize alert-transition rows from a final AlertState.

    Handles both solo states ([AP] rings) and run_batch stacks
    ([B, AP] rings); ``tags`` optionally labels each batch lane (defaults
    to the lane index — matching run_batch's auto tel_tag).  Rows carry
    the `export.ALERT_COLUMNS` schema with human-readable rule/state
    label values.
    """
    ev_time = np.asarray(alerts.ev_time)
    if ev_time.size == 0 and ev_time.ndim <= 1:
        return []
    batched = ev_time.ndim == 2
    B = ev_time.shape[0] if batched else 1

    def lane(arr, b):
        a = np.asarray(arr)
        return a[b] if batched else a

    if tags is None:
        tag_of = lambda b: float(b)
    else:
        t = np.asarray(tags).reshape(-1)
        tag_of = lambda b: float(t[b]) if t.size > 1 else float(t[0])

    rows = []
    for b in range(B):
        n = int(lane(alerts.ev_n, b).reshape(-1)[0])
        times = lane(alerts.ev_time, b)
        svcs = lane(alerts.ev_service, b)
        rules = lane(alerts.ev_rule, b)
        states = lane(alerts.ev_state, b)
        for j in range(min(n, times.shape[0])):
            rows.append({
                "time_s": float(times[j]),
                "tag": tag_of(b),
                "service": int(svcs[j]),
                "rule": ALERT_RULES[int(rules[j])],
                "state": ALERT_STATES[int(states[j])],
            })
    return rows


def drain_to_exporter(state: SimState, params: SimParams,
                      tags=None) -> None:
    """Push the final state's alert transitions to the installed alert
    sinks (`export.install_alert`).  Called from Simulation.run /
    run_batch next to the telemetry drain."""
    if not enabled(params):
        return
    from . import export
    rows = drain_events(state.alerts, tags=tags)
    if rows:
        export.dispatch_alerts(rows)
