"""Host-side metric exporter (paper §3.1 Exporter/Reporter, §9).

:func:`dispatch` is the io_callback landing zone: every flush hands it a
``[n, K]`` float32 block of sealed metric rows (K columns =
``types.TEL_METRIC_COLUMNS``; batched runs deliver one block per sweep
point per flush, tagged by the ``tag`` column).  Registered sinks see
each row as a plain dict; the built-in renderers format them as
Prometheus exposition lines or OTel-style JSON.

The default sink just accumulates rows in memory
(:class:`RowCollector`), so tests and `QoSReport` cross-checks can
compare the streamed view against end-of-run aggregates.
"""
from __future__ import annotations

import contextlib
import json
import threading
from typing import Callable, List

import numpy as np

from ..core.types import ALERT_RULES, ALERT_STATES, TEL_METRIC_COLUMNS

_COUNTERS = ("completed", "generated")      # per-window sums
_CUMULATIVE = ("failed_attempts", "retries", "spans", "span_drops")

# Alert-transition row schema (obs/slo.py drain; DESIGN.md §10).  Alert
# rows are events with string labels, not [n, K] float blocks, so they
# ride a parallel sink registry instead of the strict metric pipeline.
ALERT_COLUMNS = ("time_s", "tag", "service", "rule", "state")

_lock = threading.Lock()
_sinks: List[Callable[[dict], None]] = []
_alert_sinks: List[Callable[[dict], None]] = []


def install(sink: Callable[[dict], None]) -> None:
    """Register a sink; it receives one dict per streamed metric row."""
    with _lock:
        _sinks.append(sink)


def uninstall(sink: Callable[[dict], None]) -> None:
    with _lock:
        with contextlib.suppress(ValueError):
            _sinks.remove(sink)


def dispatch(rows) -> None:
    """Deliver a flushed row block to every installed sink.

    Called from the io_callback tap (device thread) and from the
    end-of-run drain; tolerant of any leading batching — rows are
    reshaped to ``[-1, K]``.
    """
    rows = np.asarray(rows, np.float32).reshape(-1,
                                                len(TEL_METRIC_COLUMNS))
    with _lock:
        sinks = list(_sinks)
    if not sinks:
        return
    for r in rows:
        d = {n: float(v) for n, v in zip(TEL_METRIC_COLUMNS, r)}
        for s in sinks:
            s(d)


class RowCollector:
    """Thread-safe accumulating sink (the default test/report consumer)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._rows: List[dict] = []

    def __call__(self, row: dict) -> None:
        with self._lock:
            self._rows.append(row)

    @property
    def rows(self) -> List[dict]:
        with self._lock:
            return list(self._rows)

    def rows_np(self) -> np.ndarray:
        """[n, K] float32 in column order TEL_METRIC_COLUMNS."""
        rows = self.rows
        out = np.zeros((len(rows), len(TEL_METRIC_COLUMNS)), np.float32)
        for i, r in enumerate(rows):
            out[i] = [r[n] for n in TEL_METRIC_COLUMNS]
        return out


@contextlib.contextmanager
def collecting():
    """``with export.collecting() as rows:`` — scoped RowCollector."""
    c = RowCollector()
    install(c)
    try:
        yield c
    finally:
        uninstall(c)


# ----------------------------------------------------------------------
# Alert-transition channel (obs/slo.py, DESIGN.md §10)
# ----------------------------------------------------------------------
def install_alert(sink: Callable[[dict], None]) -> None:
    """Register an alert sink; it receives one dict per alert transition
    (``ALERT_COLUMNS`` schema, rule/state as label strings)."""
    with _lock:
        _alert_sinks.append(sink)


def uninstall_alert(sink: Callable[[dict], None]) -> None:
    with _lock:
        with contextlib.suppress(ValueError):
            _alert_sinks.remove(sink)


def dispatch_alerts(rows: List[dict]) -> None:
    """Deliver drained alert-transition rows to every alert sink."""
    with _lock:
        sinks = list(_alert_sinks)
    if not sinks:
        return
    for r in rows:
        for s in sinks:
            s(dict(r))


@contextlib.contextmanager
def alert_collecting():
    """``with export.alert_collecting() as events:`` — scoped collector
    on the alert channel (RowCollector semantics)."""
    c = RowCollector()
    install_alert(c)
    try:
        yield c
    finally:
        uninstall_alert(c)


# ----------------------------------------------------------------------
# Renderers
# ----------------------------------------------------------------------
def prometheus_line(row: dict, prefix: str = "repro") -> str:
    """One Prometheus exposition block per row (gauge per column)."""
    tag = int(row.get("tag", 0.0))
    win = int(row.get("window", 0.0))
    ts = row.get("time_s", 0.0)
    labels = f'{{point="{tag}",window="{win}"}}'
    lines = []
    for n in TEL_METRIC_COLUMNS:
        if n in ("window", "tag", "time_s"):
            continue
        kind = "counter" if n in _COUNTERS + _CUMULATIVE else "gauge"
        lines.append(f"# TYPE {prefix}_{n} {kind}")
        lines.append(f"{prefix}_{n}{labels} {row[n]:g} {ts:g}")
    return "\n".join(lines)


def otel_json(row: dict) -> str:
    """OTel-style JSON datapoint for the whole row."""
    return json.dumps({
        "resource": {"point": int(row.get("tag", 0.0))},
        "time_s": row.get("time_s", 0.0),
        "window": int(row.get("window", 0.0)),
        "metrics": {n: row[n] for n in TEL_METRIC_COLUMNS
                    if n not in ("window", "tag", "time_s")},
    }, sort_keys=True)


def printer(render: Callable[[dict], str] = otel_json,
            out=None) -> Callable[[dict], None]:
    """Sink that renders each row and prints it (live streaming view)."""
    import sys
    stream = out or sys.stdout

    def sink(row: dict) -> None:
        print(render(row), file=stream, flush=True)

    return sink


def prometheus_alert_line(ev: dict, prefix: str = "repro") -> str:
    """Prometheus `ALERTS`-convention exposition line for one transition:
    ``ALERTS{alertname,service,state,point} 1 <ts>`` — the series a real
    Alertmanager scrape would show while the alert is in that state."""
    labels = (f'{{alertname="{ev["rule"]}",service="{ev["service"]}",'
              f'alertstate="{ev["state"]}",point="{int(ev["tag"])}"}}')
    return (f"# TYPE ALERTS gauge\n"
            f"ALERTS{labels} 1 {ev['time_s']:g}")


def otel_alert_event(ev: dict) -> str:
    """OTel span-event JSON for one alert transition."""
    return json.dumps({
        "name": ev["rule"],
        "resource": {"point": int(ev["tag"])},
        "time_s": ev["time_s"],
        "attributes": {"service": int(ev["service"]),
                       "state": ev["state"]},
    }, sort_keys=True)


def validate_alert_rows(rows: List[dict]) -> None:
    """Schema check for drained alert transitions: every row carries the
    full ALERT_COLUMNS schema with known rule/state labels and finite,
    non-decreasing timestamps per (tag, service, rule) lane."""
    lanes: dict = {}
    for i, r in enumerate(rows):
        missing = [n for n in ALERT_COLUMNS if n not in r]
        if missing:
            raise ValueError(f"alert row {i} missing columns {missing}")
        if r["rule"] not in ALERT_RULES:
            raise ValueError(f"alert row {i} unknown rule {r['rule']!r}")
        if r["state"] not in ALERT_STATES:
            raise ValueError(f"alert row {i} unknown state {r['state']!r}")
        if not np.isfinite(r["time_s"]):
            raise ValueError(f"alert row {i} non-finite time_s")
        key = (r["tag"], r["service"], r["rule"])
        if lanes.get(key, -np.inf) > r["time_s"]:
            raise ValueError(
                f"alert row {i} time_s {r['time_s']} decreases within "
                f"lane {key}")
        lanes[key] = r["time_s"]


def validate_rows(rows: List[dict]) -> None:
    """Schema check for CI: every row carries every column, finite,
    with monotone non-negative window ids per tag."""
    if not rows:
        raise ValueError("no telemetry rows streamed")
    per_tag: dict = {}
    for i, r in enumerate(rows):
        missing = [n for n in TEL_METRIC_COLUMNS if n not in r]
        if missing:
            raise ValueError(f"row {i} missing columns {missing}")
        bad = [n for n in TEL_METRIC_COLUMNS if not np.isfinite(r[n])]
        if bad:
            raise ValueError(f"row {i} non-finite columns {bad}")
        if r["window"] < 0:
            raise ValueError(f"row {i} negative window id")
        per_tag.setdefault(r["tag"], []).append(r["window"])
    for tag, wins in per_tag.items():
        if sorted(wins) != list(range(len(wins))):
            raise ValueError(
                f"tag {tag}: windows {sorted(wins)} are not the "
                f"contiguous range 0..{len(wins) - 1}")
