"""Host-side metric exporter (paper §3.1 Exporter/Reporter, §9).

:func:`dispatch` is the io_callback landing zone: every flush hands it a
``[n, K]`` float32 block of sealed metric rows (K columns =
``types.TEL_METRIC_COLUMNS``; batched runs deliver one block per sweep
point per flush, tagged by the ``tag`` column).  Registered sinks see
each row as a plain dict; the built-in renderers format them as
Prometheus exposition lines or OTel-style JSON.

The default sink just accumulates rows in memory
(:class:`RowCollector`), so tests and `QoSReport` cross-checks can
compare the streamed view against end-of-run aggregates.
"""
from __future__ import annotations

import contextlib
import json
import threading
from typing import Callable, List

import numpy as np

from ..core.types import TEL_METRIC_COLUMNS

_COUNTERS = ("completed", "generated")      # per-window sums
_CUMULATIVE = ("failed_attempts", "retries", "spans", "span_drops")

_lock = threading.Lock()
_sinks: List[Callable[[dict], None]] = []


def install(sink: Callable[[dict], None]) -> None:
    """Register a sink; it receives one dict per streamed metric row."""
    with _lock:
        _sinks.append(sink)


def uninstall(sink: Callable[[dict], None]) -> None:
    with _lock:
        with contextlib.suppress(ValueError):
            _sinks.remove(sink)


def dispatch(rows) -> None:
    """Deliver a flushed row block to every installed sink.

    Called from the io_callback tap (device thread) and from the
    end-of-run drain; tolerant of any leading batching — rows are
    reshaped to ``[-1, K]``.
    """
    rows = np.asarray(rows, np.float32).reshape(-1,
                                                len(TEL_METRIC_COLUMNS))
    with _lock:
        sinks = list(_sinks)
    if not sinks:
        return
    for r in rows:
        d = {n: float(v) for n, v in zip(TEL_METRIC_COLUMNS, r)}
        for s in sinks:
            s(d)


class RowCollector:
    """Thread-safe accumulating sink (the default test/report consumer)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._rows: List[dict] = []

    def __call__(self, row: dict) -> None:
        with self._lock:
            self._rows.append(row)

    @property
    def rows(self) -> List[dict]:
        with self._lock:
            return list(self._rows)

    def rows_np(self) -> np.ndarray:
        """[n, K] float32 in column order TEL_METRIC_COLUMNS."""
        rows = self.rows
        out = np.zeros((len(rows), len(TEL_METRIC_COLUMNS)), np.float32)
        for i, r in enumerate(rows):
            out[i] = [r[n] for n in TEL_METRIC_COLUMNS]
        return out


@contextlib.contextmanager
def collecting():
    """``with export.collecting() as rows:`` — scoped RowCollector."""
    c = RowCollector()
    install(c)
    try:
        yield c
    finally:
        uninstall(c)


# ----------------------------------------------------------------------
# Renderers
# ----------------------------------------------------------------------
def prometheus_line(row: dict, prefix: str = "repro") -> str:
    """One Prometheus exposition block per row (gauge per column)."""
    tag = int(row.get("tag", 0.0))
    win = int(row.get("window", 0.0))
    ts = row.get("time_s", 0.0)
    labels = f'{{point="{tag}",window="{win}"}}'
    lines = []
    for n in TEL_METRIC_COLUMNS:
        if n in ("window", "tag", "time_s"):
            continue
        kind = "counter" if n in _COUNTERS + _CUMULATIVE else "gauge"
        lines.append(f"# TYPE {prefix}_{n} {kind}")
        lines.append(f"{prefix}_{n}{labels} {row[n]:g} {ts:g}")
    return "\n".join(lines)


def otel_json(row: dict) -> str:
    """OTel-style JSON datapoint for the whole row."""
    return json.dumps({
        "resource": {"point": int(row.get("tag", 0.0))},
        "time_s": row.get("time_s", 0.0),
        "window": int(row.get("window", 0.0)),
        "metrics": {n: row[n] for n in TEL_METRIC_COLUMNS
                    if n not in ("window", "tag", "time_s")},
    }, sort_keys=True)


def printer(render: Callable[[dict], str] = otel_json,
            out=None) -> Callable[[dict], None]:
    """Sink that renders each row and prints it (live streaming view)."""
    import sys
    stream = out or sys.stdout

    def sink(row: dict) -> None:
        print(render(row), file=stream, flush=True)

    return sink


def validate_rows(rows: List[dict]) -> None:
    """Schema check for CI: every row carries every column, finite,
    with monotone non-negative window ids per tag."""
    if not rows:
        raise ValueError("no telemetry rows streamed")
    per_tag: dict = {}
    for i, r in enumerate(rows):
        missing = [n for n in TEL_METRIC_COLUMNS if n not in r]
        if missing:
            raise ValueError(f"row {i} missing columns {missing}")
        bad = [n for n in TEL_METRIC_COLUMNS if not np.isfinite(r[n])]
        if bad:
            raise ValueError(f"row {i} non-finite columns {bad}")
        if r["window"] < 0:
            raise ValueError(f"row {i} negative window id")
        per_tag.setdefault(r["tag"], []).append(r["window"])
    for tag, wins in per_tag.items():
        if sorted(wins) != list(range(len(wins))):
            raise ValueError(
                f"tag {tag}: windows {sorted(wins)} are not the "
                f"contiguous range 0..{len(wins) - 1}")
