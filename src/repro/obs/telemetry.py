"""Device-side telemetry: metric-row ring + sampled span ring (§9).

Two fixed-capacity buffers ride the scan carry (``TelemetryState``):

- the **metric ring** ``[W, K]`` holds one row per closed window of
  ``tel_window_ticks`` ticks; it is double-buffered — while ticks write
  rows into one half, :func:`flush` hands the other, just-completed half
  to the host exporter through ``jax.experimental.io_callback``;
- the **span ring** ``[SP, NSI|NSF]`` appends one span per finished
  cloudlet (hop) of a seeded 1-in-k request sample; at capacity it never
  overwrites — it counts every dropped span exactly instead.

Everything here is observation-only: no tick RNG is consumed (the sample
mask is drawn once at init from a named ``fold_in`` stream), no sim
column is written, and the pool layout is provably unchanged
(``types._layout_for`` rejects any Telemetry phase column outside the
mode's existing set).  ``telemetry="none"`` carries zero-width buffers
and builds the exact pre-observability program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from ..analysis import jaxpr_lint
from ..core import network as netmod
from ..core.types import (CL_EXEC, CL_TRANSIT, CL_WAITING,
                          TEL_METRIC_COLUMNS, DynParams, SimParams,
                          SimState, TickTrace)
from . import export

_COL = {n: i for i, n in enumerate(TEL_METRIC_COLUMNS)}


def flush_ticks(params: SimParams) -> int:
    """Ticks between io_callback flushes: half the ring's windows."""
    return params.tel_window_ticks * (params.tel_windows // 2)


def _telemetry_tap(rows) -> None:
    """Host-side flush target — the ONE declared callback in the hot
    loop (jaxpr lint's allowlist is keyed on this function's name)."""
    export.dispatch(np.asarray(rows))


jaxpr_lint.declare_callback("_telemetry_tap")


# ----------------------------------------------------------------------
# In-tick recording (pure; traced inside the scan body)
# ----------------------------------------------------------------------
def record_spans(state: SimState, info, params: SimParams) -> SimState:
    """Append one span per finished cloudlet of a sampled request.

    Runs between Execute and Derive: ``execute`` clears only
    status/rem/inst on finish, so the descriptive columns (req, service,
    wait_ticks, arrival, start — plus edge/attempt/src_host where the
    mode carries them) are still readable, and Derive has not yet
    respawned over the freed slots.  The ring is append-until-full with
    an exact overflow counter (never a silent cap).

    Sampled finishers are rank-compacted into a ``[KB = min(SP, C)]``
    gather FIRST, so the per-tick row build and scatter touch KB slots
    instead of stacking the full ``[C, 11]`` pool (the PR-8 obs-overhead
    regression: at case1b scale that was 2^18 × 11 values staged per tick
    for ≤ a handful of sampled spans).  Identical semantics: a sampled
    item with compaction rank ≥ KB either can't exist (KB = C) or would
    have overflowed the ring anyway (KB = SP ≤ dst), so the kept set,
    write order, and drop counts match the full-pool scatter bit-for-bit.

    ``tel_span_tick_cap`` (> 0) tightens KB further — the ring capacity
    SP sizes the whole-run budget, so a big ring otherwise re-inflates
    the per-tick staging it exists to amortize (case1b: SP=4096 rows
    built every tick for ~15 expected sampled finishers).  A tick with
    more sampled finishers than the budget drops the excess — counted
    exactly in ``span_drops``, the ring discipline, never silent.
    """
    cl, tel = state.cloudlets, state.telemetry
    i32, f32 = jnp.int32, jnp.float32
    C = info.fin.shape[0]
    SP = tel.span_i.shape[0]
    KB = min(SP, C)
    if params.tel_span_tick_cap:
        KB = min(KB, params.tel_span_tick_cap)

    r_safe = jnp.maximum(info.pre_req, 0)
    sampled = info.fin & (info.pre_req >= 0) & (tel.sample[r_safe] > 0)
    csum = jnp.cumsum(sampled.astype(i32))
    n_want = csum[C - 1]

    # invert the ranking: slot j ← pool index of the j-th sampled
    # finisher.  csum jumps to j+1 exactly at that pool index, so a
    # searchsorted over the (sorted) cumsum finds all KB slots in
    # O(KB log C) — not the [C]-length scatter this used to be (CPU
    # scatters serialize; past-the-end queries return C = invalid).
    src = jnp.searchsorted(csum, jnp.arange(1, KB + 1, dtype=i32),
                           side="left").astype(i32)
    valid = src < C
    sc = jnp.minimum(src, C - 1)            # safe gather index

    inst_k = info.pre_inst[sc]
    host = jnp.where(inst_k >= 0,
                     state.instances.host[jnp.maximum(inst_k, 0)], -1)
    cols = cl.layout.columns
    neg1 = jnp.full((KB,), -1, i32)
    edge = cl.edge[sc] if "edge" in cols else neg1
    attempt = cl.attempt[sc] if "attempt" in cols else jnp.zeros((KB,), i32)
    src_host = cl.src_host[sc] if "src_host" in cols else neg1
    # column order == TEL_SPAN_I_COLUMNS / TEL_SPAN_F_COLUMNS
    rows_i = jnp.stack([cl.req[sc], cl.service[sc], inst_k, host, src_host,
                        edge, attempt, cl.wait_ticks[sc]], axis=1)
    rows_f = jnp.stack([cl.arrival[sc], cl.start[sc], info.tfin[sc]], axis=1)

    dst = tel.span_n[0] + jnp.arange(KB, dtype=i32)
    keep = valid & (dst < SP)
    n_keep = jnp.sum(keep.astype(i32))
    idx = jnp.where(keep, dst, SP)          # SP = drop sentinel

    tel = tel._replace(
        span_i=tel.span_i.at[idx].set(rows_i, mode="drop"),
        span_f=tel.span_f.at[idx].set(rows_f, mode="drop"),
        span_n=tel.span_n + n_keep,
        span_drops=tel.span_drops + (n_want - n_keep))
    return state._replace(telemetry=tel)


def close_window(state: SimState, params: SimParams, dyn: DynParams,
                 trace: TickTrace) -> SimState:
    """Accumulate this tick into the open window; on the window's last
    tick, seal a metric row into the ring slot ``win % W``."""
    tel = state.telemetry
    f32, i32 = jnp.float32, jnp.int32
    W = params.tel_windows
    Wt = params.tel_window_ticks

    acc = tel.acc + jnp.stack([trace.completed.astype(f32),
                               trace.generated.astype(f32)])
    due = (state.tick % Wt) == (Wt - 1)

    if params.network == "fabric":
        inflight = netmod.inflight_mb(state.cloudlets)
    else:
        inflight = jnp.zeros((), f32)
    if params.faults == "chaos":
        failed = state.fstats.failed_attempts.astype(f32)
        retries = state.fstats.retries.astype(f32)
    else:
        failed = retries = jnp.zeros((), f32)

    row = jnp.stack([                       # order == TEL_METRIC_COLUMNS
        tel.win[0].astype(f32),
        state.time + dyn.dt,
        dyn.tel_tag,
        acc[0], acc[1],
        trace.n_waiting.astype(f32),
        trace.n_exec.astype(f32),
        trace.n_transit.astype(f32),
        trace.used_mips,
        trace.active_instances.astype(f32),
        inflight, failed, retries,
        tel.span_n[0].astype(f32),
        tel.span_drops[0].astype(f32)])

    slot = tel.win[0] % W
    ring = tel.ring.at[slot].set(jnp.where(due, row, tel.ring[slot]))
    tel = tel._replace(ring=ring,
                       acc=jnp.where(due, jnp.zeros_like(acc), acc),
                       win=tel.win + due.astype(i32))
    return state._replace(telemetry=tel)


# ----------------------------------------------------------------------
# Flush + chunked scan (the io_callback lives OUTSIDE the tick scan)
# ----------------------------------------------------------------------
def flush(state: SimState, params: SimParams) -> SimState:
    """Tap the just-completed half of the metric ring out to the host.

    Called between chunks of :func:`chunked_scan`, i.e. every
    ``flush_ticks`` ticks — exactly one window half is newly sealed, so
    the slice alternates [0, W/2) / [W/2, W) and never races the half
    the next chunk writes.  ``ordered=False``: flushes carry their own
    window indices, so the exporter can reorder safely.
    """
    tel = state.telemetry
    W = params.tel_windows
    half = W // 2
    start = (tel.win[0] - half) % W
    rows = jax.lax.dynamic_slice_in_dim(tel.ring, start, half, axis=0)
    io_callback(_telemetry_tap, None, rows, ordered=False)
    return state


def chunked_scan(tick_fn, state, params: SimParams, n_ticks: int,
                 flush_fn=None):
    """Scan ``tick_fn`` for ``n_ticks`` with a flush between chunks.

    The flush must NOT sit behind a ``lax.cond`` inside the tick scan —
    vmap-of-cond rejects IO effects, which would sink ``run_batch``.
    Instead the run becomes an outer scan over chunks of ``flush_ticks``
    ticks whose body flushes unconditionally; under vmap the callback
    then fires once per sweep point per chunk with that point's rows.
    Traces are reshaped back to the flat [n_ticks, …] layout, so the
    result is numerically identical to the plain scan.
    """
    chunk = flush_ticks(params)
    n_chunks, rem = divmod(n_ticks, chunk)
    if flush_fn is None:
        flush_fn = lambda s: flush(s, params)
    traces = []

    def chunk_body(s, _):
        s, tr = jax.lax.scan(tick_fn, s, None, length=chunk)
        return flush_fn(s), tr

    if n_chunks:
        state, tr = jax.lax.scan(chunk_body, state, None, length=n_chunks)
        traces.append(jax.tree_util.tree_map(
            lambda x: x.reshape((n_chunks * chunk,) + x.shape[2:]), tr))
    if rem:                       # tail windows drain host-side after run
        state, tr = jax.lax.scan(tick_fn, state, None, length=rem)
        traces.append(tr)
    trace = traces[0] if len(traces) == 1 else jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0), *traces)
    return state, trace


# ----------------------------------------------------------------------
# Host-side drain (rows sealed but not yet flushed when the run ends)
# ----------------------------------------------------------------------
def drain_rows(state: SimState, params: SimParams) -> np.ndarray:
    """Collect the sealed-but-unflushed tail of the metric ring.

    Returns ``[n, K]`` float32 (empty when telemetry is off).  Batched
    final states ([B, W, K] rings) drain lane by lane, concatenated.
    """
    ring = np.asarray(state.telemetry.ring)
    win = np.asarray(state.telemetry.win)
    if ring.size == 0:
        return np.zeros((0, len(TEL_METRIC_COLUMNS)), np.float32)
    if ring.ndim == 3:
        return np.concatenate(
            [_drain_one(ring[b], int(win[b, 0]), params)
             for b in range(ring.shape[0])], axis=0)
    return _drain_one(ring, int(win[0]), params)


def _drain_one(ring: np.ndarray, w: int, params: SimParams) -> np.ndarray:
    W = params.tel_windows
    half = W // 2
    flushed = (w // half) * half            # sealed rows already tapped
    idx = [(flushed + j) % W for j in range(w - flushed)]
    if not idx:
        return np.zeros((0, ring.shape[1]), np.float32)
    return ring[idx]


def drain_to_exporter(state: SimState, params: SimParams) -> None:
    rows = drain_rows(state, params)
    if rows.size:
        export.dispatch(rows)
