"""Per-phase profiling via prefix programs (ROADMAP item b, §9).

XLA fuses the whole tick into one executable, so no in-program timer
can attribute wall cost to a phase.  Instead we build a *family* of
programs with ``make_tick(stop_after=...)`` — each truncates the tick
right after one phase (keeping that phase's outputs live in the carry,
so DCE cannot strip the work being timed) — scan each for the same
number of ticks, and difference the best-of-N walls:

    cost(phase_i) ≈ wall(prefix through i) − wall(prefix through i−1)

The same trick descends INTO the Disruption phase through
``faults.disruption(stop_after=<stage>)`` (schedule / doom / respawn /
breaker / ejection), which is what finally attributes the ~1.7× chaos
wall overhead (DESIGN.md §7 cost table).

Caveats: prefix programs re-fuse, so deltas are estimates of marginal
cost, not exact slices — small negative deltas mean the longer prefix
fused better than the shorter one; treat |delta| below a few percent of
total as noise.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import List, Optional

import jax
import numpy as np

from ..core.engine import Simulation, make_tick
from ..core.faults import DISRUPTION_STAGES
from ..core.types import DynParams


@dataclasses.dataclass
class PhaseCost:
    """One row of a profile: marginal wall attributed to ``label``."""

    label: str
    wall_s: float     # best wall of the prefix ENDING at this phase
    delta_s: float    # wall(this prefix) − wall(previous prefix)
    share: float      # delta_s / wall(full program)


def _time_program(sim: Simulation, stop_after: Optional[str],
                  n_ticks: int, reps: int) -> float:
    """Best-of-N wall of the prefix program (compile excluded)."""
    tick = make_tick(sim.caps, sim.params, sim._has_edges,
                     stop_after=stop_after)

    def run_fn(st, dp, app):
        return jax.lax.scan(lambda s, _: tick(s, dp, app), st, None,
                            length=n_ticks)

    fn = jax.jit(run_fn)
    dyn = DynParams.from_params(sim.params)
    state = sim._unalias(sim.init_state())
    jax.block_until_ready(fn(state, dyn, sim.app))    # compile + warm
    best = float("inf")
    for _ in range(max(reps, 1)):
        state = sim._unalias(sim.init_state())
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(state, dyn, sim.app))
        best = min(best, _time.perf_counter() - t0)
    return best


def tick_phases(sim: Simulation) -> List[str]:
    """The phases this sim's mode combo actually builds, in tick order."""
    p = sim.params
    ph = ["Generation"]
    if p.faults == "chaos":
        ph.append("Disruption")
    if p.network == "fabric":
        ph.append("Transit")
    ph += ["Dispatch", "Execute"]
    if p.telemetry == "stream" and p.alerting == "burn":
        # the Alerting prefix cut also covers the Telemetry span pass
        # (record_spans traces between Execute and Alerting)
        ph.append("Alerting")
    if sim._has_edges:
        ph.append("Derive")
    ph.append("Response")
    if p.scaling_policy or p.migration_enabled:
        ph.append("Scaling")
    return ph


def _diff(labels: List[str], walls: List[float], base: float,
          total: float) -> List[PhaseCost]:
    out, prev = [], base
    for label, wall in zip(labels, walls):
        out.append(PhaseCost(label=label, wall_s=wall,
                             delta_s=wall - prev,
                             share=(wall - prev) / max(total, 1e-12)))
        prev = wall
    return out


def phase_breakdown(sim: Simulation, reps: int = 3,
                    n_ticks: Optional[int] = None) -> List[PhaseCost]:
    """Wall cost per tick phase (prefix-difference, best-of-``reps``).

    The final row ("Trace+rest") is the full program minus the longest
    prefix: trace assembly plus whatever the mode adds after Scaling.
    """
    T = n_ticks or sim.params.n_ticks
    phases = tick_phases(sim)
    walls = [_time_program(sim, ph, T, reps) for ph in phases]
    full = _time_program(sim, None, T, reps)
    costs = _diff(phases, walls, base=0.0, total=full)
    costs.append(PhaseCost(label="Trace+rest", wall_s=full,
                           delta_s=full - walls[-1],
                           share=(full - walls[-1]) / max(full, 1e-12)))
    return costs


def disruption_breakdown(sim: Simulation, reps: int = 3,
                         n_ticks: Optional[int] = None) -> List[PhaseCost]:
    """Stage-level cost attribution INSIDE the Disruption phase.

    Baseline = prefix through Generation (the phase just before
    Disruption); stages then cut after schedule / doom / respawn /
    breaker, and the full-phase prefix adds the outlier-ejection tail.
    """
    if sim.params.faults != "chaos":
        raise ValueError("disruption_breakdown needs faults='chaos'")
    T = n_ticks or sim.params.n_ticks
    base = _time_program(sim, "Generation", T, reps)
    full = _time_program(sim, "Disruption", T, reps)
    stages = [f"Disruption/{s}" for s in DISRUPTION_STAGES]
    walls = [_time_program(sim, s, T, reps) for s in stages]
    costs = _diff(list(DISRUPTION_STAGES), walls, base=base,
                  total=full - base)
    costs.append(PhaseCost(label="ejection", wall_s=full,
                           delta_s=full - walls[-1],
                           share=(full - walls[-1])
                           / max(full - base, 1e-12)))
    return costs


def format_table(costs: List[PhaseCost], title: str = "phase") -> str:
    """Markdown cost table (DESIGN.md §7 / example output)."""
    lines = [f"| {title} | prefix wall (s) | delta (s) | share |",
             "|---|---|---|---|"]
    for c in costs:
        lines.append(f"| {c.label} | {c.wall_s:.4f} | {c.delta_s:+.4f} "
                     f"| {100.0 * c.share:+.1f}% |")
    return "\n".join(lines)


def profile_np(costs: List[PhaseCost]) -> np.ndarray:
    """[n, 3] (wall, delta, share) float64 — programmatic consumers."""
    return np.array([[c.wall_s, c.delta_s, c.share] for c in costs],
                    np.float64)
