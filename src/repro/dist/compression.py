"""Int8 gradient compression with error feedback.

Under SPMD the quantize/dequantize pair brackets the gradient all-reduce,
so the cross-pod traffic is 1/4 width; error feedback carries each step's
quantization residual into the next step, removing the bias a plain
round-to-nearest codec accumulates on small gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_decompress(x: jnp.ndarray) -> jnp.ndarray:
    """Symmetric per-tensor int8 quantize → dequantize (scale = absmax/127)."""
    x = jnp.asarray(x)
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * safe).astype(x.dtype)


def ef_init(grads):
    """Zero error-feedback residual, one per gradient leaf."""
    return jax.tree_util.tree_map(jnp.zeros_like, grads)


def ef_compress(grads, ef):
    """Compress ``grads + ef``; the new residual is what the codec lost."""
    def one(g, e):
        c = compress_decompress(g + e)
        return c, g + e - c

    flat = jax.tree_util.tree_map(one, grads, ef)
    comp = jax.tree_util.tree_map(lambda ce: ce[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda ce: ce[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_ef
