"""Logical-axis → mesh-axis resolution (the sharding rulebook).

Every parameter/activation dimension carries a *logical* name ("heads",
"batch", ...); the tables below map each name to the mesh axes it may be
sharded over, in preference order.  ``resolve`` applies two guards per
tensor:

  * divisibility — a dim is only sharded if the product of the chosen mesh
    axis sizes divides it (trailing candidate axes are dropped until it
    does); otherwise the dim replicates,
  * uniqueness — a mesh axis is consumed by the first dim that claims it
    (XLA forbids reusing a mesh axis within one PartitionSpec).

Rules reference axes that may not exist on the current mesh (e.g. "pod" on
a single-pod run); missing axes are skipped, which is what makes the same
rulebook serve the 256-chip and 512-chip layouts unchanged.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec

# Tensor-parallel parameter dims go to "model"; everything else replicates.
PARAM_RULES: dict = {
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "embed": (),
    "layers": (),
    "state": (),
    "conv": (),
    "frames": (),
    "periods": (),
}

# Activations: batch dims spread over the data-parallel axes (both of them
# on multi-pod meshes); sequence stays local during training.
ACT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "frames": (),
    "state": (),
    "conv": (),
    "layers": (),
}


def resolve(mesh, shape, axes, rules) -> PartitionSpec:
    """PartitionSpec for one tensor given its logical axes and the rules."""
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        cand = [a for a in rules.get(name, ()) or ()
                if a in mesh.axis_names and a not in used] \
            if name is not None else []
        size = math.prod(mesh.shape[a] for a in cand) if cand else 1
        while cand and dim % size != 0:          # divisibility guard
            size //= mesh.shape[cand[-1]]
            cand.pop()
        if not cand:
            out.append(None)
            continue
        used.update(cand)
        out.append(cand[0] if len(cand) == 1 else tuple(cand))
    return PartitionSpec(*out)


def tree_shardings(mesh, abstract_tree, logical_tree, rules):
    """NamedSharding per leaf of ``abstract_tree``.

    ``logical_tree`` mirrors the abstract tree down to its leaves, where it
    holds the per-dim logical-name tuples (``flatten_up_to`` semantics: the
    tuples are *not* traversed).
    """
    return jax.tree_util.tree_map(
        lambda a, axes: NamedSharding(mesh, resolve(mesh, a.shape, axes,
                                                    rules)),
        abstract_tree, logical_tree)
