"""Distribution substrate: logical-axis sharding rules + gradient compression.

``sharding`` resolves logical axis names (declared once per parameter in the
model schemas) against whatever mesh is current — the indirection that makes
checkpoints elastic (ckpt/elastic.py) and the dry-run mesh-agnostic
(launch/specs.py).  ``compression`` is the int8 + error-feedback gradient
codec the train step brackets around the cross-pod all-reduce.
"""
from . import compression, sharding  # noqa: F401
