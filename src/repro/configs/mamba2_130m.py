"""mamba2-130m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from repro.models.mamba2 import MambaDims

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv=0, head_dim=0,
    d_ff=0, vocab=50280,
    mamba=MambaDims.make(768, headdim=64, d_state=128, n_groups=1,
                         d_conv=4, expand=2),
    ssd_chunk=128, tie_embeddings=True, sub_quadratic=True,
)
