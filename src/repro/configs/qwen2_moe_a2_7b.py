"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.models.moe import MoECfg

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=1408, vocab=151936, rope_theta=1e6,
    moe=MoECfg(n_experts=60, top_k=4, d_expert=1408,
               n_shared=4, d_shared=5632, norm_topk=False),
)
