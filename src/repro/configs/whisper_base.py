"""whisper-base — encoder-decoder audio backbone; conv frontend stubbed
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356;
unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, head_dim=64,
    d_ff=2048, vocab=51865, n_enc_layers=6, n_frames=1500,
    tie_embeddings=True, rope_theta=1e4,
)
