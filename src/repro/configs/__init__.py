"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

One module per assigned architecture (exact public-literature configs) plus
the paper's own SockShop application config (sockshop.py).
"""
from __future__ import annotations

from .base import SHAPES, ArchConfig, ShapeCfg, shape_applies  # noqa: F401


def _registry():
    from . import (granite_20b, internlm2_1_8b, jamba_1_5_large,
                   mamba2_130m, phi3_medium_14b, qwen2_moe_a2_7b,
                   qwen2_vl_7b, qwen3_0_6b, qwen3_moe_30b_a3b, whisper_base)
    mods = [qwen3_0_6b, granite_20b, phi3_medium_14b, internlm2_1_8b,
            whisper_base, mamba2_130m, jamba_1_5_large, qwen2_vl_7b,
            qwen2_moe_a2_7b, qwen3_moe_30b_a3b]
    return {m.CONFIG.name: m.CONFIG for m in mods}


ARCH_IDS = (
    "qwen3-0.6b", "granite-20b", "phi3-medium-14b", "internlm2-1.8b",
    "whisper-base", "mamba2-130m", "jamba-1.5-large-398b", "qwen2-vl-7b",
    "qwen2-moe-a2.7b", "qwen3-moe-30b-a3b",
)


def get_config(name: str) -> ArchConfig:
    reg = _registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name]


def all_configs():
    return _registry()
