"""qwen3-0.6b — dense, GQA + per-head qk-norm [hf:Qwen/Qwen3-8B family; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv=8, head_dim=128,
    d_ff=3072, vocab=151936, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
)
