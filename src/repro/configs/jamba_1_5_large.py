"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with
MoE 16e top-2 on alternating layers [arXiv:2403.19887; hf]."""
from repro.models.mamba2 import MambaDims
from repro.models.moe import MoECfg

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, head_dim=128,
    d_ff=24576, vocab=65536,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=24576),
    mamba=MambaDims.make(8192, headdim=128, d_state=128, n_groups=1,
                         d_conv=4, expand=2),
    attn_period=8, ssd_chunk=128, sub_quadratic=True,
)
