"""SockShop — the paper's case-study application (§6.3, Figs 8/10).

The microservice e-commerce demo (https://github.com/microservices-demo):
NodeJS front-end, Java orders, Go services, MySQL/MongoDB stores, RabbitMQ
shipping pipeline.  APIs map to entry services exactly as the paper's file
registry does (Fig 3a: ``POST /orders`` → service ``orders``); the chain of
a request is the subgraph reachable from its entry service.

``app_spec()`` / ``instance_spec()`` return the two registry documents
(JSON/YAML shapes of Fig 3); ``make_sim(...)`` builds the calibrated
Simulation used by benchmarks/bench_response.py to reproduce Fig 10.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import SimCaps, SimParams, Simulation, register

# Calibrated against the paper's testbed measurements (Fig 10): average
# response 749 ms at 100 clients → 2574 ms at 300 clients, Locust wait
# U[5, 15] s, 600 s runs.  `mi` is the mean Gaussian cloudlet length
# (paper §4.1.2); shares are milicores (1 milicore ≡ 1 MIPS here).
SERVICES: Dict[str, dict] = {
    # name:             (calls,                                  mi)
    "front-end":   dict(calls=["catalogue", "carts", "user"],    mi=70.0),
    "orders":      dict(calls=["orders-db", "carts", "user",
                               "payment", "shipping"],           mi=90.0),
    "orders-db":   dict(calls=[],                                mi=55.0),
    "carts":       dict(calls=["carts-db"],                      mi=60.0),
    "carts-db":    dict(calls=[],                                mi=45.0),
    "user":        dict(calls=["user-db"],                       mi=55.0),
    "user-db":     dict(calls=[],                                mi=40.0),
    "catalogue":   dict(calls=["catalogue-db"],                  mi=65.0),
    "catalogue-db":dict(calls=[],                                mi=50.0),
    "payment":     dict(calls=[],                                mi=50.0),
    "shipping":    dict(calls=["rabbitmq"],                      mi=55.0),
    "rabbitmq":    dict(calls=["queue-master"],                  mi=35.0),
    "queue-master":dict(calls=[],                                mi=40.0),
}

APIS = [
    # (api name, entry service, weight) — Fig 3a format
    ("GET /",          "front-end", 3.0),
    ("GET /catalogue", "catalogue", 3.0),
    ("GET /login",     "user",      1.0),
    ("GET /basket",    "carts",     2.0),
    ("POST /orders",   "orders",    1.0),
]

# Per-edge RPC payloads in MB (request + response lumped), network fabric
# mode (DESIGN.md §6).  Sized from the public sock-shop API shapes: the
# catalogue returns full product listings (images metadata — the fat edge),
# DB round-trips return document sets, control-plane calls (payment auth,
# shipping hand-off) are near-empty.  Unlisted edges default to 0.01 MB.
PAYLOADS_MB = {
    ("front-end", "catalogue"):    0.120,
    ("front-end", "carts"):        0.030,
    ("front-end", "user"):         0.020,
    ("catalogue", "catalogue-db"): 0.150,
    ("carts", "carts-db"):         0.040,
    ("user", "user-db"):           0.015,
    ("orders", "orders-db"):       0.050,
    ("orders", "carts"):           0.030,
    ("orders", "user"):            0.015,
    ("orders", "payment"):         0.002,
    ("orders", "shipping"):        0.005,
    ("shipping", "rabbitmq"):      0.005,
    ("rabbitmq", "queue-master"):  0.005,
}

# Client→entry request payloads per API (MB): page requests are small;
# order submissions carry the basket document.
API_PAYLOADS_MB = {
    "GET /":          0.004,
    "GET /catalogue": 0.002,
    "GET /login":     0.001,
    "GET /basket":    0.002,
    "POST /orders":   0.020,
}


def app_spec(mi_scale: float = 1.0) -> dict:
    """The Fig 3a JSON document (as a dict; json.dump-able)."""
    return {
        "apis": [{"name": n, "entry": e, "weight": w,
                  "payload": API_PAYLOADS_MB[n]} for n, e, w in APIS],
        "services": [
            {"name": n, "calls": v["calls"], "mi": v["mi"] * mi_scale,
             "mi_std": 0.15 * v["mi"] * mi_scale,
             "payloads": {callee: mb for (src, callee), mb
                          in PAYLOADS_MB.items() if src == n}}
            for n, v in SERVICES.items()
        ],
    }


def instance_spec(share: float = 420.0, replicas: int = 1) -> dict:
    """The Fig 3b YAML document (as a dict; yaml.dump-able).

    Matches the paper's example: requests/limits blocks per instance group.
    """
    return {
        "instances": [
            {
                "prefix": name, "type": "pod", "labels": [name],
                "replicas": replicas, "size": 500,
                "rec_bw": 100, "trans_bw": 100,
                "requests": {"share": share, "ram": 300},
                "limits": {"share": 5 * share, "ram": 500},
            }
            for name in SERVICES
        ]
    }


# Calibrated constants (fit to the paper's published endpoints with the
# 2-knob secant in benchmarks/bench_response.py; see EXPERIMENTS.md):
#   mi_scale   — global cloudlet-length scale (congestion/curvature knob)
#   share      — per-instance CPU share, milicores (fixed during the fit)
#   net_latency— per-RPC-hop transport latency, seconds (level knob)
CALIBRATED = dict(mi_scale=1.052, share=1250.0, net_latency_s=0.1888)


def make_sim(n_clients: int = 100, duration_s: float = 600.0,
             dt: float = 0.1, mi_scale: float = CALIBRATED["mi_scale"],
             share: float = CALIBRATED["share"],
             net_latency_s: float = CALIBRATED["net_latency_s"],
             scaling_policy: int = 0, seed: int = 0,
             max_replicas: int = 4, spawn_rate: float | None = None,
             placement_policy: int | None = None, replicas: int = 1,
             host_zone: np.ndarray | None = None,
             vm_mips: np.ndarray | None = None,
             host_cpu_scale: np.ndarray | None = None,
             **param_overrides) -> Simulation:
    """Build the paper's §6.3 experiment: Locust wait U[5,15] s, 600 s.

    Pass ``network="fabric"`` (plus ``nic_egress_mbps``/``nic_ingress_mbps``)
    to replace the calibrated uniform hop latency with payload transit over
    the 10-node cluster's NICs (DESIGN.md §6) — e.g. the saturation sweep in
    examples/network_saturation.py.

    Pass ``faults="chaos"`` (plus the fault-rate knobs) to enable the
    Disruption phase (DESIGN.md §7) — e.g. the availability study in
    examples/chaos_study.py; ``replicas`` sets the initial replica count
    per service (chaos runs want ≥ 2 so a lone host crash degrades rather
    than blackholes a service).  ``host_zone`` maps the 10 nodes onto
    correlated failure domains for zone-level chaos (§7.1); default is
    one zone per node.
    """
    param_overrides.setdefault("net_latency_s", net_latency_s)
    max_replicas = max(max_replicas, replicas)
    caps = SimCaps(
        n_clients=max(n_clients, 1),
        max_requests=int(n_clients * duration_s / 8.0) + 256,
        max_cloudlets=1 << 13,
        max_instances=len(SERVICES) * max_replicas + 8,
        n_vms=10,                      # the paper's 10-node cluster
        d_max=5,
        max_replicas=max_replicas,
    )
    params = SimParams(
        dt=dt,
        n_ticks=int(duration_s / dt),
        n_clients=n_clients,
        spawn_rate=spawn_rate if spawn_rate is not None else n_clients / 30.0,
        wait_lo=5.0, wait_hi=15.0,     # paper: "wait times 5 to 15 seconds"
        slo_ms=1000.0,
        scaling_policy=scaling_policy,
        scale_interval=max(int(15.0 / dt), 1),
        seed=seed,
        **param_overrides,
    )
    # 3 master + 7 workers; capacities follow the paper's node list
    # (32..104 cores), 1 core ≡ 1000 milicores ≡ 1000 MIPS.  ``vm_mips``
    # overrides the node capacities (heterogeneous-hardware studies, e.g.
    # examples/hetero_study.py) while keeping the 10-node shape.
    if vm_mips is None:
        vm_mips = np.array([32, 32, 32, 32, 32, 32, 32, 56, 104, 64],
                           np.float32) * 1000.0
    vm_mips = np.asarray(vm_mips, np.float32)
    if vm_mips.shape != (10,):
        raise ValueError("sockshop runs on the paper's 10-node cluster; "
                         f"vm_mips must have 10 entries, got "
                         f"{vm_mips.shape}")
    vm_ram = np.array([64, 64, 64, 64, 64, 64, 64, 128, 256, 64],
                      np.float32) * 1024.0
    return register(app_spec(mi_scale), instance_spec(share, replicas),
                    caps=caps, params=params, vm_mips=vm_mips, vm_ram=vm_ram,
                    placement_policy=placement_policy, host_zone=host_zone,
                    host_cpu_scale=host_cpu_scale)


# Paper Fig 10 testbed reference (ms).  Only the 100/300-client values are
# published in the text; the figure's intermediate bars are unlabeled, so
# benchmarks score accuracy on the published points only and report the
# midpoints as predictions.
TESTBED_MS = {100: 749.0, 300: 2574.0}
