"""qwen2-vl-7b — VLM backbone with M-RoPE; patch frontend stubbed
(input_specs provides merged embeddings) [arXiv:2409.12191; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, head_dim=128,
    d_ff=18944, vocab=152064, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
)
