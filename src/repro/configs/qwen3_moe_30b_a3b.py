"""qwen3-moe-30b-a3b — 128 routed experts top-8, qk-norm
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.models.moe import MoECfg

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, head_dim=128,
    d_ff=768, vocab=151936, qk_norm=True, rope_theta=1e6,
    moe=MoECfg(n_experts=128, top_k=8, d_expert=768),
)
