"""Architecture config schema + the shape grid assigned to every arch."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.mamba2 import MambaDims
from repro.models.moe import MoECfg


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, int, int]] = None
    moe: Optional[MoECfg] = None
    mamba: Optional[MambaDims] = None
    attn_period: int = 0     # hybrid: layers per period (1 attn + rest mamba)
    ssd_chunk: int = 128
    n_enc_layers: int = 0        # enc-dec only
    n_frames: int = 0            # audio/vision stub frontend length
    tie_embeddings: bool = False
    sub_quadratic: bool = False  # True → long_500k cell applies
    # §Perf knob — attention sharding formulation:
    #   grouped       : baseline GQA einsum [B, Hkv, g, S, D] (head
    #                   sharding capped at n_kv → replication when
    #                   n_kv ∤ model-axis)
    #   flat          : repeat K/V to Hq heads; head dim shards when
    #                   Hq % model == 0
    #   flat_seqshard : flat + query-sequence sharding constraint over the
    #                   model axis (context parallelism; works ∀ head counts)
    attn_impl: str = "grouped"
    # §Perf knob — decode KV cache precision: "bf16" | "int8" (halves the
    # cache-read bytes that dominate the decode memory term)
    kv_dtype: str = "bf16"

    def reduced(self, **kw) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            name=self.name + "-smoke", family=self.family,
            n_layers=4 if self.attn_period else min(self.n_layers, 2),
            d_model=64,
            n_heads=4, n_kv=max(1, min(self.n_kv, 2)), head_dim=16,
            d_ff=128, vocab=256, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta, mrope_sections=None,
            moe=None, mamba=None, attn_period=self.attn_period and 4,
            ssd_chunk=16, n_enc_layers=min(self.n_enc_layers, 2),
            n_frames=min(self.n_frames, 8) if self.n_frames else 0,
            tie_embeddings=self.tie_embeddings,
            sub_quadratic=self.sub_quadratic,
        )
        if self.mrope_sections is not None:
            base["mrope_sections"] = (2, 3, 3)   # sums to head_dim/2 = 8
        if self.moe is not None:
            base["moe"] = MoECfg(
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_expert=32,
                n_shared=min(self.moe.n_shared, 1),
                d_shared=64 if self.moe.n_shared else 0,
                capacity_factor=self.moe.capacity_factor,
                norm_topk=self.moe.norm_topk)
        if self.mamba is not None:
            base["mamba"] = MambaDims.make(64, headdim=16, d_state=16,
                                           n_groups=1, d_conv=4)
        base.update(kw)
        return ArchConfig(**base)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = (
    ShapeCfg("train_4k", 4_096, 256, "train"),
    ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    ShapeCfg("decode_32k", 32_768, 128, "decode"),
    ShapeCfg("long_500k", 524_288, 1, "decode"),
)


def shape_applies(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """The assignment's skip rules (documented in DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (skip for " \
                      "pure full-attention archs)"
    return True, ""
