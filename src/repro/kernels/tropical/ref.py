"""Pure-jnp oracle for max-plus (tropical) semiring linear algebra.

``C[i,j] = max_k X[i,k] + A[k,j]`` — longest-path relaxation over a DAG
adjacency (paper Alg 2: the critical path is the max-delay chain).  These
references define the semantics the Pallas kernel must match bit-for-bit
(same f32 arithmetic, -inf padding).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

NEG_INF = -jnp.inf


def tropical_matmul(x: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """(…, N, K) ⊗ (…, K, M) → (…, N, M) in the (max, +) semiring."""
    return jnp.max(x[..., :, :, None] + a[..., None, :, :], axis=-2)


def tropical_identity(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Identity of the (max,+) semiring: 0 on the diagonal, -inf elsewhere."""
    return jnp.where(jnp.eye(n, dtype=bool), jnp.zeros((), dtype),
                     jnp.asarray(NEG_INF, dtype))


def tropical_closure(a: jnp.ndarray, depth: int | None = None) -> jnp.ndarray:
    """All-pairs longest path of a DAG: (I ⊕ A)^(2^⌈log₂ depth⌉).

    ``a[i, j]`` is the edge weight i→j (NEG_INF = no edge); the result
    ``D[i, j]`` is the maximum total weight over all i→j paths (0 for i=i).
    Repeated squaring needs ⌈log₂ depth⌉ tropical matmuls.
    """
    n = a.shape[-1]
    depth = n if depth is None else max(int(depth), 1)
    m = jnp.maximum(a, tropical_identity(n, a.dtype))
    for _ in range(int(np.ceil(np.log2(max(depth, 2))))):
        m = tropical_matmul(m, m)
    return m
