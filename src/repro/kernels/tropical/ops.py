"""Jitted public wrappers for tropical (max-plus) linear algebra.

Dispatch policy: the Pallas kernel runs on TPU backends (or under
``interpret=True`` for CPU validation); every other path uses the pure-jnp
oracle in ref.py.  Inputs are padded with -inf to 128-aligned tiles so
arbitrary service-graph sizes are accepted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .kernel import tropical_matmul_pallas

_TILE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_pow(x: jnp.ndarray, m_to: int, n_to: int) -> jnp.ndarray:
    pm = m_to - x.shape[-2]
    pn = n_to - x.shape[-1]
    if pm == 0 and pn == 0:
        return x
    cfg = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
    return jnp.pad(x, cfg, constant_values=ref.NEG_INF)


def tropical_matmul(x: jnp.ndarray, a: jnp.ndarray,
                    use_pallas: bool | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """(…, N, K) ⊗ (…, K, M) with automatic kernel/ref dispatch."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas and not interpret:
        return ref.tropical_matmul(x, a)

    batch_shape = x.shape[:-2]
    M, K = x.shape[-2:]
    N = a.shape[-1]
    Mp = -(-M // _TILE) * _TILE
    Kp = -(-K // _TILE) * _TILE
    Np = -(-N // _TILE) * _TILE
    xb = _pad_pow(x.reshape((-1, M, K)), Mp, Kp)
    ab = _pad_pow(a.reshape((-1, K, N)), Kp, Np)
    out = tropical_matmul_pallas(xb, ab, interpret=interpret)
    return out[..., :M, :N].reshape(batch_shape + (M, N))


def tropical_closure(a: jnp.ndarray, depth: int | None = None,
                     use_pallas: bool | None = None,
                     interpret: bool = False) -> jnp.ndarray:
    """All-pairs longest path via ⌈log₂ depth⌉ squarings (see ref)."""
    n = a.shape[-1]
    depth = n if depth is None else max(int(depth), 1)
    m = jnp.maximum(a, ref.tropical_identity(n, a.dtype))
    for _ in range(int(np.ceil(np.log2(max(depth, 2))))):
        m = tropical_matmul(m, m, use_pallas=use_pallas, interpret=interpret)
    return m
