"""Pallas TPU kernel: blocked max-plus (tropical) matmul.

The paper's critical-path analysis (Alg 2) is longest-path over the service
DAG; at fleet scale (thousands of services × batched delay snapshots) it is
matmul-shaped.  The MXU cannot help (the semiring replaces multiply-add
with add-max), so this kernel keeps the *memory* discipline of a blocked
matmul — HBM→VMEM tiles, 128-aligned, k-innermost accumulation — and does
the arithmetic on the VPU.

Grid: (B, M/bm, N/bn, K/bk), k innermost so the output tile stays resident
in VMEM across the k sweep (standard revisiting-accumulator pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _tropical_kernel(x_ref, a_ref, o_ref, *, bk: int):
    """One (bm × bn) output tile; accumulate max over the k-grid axis."""
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, NEG_INF)

    x = x_ref[0]          # [bm, bk]
    a = a_ref[0]          # [bk, bn]
    acc = o_ref[0]        # [bm, bn]

    def body(kk, acc):
        # rank-1 max-plus update: acc = max(acc, x[:, kk] + a[kk, :])
        return jnp.maximum(acc, x[:, kk][:, None] + a[kk, :][None, :])

    acc = jax.lax.fori_loop(0, bk, body, acc)
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def tropical_matmul_pallas(x: jnp.ndarray, a: jnp.ndarray,
                           bm: int = 128, bn: int = 128, bk: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """Batched (B, M, K) ⊗ (B, K, N) → (B, M, N) in (max, +).

    Shapes must tile evenly (ops.py pads with -inf); tiles default to the
    128-aligned VPU lane width.  VMEM footprint per step:
    bm·bk + bk·bn + bm·bn floats ≈ 192 KiB at 128³ — well inside v5e VMEM.
    """
    B, M, K = x.shape
    B2, K2, N = a.shape
    assert B == B2 and K == K2, (x.shape, a.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shapes {(M, N, K)} must tile by {(bm, bn, bk)}"

    grid = (B, M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_tropical_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, j, k: (b, i, k)),
            pl.BlockSpec((1, bk, bn), lambda b, i, j, k: (b, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M, N), x.dtype),
        interpret=interpret,
    )(x, a)
