from .ops import tropical_closure, tropical_matmul  # noqa: F401
from .ref import NEG_INF, tropical_identity  # noqa: F401
