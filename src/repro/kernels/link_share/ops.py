"""Jitted wrapper for the link_share water-filling with backend dispatch."""
from __future__ import annotations

import os

import jax

from . import ref
from .kernel import link_share_pallas

# The water-fill solve needs the whole transfer set resident in VMEM
# (DESIGN.md §6); beyond this lane count the jnp path takes over.
_VMEM_LANES = 1 << 15


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET") == "1"


def link_share(src, dst, active, cap_e, cap_i, iters: int = 4,
               use_pallas: bool | None = None, interpret: bool = False):
    """Max-min fair per-transfer rates (MB/s) over host NIC ports.

    Dispatches to the Pallas kernel on TPU (or in interpret mode) and to
    the jnp oracle elsewhere; both run the identical float program.
    """
    interpret = interpret or _force_interpret()
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas and not interpret and src.shape[0] > _VMEM_LANES:
        use_pallas = False
    if not (use_pallas or interpret):
        return ref.link_share(src, dst, active, cap_e, cap_i, iters)
    return link_share_pallas(src, dst, active, cap_e, cap_i, iters=iters,
                             interpret=interpret)
