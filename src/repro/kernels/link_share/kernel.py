"""Pallas TPU kernel: max-min fair NIC water-filling (DESIGN.md §6).

The water-filling recurrence is a *global* fixed-point: every round computes
one water level λ = min over all occupied ports, so the transfer set cannot
be streamed block-by-block — it must be VMEM-resident for the whole solve.
That fits: the active cloudlet buffer is ≤ 2¹³–2¹⁵ lanes (5 × f32/i32 ≈
160 KB at 8 K) and the per-host port tables are tiny.  The kernel therefore
runs on a single grid step with whole-array blocks and executes the exact
float program of ``ref.waterfill`` (same op order) on the loaded values —
interpret-mode tests assert bit-equality against the jnp oracle.

Pools too large for VMEM take the jnp path in ops.py (identical numerics);
arbitrary pool sizes are supported by padding the transfer axis with
inactive lanes (they never occupy a port).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _link_share_kernel(src_ref, dst_ref, active_ref, cap_e_ref, cap_i_ref,
                       rate_o, *, iters: int):
    rate_o[...] = ref.waterfill(
        src_ref[...], dst_ref[...], active_ref[...] != 0,
        cap_e_ref[...], cap_i_ref[...], iters)


def _pad_to(x, n, value):
    pad = n - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("iters", "bc", "interpret"))
def link_share_pallas(src, dst, active, cap_e, cap_i, iters: int = 4,
                      bc: int = 1024, interpret: bool = False):
    """Fair-share rates with the transfer axis padded to a ``bc`` multiple
    (inactive padding lanes never touch a port); returns [C] f32 rates."""
    C = src.shape[0]
    H = cap_e.shape[0]
    Cp = C + (-C % bc)
    src = _pad_to(src, Cp, -1)
    dst = _pad_to(dst, Cp, -1)
    active = _pad_to(active.astype(jnp.int32), Cp, 0)
    whole = lambda n: pl.BlockSpec((n,), lambda: (0,))
    rate = pl.pallas_call(
        functools.partial(_link_share_kernel, iters=iters),
        grid=(),
        in_specs=[whole(Cp), whole(Cp), whole(Cp), whole(H), whole(H)],
        out_specs=whole(Cp),
        out_shape=jax.ShapeDtypeStruct((Cp,), jnp.float32),
        interpret=interpret,
    )(src, dst, active, cap_e, cap_i)
    return rate[:C]
