"""Max-min fair NIC bandwidth sharing (network fabric, DESIGN.md §6)."""
from .kernel import link_share_pallas  # noqa: F401
from .ops import link_share  # noqa: F401
from .ref import link_share as link_share_ref  # noqa: F401
from .ref import waterfill  # noqa: F401
