"""Pure-jnp oracle for max-min fair NIC bandwidth sharing (DESIGN.md §6).

Every in-flight transfer ``t`` occupies up to two ports: the egress NIC of
its source host (``src[t]``, -1 = external client — no egress constraint)
and the ingress NIC of its destination host (``dst[t]``).  The max-min fair
allocation is computed by progressive water-filling:

  repeat ``iters`` times:
    * per-port fair share  s_p = remaining_cap_p / live_transfers_on_p
    * global water level   λ   = min over occupied ports of s_p
    * every live transfer gains λ; ports drain λ·n_p
    * transfers touching a now-saturated port freeze at their current rate

  finally, still-live transfers (more bottleneck levels than rounds) take
  one conservative fill: min over their ports of the residual fair share —
  always capacity-feasible, so the allocation never oversubscribes a link.

The recurrence is exact max-min when the scenario has at most ``iters``
distinct bottleneck water levels; beyond that it under-allocates only the
transfers still live after the last round.  The Pallas kernel runs this
exact float program (same op order) on VMEM-resident arrays, so
interpret-mode tests assert bit-equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# A port counts as saturated once its residual capacity falls below this
# relative tolerance — exact-arithmetic zero crossings land within a few ULP.
SAT_REL = 1e-5

# Occupancy via one-hot [C, H] masked sums while they fit in cache; past
# this element budget the O(C) scatter-add takes over.  Counts are small
# integers (exact in f32 in any order), and the choice is made on static
# shapes, so ref and kernel always agree per-shape — the bit-equality
# contract holds.
ONE_HOT_BUDGET = 1 << 22


def waterfill(src, dst, active, cap_e, cap_i, iters: int):
    """Shared fair-share recurrence (called by both ref and kernel body).

    Parameters (all jnp arrays)
    ---------------------------
    src : [C] i32 source host per transfer (-1 = no egress constraint).
    dst : [C] i32 destination host per transfer.
    active : [C] bool transfer is in flight.
    cap_e / cap_i : [H] f32 egress / ingress port capacities (MB/s).
    iters : static number of freeze rounds.

    Returns [C] f32 per-transfer rates (MB/s); 0 on inactive transfers.
    """
    f32 = jnp.float32
    H = cap_e.shape[0]
    inf = jnp.asarray(jnp.inf, f32)

    live = active & (dst >= 0)
    rate = jnp.zeros(src.shape, f32)
    rem_e = cap_e.astype(f32)
    rem_i = cap_i.astype(f32)

    # Port occupancy: one-hot reduction (vectorizes where CPU/TPU scatters
    # serialize) while [C, H] fits the budget, scatter-add beyond.  The
    # same code runs inside the Pallas kernel, so bit-equality holds.
    hosts = jnp.arange(H, dtype=src.dtype)
    one_hot = src.shape[0] * H <= ONE_HOT_BUDGET

    def occupancy(live):
        has_src = live & (src >= 0)
        if one_hot:
            n_e = jnp.sum(jnp.where(has_src[:, None],
                                    src[:, None] == hosts[None, :], False)
                          .astype(f32), axis=0)
            n_i = jnp.sum(jnp.where(live[:, None],
                                    dst[:, None] == hosts[None, :], False)
                          .astype(f32), axis=0)
        else:
            eidx = jnp.where(has_src, src, H)
            iidx = jnp.where(live, dst, H)
            n_e = jnp.zeros((H + 1,), f32).at[eidx].add(
                1.0, mode="drop")[:H]
            n_i = jnp.zeros((H + 1,), f32).at[iidx].add(
                1.0, mode="drop")[:H]
        return n_e, n_i

    for _ in range(iters):
        n_e, n_i = occupancy(live)
        share_e = rem_e / jnp.maximum(n_e, 1.0)
        share_i = rem_i / jnp.maximum(n_i, 1.0)
        lam = jnp.minimum(
            jnp.min(jnp.where(n_e > 0, share_e, inf)),
            jnp.min(jnp.where(n_i > 0, share_i, inf)))
        lam = jnp.where(jnp.isfinite(lam), jnp.maximum(lam, 0.0), 0.0)
        rate = rate + jnp.where(live, lam, 0.0)
        rem_e = rem_e - lam * n_e
        rem_i = rem_i - lam * n_i
        sat_e = (n_e > 0) & (rem_e <= SAT_REL * cap_e)
        sat_i = (n_i > 0) & (rem_i <= SAT_REL * cap_i)
        frozen = ((src >= 0) & sat_e[jnp.maximum(src, 0)]) \
            | sat_i[jnp.maximum(dst, 0)]
        live = live & ~frozen

    # Conservative final fill for transfers still live after the rounds.
    n_e, n_i = occupancy(live)
    share_e = rem_e / jnp.maximum(n_e, 1.0)
    share_i = rem_i / jnp.maximum(n_i, 1.0)
    fill = jnp.minimum(
        jnp.where(src >= 0, share_e[jnp.maximum(src, 0)], inf),
        share_i[jnp.maximum(dst, 0)])
    rate = rate + jnp.where(live, jnp.maximum(fill, 0.0), 0.0)

    # External-client uploads into an uncontended port: rate stays what the
    # water-filling gave them (ingress-limited); fully uncontended src=-1
    # transfers with dst<0 never occur (masked inactive above).
    return jnp.where(active & (dst >= 0), rate, 0.0)


@functools.partial(jax.jit, static_argnames=("iters",))
def link_share(src, dst, active, cap_e, cap_i, iters: int = 4):
    """Max-min fair per-transfer rates — jnp reference path.

    Jitted so the oracle is the *compiled* float program: eager op-by-op
    execution rounds FMA-fusable chains differently (~1 ULP) and would
    break the bit-equality contract with the kernel.
    """
    return waterfill(src, dst, active, cap_e, cap_i, iters)
