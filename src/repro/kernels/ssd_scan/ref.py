"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) scan.

Semantics (per batch b, head h; arXiv:2405.21060 §6):

    h_t = a_t · h_{t-1} + Δ_t · b_t ⊗ x_t        h ∈ R^{N×P}
    y_t = c_t · h_t + D_h · x_t

with a_t = exp(Δ_t · A_h) (A_h < 0 scalar per head), b_t, c_t ∈ R^N,
x_t ∈ R^P.  ``ssd_ref`` is the sequential scan (bit-true ground truth);
``ssd_chunked_ref`` is the chunked reformulation the Pallas kernel
implements (intra-chunk quadratic + inter-chunk state recurrence) — the
two must agree to float tolerance, and the kernel must match the chunked
form block-for-block.

Shapes: x [B, T, H, P], dt [B, T, H], A [H], B/C [B, T, G, N] with
H % G == 0 (G = state groups à la GQA), D [H].  Output [B, T, H, P].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(bc: jnp.ndarray, h: int) -> jnp.ndarray:
    """[B, T, G, N] → [B, T, H, N] by repeating each group H/G times."""
    g = bc.shape[2]
    assert h % g == 0
    return jnp.repeat(bc, h // g, axis=2)


def ssd_ref(x, dt, A, B, C, D=None):
    """Sequential scan oracle — O(T) steps, exact semantics."""
    Bsz, T, H, P = x.shape
    N = B.shape[-1]
    Bh = _expand_groups(B, H).astype(jnp.float32)
    Ch = _expand_groups(C, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32)[None, None, :])   # [B,T,H]

    def step(h_prev, inp):
        a_t, dt_t, b_t, c_t, x_t = inp
        # h: [B, H, N, P]
        h_new = (a_t[..., None, None] * h_prev
                 + (dt_t[..., None] * b_t)[..., :, None]
                 * x_t[..., None, :])
        y = jnp.einsum("bhn,bhnp->bhp", c_t, h_new)
        return h_new, y

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    inputs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(dtf, 1, 0),
              jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0),
              jnp.moveaxis(xf, 1, 0))
    _, ys = jax.lax.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1)                                 # [B,T,H,P]
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)


def chunk_intra(x_c, dt_c, la_c, b_c, c_c):
    """Intra-chunk quadratic part + per-chunk state summary.

    Inputs are per-chunk slices (f32): x_c [L,P], dt_c [L], la_c [L]
    (log a), b_c/c_c [L,N].  Returns (y_intra [L,P], state [N,P],
    total_decay scalar, in_decay [L]) where
      y_intra[i] = Σ_{j≤i} exp(cum[i]-cum[j]) (c_i·b_j) Δ_j x_j
      state      = Σ_j exp(cum[L-1]-cum[j]) Δ_j b_j ⊗ x_j
      in_decay[i]= exp(cum[i])   (decay applied to the carried-in state)
    This is exactly what the Pallas kernel computes per grid cell.
    """
    L = x_c.shape[0]
    cum = jnp.cumsum(la_c)                       # [L]
    seg = cum[:, None] - cum[None, :]            # [L, L] log decay i←j
    causal = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: upper-triangle seg is positive-large and would
    # overflow, poisoning the VJP with inf·0 NaNs
    gate = jnp.exp(jnp.where(causal, seg, -1e30))
    scores = (c_c @ b_c.T) * gate                # [L, L]
    dx = dt_c[:, None] * x_c                     # [L, P]
    y_intra = scores @ dx
    out_decay = jnp.exp(cum[-1] - cum)           # [L]
    state = (out_decay[:, None] * dt_c[:, None] * b_c).T @ x_c   # [N, P]
    in_decay = jnp.exp(cum)
    return y_intra, state, jnp.exp(cum[-1]), in_decay


def ssd_chunked_ref(x, dt, A, B, C, D=None, chunk: int = 64):
    """Chunked SSD — the algorithm the kernel implements.

    T must be divisible by ``chunk`` (callers pad; the model uses
    pad-to-chunk internally).
    """
    Bsz, T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0, (T, chunk)
    K = T // chunk
    Bh = _expand_groups(B, H).astype(jnp.float32)
    Ch = _expand_groups(C, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    la = dtf * A.astype(jnp.float32)[None, None, :]            # log a

    def per_chunk(xk, dtk, lak, bk, ck):
        return chunk_intra(xk, dtk, lak, bk, ck)

    # vmap over (batch, head, chunk)
    xr = xf.reshape(Bsz, K, chunk, H, P).transpose(0, 3, 1, 2, 4)
    dtr = dtf.reshape(Bsz, K, chunk, H).transpose(0, 3, 1, 2)
    lar = la.reshape(Bsz, K, chunk, H).transpose(0, 3, 1, 2)
    br = Bh.reshape(Bsz, K, chunk, H, N).transpose(0, 3, 1, 2, 4)
    cr = Ch.reshape(Bsz, K, chunk, H, N).transpose(0, 3, 1, 2, 4)
    f = jax.vmap(jax.vmap(jax.vmap(per_chunk)))
    y_intra, states, total_decay, in_decay = f(xr, dtr, lar, br, cr)
    # y_intra [B,H,K,L,P]; states [B,H,K,N,P]; total_decay [B,H,K];
    # in_decay [B,H,K,L]

    def carry(h_prev, inp):
        st, dec = inp                            # [B,H,N,P], [B,H]
        h_in = h_prev
        h_out = dec[..., None, None] * h_prev + st
        return h_out, h_in

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, h_ins = jax.lax.scan(
        carry, h0, (jnp.moveaxis(states, 2, 0),
                    jnp.moveaxis(total_decay, 2, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 2)            # [B,H,K,N,P] carried in
    y_carry = jnp.einsum("bhkln,bhkl,bhknp->bhklp", cr, in_decay, h_ins)
    y = (y_intra + y_carry).transpose(0, 2, 3, 1, 4).reshape(Bsz, T, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)


def ssd_decode_step(h, x_t, dt_t, A, b_t, c_t, D=None):
    """O(1) single-token decode: update state, emit one output.

    h [B,H,N,P]; x_t [B,H,P]; dt_t [B,H]; b_t/c_t [B,G,N].
    """
    H = x_t.shape[1]
    G = b_t.shape[1]
    rep = H // G
    bh = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)
    a_t = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    h_new = (a_t[..., None, None] * h
             + (dt_t[..., None].astype(jnp.float32) * bh)[..., :, None]
             * x_t.astype(jnp.float32)[..., None, :])
    y = jnp.einsum("bhn,bhnp->bhp", ch, h_new)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :, None] * x_t.astype(jnp.float32)
    return h_new, y.astype(x_t.dtype)
