from .ops import ssd, ssd_decode_step  # noqa: F401
from .ref import ssd_chunked_ref, ssd_ref  # noqa: F401
