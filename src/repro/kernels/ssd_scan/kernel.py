"""Pallas TPU kernel: Mamba-2 SSD intra-chunk compute.

The chunked SSD algorithm (arXiv:2405.21060) splits the sequence into
chunks of length L: a quadratic *intra-chunk* part (this kernel — the
compute hot spot, matmul-shaped for the MXU) and a cheap inter-chunk state
recurrence (plain ``lax.scan`` in ops.py).

Per grid cell (one batch·head, one chunk) the kernel computes, in VMEM:

    cum   = cumsum(log a)                               [L]
    M     = exp(cum_i - cum_j) ⊙ causal ⊙ (C Bᵀ)        [L, L]
    y     = M (Δ ⊙ X)                                   [L, P]
    state = ((exp(cum_L - cum) ⊙ Δ) B)ᵀ X               [N, P]
    extra outputs: in_decay = exp(cum), total = exp(cum_L)

VMEM footprint at L=128, N=128, P=64: X/B/C/M + outputs ≈ 0.4 MB.
The carried-state contribution (C Λ h_in) is applied outside — it depends
on the sequential scan and would serialize the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref,
                      y_ref, st_ref, dec_ref, tot_ref):
    x = x_ref[0, 0].astype(jnp.float32)    # [L, P]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [L, 1]
    la = la_ref[0, 0].astype(jnp.float32)  # [L, 1]
    b = b_ref[0, 0].astype(jnp.float32)    # [L, N]
    c = c_ref[0, 0].astype(jnp.float32)    # [L, N]
    L = x.shape[0]

    cum = jnp.cumsum(la, axis=0)           # [L, 1]
    seg = cum - cum.reshape(1, L)          # [L, L] log-decay i←j
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    gate = jnp.exp(jnp.where(causal, seg, -1e30))   # mask-before-exp
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * gate
    dx = dt * x                            # [L, P]
    y_ref[0, 0] = jax.lax.dot_general(
        scores, dx, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    out_decay = jnp.exp(cum[L - 1] - cum)  # [L, 1]
    wb = out_decay * dt * b                # [L, N]
    st_ref[0, 0] = jax.lax.dot_general(
        wb, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(st_ref.dtype)
    dec_ref[0, 0] = jnp.exp(cum).astype(dec_ref.dtype)
    tot_ref[0, 0] = jnp.exp(cum[L - 1]).reshape(1, 1).astype(tot_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(x, dt, la, b, c, interpret: bool = False):
    """Intra-chunk SSD over every (batch·head, chunk) grid cell.

    x [M, K, L, P]; dt, la [M, K, L, 1]; b, c [M, K, L, N]  (M = B·H
    flattened, K chunks).  Returns (y [M,K,L,P], state [M,K,N,P],
    in_decay [M,K,L,1], total_decay [M,K,1,1]) — all f32.
    """
    M, K, L, P = x.shape
    N = b.shape[-1]
    grid = (M, K)
    spec = lambda d: pl.BlockSpec((1, 1, L, d), lambda m, k: (m, k, 0, 0))
    f32 = jnp.float32
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda m, k: (m, k, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda m, k: (m, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda m, k: (m, k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K, L, P), f32),
            jax.ShapeDtypeStruct((M, K, N, P), f32),
            jax.ShapeDtypeStruct((M, K, L, 1), f32),
            jax.ShapeDtypeStruct((M, K, 1, 1), f32),
        ],
        interpret=interpret,
    )(x, dt, la, b, c)
