"""Public SSD op: chunked scan with kernel/ref dispatch.

``ssd(x, dt, A, B, C, D)`` computes the full Mamba-2 SSD layer output.
The intra-chunk quadratic part runs in the Pallas kernel (TPU / interpret);
the inter-chunk state recurrence is a cheap ``lax.scan``.  Non-TPU
backends lower the pure-jnp chunked reference (identical math).
Differentiable: the kernel path uses a recompute-vjp against the ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import ssd_chunk_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_ssd(x, dt, A, B, C, D, chunk, interpret):
    Bsz, T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0, (T, chunk)
    K = T // chunk
    Bh = ref._expand_groups(B, H).astype(jnp.float32)
    Ch = ref._expand_groups(C, H).astype(jnp.float32)
    la = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]

    def to_mk(v, d):
        # [B, T, H, d] → [B·H, K, L, d]
        return (v.reshape(Bsz, K, chunk, H, d).transpose(0, 3, 1, 2, 4)
                .reshape(Bsz * H, K, chunk, d))

    xk = to_mk(x.astype(jnp.float32), P)
    dtk = to_mk(dt.astype(jnp.float32)[..., None], 1)
    lak = to_mk(la[..., None], 1)
    bk = to_mk(Bh, N)
    ck = to_mk(Ch, N)

    y_intra, states, in_decay, total = ssd_chunk_pallas(
        xk, dtk, lak, bk, ck, interpret=interpret)

    # inter-chunk recurrence over K (cheap: [B·H, N, P] carries)
    def carry(h_prev, inp):
        st, dec = inp
        return dec[:, 0, 0, None, None] * h_prev + st, h_prev

    _, h_ins = jax.lax.scan(carry,
                            jnp.zeros((Bsz * H, N, P), jnp.float32),
                            (jnp.moveaxis(states, 1, 0),
                             jnp.moveaxis(total, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)              # [B·H, K, N, P]
    y_carry = jnp.einsum("mkln,mklo,mknp->mklp", ck, in_decay, h_ins)
    y = (y_intra + y_carry).reshape(Bsz, H, K, chunk, P) \
        .transpose(0, 2, 3, 1, 4).reshape(Bsz, T, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] \
            * x.astype(jnp.float32)
    return y.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _ssd_kernel_vjp(x, dt, A, B, C, D, chunk, interpret):
    return _kernel_ssd(x, dt, A, B, C, D, chunk, interpret)


def _fwd(x, dt, A, B, C, D, chunk, interpret):
    return (_kernel_ssd(x, dt, A, B, C, D, chunk, interpret),
            (x, dt, A, B, C, D))


def _bwd(chunk, interpret, res, g):
    x, dt, A, B, C, D = res
    _, vjp = jax.vjp(
        lambda *a: ref.ssd_chunked_ref(*a, chunk=chunk), x, dt, A, B, C, D)
    return vjp(g)


_ssd_kernel_vjp.defvjp(_fwd, _bwd)


def ssd(x, dt, A, B, C, D=None, chunk: int = 64, impl: str | None = None,
        interpret: bool = False):
    """Mamba-2 SSD layer.  impl: None (auto) | 'ref' | 'chunked' | 'kernel'."""
    if impl is None:
        impl = "kernel" if (_on_tpu() or interpret) else "chunked"
    T = x.shape[1]
    pad = (-T) % chunk
    if pad and impl != "ref":
        # zero-Δ padding is inert: a = exp(0·A) = 1 and Δ·b·x = 0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if impl == "ref":
        out = ref.ssd_ref(x, dt, A, B, C, D)
    elif impl == "chunked":
        out = ref.ssd_chunked_ref(x, dt, A, B, C, D, chunk=chunk)
    elif impl == "kernel":
        out = _ssd_kernel_vjp(x, dt, A, B, C, D, chunk, interpret)
    else:
        raise ValueError(f"unknown ssd impl {impl!r}")
    return out[:, :T] if pad else out


ssd_decode_step = ref.ssd_decode_step
