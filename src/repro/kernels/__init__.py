"""Pallas TPU kernels with pure-jnp oracles.

Each kernel lives in its own subpackage with three files:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jitted public wrapper with backend dispatch + padding
  ref.py    — pure-jnp oracle used for interpret-mode validation

This container is CPU-only: kernels are validated with ``interpret=True``
(tests sweep shapes/dtypes against ref) and the reference path is what the
multi-pod dry-run lowers (DESIGN.md §4).
"""
