"""Public attention op with kernel/ref dispatch and padding.

``attention(q, k, v)`` is differentiable everywhere: the Pallas kernel is
wired through ``jax.custom_vjp`` with a recompute backward based on the
reference implementation (correct gradients today; a fused backward kernel
is a listed §Perf follow-up).  On non-TPU backends (and in the multi-pod
dry-run) the pure-jnp reference path is lowered directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_len(t: int, b: int) -> int:
    return (-t) % b


def _kernel_call(q, k, v, causal, scale, bq, bk, interpret):
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    pq = _pad_len(Tq, bq)
    pk = _pad_len(Tk, bk)
    if pq or pk:
        # Right-pad; the kernel masks with the ORIGINAL offset and kv_len,
        # so padded keys are inert and padded-query rows are dropped here.
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        out = flash_attention_pallas(qp, kp, vp, causal=causal, scale=scale,
                                     bq=bq, bk=bk, interpret=interpret,
                                     off=Tk - Tq, kv_len=Tk)
        return out[:, :, :Tq]
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  bq=bq, bk=bk, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, scale, bq, bk, interpret):
    return _kernel_call(q, k, v, causal, scale, bq, bk, interpret)


def _flash_fwd(q, k, v, causal, scale, bq, bk, interpret):
    return _kernel_call(q, k, v, causal, scale, bq, bk, interpret), (q, k, v)


def _flash_bwd(causal, scale, bq, bk, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: ref.attention(a, b, c, causal, scale),
                     q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, scale: float | None = None,
              impl: str | None = None, bq: int = 128, bk: int = 128,
              interpret: bool = False) -> jnp.ndarray:
    """Causal GQA attention.  impl: None (auto) | 'ref' | 'flash'."""
    if impl is None:
        impl = "flash" if (_on_tpu() or interpret) else "ref"
    if impl == "ref":
        return ref.attention(q, k, v, causal=causal, scale=scale)
    if impl == "flash":
        return _flash(q, k, v, causal, scale, bq, bk, interpret)
    raise ValueError(f"unknown attention impl {impl!r}")
