"""Pure-jnp oracle for causal GQA attention (the kernel's ground truth).

Shapes:
  q: [B, Hq, Tq, D]   k, v: [B, Hkv, Tk, D]   with Hq % Hkv == 0.
Causal masking aligns the *ends* of the sequences (decode-style offset):
query position i attends to key positions j with  j ≤ i + (Tk - Tq).
All arithmetic in f32 regardless of input dtype (matches kernel policy).
"""
from __future__ import annotations

import jax.numpy as jnp


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Tq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if causal:
        off = Tk - Tq
        qi = jnp.arange(Tq)[:, None]
        kj = jnp.arange(Tk)[None, :]
        mask = kj <= qi + off
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, vf)
    return out.reshape(B, Hq, Tq, D).astype(q.dtype)
