"""Pallas TPU kernel: blocked causal GQA flash attention (forward).

Prefill is the compute hot spot of the serving path (32k-token contexts);
this kernel streams K/V blocks through VMEM with the online-softmax
recurrence so the [Tq, Tk] logits matrix never materializes in HBM.

Grid: (B, Hq, Tq/bq, Tk/bk) with the key axis innermost; the running
max/denominator/accumulator live in VMEM scratch and persist across the
key sweep (TPU grids execute as a sequential loop per core).  GQA is a
pure index-map trick: the K/V BlockSpecs map query head h → kv head
h // group, so no head replication is materialized.

Causal masking aligns sequence ends (query i sees keys ≤ i + Tk - Tq),
which serves both training (Tq == Tk) and chunked prefill (Tq < Tk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(jnp.finfo(jnp.float32).min)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  off: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)          # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len                          # right-padding is inert
    if causal:
        # end-aligned horizon of the ORIGINAL (unpadded) shapes
        mask &= kpos <= qpos + off
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                           # [bq, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        # fully-masked rows (possible when Tq > Tk + off) produce l == 0
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "bq", "bk", "interpret",
                     "off", "kv_len"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False,
                           off: int | None = None,
                           kv_len: int | None = None) -> jnp.ndarray:
    """q: [B, Hq, Tq, D]; k, v: [B, Hkv, Tk, D] → [B, Hq, Tq, D].

    Tq % bq == Tk % bk == 0 (ops.py pads); D should be 128-aligned for MXU
    efficiency.  VMEM per step: (bq + 2·bk)·D + bq·bk + bq·(D+2) floats
    ≈ 0.33 MB at 128²×128 — leaves room for double buffering.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, Tk, bq, bk)
    scale_v = (D ** -0.5) if scale is None else scale
    if off is None:
        off = Tk - Tq
    if kv_len is None:
        kv_len = Tk

    grid = (B, Hq, Tq // bq, Tk // bk)
    kernel = functools.partial(_flash_kernel, scale=scale_v, causal=causal,
                               bq=bq, bk=bk, off=off, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator l
            pltpu.VMEM((bq, D), jnp.float32),   # weighted-V accumulator
        ],
        interpret=interpret,
    )(q, k, v)
