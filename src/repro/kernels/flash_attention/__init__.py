from .ops import attention  # noqa: F401
from .ref import attention as attention_ref  # noqa: F401
