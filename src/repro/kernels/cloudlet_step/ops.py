"""Jitted wrappers for the fused cloudlet tick with backend dispatch."""
from __future__ import annotations

import os

import jax

from . import ref
from .kernel import cloudlet_finish_pallas, cloudlet_step_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    """CI hook: REPRO_PALLAS_INTERPRET=1 routes every engine-level call
    through the Pallas kernel in interpret mode, gating the kernels against
    their jnp oracles on every push."""
    return os.environ.get("REPRO_PALLAS_INTERPRET") == "1"


def cloudlet_step(status, rem, inst, rate, time, dt, n_inst: int,
                  use_pallas: bool | None = None, interpret: bool = False):
    """Advance all executing cloudlets one tick (see ref.py for contract)."""
    interpret = interpret or _force_interpret()
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return ref.cloudlet_step(status, rem, inst, rate, time, dt, n_inst)
    C = status.shape[0]
    return cloudlet_step_pallas(status, rem, inst, rate, time, dt,
                                n_inst=n_inst, bc=min(8192, C),
                                interpret=interpret)


def cloudlet_finish(status, rem, inst, req, arrival, start, depth,
                    rate, time, dt, req_finish, req_crit, req_out,
                    n_inst: int,
                    use_pallas: bool | None = None, interpret: bool = False
                    ) -> ref.FinishOut:
    """One-pass execution tick + all finish reductions (engine hot path).

    Dispatches to the extended Pallas kernel on TPU (or in interpret mode)
    and to the stacked-scatter jnp reference otherwise.
    """
    interpret = interpret or _force_interpret()
    if use_pallas is None:
        use_pallas = _on_tpu()
    # The kernel keeps the six [R] request arrays resident in VMEM
    # (revisited every grid step); past ~8 MB of request state fall back
    # to the jnp path, which is scatter-for-scatter equivalent.
    R = req_finish.shape[0]
    if use_pallas and not interpret and 6 * 4 * R > (8 << 20):
        use_pallas = False
    if not (use_pallas or interpret):
        return ref.cloudlet_finish(status, rem, inst, req, arrival,
                                   start, depth, rate, time, dt,
                                   req_finish, req_crit, req_out,
                                   n_inst=n_inst)
    C = status.shape[0]
    outs = cloudlet_finish_pallas(status, rem, inst, req, arrival,
                                  start, depth, rate, time, dt,
                                  req_finish, req_crit, req_out,
                                  n_inst=n_inst,
                                  bc=min(8192, C), interpret=interpret)
    return ref.FinishOut(*outs)


def cloudlet_finish_pool(cl, rate, time, dt, req_finish, req_crit, req_out,
                         n_inst: int, use_pallas: bool | None = None,
                         interpret: bool = False) -> ref.FinishOut:
    """Engine-facing entry over the stacked cloudlet pool.

    The kernel's input columns are sliced out of the ``[C, NI]``/``[C, NF]``
    blocks through the mode-keyed :class:`core.types.PoolLayout` carried by
    ``cl`` — no hard-coded column positions — then dispatched exactly like
    :func:`cloudlet_finish`.  Works for any layout that registers the
    Execute-phase columns (every mode does).
    """
    L = cl.layout
    ints, flts = cl.ints, cl.flts
    return cloudlet_finish(
        ints[:, L.i("status")], flts[:, L.f("rem")], ints[:, L.i("inst")],
        ints[:, L.i("req")], flts[:, L.f("arrival")],
        flts[:, L.f("start")], ints[:, L.i("depth")], rate, time, dt,
        req_finish, req_crit, req_out, n_inst=n_inst,
        use_pallas=use_pallas, interpret=interpret)
