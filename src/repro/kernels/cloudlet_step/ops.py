"""Jitted wrapper for the fused cloudlet tick with backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kernel import cloudlet_step_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cloudlet_step(status, rem, inst, rate, time, dt, n_inst: int,
                  use_pallas: bool | None = None, interpret: bool = False):
    """Advance all executing cloudlets one tick (see ref.py for contract)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return ref.cloudlet_step(status, rem, inst, rate, time, dt, n_inst)
    C = status.shape[0]
    bc = min(8192, C)
    while C % bc:
        bc //= 2
    return cloudlet_step_pallas(status, rem, inst, rate, time, dt,
                                n_inst=n_inst, bc=max(bc, 1),
                                interpret=interpret)
