from .ops import cloudlet_step  # noqa: F401
from .ref import cloudlet_step as cloudlet_step_ref  # noqa: F401
