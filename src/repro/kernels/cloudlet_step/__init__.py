from .ops import (cloudlet_finish, cloudlet_finish_pool,  # noqa: F401
                  cloudlet_step)  # noqa: F401
from .ref import FinishOut  # noqa: F401
from .ref import cloudlet_finish as cloudlet_finish_ref  # noqa: F401
from .ref import cloudlet_step as cloudlet_step_ref  # noqa: F401
