"""Pallas TPU kernel: fused cloudlet execution tick (paper §4.2 hot loop).

One VMEM pass over the active cloudlet buffer computes the elementwise
progress/finish chain AND every finish-time reduction the scheduler needs:
per-instance consumption + finish counts + sojourn/exec/wait sums (the
per-service statistics fall out of a tiny instance→service reduction
outside), and the per-request aggregates (max finish time, max critical
depth, outstanding count) updated in place.  The jnp path needs
five separate scatter passes for the same update (see ref.cloudlet_finish);
here the accumulator outputs are *revisited* by every grid step
(index_map → block 0) — the canonical Pallas reduction pattern; the
cloudlet axis is the grid.  The request-side outputs are seeded from their
input arrays on the first grid step, then scatter-updated per block.

Scatter note: TPU vector scatter (`.at[].add`/`.at[].max` on a VMEM block)
is legal but serializes per unique index; instance/service counts (≤ a few
thousand) keep the accumulators resident in VMEM, and capacity-test shapes
put ~2⁶ lanes per instance so contention is modest.  The per-request
arrays ride along whole — for request pools too large for VMEM run the
jnp path (it is scatter-for-scatter equivalent).

Arbitrary pool sizes are supported: inputs are padded up to the block
multiple with free slots (status 0 never contributes) and the per-cloudlet
outputs sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CL_EXEC = 2


def _cloudlet_kernel(time_ref, dt_ref, status_ref, rem_ref, inst_ref,
                     req_ref, arr_ref, start_ref, depth_ref,
                     rate_ref, reqf_in, reqc_in, reqo_in,
                     rem_o, fin_o, tfin_o, cons_o,
                     inst_o, reqf_o, reqc_o, reqo_o,
                     *, n_inst: int, n_req: int):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        inst_o[...] = jnp.zeros_like(inst_o)
        reqf_o[...] = reqf_in[...]
        reqc_o[...] = reqc_in[...]
        reqo_o[...] = reqo_in[...]

    time = time_ref[0]
    dt = dt_ref[0]
    status = status_ref[...]
    rem = rem_ref[...]
    inst = inst_ref[...]
    req = req_ref[...]
    arrival = arr_ref[...]
    start = start_ref[...]
    depth = depth_ref[...]
    rate = rate_ref[...]
    f32 = jnp.float32

    execm = status == CL_EXEC
    prog = rate * dt
    fin = execm & (rem <= prog) & (rate > 0)
    tfin = jnp.where(
        fin, jnp.clip(time + rem / jnp.maximum(rate, 1e-9), time, time + dt),
        0.0)
    consumed = jnp.where(execm, jnp.minimum(prog, rem), 0.0)
    finf = fin.astype(f32)

    rem_o[...] = jnp.where(execm, jnp.maximum(rem - prog, 0.0), rem)
    fin_o[...] = fin.astype(jnp.int32)
    tfin_o[...] = tfin
    cons_o[...] = consumed

    started = jnp.maximum(start, arrival)
    sojourn = jnp.where(fin, tfin - arrival, 0.0)
    exec_t = jnp.where(fin, tfin - started, 0.0)
    wait_t = jnp.where(fin, started - arrival, 0.0)
    iidx = jnp.where(execm & (inst >= 0), inst, n_inst)
    inst_o[...] = inst_o[...].at[iidx].add(
        jnp.stack([consumed / dt, finf, sojourn, exec_t, wait_t], axis=1),
        mode="drop")

    ridx = jnp.where(fin & (req >= 0), req, n_req)
    reqf_o[...] = reqf_o[...].at[ridx].max(tfin, mode="drop")
    reqc_o[...] = reqc_o[...].at[ridx].max(depth + 1, mode="drop")
    reqo_o[...] = reqo_o[...].at[ridx].add(-fin.astype(jnp.int32),
                                           mode="drop")


def _pad_to(x, n, value):
    pad = n - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


@functools.partial(jax.jit, static_argnames=("n_inst", "bc", "interpret"))
def cloudlet_finish_pallas(status, rem, inst, req, arrival, start,
                           depth, rate, time, dt, req_finish, req_crit,
                           req_out, n_inst: int,
                           bc: int = 8192, interpret: bool = False):
    """Extended finish-reduction kernel; returns the ref.FinishOut fields
    (fin as bool) with per-cloudlet outputs sliced back to the input size."""
    C = status.shape[0]
    R = req_finish.shape[0]
    bc = min(bc, C)
    Cp = C + (-C % bc)          # pad the pool to the block multiple
    grid = (Cp // bc,)
    status = _pad_to(status, Cp, 0)          # CL_FREE: never contributes
    rem = _pad_to(rem, Cp, 0.0)
    inst = _pad_to(inst, Cp, -1)
    req = _pad_to(req, Cp, -1)
    arrival = _pad_to(arrival, Cp, 0.0)
    start = _pad_to(start, Cp, -1.0)
    depth = _pad_to(depth, Cp, 0)
    rate = _pad_to(rate, Cp, 0.0)
    time_a = jnp.asarray(time, jnp.float32).reshape(1)
    dt_a = jnp.asarray(dt, jnp.float32).reshape(1)
    blk = lambda: pl.BlockSpec((bc,), lambda c: (c,))
    acc = lambda *shape: pl.BlockSpec(shape, lambda c: (0,) * len(shape))
    f32, i32 = jnp.float32, jnp.int32
    outs = pl.pallas_call(
        functools.partial(_cloudlet_kernel, n_inst=n_inst, n_req=R),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda c: (0,)),
            pl.BlockSpec((1,), lambda c: (0,)),
            blk(), blk(), blk(), blk(), blk(), blk(), blk(), blk(),
            acc(R), acc(R), acc(R),
        ],
        out_specs=[
            blk(), blk(), blk(), blk(),
            acc(n_inst + 1, 5),                          # revisited accums
            acc(R), acc(R), acc(R),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Cp,), f32),
            jax.ShapeDtypeStruct((Cp,), i32),
            jax.ShapeDtypeStruct((Cp,), f32),
            jax.ShapeDtypeStruct((Cp,), f32),
            jax.ShapeDtypeStruct((n_inst + 1, 5), f32),
            jax.ShapeDtypeStruct((R,), f32),
            jax.ShapeDtypeStruct((R,), i32),
            jax.ShapeDtypeStruct((R,), i32),
        ],
        interpret=interpret,
    )(time_a, dt_a, status, rem, inst, req, arrival, start, depth,
      rate, req_finish, req_crit, req_out)
    new_rem, fin, tfin, cons, inst_acc, reqf, reqc, reqo = outs
    return (new_rem[:C], fin[:C].astype(bool), tfin[:C], cons[:C],
            inst_acc, reqf, reqc, reqo)


@functools.partial(jax.jit, static_argnames=("n_inst", "bc", "interpret"))
def cloudlet_step_pallas(status, rem, inst, rate, time, dt, n_inst: int,
                         bc: int = 8192, interpret: bool = False):
    """Legacy 5-output API, served by the extended kernel with inert
    service/request lanes (their accumulators are dropped)."""
    C = status.shape[0]
    neg_i = jnp.full((C,), -1, jnp.int32)
    zero_f = jnp.zeros((C,), jnp.float32)
    outs = cloudlet_finish_pallas(
        status, rem, inst, neg_i, zero_f, zero_f,
        jnp.zeros((C,), jnp.int32), rate, time, dt,
        jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.int32),
        jnp.zeros((1,), jnp.int32),
        n_inst=n_inst, bc=bc, interpret=interpret)
    new_rem, fin, tfin, cons, inst_acc = outs[:5]
    return new_rem, fin, tfin, cons, inst_acc[:n_inst, 0]
