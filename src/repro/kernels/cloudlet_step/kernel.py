"""Pallas TPU kernel: fused cloudlet execution tick (paper §4.2 hot loop).

Fuses the elementwise progress/finish chain with the per-instance
consumption reduction so the active buffer streams through VMEM exactly
once per tick (the jnp path makes ~5 passes).  The per-instance
accumulator output is *revisited* by every grid step (index_map → block 0)
— the canonical Pallas reduction pattern; the cloudlet axis is the grid.

Scatter note: TPU vector scatter (`.at[].add` on a VMEM block) is legal
but serializes per unique index; instance counts (≤ a few thousand) keep
the accumulator resident in VMEM, and capacity-test shapes put ~2⁶ lanes
per instance so contention is modest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CL_EXEC = 2


def _cloudlet_kernel(time_ref, dt_ref, status_ref, rem_ref, inst_ref,
                     rate_ref, rem_o, fin_o, tfin_o, cons_o, used_o,
                     *, n_inst: int):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        used_o[...] = jnp.zeros_like(used_o)

    time = time_ref[0]
    dt = dt_ref[0]
    status = status_ref[...]
    rem = rem_ref[...]
    inst = inst_ref[...]
    rate = rate_ref[...]

    execm = status == CL_EXEC
    prog = rate * dt
    fin = execm & (rem <= prog) & (rate > 0)
    tfin = jnp.where(
        fin, jnp.clip(time + rem / jnp.maximum(rate, 1e-9), time, time + dt),
        0.0)
    consumed = jnp.where(execm, jnp.minimum(prog, rem), 0.0)

    rem_o[...] = jnp.where(execm, jnp.maximum(rem - prog, 0.0), rem)
    fin_o[...] = fin.astype(jnp.int32)
    tfin_o[...] = tfin
    cons_o[...] = consumed

    idx = jnp.where(execm & (inst >= 0), inst, n_inst)
    used_o[...] = used_o[...].at[idx].add(consumed / dt, mode="drop")


@functools.partial(jax.jit, static_argnames=("n_inst", "bc", "interpret"))
def cloudlet_step_pallas(status, rem, inst, rate, time, dt, n_inst: int,
                         bc: int = 8192, interpret: bool = False):
    C = status.shape[0]
    assert C % bc == 0, (C, bc)
    grid = (C // bc,)
    time_a = jnp.asarray(time, jnp.float32).reshape(1)
    dt_a = jnp.asarray(dt, jnp.float32).reshape(1)
    blk = lambda: pl.BlockSpec((bc,), lambda c: (c,))
    outs = pl.pallas_call(
        functools.partial(_cloudlet_kernel, n_inst=n_inst),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda c: (0,)),
            pl.BlockSpec((1,), lambda c: (0,)),
            blk(), blk(), blk(), blk(),
        ],
        out_specs=[
            blk(), blk(), blk(), blk(),
            pl.BlockSpec((n_inst + 1,), lambda c: (0,)),   # revisited accum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((C,), jnp.int32),
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((n_inst + 1,), jnp.float32),
        ],
        interpret=interpret,
    )(time_a, dt_a, status, rem, inst, rate)
    new_rem, fin, tfin, consumed, used = outs
    return new_rem, fin.astype(bool), tfin, consumed, used[:n_inst]
