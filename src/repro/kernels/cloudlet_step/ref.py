"""Pure-jnp oracles for the fused cloudlet execution tick (paper §4.2).

Two contracts share the elementwise progress/finish core:

``cloudlet_step``  — the original 5-output update (progress, sub-tick
finishes, consumption, per-instance usage).  Kept verbatim: it is the
oracle for the legacy kernel API tests.

``cloudlet_finish`` — the single-pass finish reduction the engine now
runs every tick: progress PLUS every per-finish aggregate the scheduler
needs.  Per-instance statistics (usage, finish count, sojourn/exec/wait
sums) land in ONE stacked [I+1, 5] scatter; per-service stats are derived
outside by reducing that table over the (tiny) instance→service map; the
per-request aggregates (max finish time, max critical depth, outstanding)
are updated in place so the request pool is never re-streamed.  This is
the jnp mirror of the extended Pallas kernel's one VMEM pass.

Inputs (all [C] unless noted):
  status i32 (2 = executing), rem f32 (MI), inst i32,
  req i32, arrival f32, start f32, depth i32, rate f32 (MI/s),
  time scalar, dt scalar, req_finish/req_crit/req_out [R]; n_inst static.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from ...analysis.annotate import collide

CL_EXEC = 2


def cloudlet_step(status, rem, inst, rate, time, dt, n_inst: int):
    execm = status == CL_EXEC
    prog = rate * dt
    fin = execm & (rem <= prog) & (rate > 0)
    tfin = jnp.where(
        fin, jnp.clip(time + rem / jnp.maximum(rate, 1e-9), time, time + dt),
        0.0)
    consumed = jnp.where(execm, jnp.minimum(prog, rem), 0.0)
    new_rem = jnp.where(execm, jnp.maximum(rem - prog, 0.0), rem)
    idx = jnp.where(execm & (inst >= 0), inst, n_inst)
    used = jnp.zeros((n_inst,), jnp.float32).at[idx].add(
        consumed / dt, mode="drop")
    return new_rem, fin, tfin, consumed, used


class FinishOut(NamedTuple):
    """Outputs of the fused finish reduction (see module docstring)."""

    new_rem: jnp.ndarray    # [C] f32
    fin: jnp.ndarray        # [C] bool
    tfin: jnp.ndarray       # [C] f32 sub-tick finish timestamp
    consumed: jnp.ndarray   # [C] f32 MI consumed this tick
    inst_acc: jnp.ndarray   # [I+1, 5] f32: used MI/s, finish count,
    #                         sojourn / exec / wait sums (row I = overflow)
    req_finish: jnp.ndarray  # [R] f32 updated max finish time per request
    req_crit: jnp.ndarray    # [R] i32 updated max critical depth
    req_out: jnp.ndarray     # [R] i32 updated outstanding count


# inst_acc column indices
ACC_USED, ACC_FIN, ACC_SOJOURN, ACC_EXEC, ACC_WAIT = range(5)


def cloudlet_finish(status, rem, inst, req, arrival, start, depth,
                    rate, time, dt, req_finish, req_crit, req_out,
                    n_inst: int) -> FinishOut:
    f32, i32 = jnp.float32, jnp.int32
    n_req = req_finish.shape[0]
    execm = status == CL_EXEC
    prog = rate * dt
    fin = execm & (rem <= prog) & (rate > 0)
    tfin = jnp.where(
        fin, jnp.clip(time + rem / jnp.maximum(rate, 1e-9), time, time + dt),
        0.0)
    consumed = jnp.where(execm, jnp.minimum(prog, rem), 0.0)
    new_rem = jnp.where(execm, jnp.maximum(rem - prog, 0.0), rem)
    finf = fin.astype(f32)

    # per-instance: usage + finish count + finish-time statistics in ONE
    # stacked scatter; the (tiny) instance→service reduction that turns
    # these into per-service stats happens outside, so the cloudlet axis
    # is streamed exactly once for all five statistics
    started = jnp.maximum(start, arrival)
    sojourn = jnp.where(fin, tfin - arrival, 0.0)
    exec_t = jnp.where(fin, tfin - started, 0.0)
    wait_t = jnp.where(fin, started - arrival, 0.0)
    iidx = jnp.where(execm & (inst >= 0), inst, n_inst)
    with collide("inst_acc"):
        inst_acc = jnp.zeros((n_inst + 1, 5), f32).at[iidx].add(
            jnp.stack([consumed / dt, finf, sojourn, exec_t, wait_t], axis=1),
            mode="drop")

    # per-request finish aggregates.  Two static strategies, same results:
    #  * small request pool (R ≤ C, Table 2 services-dominated cases):
    #    stack both maxima into one pool-sized scatter, then merge — max
    #    is associative so the merge is exact, and the merge passes are
    #    over the small R;
    #  * large request pool (R > C, requests-dominated cases): update in
    #    place, so the [R] arrays are never re-streamed.
    ridx = jnp.where(fin & (req >= 0), req, n_req)
    with collide("req_finish_acc"):
        if n_req <= status.shape[0]:
            critf = jnp.where(fin, (depth + 1).astype(f32), 0.0)
            mx = jnp.zeros((n_req + 1, 2), f32).at[ridx].max(
                jnp.stack([tfin, critf], axis=1), mode="drop")
            req_finish = jnp.maximum(req_finish, mx[:n_req, 0])
            req_crit = jnp.maximum(req_crit, mx[:n_req, 1].astype(i32))
        else:
            req_finish = req_finish.at[ridx].max(tfin, mode="drop")
            req_crit = req_crit.at[ridx].max(depth + 1, mode="drop")
        req_out = req_out.at[ridx].add(-fin.astype(i32), mode="drop")

    return FinishOut(new_rem=new_rem, fin=fin, tfin=tfin, consumed=consumed,
                     inst_acc=inst_acc, req_finish=req_finish,
                     req_crit=req_crit, req_out=req_out)
