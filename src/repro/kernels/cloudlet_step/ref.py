"""Pure-jnp oracle for the fused cloudlet execution update (paper §4.2).

One simulator tick's execution phase over the active cloudlet buffer:
given per-cloudlet rates (already load-balanced by the scheduler), advance
remaining work, detect sub-tick finishes, and accumulate per-instance
consumption — the inner loop the engine runs millions of times in the
capacity tests (Table 2).

Inputs (all [C] unless noted):
  status i32 (2 = executing), rem f32 (MI), inst i32, rate f32 (MI/s),
  time scalar, dt scalar, n_inst static.
Outputs:
  new_rem f32, fin bool, tfin f32, consumed f32, used [I] f32 (MI/s).
"""
from __future__ import annotations

import jax.numpy as jnp

CL_EXEC = 2


def cloudlet_step(status, rem, inst, rate, time, dt, n_inst: int):
    execm = status == CL_EXEC
    prog = rate * dt
    fin = execm & (rem <= prog) & (rate > 0)
    tfin = jnp.where(
        fin, jnp.clip(time + rem / jnp.maximum(rate, 1e-9), time, time + dt),
        0.0)
    consumed = jnp.where(execm, jnp.minimum(prog, rem), 0.0)
    new_rem = jnp.where(execm, jnp.maximum(rem - prog, 0.0), rem)
    idx = jnp.where(execm & (inst >= 0), inst, n_inst)
    used = jnp.zeros((n_inst,), jnp.float32).at[idx].add(
        consumed / dt, mode="drop")
    return new_rem, fin, tfin, consumed, used
