"""Sharding-readiness auditor (DESIGN.md §8).

ROADMAP's top open item — mega-scale via ``shard_map`` — proposes
sharding the tick program over the cloudlet axis C and the instance
axis I.  Before that port exists, this pass answers the question it
depends on: **which eqns of today's tick program stay shard-local
under that sharding, and which need communication?**

The analysis is extent-based: the audit sim is built with
collision-free caps (every labeled axis extent unique among all array
extents in the program), so a dimension of size ``max_cloudlets`` IS
the cloudlet axis and can be labeled ``C`` without dataflow tracking.
Each eqn is then classified from its primitive semantics and the
labels of its operand/result dims:

* ``local`` — no labeled dim, or the op is elementwise/structural
  along labeled dims (every shard computes its slice independently);
* ``gather`` — the op reads or writes *across* a labeled dim in a
  data-dependent or sequential way (gathers addressed into a sharded
  dim, scatter-set, cumsum/sort along the dim, reshapes that merge a
  sharded dim away): the shard_map port needs a gather/permute here;
* ``all_reduce`` — an associative combine across a labeled dim
  (reductions over C/I, scatter-add/max/min accumulators): the port
  keeps a per-shard partial and all-reduces it.

The per-phase report is pinned as a committed baseline
(``shard_baseline.json``); CI fails when a change ADDS cross-shard
eqns to any phase — the regression gate the sharding PR lands behind.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Dict, List, Optional, Tuple

import jax

from .intervals import _PHASES, _phase_of, _site_str

# primitives that are elementwise or structural along every dim they
# keep: each output lane depends only on the same lanes of the inputs,
# so a sharded dim passes straight through
_ELEMENTWISE = frozenset("""
add sub mul div rem max min pow and or xor not neg sign abs floor ceil
round exp log log1p expm1 sqrt rsqrt cbrt logistic tanh sin cos erf
erf_inv integer_pow eq ne lt le gt ge select_n convert_element_type
stop_gradient shift_left shift_right_logical shift_right_arithmetic
nextafter is_finite clamp square copy real imag bitcast_convert_type
population_count clz
""".split())

_STRUCTURAL = frozenset("""
broadcast_in_dim squeeze expand_dims slice pad rev transpose iota
reshape concatenate split copy_p device_put
""".split())

# reductions: associative combine over `axes` → all-reduce when a
# labeled axis is reduced
_REDUCTIONS = frozenset("""
reduce_sum reduce_max reduce_min reduce_and reduce_or reduce_prod
reduce_xor argmax argmin reduce_precision
""".split())

# sequential/prefix ops over `axis` → cross-shard pipeline (gather)
_SEQUENTIAL = frozenset("""
cumsum cumprod cummax cummin cumlogsumexp sort
""".split())

_CONTROL = ("scan", "while", "cond", "pjit", "closed_call", "remat",
            "custom_jvp_call", "custom_vjp_call", "checkpoint")

_RNG = frozenset("""
random_bits random_seed random_wrap random_unwrap random_fold_in
threefry2x32 random_gamma
""".split())


@dataclasses.dataclass
class ShardEqn:
    phase: str
    cls: str        # "gather" | "all_reduce"
    prim: str
    site: str
    why: str

    def __str__(self):
        return (f"{self.phase:>10s} {self.cls:<10s} {self.prim:<18s} "
                f"{self.site}  ({self.why})")


@dataclasses.dataclass
class ShardReport:
    combo: str
    entries: List[ShardEqn]          # non-local eqns only
    n_local: int
    n_total: int

    def phase_table(self) -> Dict[str, Dict[str, int]]:
        """phase -> {'gather': n, 'all_reduce': n} (phases with no
        cross-shard eqns map to zeros)."""
        table = {p: {"gather": 0, "all_reduce": 0} for p in _PHASES}
        for e in self.entries:
            table.setdefault(e.phase, {"gather": 0, "all_reduce": 0})
            table[e.phase][e.cls] += 1
        return table

    def to_json(self) -> dict:
        """Baseline shape: per (phase, class, primitive) counts — stable
        across line-number churn, sensitive to new cross-shard eqns."""
        counts = Counter((e.phase, e.cls, e.prim) for e in self.entries)
        return {
            "combo": self.combo,
            "n_local": self.n_local,
            "n_total": self.n_total,
            "cross_shard": {f"{p}:{c}:{m}": n
                            for (p, c, m), n in sorted(counts.items())},
        }

    def summary(self) -> str:
        t = self.phase_table()
        hot = sum(v["gather"] + v["all_reduce"] for v in t.values())
        return (f"{self.combo}: {self.n_total} eqns, "
                f"{self.n_local} shard-local, {hot} cross-shard "
                f"({sum(v['gather'] for v in t.values())} gather, "
                f"{sum(v['all_reduce'] for v in t.values())} all-reduce)")


class ShardAudit:
    """Walks a ClosedJaxpr classifying every eqn against an axis spec
    ``{label: (extent, ...)}`` — e.g. ``{"C": (4096,), "I": (64, 65)}``
    labels every dim of extent 4096 as the cloudlet axis and dims of
    64 or 65 (the [I+1] accumulator rows) as the instance axis."""

    def __init__(self, spec: Dict[str, Tuple[int, ...]]):
        self.ext2label = {}
        for label, extents in spec.items():
            for e in extents:
                if e in self.ext2label:
                    raise ValueError(
                        f"axis extent {e} labeled both "
                        f"{self.ext2label[e]!r} and {label!r} — pick "
                        f"collision-free caps for the audit sim")
                self.ext2label[e] = label
        self.entries: List[ShardEqn] = []
        self.n_local = 0
        self.n_total = 0

    # -- labeling ----------------------------------------------------------

    def _labels(self, aval) -> Tuple[Optional[str], ...]:
        shape = getattr(aval, "shape", ())
        return tuple(self.ext2label.get(int(d)) for d in shape)

    def _labeled_extents(self, aval) -> Counter:
        shape = getattr(aval, "shape", ())
        return Counter(int(d) for d in shape
                       if int(d) in self.ext2label)

    def _label_counts(self, aval) -> Counter:
        """Counter over axis *labels* (not extents): [I+1] → [I] slices
        keep the label even though the extent changes."""
        shape = getattr(aval, "shape", ())
        return Counter(self.ext2label[int(d)] for d in shape
                       if int(d) in self.ext2label)

    def _any_labeled(self, eqn) -> bool:
        for v in list(eqn.invars) + list(eqn.outvars):
            if any(l is not None for l in self._labels(v.aval)):
                return True
        return False

    # -- walk --------------------------------------------------------------

    def run(self, closed, scope: str = "") -> None:
        jx = closed.jaxpr if hasattr(closed, "jaxpr") else closed
        for eqn in jx.eqns:
            self.eqn(eqn, scope)

    def eqn(self, eqn, scope: str) -> None:
        name = eqn.primitive.name
        stack = str(eqn.source_info.name_stack)
        esc = scope + ("/" if scope and stack else "") + stack

        if name in ("scan", "while", "cond") or name in _CONTROL:
            for key in ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    self.run(sub, esc)
            for br in eqn.params.get("branches", ()):
                self.run(br, esc)
            return

        self.n_total += 1
        cls, why = self._classify(eqn, name)
        if cls == "local":
            self.n_local += 1
            return
        self.entries.append(ShardEqn(_phase_of(esc), cls, name,
                                     _site_str(eqn), why))

    # -- classification ----------------------------------------------------

    def _classify(self, eqn, name: str) -> Tuple[str, str]:
        if not self._any_labeled(eqn):
            return "local", ""
        if name in _ELEMENTWISE or name in _RNG:
            return "local", ""

        if name in _REDUCTIONS:
            axes = eqn.params.get("axes", ())
            shape = getattr(eqn.invars[0].aval, "shape", ())
            hit = [a for a in axes
                   if int(shape[a]) in self.ext2label]
            if hit:
                lbl = self.ext2label[int(shape[hit[0]])]
                return "all_reduce", f"reduces the {lbl} axis"
            return "local", ""

        if name in _SEQUENTIAL:
            ax = eqn.params.get("axis", eqn.params.get("dimension", 0))
            shape = getattr(eqn.invars[0].aval, "shape", ())
            if shape and int(shape[ax]) in self.ext2label:
                lbl = self.ext2label[int(shape[ax])]
                return "gather", f"sequential along the {lbl} axis"
            return "local", ""

        if name == "gather":
            dnums = eqn.params["dimension_numbers"]
            op_shape = eqn.invars[0].aval.shape
            for d in dnums.start_index_map:
                if int(op_shape[d]) in self.ext2label:
                    lbl = self.ext2label[int(op_shape[d])]
                    return "gather", f"indexes into the {lbl} axis"
            return "local", ""

        if name == "dynamic_slice":
            op_shape = eqn.invars[0].aval.shape
            sizes = eqn.params["slice_sizes"]
            for d, (full, win) in enumerate(zip(op_shape, sizes)):
                if int(win) < int(full) and int(full) in self.ext2label:
                    lbl = self.ext2label[int(full)]
                    return "gather", f"dynamic start along the {lbl} axis"
            return "local", ""

        if name in ("scatter", "scatter-add", "scatter-max", "scatter-min",
                    "scatter-mul", "dynamic_update_slice"):
            assoc = name in ("scatter-add", "scatter-max", "scatter-min",
                             "scatter-mul")
            op_shape = eqn.invars[0].aval.shape
            if name == "dynamic_update_slice":
                upd_shape = eqn.invars[1].aval.shape
                tgt = [d for d, (full, win) in
                       enumerate(zip(op_shape, upd_shape))
                       if int(win) < int(full)
                       and int(full) in self.ext2label]
            else:
                dnums = eqn.params["dimension_numbers"]
                tgt = [d for d in dnums.scatter_dims_to_operand_dims
                       if int(op_shape[d]) in self.ext2label]
            if tgt:
                lbl = self.ext2label[int(op_shape[tgt[0]])]
                if assoc:
                    return ("all_reduce",
                            f"associative scatter into the {lbl} axis")
                return "gather", f"scatter-set into the {lbl} axis"
            # Sharded dims that the operand also carries pass through as
            # aligned window dims (e.g. a per-lane column write into the
            # [C, NI] pool) — shard-local.  Only update labels the
            # operand LACKS cross shards to reach the target.
            op_lbl = self._label_counts(eqn.invars[0].aval)
            for v in eqn.invars[1:]:
                crossing = self._label_counts(v.aval) - op_lbl
                if crossing:
                    lbl = next(iter(crossing))
                    if assoc:
                        return ("all_reduce",
                                f"accumulates {lbl}-sharded updates "
                                f"into a replicated target")
                    return ("gather",
                            f"writes {lbl}-sharded updates into a "
                            f"replicated target")
            return "local", ""

        if name == "reshape":
            lost = (self._label_counts(eqn.invars[0].aval)
                    - self._label_counts(eqn.outvars[0].aval))
            if lost:
                lbl = next(iter(lost))
                return "gather", f"reshape merges the {lbl} axis away"
            return "local", ""

        if name == "dot_general":
            dnums = eqn.params["dimension_numbers"]
            (lc, rc), _ = dnums
            lshape = eqn.invars[0].aval.shape
            for d in lc:
                if int(lshape[d]) in self.ext2label:
                    lbl = self.ext2label[int(lshape[d])]
                    return "all_reduce", f"contracts the {lbl} axis"
            return "local", ""

        if name in _STRUCTURAL:
            # structural ops that keep every labeled AXIS are local —
            # the diff runs over labels, not extents, so [I+1] → [I]
            # slices pass; flattening a labeled axis away does not
            src = Counter()
            for v in eqn.invars:
                src |= self._label_counts(v.aval)
            dst = Counter()
            for v in eqn.outvars:
                dst |= self._label_counts(v.aval)
            lost = src - dst
            if lost:
                lbl = next(iter(lost))
                return "gather", f"{name} drops the {lbl} axis"
            return "local", ""

        # unclassified primitive touching a sharded dim: surface it so a
        # new cross-shard dependency can never slip in silently
        return "gather", f"unclassified primitive {name!r}"


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def _audit_sim(network: str, faults: str):
    """The audit sim: same diamond app as the golden combos but with
    collision-free caps — C=96 and I=12/13 match no other extent in the
    program, so extent-based labeling is unambiguous."""
    from repro.core import SimCaps, SimParams, Simulation, diamond

    caps = SimCaps(n_clients=7, max_requests=40, max_cloudlets=96,
                   max_instances=12, n_vms=3, d_max=2, max_replicas=4)
    params = SimParams(dt=0.05, n_ticks=4, n_clients=6, spawn_rate=10.0,
                       wait_lo=0.1, wait_hi=0.3, seed=7,
                       scaling_policy=1, network=network, faults=faults)
    return Simulation(diamond(mi=200.0), caps=caps, params=params)


def default_spec(caps) -> Dict[str, Tuple[int, ...]]:
    """The ROADMAP sharding proposal: cloudlet axis C, instance axis I
    (including the [I+1]-row finish/ejection accumulators)."""
    return {"C": (caps.max_cloudlets,),
            "I": (caps.max_instances, caps.max_instances + 1)}


def audit_combo(network: str, faults: str, *, sim=None,
                spec: Optional[Dict[str, Tuple[int, ...]]] = None
                ) -> ShardReport:
    from repro.core.types import DynParams

    sim = sim or _audit_sim(network, faults)
    state = sim.init_state()
    dyn = DynParams.from_params(sim.params)
    closed = jax.make_jaxpr(sim._tick)(state, dyn, sim.app)
    audit = ShardAudit(spec or default_spec(sim.caps))
    audit.run(closed)
    return ShardReport(f"{network}+{faults}", audit.entries,
                       audit.n_local, audit.n_total)


def audit_jaxpr(closed, spec: Dict[str, Tuple[int, ...]],
                combo: str = "adhoc") -> ShardReport:
    """Library entry for tests: audit one ClosedJaxpr against a spec."""
    audit = ShardAudit(spec)
    audit.run(closed)
    return ShardReport(combo, audit.entries, audit.n_local, audit.n_total)


def compare_to_baseline(reports: List[ShardReport],
                        baseline: dict) -> List[str]:
    """Regression gate: a (phase, class, primitive) count may shrink
    (improvement — re-pin the baseline) but any increase or new key is
    a violation."""
    problems: List[str] = []
    base_combos = baseline.get("combos", {})
    for rep in reports:
        cur = rep.to_json()["cross_shard"]
        base = base_combos.get(rep.combo, {}).get("cross_shard")
        if base is None:
            problems.append(
                f"[{rep.combo}] no committed shardability baseline — "
                f"re-pin analysis/shard_baseline.json")
            continue
        for key, n in cur.items():
            b = base.get(key, 0)
            if n > b:
                problems.append(
                    f"[{rep.combo}] cross-shard eqns at {key} grew "
                    f"{b} → {n}: a new cross-shard dependency entered "
                    f"this phase (re-pin only if intended)")
    return problems


def baseline_json(reports: List[ShardReport]) -> dict:
    return {"combos": {r.combo: r.to_json() for r in reports}}


def write_report(reports: List[ShardReport], path: str) -> None:
    doc = baseline_json(reports)
    for rep in reports:
        doc["combos"][rep.combo]["phase_table"] = rep.phase_table()
        doc["combos"][rep.combo]["entries"] = [
            dataclasses.asdict(e) for e in rep.entries]
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
