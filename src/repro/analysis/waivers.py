"""Persistent simcheck waivers (DESIGN.md §8).

A waiver silences one analyzer finding — by rule id, optionally pinned
to one site — for a bounded time.  Waivers live in
``analysis/waivers.toml`` next to this module (one ``[[waiver]]`` table
each), NOT in CLI flags: a flag waives forever and invisibly, a file
row is reviewed in the diff, carries its reason, and **expires**:

.. code-block:: toml

    [[waiver]]
    rule = "donation"              # analyzer rule id
    site = "pool.py:111"           # optional substring match ("" = any)
    reason = "tracked in #42: batch path cannot donate yet"
    expires = 2026-12-31           # TOML date; past due ⇒ CI failure

Expired waivers and waivers that matched nothing are both violations —
a stale waiver is a silenced alarm nobody remembers.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import pathlib
from typing import List, Optional, Sequence, Tuple

try:                      # Python 3.11+
    import tomllib as _toml
except ImportError:       # 3.10: vendored tomli is available in-image
    import tomli as _toml

WAIVERS_PATH = pathlib.Path(__file__).with_name("waivers.toml")


@dataclasses.dataclass(frozen=True)
class Waiver:
    rule: str
    site: str            # substring of the finding text; "" matches any
    reason: str
    expires: _dt.date

    def expired(self, today: Optional[_dt.date] = None) -> bool:
        return (today or _dt.date.today()) > self.expires

    def matches(self, rule: str, text: str) -> bool:
        return self.rule == rule and (not self.site or self.site in text)


def load_waivers(path: Optional[pathlib.Path] = None) -> List[Waiver]:
    path = path or WAIVERS_PATH
    if not path.exists():
        return []
    with open(path, "rb") as fh:
        doc = _toml.load(fh)
    out: List[Waiver] = []
    for i, row in enumerate(doc.get("waiver", [])):
        missing = [k for k in ("rule", "reason", "expires") if k not in row]
        if missing:
            raise ValueError(
                f"waivers.toml [[waiver]] #{i + 1} is missing required "
                f"key(s) {missing} — every waiver needs a rule, a "
                f"reason, and an expiry date")
        exp = row["expires"]
        if isinstance(exp, _dt.datetime):
            exp = exp.date()
        if not isinstance(exp, _dt.date):
            raise ValueError(
                f"waivers.toml [[waiver]] #{i + 1}: 'expires' must be a "
                f"TOML date (e.g. 2026-12-31), got {exp!r}")
        out.append(Waiver(rule=str(row["rule"]), site=str(row.get("site", "")),
                          reason=str(row["reason"]), expires=exp))
    return out


def apply_waivers(findings: Sequence[Tuple[str, str]],
                  waivers: Sequence[Waiver],
                  today: Optional[_dt.date] = None
                  ) -> Tuple[List[str], List[str]]:
    """Filter ``(rule, text)`` findings through the waiver list.

    Returns ``(surviving_texts, waiver_problems)`` where the problems
    list holds one violation per expired waiver and per waiver that
    matched no finding (unused) — both fail CI.
    """
    today = today or _dt.date.today()
    used = [False] * len(waivers)
    surviving: List[str] = []
    for rule, text in findings:
        waived = False
        for i, w in enumerate(waivers):
            if w.matches(rule, text) and not w.expired(today):
                used[i] = True
                waived = True
        if not waived:
            surviving.append(text)
    problems: List[str] = []
    for i, w in enumerate(waivers):
        if w.expired(today):
            problems.append(
                f"waiver for rule {w.rule!r}"
                + (f" site {w.site!r}" if w.site else "")
                + f" expired {w.expires.isoformat()} ({w.reason}) — "
                  f"fix the finding or renew the waiver")
        elif not used[i]:
            problems.append(
                f"waiver for rule {w.rule!r}"
                + (f" site {w.site!r}" if w.site else "")
                + " matched no finding — delete the stale waiver")
    return surviving, problems
