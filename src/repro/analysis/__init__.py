"""simcheck — static analysis of the jitted tick program (DESIGN.md §8).

Four analyzers, one CLI (``python -m repro.analysis``):

* :mod:`.jaxpr_lint` — walks the ClosedJaxpr of the scan body for every
  network×faults combo: f64 introduction, host callbacks / transfers in
  the hot loop, non-donated carry.
* :mod:`.layout_check` — replays one tick against a recording layout
  proxy and diffs actual column read/write sets against
  ``PHASE_COLUMNS``.
* :mod:`.streams` — named RNG streams; reuse/collision audit + golden
  topology digest.  (The only module the core imports — it must stay
  free of ``repro.core`` imports.)
* :mod:`.recompile` — jit cache-miss sentinel over a ``run_batch``
  sweep and the golden matrix.

``streams`` is imported eagerly (the engine needs it on every import);
the checkers — which import ``repro.core`` back — load lazily so that
``core → analysis.streams`` stays cycle-free.
"""
from . import streams  # noqa: F401  (eager: the core's wrapper target)

_LAZY = {
    "jaxpr_lint": ".jaxpr_lint",
    "layout_check": ".layout_check",
    "recompile": ".recompile",
    "simcheck": ".simcheck",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(_LAZY[name], __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
