"""Recompile sentinel — jit cache misses across sweeps must be zero.

The engine's whole performance pitch is compile-once: `Simulation`
keys its AOT cache on static knobs + pytree shapes, sweeps travel as
traced ``DynParams`` leaves, and seed changes reuse the executable (the
cache key deliberately omits the seed).  That contract silently breaks
the moment a Python scalar is closed over where a traced value belongs,
or a weak-typed constant flips an argument dtype — every sweep point
then pays a full XLA compile and an 8-point study runs 8× slower with
bit-identical results.

The sentinel counts *backend compiles* via JAX's monitoring events
(``/jax/core/compile/backend_compile_duration`` fires once per XLA
compilation, including the small eager-op kernels): a **warm pass**
runs each golden combo solo plus an 8-point ``run_batch`` sweep, then a
**counting pass** re-runs everything with different values — new seed,
perturbed sweep scalars — in identical shapes.  Any compile event in
the counting pass is a cache miss the design says cannot exist.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, List

from jax._src import monitoring

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


@contextlib.contextmanager
def count_backend_compiles() -> Iterator[List[int]]:
    """Yields a one-cell list accumulating backend-compile events."""
    hits = [0]

    def _listener(event: str, duration: float, **kw) -> None:
        if event == COMPILE_EVENT:
            hits[0] += 1

    monitoring.register_event_duration_secs_listener(_listener)
    try:
        yield hits
    finally:
        monitoring._unregister_event_duration_listener_by_callback(
            _listener)


@dataclasses.dataclass
class SentinelReport:
    warm_compiles: int
    counting_compiles: int

    @property
    def problems(self) -> List[str]:
        if self.counting_compiles:
            return [
                f"recompile: {self.counting_compiles} backend compile(s) "
                "in the counting pass (warm pass compiled "
                f"{self.warm_compiles}) — some value that should be "
                "traced (DynParams leaf) or cache-keyed is being closed "
                "over as a fresh Python object per run"]
        return []


def _sweep_points(params, n_points: int = 8, offset: float = 0.0):
    return [dataclasses.replace(params,
                                spawn_rate=params.spawn_rate
                                + 0.5 * i + offset,
                                slo_ms=params.slo_ms + 10.0 * i + offset)
            for i in range(n_points)]


def run_sentinel(n_points: int = 8) -> SentinelReport:
    """Warm-then-count over the four golden combos + an 8-point sweep."""
    from .layout_check import _tiny_sim

    combos = [("uniform", "none"), ("uniform", "chaos"),
              ("fabric", "none"), ("fabric", "chaos")]

    with count_backend_compiles() as warm:
        for net, fl in combos:
            sim = _tiny_sim(net, fl, False)
            sim.run()
            sim.run_batch(_sweep_points(sim.params, n_points))

    with count_backend_compiles() as cold:
        for net, fl in combos:
            # Fresh Simulation objects: the cache must hit across
            # *instances*, not just across calls on one instance.
            sim = _tiny_sim(net, fl, False)
            sim.run(seed=sim.params.seed + 1)     # seed is not a cache key
            sim.run_batch(_sweep_points(sim.params, n_points, offset=0.25),
                          seed=sim.params.seed + 1)

    return SentinelReport(warm_compiles=warm[0], counting_compiles=cold[0])
