"""Index-safety verifier — forward interval abstract interpretation of the
tick jaxpr (DESIGN.md §8).

PR 4's edge-table undersizing bug produced wrong goldens, not crashes: the
engine's gathers default to ``PROMISE_IN_BOUNDS`` (out-of-bounds is undefined
behaviour) and its scatters to ``FILL_OR_DROP`` (out-of-bounds writes vanish
silently).  This pass walks ``Simulation._tick``'s ClosedJaxpr with an
integer-interval abstract domain and *proves*, per combo:

* every ``gather`` / ``dynamic_slice`` index is in bounds for its operand;
* every ``scatter`` / ``scatter-add`` / ``scatter-min`` / ``scatter-max``
  index vector is duplicate-free — via jnp's own ``unique_indices`` flag
  (basic indexing), concrete index arrays, or the prefix-sum slot-assignment
  pattern (``cumsum`` of a 0/1 mask is strictly increasing on mask lanes) —
  unless the site sits inside a :mod:`repro.analysis.annotate` scope:
  ``repro_collide:`` (segment-sum-style accumulation, collisions intended)
  or ``repro_disjoint:`` (asserted disjoint, runtime-checked under
  ``REPRO_CHECKED=1``);
* the tick is *inductive*: output-state intervals stay inside the declared
  seeds (``types.POOL_COLUMN_BOUNDS`` + the per-leaf table below), so the
  per-tick proof extends to whole runs.

Abstract values carry, besides ``[lo, hi]``:

* per-column intervals along one axis (``cols``) — the stacked cloudlet
  blocks are one array in the jaxpr, but ``status``/``edge``/… have very
  different ranges;
* conjunction *atoms* for booleans (each comparison eqn mints an atom;
  ``and`` unions them) — used to refine ``select_n`` cases under the
  predicate, which is what sees through jnp's negative-index-wrap idiom
  (``select(idx < 0, idx + n, idx)``) without widening;
* a prefix-rank tag (``cumsum`` of an indicator: on mask lanes the values
  are pairwise distinct and ≥ ``rank_lo``) and a uniqueness tag
  (pairwise-distinct except sentinel ``filler`` values, which a
  ``FILL_OR_DROP`` scatter drops).

The interpreter inlines ``pjit``, joins ``cond`` branches, and runs
``scan``/``while`` bodies to a widened carry fixpoint.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
import jax.tree_util as jtu
from jax.extend import core as jex_core

from .annotate import COLLIDE_PREFIX, DISJOINT_PREFIX

INF = float("inf")

# --------------------------------------------------------------------------
# Abstract value
# --------------------------------------------------------------------------

_vid_counter = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class IVal:
    """Interval + relational tags for one jaxpr value (array-level: the
    bounds hold for every element)."""
    lo: float
    hi: float
    # identity for refinement: select_n cases are matched to comparison
    # atoms by vid, which survives pjit in/out binding and passthroughs.
    vid: int = 0
    # bools: this value is the conjunction of these atoms (see Interp.atoms)
    atoms: frozenset = frozenset()
    # prefix-rank: on lanes where the rank_mask atoms hold, elements are
    # pairwise distinct and >= rank_lo
    rank_mask: Optional[frozenset] = None
    rank_lo: float = 0.0
    # uniqueness: elements pairwise distinct, except those inside `filler`
    unique: bool = False
    filler: Optional[Tuple[float, float]] = None
    # per-index intervals along axis `col_axis` (stacked pool blocks)
    cols: Optional[Tuple[Tuple[float, float], ...]] = None
    col_axis: Optional[int] = None
    # affine provenance: value == <vid `origin[0]`> + origin[1]; lets
    # refine() apply an atom minted on the base to a shifted copy (the
    # negative-index wrap idiom adds `n` before selecting)
    origin: Optional[Tuple[int, float]] = None
    # concrete value when statically known (index columns of `.at[:, k]`
    # writes arrive as consts/iota, not Literals)
    conc: Optional[np.ndarray] = dataclasses.field(
        default=None, compare=False, repr=False)

    def r(self, **kw) -> "IVal":
        return dataclasses.replace(self, **kw)

    @property
    def const(self) -> bool:
        return self.lo == self.hi and math.isfinite(self.lo)


def ival(lo, hi, **kw) -> IVal:
    return IVal(float(lo), float(hi), vid=next(_vid_counter), **kw)


def top_for(aval) -> IVal:
    """Dtype-top: the widest sound interval for a value of this aval."""
    dt = getattr(aval, "dtype", None)
    try:
        if dt is None:
            return ival(-INF, INF)
        if dt == jnp.bool_:
            return ival(0, 1)
        if jnp.issubdtype(dt, jnp.integer):
            info = jnp.iinfo(dt)
            return ival(info.min, info.max)
    except TypeError:  # extended dtypes (PRNG keys)
        pass
    return ival(-INF, INF)


def from_concrete(x) -> IVal:
    """Exact seed from a concrete array (consts, AppStatic tables)."""
    a = np.asarray(x)
    if a.size == 0:
        return ival(0, 0)
    if a.dtype == bool:
        a = a.astype(np.int32)
    if not np.issubdtype(a.dtype, np.number):
        return ival(-INF, INF)
    lo, hi = float(np.min(a)), float(np.max(a))
    uniq = (a.ndim == 1 and np.issubdtype(a.dtype, np.integer)
            and np.unique(a).size == a.size)
    return ival(lo, hi, unique=uniq,
                conc=a if a.size <= 65536 else None)


def join(a: IVal, b: IVal) -> IVal:
    cols = col_axis = None
    if (a.cols is not None and b.cols is not None
            and a.col_axis == b.col_axis and len(a.cols) == len(b.cols)):
        cols = tuple((min(x[0], y[0]), max(x[1], y[1]))
                     for x, y in zip(a.cols, b.cols))
        col_axis = a.col_axis
    filler = None
    unique = a.unique and b.unique and a.filler == b.filler
    if unique:
        filler = a.filler
    return ival(min(a.lo, b.lo), max(a.hi, b.hi),
                atoms=a.atoms & b.atoms, unique=unique, filler=filler,
                cols=cols, col_axis=col_axis)



def _reshape_conc(conc, shape):
    if conc is None:
        return None
    try:
        return np.asarray(conc).reshape(shape)
    except ValueError:       # stale conc from an approximating transfer
        return None

def _contained(a: IVal, b: IVal) -> bool:
    return a.lo >= b.lo and a.hi <= b.hi


def _widen(old: IVal, new: IVal, aval) -> IVal:
    """Classic interval widening against the dtype top."""
    top = top_for(aval)
    lo = old.lo if new.lo >= old.lo else top.lo
    hi = old.hi if new.hi <= old.hi else top.hi
    return ival(lo, hi)


# interval arithmetic -------------------------------------------------------

def _mx(a, b):
    """inf-safe product (0 * inf -> 0)."""
    if a == 0 or b == 0:
        return 0.0
    return a * b


def _iv_add(a, b):
    return a[0] + b[0], a[1] + b[1]


def _iv_mul(a, b):
    c = [_mx(a[0], b[0]), _mx(a[0], b[1]), _mx(a[1], b[0]), _mx(a[1], b[1])]
    return min(c), max(c)


# --------------------------------------------------------------------------
# Sites & report
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Site:
    kind: str       # gather | scatter | scatter-add | dynamic_slice | ...
    where: str      # "pool.py:111 (scatter_pool)"
    phase: str      # tick phase from the name stack ("?" before Dispatch)
    bounds: str     # in-bounds | drop | clamped | fill | OOB
    dups: str       # n/a | unique(...) | declared-collide/-disjoint | DUP
    ok: bool
    rule: str = ""  # violation rule id when not ok
    detail: str = ""

    def line(self) -> str:
        flag = "ok " if self.ok else "FAIL"
        return (f"{flag} {self.phase:>10s} {self.kind:<14s} "
                f"bounds={self.bounds:<9s} dups={self.dups:<18s} {self.where}"
                + (f"  [{self.detail}]" if self.detail else ""))


def _site_str(eqn) -> str:
    """Stable site id: 'file.py:line (function)' of the topmost repro frame."""
    try:
        from jax._src import source_info_util
        s = source_info_util.summarize(eqn.source_info)
        # '.../src/repro/core/pool.py:111:4 (scatter_pool)' → short form
        path, _, rest = s.partition(":")
        short = "/".join(path.split("/")[-1:])
        line = rest.split(":")[0]
        fn = s.partition("(")[2].rstrip(")")
        return f"{short}:{line}" + (f" ({fn})" if fn else "")
    except Exception:
        return "<unknown>"


_PHASES = ("Generation", "Disruption", "Transit", "Dispatch", "Execute",
           "Alerting", "Derive", "Response", "Scaling", "Telemetry", "Trace")


def _phase_of(scope: str) -> str:
    for part in scope.split("/"):
        if part in _PHASES:
            return part
    return "?"


# --------------------------------------------------------------------------
# The interpreter
# --------------------------------------------------------------------------

class Interp:
    def __init__(self):
        self.sites: List[Site] = []
        self.unknown: Counter = Counter()
        # atom id → (op, lhs_vid, rhs_vid, lhs IVal, rhs IVal)
        self.atoms: Dict[int, tuple] = {}
        self._atom_ids = itertools.count(1)

    # -- plumbing ----------------------------------------------------------

    def read(self, v, env) -> IVal:
        if isinstance(v, jex_core.Literal):
            return from_concrete(v.val)
        return env[v]

    def run(self, closed, invals: Sequence[IVal], scope: str = "") -> List[IVal]:
        jx = closed.jaxpr if hasattr(closed, "jaxpr") else closed
        consts = closed.consts if hasattr(closed, "consts") else []
        env: Dict = {}
        for v, c in zip(jx.constvars, consts):
            env[v] = from_concrete(c)
        for v, val in zip(jx.invars, invals):
            env[v] = val
        for eqn in jx.eqns:
            self.eqn(eqn, env, scope)
        return [self.read(v, env) for v in jx.outvars]

    def eqn(self, eqn, env, scope) -> None:
        name = eqn.primitive.name
        stack = str(eqn.source_info.name_stack)
        esc = scope + ("/" if scope and stack else "") + stack
        invals = [self.read(v, env) for v in eqn.invars]
        fn = getattr(self, "p_" + name.replace("-", "_"), None)
        if fn is None:
            self.unknown[name] += 1
            outs = [top_for(v.aval) for v in eqn.outvars]
        else:
            outs = fn(eqn, invals, esc)
        for v, val in zip(eqn.outvars, outs):
            env[v] = val

    def _tops(self, eqn):
        return [top_for(v.aval) for v in eqn.outvars]

    # -- refinement --------------------------------------------------------

    def refine(self, val: IVal, atoms: frozenset, negate: bool = False) -> IVal:
        """Tighten `val` assuming every comparison atom in `atoms` holds
        (or, with ``negate``, that the single atom is false).  The match is
        by vid, or by affine provenance: for ``val == base + off`` an atom
        on ``base`` applies with its bounds shifted by ``off``."""
        _NEG = {"lt": "ge", "le": "gt", "gt": "le", "ge": "lt"}
        if negate and len(atoms) != 1:
            return val                 # ¬(a ∧ b) is a disjunction — skip
        lo, hi = val.lo, val.hi
        for aid in atoms:
            op, lvid, rvid, liv, riv = self.atoms[aid]
            if negate:
                op = _NEG.get(op)
                if op is None:
                    continue
            for side_vid, other, flip in ((lvid, riv, False),
                                          (rvid, liv, True)):
                if val.vid == side_vid:
                    off = 0.0
                elif val.origin is not None and val.origin[0] == side_vid:
                    off = val.origin[1]
                else:
                    continue
                o = op
                if flip:  # x on the rhs of (l op x): invert the relation
                    o = {"lt": "gt", "le": "ge",
                         "gt": "lt", "ge": "le", "eq": "eq"}[op]
                if o == "lt":
                    hi = min(hi, other.hi - 1 + off)
                elif o == "le":
                    hi = min(hi, other.hi + off)
                elif o == "gt":
                    lo = max(lo, other.lo + 1 + off)
                elif o == "ge":
                    lo = max(lo, other.lo + off)
                elif o == "eq":
                    lo = max(lo, other.lo + off)
                    hi = min(hi, other.hi + off)
        if not negate and val.rank_mask and val.rank_mask <= atoms:
            lo = max(lo, val.rank_lo)
        return val.r(lo=lo, hi=hi)

    def _cmp(self, op, eqn, invals):
        a, b = invals
        lo, hi = 0, 1
        if op == "lt":
            if a.hi < b.lo:
                lo = 1
            if a.lo >= b.hi:
                hi = 0
        elif op == "le":
            if a.hi <= b.lo:
                lo = 1
            if a.lo > b.hi:
                hi = 0
        elif op == "gt":
            if a.lo > b.hi:
                lo = 1
            if a.hi <= b.lo:
                hi = 0
        elif op == "ge":
            if a.lo >= b.hi:
                lo = 1
            if a.hi < b.lo:
                hi = 0
        elif op == "eq":
            if a.const and b.const and a.lo == b.lo:
                lo = 1
            if a.hi < b.lo or a.lo > b.hi:
                hi = 0
        aid = next(self._atom_ids)
        self.atoms[aid] = (op, a.vid, b.vid, a, b)
        return [ival(lo, hi, atoms=frozenset((aid,)))]

    # -- arithmetic --------------------------------------------------------

    @staticmethod
    def _shift(val: IVal, c: float, sign: int) -> IVal:
        d = c * sign
        base = val.origin or (val.vid, 0.0)
        out = val.r(lo=val.lo + d, hi=val.hi + d, vid=next(_vid_counter),
                    atoms=frozenset(), origin=(base[0], base[1] + d),
                    conc=None if val.conc is None else val.conc + d)
        if val.rank_mask:
            out = out.r(rank_lo=val.rank_lo + d)
        if val.filler:
            out = out.r(filler=(val.filler[0] + d, val.filler[1] + d))
        if val.cols:
            out = out.r(cols=tuple((l + d, h + d) for l, h in val.cols))
        return out

    def p_add(self, eqn, invals, scope):
        a, b = invals
        if b.const:
            return [self._shift(a, b.lo, +1)]
        if a.const:
            return [self._shift(b, a.lo, +1)]
        lo, hi = _iv_add((a.lo, a.hi), (b.lo, b.hi))
        # adding the same (traced) scalar to every lane preserves pairwise
        # distinctness — both the unique tag and the prefix-rank tag
        for x, y, yv in ((a, b, eqn.invars[1]), (b, a, eqn.invars[0])):
            if getattr(yv.aval, "shape", None) == ():
                out = ival(lo, hi)
                if x.unique and x.filler is None:
                    out = out.r(unique=True)
                if x.rank_mask:
                    out = out.r(rank_mask=x.rank_mask,
                                rank_lo=x.rank_lo + y.lo)
                if out.unique or out.rank_mask:
                    return [out]
        return [ival(lo, hi)]

    def p_sub(self, eqn, invals, scope):
        a, b = invals
        if b.const:
            return [self._shift(a, b.lo, -1)]
        lo, hi = _iv_add((a.lo, a.hi), (-b.hi, -b.lo))
        if (a.unique and a.filler is None
                and getattr(eqn.invars[1].aval, "shape", None) == ()):
            return [ival(lo, hi, unique=True)]
        return [ival(lo, hi)]

    def p_mul(self, eqn, invals, scope):
        a, b = invals
        lo, hi = _iv_mul((a.lo, a.hi), (b.lo, b.hi))
        # scaling by a positive constant keeps distinctness
        for x, c in ((a, b), (b, a)):
            if c.const and c.lo > 0 and x.unique:
                f = x.filler and (x.filler[0] * c.lo, x.filler[1] * c.lo)
                return [ival(lo, hi, unique=True, filler=f)]
        return [ival(lo, hi)]

    def p_neg(self, eqn, invals, scope):
        a, = invals
        return [ival(-a.hi, -a.lo, unique=a.unique,
                     filler=a.filler and (-a.filler[1], -a.filler[0]))]

    def p_div(self, eqn, invals, scope):
        a, b = invals
        if b.lo > 0 or b.hi < 0:
            c = []
            for x in (a.lo, a.hi):
                for y in (b.lo, b.hi):
                    c.append(0.0 if x == 0 else
                             x / y if y != 0 else math.copysign(INF, x * y))
            lo, hi = min(c), max(c)
            if jnp.issubdtype(eqn.outvars[0].aval.dtype, jnp.integer):
                # lax.div truncates toward zero
                lo = (math.floor(lo) if lo >= 0 else math.ceil(lo)) \
                    if math.isfinite(lo) else lo
                hi = (math.floor(hi) if hi >= 0 else math.ceil(hi)) \
                    if math.isfinite(hi) else hi
            return [ival(lo, hi)]
        return self._tops(eqn)

    def p_rem(self, eqn, invals, scope):
        a, b = invals
        if b.lo > 0 and math.isfinite(b.hi):
            hi = b.hi - 1 if jnp.issubdtype(
                eqn.invars[0].aval.dtype, jnp.integer) else b.hi
            if a.lo >= 0:
                return [ival(0, min(a.hi, hi))]
            return [ival(-hi, hi)]
        return self._tops(eqn)

    def p_max(self, eqn, invals, scope):
        a, b = invals
        return [ival(max(a.lo, b.lo), max(a.hi, b.hi))]

    def p_min(self, eqn, invals, scope):
        a, b = invals
        return [ival(min(a.lo, b.lo), min(a.hi, b.hi))]

    def p_clamp(self, eqn, invals, scope):
        lo_v, x, hi_v = invals
        return [ival(max(lo_v.lo, min(x.lo, hi_v.hi)),
                     min(hi_v.hi, max(x.hi, lo_v.lo)))]

    def p_floor(self, eqn, invals, scope):
        a, = invals
        return [ival(math.floor(a.lo) if math.isfinite(a.lo) else a.lo,
                     math.floor(a.hi) if math.isfinite(a.hi) else a.hi)]

    def p_ceil(self, eqn, invals, scope):
        a, = invals
        return [ival(math.ceil(a.lo) if math.isfinite(a.lo) else a.lo,
                     math.ceil(a.hi) if math.isfinite(a.hi) else a.hi)]

    def p_round(self, eqn, invals, scope):
        a, = invals
        return [ival(math.floor(a.lo) if math.isfinite(a.lo) else a.lo,
                     math.ceil(a.hi) if math.isfinite(a.hi) else a.hi)]

    def p_sign(self, eqn, invals, scope):
        return [ival(-1, 1)]

    def p_abs(self, eqn, invals, scope):
        a, = invals
        lo = 0.0 if a.lo <= 0 <= a.hi else min(abs(a.lo), abs(a.hi))
        return [ival(lo, max(abs(a.lo), abs(a.hi)))]

    def p_exp(self, eqn, invals, scope):
        return [ival(0, INF)]

    def p_log(self, eqn, invals, scope):
        return [ival(-INF, INF)]

    def p_sqrt(self, eqn, invals, scope):
        return [ival(0, INF)]

    def p_erf_inv(self, eqn, invals, scope):
        return [ival(-INF, INF)]

    def p_is_finite(self, eqn, invals, scope):
        return [ival(0, 1)]

    def p_integer_pow(self, eqn, invals, scope):
        a, = invals
        y = eqn.params["y"]
        if y >= 0 and a.lo >= 0:
            return [ival(_mx(a.lo, a.lo) if y == 2 else 0,
                         a.hi ** y if math.isfinite(a.hi) else INF)]
        return self._tops(eqn)

    def p_shift_right_logical(self, eqn, invals, scope):
        a, b = invals
        if a.lo >= 0 and b.const and math.isfinite(a.hi):
            s = int(b.lo)
            return [ival(int(a.lo) >> s, int(a.hi) >> s)]
        if a.lo >= 0:
            return [ival(0, a.hi)]
        return self._tops(eqn)

    def p_bitcast_convert_type(self, eqn, invals, scope):
        return self._tops(eqn)

    # -- booleans ----------------------------------------------------------

    def p_lt(self, eqn, invals, scope):
        return self._cmp("lt", eqn, invals)

    def p_le(self, eqn, invals, scope):
        return self._cmp("le", eqn, invals)

    def p_le_to(self, eqn, invals, scope):
        # total-order ≤ used by sort/searchsorted lowering; plain boolean
        return self._cmp("le", eqn, invals)

    def p_lt_to(self, eqn, invals, scope):
        return self._cmp("lt", eqn, invals)

    def p_gt(self, eqn, invals, scope):
        return self._cmp("gt", eqn, invals)

    def p_ge(self, eqn, invals, scope):
        return self._cmp("ge", eqn, invals)

    def p_eq(self, eqn, invals, scope):
        return self._cmp("eq", eqn, invals)

    def p_ne(self, eqn, invals, scope):
        a, b = invals
        lo, hi = 0, 1
        if a.hi < b.lo or a.lo > b.hi:
            lo = 1
        if a.const and b.const and a.lo == b.lo:
            hi = 0
        return [ival(lo, hi)]

    def p_and(self, eqn, invals, scope):
        a, b = invals
        if eqn.outvars[0].aval.dtype == jnp.bool_:
            return [ival(min(a.lo, b.lo) if a.lo and b.lo else 0,
                         min(a.hi, b.hi), atoms=a.atoms | b.atoms)]
        if a.lo >= 0 and b.lo >= 0:
            return [ival(0, min(a.hi, b.hi))]
        return self._tops(eqn)

    def p_or(self, eqn, invals, scope):
        a, b = invals
        if eqn.outvars[0].aval.dtype == jnp.bool_:
            return [ival(max(a.lo, b.lo), max(a.hi, b.hi))]
        if a.lo >= 0 and b.lo >= 0 and math.isfinite(max(a.hi, b.hi)):
            m = int(max(a.hi, b.hi))
            return [ival(0, (1 << m.bit_length()) - 1)]
        return self._tops(eqn)

    def p_xor(self, eqn, invals, scope):
        return self.p_or(eqn, invals, scope)

    def p_not(self, eqn, invals, scope):
        a, = invals
        if eqn.outvars[0].aval.dtype == jnp.bool_:
            return [ival(1 - a.hi, 1 - a.lo)]
        return self._tops(eqn)

    def p_select_n(self, eqn, invals, scope):
        pred, *cases = invals
        if len(cases) == 2:
            c0, c1 = cases
            if pred.lo >= 1:      # always true → case1, tags intact
                return [c1]
            if pred.hi <= 0:      # always false → case0, tags intact
                return [c0]
            r1 = self.refine(c1, pred.atoms)
            r0 = self.refine(c0, pred.atoms, negate=True)
            out = join(r0, r1)
            if c0.const:
                # prefix-rank → unique-with-sentinel: where(mask∧…, rank, K)
                if c1.rank_mask and c1.rank_mask <= pred.atoms:
                    out = out.r(unique=True, filler=(c0.lo, c0.hi))
                # distinct values masked to a constant sentinel stay distinct
                elif c1.unique and c1.filler is None:
                    out = out.r(unique=True, filler=(c0.lo, c0.hi))
            return [out]
        out = cases[0]
        for c in cases[1:]:
            out = join(out, c)
        return [out]

    # -- structure ---------------------------------------------------------

    def p_convert_element_type(self, eqn, invals, scope):
        a, = invals
        src = eqn.invars[0].aval.dtype
        dst = eqn.outvars[0].aval.dtype
        if dst == jnp.bool_:
            lo = 1 if (a.lo > 0 or a.hi < 0) else 0
            hi = 0 if (a.lo == 0 and a.hi == 0) else 1
            return [ival(lo, hi)]
        lo, hi = a.lo, a.hi
        if jnp.issubdtype(dst, jnp.integer) and not jnp.issubdtype(
                src, jnp.integer) and src != jnp.bool_:
            lo = math.floor(lo) if math.isfinite(lo) else lo
            hi = math.ceil(hi) if math.isfinite(hi) else hi
            top = top_for(eqn.outvars[0].aval)
            return [ival(max(lo, top.lo), min(hi, top.hi))]
        keep_tags = (src == jnp.bool_
                     or (jnp.issubdtype(src, jnp.integer)
                         and jnp.issubdtype(dst, jnp.integer)))
        if keep_tags:
            # bool→int indicators keep their atoms so cumsum can see them
            return [a.r(lo=lo, hi=hi, vid=next(_vid_counter))]
        return [ival(lo, hi, cols=a.cols, col_axis=a.col_axis)]

    def p_iota(self, eqn, invals, scope):
        shape = eqn.params["shape"]
        dim = eqn.params["dimension"]
        n = shape[dim]
        uniq = int(np.prod(shape)) == n
        conc = None
        if int(np.prod(shape)) <= 65536:
            conc = np.broadcast_to(
                np.arange(n).reshape([n if i == dim else 1
                                      for i in range(len(shape))]), shape)
        return [ival(0, n - 1, unique=uniq, conc=conc)]

    def p_broadcast_in_dim(self, eqn, invals, scope):
        a, = invals
        shape = eqn.params["shape"]
        bdims = eqn.params["broadcast_dimensions"]
        in_shape = getattr(eqn.invars[0].aval, "shape", ())
        same_size = int(np.prod(shape)) == int(np.prod(in_shape))
        conc = None
        if a.conc is not None and int(np.prod(shape)) <= 65536:
            try:
                tmp = [1] * len(shape)
                for i, d in enumerate(bdims):
                    tmp[d] = in_shape[i]
                conc = np.broadcast_to(np.asarray(a.conc).reshape(tmp), shape)
            except ValueError:   # stale conc from an approximating transfer
                conc = None
        out = ival(a.lo, a.hi, unique=a.unique and same_size,
                   filler=a.filler if same_size else None, conc=conc)
        if (a.cols is not None and a.col_axis is not None
                and a.col_axis < len(bdims)
                and shape[bdims[a.col_axis]] == len(a.cols)):
            out = out.r(cols=a.cols, col_axis=bdims[a.col_axis])
        if same_size:
            # element order and count preserved → positional tags survive
            out = out.r(atoms=a.atoms, rank_mask=a.rank_mask,
                        rank_lo=a.rank_lo, vid=a.vid)
        return [out]

    def p_reshape(self, eqn, invals, scope):
        a, = invals
        old = tuple(getattr(eqn.invars[0].aval, "shape", ()))
        new = tuple(eqn.params["new_sizes"])
        # flat element order and count preserved → positional tags survive
        out = a.r(cols=None, col_axis=None, conc=_reshape_conc(a.conc, new))
        # keep cols across pure rank-extension: [..] → [.., 1] etc.
        def _core(s):
            return tuple(d for d in s if d != 1)
        if a.cols is not None and _core(old) == _core(new):
            core_pos = [i for i, d in enumerate(old) if d != 1]
            if a.col_axis in core_pos:
                k = core_pos.index(a.col_axis)
                new_pos = [i for i, d in enumerate(new) if d != 1]
                out = out.r(cols=a.cols, col_axis=new_pos[k])
            elif old == new:
                out = out.r(cols=a.cols, col_axis=a.col_axis)
        return [out]

    def p_squeeze(self, eqn, invals, scope):
        a, = invals
        dims = eqn.params["dimensions"]
        out = a.r(cols=None, col_axis=None,
                  conc=_reshape_conc(a.conc, eqn.outvars[0].aval.shape))
        if a.cols is not None and a.col_axis not in dims:
            shift = sum(1 for d in dims if d < a.col_axis)
            out = out.r(cols=a.cols, col_axis=a.col_axis - shift)
        return [out]

    def p_expand_dims(self, eqn, invals, scope):
        a, = invals
        dims = eqn.params["dimensions"]
        out = a.r(cols=None, col_axis=None,
                  conc=_reshape_conc(a.conc, eqn.outvars[0].aval.shape))
        if a.cols is not None:
            shift = sum(1 for d in dims if d <= a.col_axis)
            out = out.r(cols=a.cols, col_axis=a.col_axis + shift)
        return [out]

    def p_transpose(self, eqn, invals, scope):
        a, = invals
        perm = eqn.params["permutation"]
        out = ival(a.lo, a.hi, unique=a.unique, filler=a.filler)
        if a.cols is not None:
            out = out.r(cols=a.cols, col_axis=list(perm).index(a.col_axis))
        return [out]

    def p_rev(self, eqn, invals, scope):
        a, = invals
        return [ival(a.lo, a.hi, unique=a.unique, filler=a.filler)]

    def p_slice(self, eqn, invals, scope):
        a, = invals
        # subset of pairwise-distinct stays distinct; atoms survive (a
        # shifted slice can never align with its unsliced source in a
        # same-shape select, so refinement by vid stays sound); the
        # positional rank tag does not.
        out = ival(a.lo, a.hi, unique=a.unique, filler=a.filler,
                   atoms=a.atoms)
        if a.cols is not None:
            st = eqn.params["start_indices"][a.col_axis]
            li = eqn.params["limit_indices"][a.col_axis]
            strides = eqn.params["strides"]
            step = strides[a.col_axis] if strides else 1
            sub = a.cols[st:li:step]
            if len(sub) == 1:
                out = out.r(lo=sub[0][0], hi=sub[0][1])
            else:
                out = out.r(cols=sub, col_axis=a.col_axis,
                            lo=min(c[0] for c in sub),
                            hi=max(c[1] for c in sub))
        return [out]

    def p_concatenate(self, eqn, invals, scope):
        dim = eqn.params["dimension"]
        lo = min(v.lo for v in invals)
        hi = max(v.hi for v in invals)
        # per-column tracking when concatenating along the column axis
        cols: Optional[list] = []
        for v, var in zip(invals, eqn.invars):
            shape = getattr(var.aval, "shape", ())
            if v.cols is not None and v.col_axis == dim:
                cols.extend(v.cols)
            elif dim < len(shape):
                cols.extend([(v.lo, v.hi)] * shape[dim])
            else:
                cols = None
                break
        if cols is not None and len(cols) > 64:
            cols = None   # don't track huge axes
        return [ival(lo, hi,
                     cols=tuple(cols) if cols else None,
                     col_axis=dim if cols else None)]

    def p_pad(self, eqn, invals, scope):
        a, pad_val = invals
        return [ival(min(a.lo, pad_val.lo), max(a.hi, pad_val.hi))]

    def p_sort(self, eqn, invals, scope):
        return [ival(v.lo, v.hi, unique=v.unique, filler=v.filler)
                for v in invals]

    # -- reductions --------------------------------------------------------

    def _red_n(self, eqn):
        axes = eqn.params["axes"]
        shape = getattr(eqn.invars[0].aval, "shape", ())
        return int(np.prod([shape[a] for a in axes])) if shape else 1

    def p_reduce_sum(self, eqn, invals, scope):
        a, = invals
        n = self._red_n(eqn)
        return [ival(_mx(n, a.lo), _mx(n, a.hi))]

    def p_reduce_max(self, eqn, invals, scope):
        a, = invals
        return [ival(a.lo, a.hi)]

    def p_reduce_min(self, eqn, invals, scope):
        a, = invals
        return [ival(a.lo, a.hi)]

    def p_reduce_or(self, eqn, invals, scope):
        return [ival(0, 1)]

    def p_reduce_and(self, eqn, invals, scope):
        return [ival(0, 1)]

    def p_argmax(self, eqn, invals, scope):
        axes = eqn.params["axes"]
        shape = eqn.invars[0].aval.shape
        return [ival(0, shape[axes[0]] - 1)]

    def p_argmin(self, eqn, invals, scope):
        return self.p_argmax(eqn, invals, scope)

    def p_cumsum(self, eqn, invals, scope):
        a, = invals
        shape = eqn.invars[0].aval.shape
        n = shape[eqn.params["axis"]]
        lo = min(a.lo, _mx(n, a.lo))
        hi = max(a.hi, _mx(n, a.hi))
        out = ival(lo, hi)
        # prefix-rank: inclusive cumsum of a 0/1 indicator is strictly
        # increasing (hence pairwise distinct) and >= 1 on indicator lanes
        if (len(shape) == 1 and not eqn.params.get("reverse", False)
                and a.lo >= 0 and a.hi <= 1 and a.atoms):
            out = out.r(rank_mask=a.atoms, rank_lo=1.0)
        return [out]

    def p_cummax(self, eqn, invals, scope):
        a, = invals
        return [ival(a.lo, a.hi)]

    def p_cummin(self, eqn, invals, scope):
        a, = invals
        return [ival(a.lo, a.hi)]

    # -- RNG ---------------------------------------------------------------

    def p_random_bits(self, eqn, invals, scope):
        return self._tops(eqn)

    def p_random_wrap(self, eqn, invals, scope):
        return self._tops(eqn)

    def p_random_unwrap(self, eqn, invals, scope):
        return self._tops(eqn)

    def p_random_seed(self, eqn, invals, scope):
        return self._tops(eqn)

    def p_random_split(self, eqn, invals, scope):
        return self._tops(eqn)

    def p_random_fold_in(self, eqn, invals, scope):
        return self._tops(eqn)

    def p_random_gamma(self, eqn, invals, scope):
        return [ival(0, INF)]

    def p_threefry2x32(self, eqn, invals, scope):
        return self._tops(eqn)

    # -- control flow ------------------------------------------------------

    def p_pjit(self, eqn, invals, scope):
        return self.run(eqn.params["jaxpr"], invals, scope)

    def p_custom_jvp_call(self, eqn, invals, scope):
        return self.run(eqn.params["call_jaxpr"], invals, scope)

    def p_custom_vjp_call(self, eqn, invals, scope):
        return self.run(eqn.params["call_jaxpr"], invals, scope)

    def p_remat(self, eqn, invals, scope):
        return self.run(eqn.params["jaxpr"], invals, scope)

    def p_cond(self, eqn, invals, scope):
        pred, *ops = invals
        branches = eqn.params["branches"]
        if pred.const and 0 <= int(pred.lo) < len(branches):
            return self.run(branches[int(pred.lo)], ops, scope)
        outs = None
        for br in branches:
            o = self.run(br, ops, scope)
            outs = o if outs is None else [join(x, y) for x, y in zip(outs, o)]
        return outs

    @staticmethod
    def _strip_leading(v: IVal) -> IVal:
        out = ival(v.lo, v.hi)
        if v.cols is not None and v.col_axis is not None and v.col_axis >= 1:
            out = out.r(cols=v.cols, col_axis=v.col_axis - 1)
        return out

    @staticmethod
    def _delta(old: float, new: float) -> float:
        """Growth of one interval endpoint across one body run (0 when the
        endpoint is already infinite)."""
        if math.isinf(old):
            return 0.0
        d = new - old
        return d if math.isfinite(d) else math.copysign(INF, d)

    def p_scan(self, eqn, invals, scope):
        """Bounded-trip widening: a scan runs its body exactly `length`
        times, so carries that grow by at most [dlo, dhi] per iteration
        are bounded by init + length·[dlo, dhi].  The growth rate observed
        on the first run is re-verified at the widened state (a carry that
        accelerates falls back to dtype-top).  Only the final, widened body
        run records sites — fixpoint iterations see transient bounds."""
        body = eqn.params["jaxpr"]
        nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
        L = int(eqn.params["length"])
        consts, carry0 = list(invals[:nc]), list(invals[nc:nc + ncar])
        xs = [self._strip_leading(v) for v in invals[nc + ncar:]]
        carry_avals = [v.aval for v in eqn.invars[nc:nc + ncar]]

        mark = len(self.sites)
        first = self.run(body, consts + carry0 + xs, scope)
        dlo = [min(0.0, self._delta(c.lo, n.lo))
               for c, n in zip(carry0, first[:ncar])]
        dhi = [max(0.0, self._delta(c.hi, n.hi))
               for c, n in zip(carry0, first[:ncar])]

        # per-column deltas for carries with stacked-pool column tracking —
        # the global delta does not bound an individual column's growth
        def _colled(c, n):
            return (c.cols is not None and n is not None
                    and n.cols is not None and c.col_axis == n.col_axis
                    and len(c.cols) == len(n.cols))

        cdel = {}                  # carry j -> ([dlo per col], [dhi per col])
        for j, (c, n) in enumerate(zip(carry0, first[:ncar])):
            if _colled(c, n):
                cdel[j] = (
                    [min(0.0, self._delta(cc[0], nc[0]))
                     for cc, nc in zip(c.cols, n.cols)],
                    [max(0.0, self._delta(cc[1], nc[1]))
                     for cc, nc in zip(c.cols, n.cols)])

        def _wcar(j, c, av, trips):
            top = top_for(av)
            out = ival(max(top.lo, c.lo + _mx(trips, dlo[j])),
                       min(top.hi, c.hi + _mx(trips, dhi[j])))
            if j in cdel:
                clo, chi = cdel[j]
                out = out.r(cols=tuple(
                    (max(top.lo, cc[0] + _mx(trips, lo_d)),
                     min(top.hi, cc[1] + _mx(trips, hi_d)))
                    for cc, lo_d, hi_d in zip(c.cols, clo, chi)),
                    col_axis=c.col_axis)
            return out

        outs = first
        for _ in range(4):
            w_in = [_wcar(j, c, av, L - 1)
                    for j, (c, av) in enumerate(zip(carry0, carry_avals))]
            del self.sites[mark:]
            outs = self.run(body, consts + w_in + xs, scope)
            ok = True
            for j, (w, n) in enumerate(zip(w_in, outs[:ncar])):
                if self._delta(w.lo, n.lo) < dlo[j] - 1e-9:
                    dlo[j] = min(dlo[j], self._delta(w.lo, n.lo))
                    ok = False
                if self._delta(w.hi, n.hi) > dhi[j] + 1e-9:
                    dhi[j] = max(dhi[j], self._delta(w.hi, n.hi))
                    ok = False
                if j in cdel:
                    if not _colled(w, n):
                        del cdel[j]          # body dropped cols — stop there
                        continue
                    clo, chi = cdel[j]
                    for k, (wc, nc) in enumerate(zip(w.cols, n.cols)):
                        if self._delta(wc[0], nc[0]) < clo[k] - 1e-9:
                            clo[k] = min(clo[k], self._delta(wc[0], nc[0]))
                            ok = False
                        if self._delta(wc[1], nc[1]) > chi[k] + 1e-9:
                            chi[k] = max(chi[k], self._delta(wc[1], nc[1]))
                            ok = False
            if ok:
                break
        else:
            # growth keeps accelerating → classic widening to dtype-top
            w_in = [top_for(av) for av in carry_avals]
            del self.sites[mark:]
            outs = self.run(body, consts + w_in + xs, scope)
            return [join(c, n) for c, n in zip(w_in, outs[:ncar])] \
                + [ival(v.lo, v.hi) for v in outs[ncar:]]

        carry_out = [_wcar(j, c, av, L)
                     for j, (c, av) in enumerate(zip(carry0, carry_avals))]
        return carry_out + [ival(v.lo, v.hi) for v in outs[ncar:]]

    def p_while(self, eqn, invals, scope):
        cn, bn = eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
        cond_consts = invals[:cn]
        body_consts = invals[cn:cn + bn]
        carry = list(invals[cn + bn:])
        carry_avals = [v.aval for v in eqn.invars[cn + bn:]]
        mark = len(self.sites)
        for it in range(8):
            del self.sites[mark:]
            self.run(eqn.params["cond_jaxpr"], cond_consts + carry, scope)
            new_carry = self.run(eqn.params["body_jaxpr"],
                                 body_consts + carry, scope)
            if all(_contained(n, c) for n, c in zip(new_carry, carry)):
                break
            if it < 2:
                carry = [join(c, n) for c, n in zip(carry, new_carry)]
            else:
                carry = [_widen(c, n, av)
                         for c, n, av in zip(carry, new_carry, carry_avals)]
        return carry

    # -- indexed access: the sites we verify -------------------------------

    def _index_components(self, idx_val: IVal, idx_aval, n_comp: int):
        """Per-component intervals of a [..., n_comp] index array."""
        if n_comp == 1:
            return [(idx_val.lo, idx_val.hi)]
        if (idx_val.cols is not None
                and idx_val.col_axis == len(idx_aval.shape) - 1
                and len(idx_val.cols) == n_comp):
            return list(idx_val.cols)
        return [(idx_val.lo, idx_val.hi)] * n_comp

    def _concrete(self, var):
        if isinstance(var, jex_core.Literal):
            return np.asarray(var.val)
        return None

    def p_gather(self, eqn, invals, scope):
        op, idx = invals
        dnums = eqn.params["dimension_numbers"]
        slice_sizes = eqn.params["slice_sizes"]
        mode = str(eqn.params.get("mode", ""))
        op_shape = eqn.invars[0].aval.shape
        comps = self._index_components(idx, eqn.invars[1].aval,
                                       len(dnums.start_index_map))
        proven = True
        detail = []
        for j, d in enumerate(dnums.start_index_map):
            lim = op_shape[d] - slice_sizes[d]
            lo, hi = comps[j]
            if not (lo >= 0 and hi <= lim):
                proven = False
                detail.append(f"dim{d}: [{lo:g},{hi:g}] vs [0,{lim}]")
        if proven:
            bounds, ok, rule = "in-bounds", True, ""
        elif "CLIP" in mode:
            bounds, ok, rule = "clamped", True, ""
        elif "FILL" in mode:
            bounds, ok, rule = "fill", True, ""
        else:   # PROMISE_IN_BOUNDS: out of bounds is UB
            bounds, ok, rule = "OOB", False, "oob-gather"
        self.sites.append(Site("gather", _site_str(eqn), _phase_of(scope),
                               bounds, "n/a", ok, rule, "; ".join(detail)))
        out = ival(op.lo, op.hi)
        if not proven and "FILL" in mode:
            out = join(out, top_for(eqn.outvars[0].aval))
        # row-gathers of a column-stacked operand keep per-column intervals
        if (op.cols is not None and op.col_axis is not None
                and op.col_axis not in dnums.start_index_map
                and op.col_axis not in dnums.collapsed_slice_dims
                and slice_sizes[op.col_axis] == len(op.cols)):
            kept = [d for d in range(len(op_shape))
                    if d not in dnums.collapsed_slice_dims]
            if op.col_axis in kept:
                out_axis = dnums.offset_dims[kept.index(op.col_axis)]
                out = out.r(cols=op.cols, col_axis=out_axis)
        return [out]

    def p_dynamic_slice(self, eqn, invals, scope):
        op, *starts = invals
        slice_sizes = eqn.params["slice_sizes"]
        op_shape = eqn.invars[0].aval.shape
        proven = True
        detail = []
        for d, s in enumerate(starts):
            lim = op_shape[d] - slice_sizes[d]
            if not (s.lo >= 0 and s.hi <= lim):
                proven = False
                detail.append(f"dim{d}: [{s.lo:g},{s.hi:g}] vs [0,{lim}]")
        # XLA clamps dynamic_slice starts, so memory safety is structural —
        # but a clamped start reads the wrong window; require the proof.
        bounds = "in-bounds" if proven else "OOB"
        rule = "" if proven else "oob-dslice"
        self.sites.append(Site("dynamic_slice", _site_str(eqn),
                               _phase_of(scope), bounds, "n/a", proven, rule,
                               "; ".join(detail)))
        out = ival(op.lo, op.hi, unique=op.unique, filler=op.filler)
        if (op.cols is not None and op.col_axis is not None
                and slice_sizes[op.col_axis] == len(op.cols)):
            out = out.r(cols=op.cols, col_axis=op.col_axis)
        return [out]

    def p_dynamic_update_slice(self, eqn, invals, scope):
        op, upd, *starts = invals
        op_shape = eqn.invars[0].aval.shape
        upd_shape = eqn.invars[1].aval.shape
        proven = True
        detail = []
        for d, s in enumerate(starts):
            lim = op_shape[d] - upd_shape[d]
            if not (s.lo >= 0 and s.hi <= lim):
                proven = False
                detail.append(f"dim{d}: [{s.lo:g},{s.hi:g}] vs [0,{lim}]")
        bounds = "in-bounds" if proven else "OOB"
        rule = "" if proven else "oob-dslice"
        self.sites.append(Site("dyn_update_slice", _site_str(eqn),
                               _phase_of(scope), bounds, "n/a", proven, rule,
                               "; ".join(detail)))
        out = ival(min(op.lo, upd.lo), max(op.hi, upd.hi))
        if (op.cols is not None and op.col_axis is not None
                and upd_shape[op.col_axis] == op_shape[op.col_axis]):
            ucols = (upd.cols if upd.cols is not None
                     and upd.col_axis == op.col_axis
                     and len(upd.cols) == len(op.cols)
                     else [(upd.lo, upd.hi)] * len(op.cols))
            out = out.r(cols=tuple(
                (min(a[0], b[0]), max(a[1], b[1]))
                for a, b in zip(op.cols, ucols)), col_axis=op.col_axis)
        return [out]

    def _scatter(self, eqn, invals, scope, kind):
        op, idx, upd = invals
        dnums = eqn.params["dimension_numbers"]
        mode = str(eqn.params.get("mode", ""))
        uniq_flag = eqn.params.get("unique_indices", False)
        op_shape = eqn.invars[0].aval.shape
        upd_shape = eqn.invars[2].aval.shape
        sdod = dnums.scatter_dims_to_operand_dims
        # window size along each indexed operand dim
        kept = [d for d in range(len(op_shape))
                if d not in dnums.inserted_window_dims]
        win = {d: 1 for d in range(len(op_shape))}
        for k, d in enumerate(kept):
            win[d] = upd_shape[dnums.update_window_dims[k]] \
                if k < len(dnums.update_window_dims) else 1
        comps = self._index_components(idx, eqn.invars[1].aval, len(sdod))
        proven = True
        detail = []
        lims = []
        for j, d in enumerate(sdod):
            lim = op_shape[d] - win[d]
            lims.append(lim)
            lo, hi = comps[j]
            if not (lo >= 0 and hi <= lim):
                proven = False
                detail.append(f"dim{d}: [{lo:g},{hi:g}] vs [0,{lim}]")
        drop = "FILL_OR_DROP" in mode
        if proven:
            bounds, b_ok = "in-bounds", True
        elif drop:
            bounds, b_ok = "drop", True      # OOB writes are dropped
        elif "CLIP" in mode:
            bounds, b_ok = "OOB", False      # clamped into the WRONG slot
        else:
            bounds, b_ok = "OOB", False
        # --- duplicate-freedom ---
        conc = idx.conc if idx.conc is not None \
            else self._concrete(eqn.invars[1])
        idx_size = int(np.prod(getattr(eqn.invars[1].aval, "shape", ())))
        n_rows = idx_size // len(sdod) if sdod else 0
        rows_uniq = None
        if conc is not None:
            rows = np.asarray(conc).reshape(-1, len(sdod))
            inb = np.all((rows >= 0) & (rows <= np.asarray(lims)), axis=1)
            live = rows[inb] if drop else rows
            rows_uniq = np.unique(live, axis=0).shape[0] == live.shape[0]
        dups, d_ok = "DUP", False
        if uniq_flag:
            dups, d_ok = "unique(jnp)", True
        elif n_rows == 1:
            dups, d_ok = "unique(single)", True
        elif rows_uniq:
            dups, d_ok = "unique(const)", True
        elif (len(sdod) == 1 and idx.unique
              and (idx.filler is None
                   or (drop and (idx.filler[0] > lims[0]
                                 or idx.filler[1] < 0)))):
            dups, d_ok = "unique(proven)", True
        elif COLLIDE_PREFIX in scope:
            dups, d_ok = "declared-collide", True
        elif DISJOINT_PREFIX in scope:
            dups, d_ok = "declared-disjoint", True
        ok = b_ok and d_ok
        rule = "" if ok else ("oob-scatter" if not b_ok else "dup-scatter")
        self.sites.append(Site(kind, _site_str(eqn), _phase_of(scope),
                               bounds, dups, ok, rule, "; ".join(detail)))
        # synthesize operand columns for a column-less accumulator (e.g.
        # a fresh jnp.zeros) when the update block tracks per-column
        # intervals along a window dim — the stacked [n, 5] stats tables
        if (op.cols is None and upd.cols is not None and len(sdod) == 1
                and sdod[0] != 1 and len(op_shape) == 2
                and len(upd.cols) == op_shape[1]):
            op = op.r(cols=((op.lo, op.hi),) * op_shape[1], col_axis=1)
        # --- result value ---
        if kind == "scatter":
            out = ival(min(op.lo, upd.lo), max(op.hi, upd.hi))
        elif kind == "scatter-add":
            lo = op.lo if upd.lo >= 0 else -INF
            hi = op.hi if upd.hi <= 0 else INF
            if d_ok and dups.startswith("unique"):
                lo = op.lo + min(0.0, upd.lo)
                hi = op.hi + max(0.0, upd.hi)
            out = ival(lo, hi)
        elif kind == "scatter-min":
            out = ival(min(op.lo, upd.lo), op.hi)
        elif kind == "scatter-max":
            out = ival(op.lo, max(op.hi, upd.hi))
        else:
            out = join(ival(op.lo, op.hi), top_for(eqn.outvars[0].aval))
        if op.cols is not None and op.col_axis is not None \
                and tuple(sdod) == (op.col_axis,) and conc is not None \
                and conc.size <= 8:
            # constant column id(s): only the named columns change — this is
            # the ``with_cols`` write path (``ints.at[:, k].set(v)``).
            full = all(win[d] == op_shape[d]
                       for d in range(len(op_shape)) if d != op.col_axis)
            cols = list(op.cols)
            for k in np.asarray(conc).ravel().tolist():
                k = int(k)
                if not (0 <= k < len(cols)):
                    continue
                old = cols[k]
                if kind == "scatter" and full and not drop:
                    cols[k] = (upd.lo, upd.hi)
                elif kind == "scatter":
                    cols[k] = (min(old[0], upd.lo), max(old[1], upd.hi))
                elif kind == "scatter-add":
                    cols[k] = (old[0] if upd.lo >= 0 else -INF,
                               old[1] if upd.hi <= 0 else INF)
                elif kind == "scatter-min":
                    cols[k] = (min(old[0], upd.lo), old[1])
                elif kind == "scatter-max":
                    cols[k] = (old[0], max(old[1], upd.hi))
                else:
                    cols[k] = (min(old[0], upd.lo), max(old[1], upd.hi))
            cols = tuple(cols)
            out = out.r(cols=cols, col_axis=op.col_axis,
                        lo=min(c[0] for c in cols),
                        hi=max(c[1] for c in cols))
        elif op.cols is not None and op.col_axis is not None \
                and op.col_axis not in sdod:
            # per-column union with the update block
            k = (kept.index(op.col_axis) if op.col_axis in kept else None)
            uax = (dnums.update_window_dims[k]
                   if k is not None and k < len(dnums.update_window_dims)
                   else None)
            ucols = (upd.cols if upd.cols is not None and uax is not None
                     and upd.col_axis == uax and len(upd.cols) == len(op.cols)
                     else [(upd.lo, upd.hi)] * len(op.cols))
            if kind == "scatter":
                cols = tuple((min(a[0], b[0]), max(a[1], b[1]))
                             for a, b in zip(op.cols, ucols))
            elif kind == "scatter-add":
                cols = tuple(
                    (a[0] if b[0] >= 0 else -INF, a[1] if b[1] <= 0 else INF)
                    for a, b in zip(op.cols, ucols))
            else:
                cols = tuple((min(a[0], b[0]), max(a[1], b[1]))
                             for a, b in zip(op.cols, ucols))
            out = out.r(cols=cols, col_axis=op.col_axis,
                        lo=min(out.lo, min(c[0] for c in cols)),
                        hi=max(out.hi, max(c[1] for c in cols)))
        return [out]

    def p_scatter(self, eqn, invals, scope):
        return self._scatter(eqn, invals, scope, "scatter")

    def p_scatter_add(self, eqn, invals, scope):
        return self._scatter(eqn, invals, scope, "scatter-add")

    def p_scatter_min(self, eqn, invals, scope):
        return self._scatter(eqn, invals, scope, "scatter-min")

    def p_scatter_max(self, eqn, invals, scope):
        return self._scatter(eqn, invals, scope, "scatter-max")

    def p_scatter_mul(self, eqn, invals, scope):
        return self._scatter(eqn, invals, scope, "scatter-mul")


# --------------------------------------------------------------------------
# Seeding: declared inductive bounds per state leaf
# --------------------------------------------------------------------------

def _state_bound_rules(caps, app):
    """path-suffix → (lo, hi) for int leaves whose range matters.  Floats
    and counters default to dtype-top / [0, inf) and are listed only when
    they feed an index computation."""
    from repro.core.types import CL_TRANSIT, INST_DOWN, edge_table_size
    S = app.n_services
    H = app.n_hosts
    A = app.n_apis
    E = edge_table_size(S, caps.d_max, A)
    return {
        ".tick": (0, INF),
        ".time": (0, INF),
        ".rr": (0, caps.max_replicas - 1),
        ".clients.wait": (0, INF),
        ".requests.count": (0, INF),
        ".requests.api": (-1, A - 1),
        ".requests.outstanding": (-INF, INF),
        ".requests.spawned": (0, INF),
        ".requests.critical_len": (0, INF),
        ".instances.status": (0, INST_DOWN),
        ".instances.service": (-1, S - 1),
        ".instances.vm": (-1, caps.n_vms - 1),
        ".instances.host": (-1, H - 1),
        ".instances.n_exec": (-INF, INF),
        ".instances.busy_ticks": (0, INF),
        ".net.transits": (0, INF),
        ".net.hist": (0, INF),
        ".sched.inst_of_rank": (-1, caps.max_instances - 1),
        ".sched.svc_replicas": (0, caps.max_replicas),
        ".svc_stats.finished": (-INF, INF),
        ".fault.host_up": (0, 1),
        ".fault.nic_ok": (0, 1),
        ".fault.host_slow": (0, 1),
        ".fault.zone_cut": (0, 1),
        ".fault.edge_succ": (0, INF),
        ".fault.inst_succ": (0, INF),
        ".alerts.astate": (0, 3),
        ".alerts.ev_service": (-1, S - 1),
        ".alerts.ev_rule": (0, 7),
        ".alerts.ev_state": (0, 3),
        "_E_sentinel": (0, E),    # referenced by tests; not a real leaf
    }


def seed_vals(sim, state, dyn):
    """IVal seeds for the flattened (state, dyn, app) argument list, plus
    the path list used for the inductive output check."""
    from repro.core.types import POOL_COLUMN_BOUNDS
    caps, app = sim.caps, sim.app
    rules = _state_bound_rules(caps, app)
    layout = state.cloudlets.layout

    def pool_cols(fields):
        cs = tuple(POOL_COLUMN_BOUNDS[n](caps, app) for n in fields)
        return ival(min(c[0] for c in cs), max(c[1] for c in cs),
                    cols=cs, col_axis=1)

    state_leaves = jtu.tree_flatten_with_path(state)[0]
    paths, vals = [], []
    for p, leaf in state_leaves:
        ks = jtu.keystr(p)
        if ks.startswith(".cloudlets"):
            fields = (layout.i_fields if "index 0" in ks else layout.f_fields)
            v = pool_cols(fields)
        elif ks in rules:
            v = ival(*rules[ks])
        elif ks.startswith((".counters.", ".fstats.", ".qos.", ".slo.")):
            # accumulators and tallies; never feed an index computation
            v = ival(-INF, INF)
        else:
            dt = getattr(leaf, "dtype", None)
            if dt is not None and jnp.issubdtype(dt, jnp.floating):
                v = ival(-INF, INF)
            else:
                v = top_for(leaf)
        paths.append(ks)
        vals.append(v)

    # dyn params: user-facing rates/thresholds, documented nonnegative
    dyn_leaves = jtu.tree_flatten(dyn)[0]
    dyn_vals = [ival(0, INF) for _ in dyn_leaves]
    # app: concrete build-validated tables → exact seeds
    app_vals = [from_concrete(leaf) for leaf in jtu.tree_flatten(app)[0]]
    return paths, vals, dyn_vals, app_vals


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ComboReport:
    combo: str
    sites: List[Site]
    induction_fails: List[str]
    unknown_prims: Dict[str, int]

    @property
    def violations(self) -> List[Site]:
        return [s for s in self.sites if not s.ok]

    def summary(self) -> str:
        n_ok = sum(1 for s in self.sites if s.ok)
        return (f"{self.combo}: {len(self.sites)} sites, {n_ok} ok, "
                f"{len(self.violations)} violations, "
                f"{len(self.induction_fails)} induction failures")


def analyze_jaxpr(closed, invals) -> Tuple[List[Site], List[IVal], Interp]:
    """Library entry for tests: interpret one ClosedJaxpr with given seeds."""
    it = Interp()
    outs = it.run(closed, invals)
    return it.sites, outs, it


def verify_combo(network: str, faults: str, *, sim=None,
                 telemetry: str = "none") -> ComboReport:
    """Prove index safety of one combo's tick program."""
    from repro.core.types import DynParams
    from .layout_check import _tiny_sim

    sim = sim or _tiny_sim(network, faults, False, telemetry)
    state = sim.init_state()
    dyn = DynParams.from_params(sim.params)
    closed = jax.make_jaxpr(sim._tick)(state, dyn, sim.app)

    paths, svals, dvals, avals = seed_vals(sim, state, dyn)
    it = Interp()
    outs = it.run(closed, list(svals) + list(dvals) + list(avals))

    # inductive step: the tick's output state must stay inside the seeds
    out_shapes = jax.eval_shape(sim._tick, state, dyn, sim.app)
    out_paths = [jtu.keystr(p)
                 for p, _ in jtu.tree_flatten_with_path(out_shapes)[0]]
    seed_by_path = dict(zip(paths, svals))
    fails = []
    for ks, ov in zip(out_paths, outs):
        key = ks[3:] if ks.startswith("[0]") else None   # "[0].tick" → ".tick"
        if key is None or key not in seed_by_path:
            continue
        sv = seed_by_path[key]
        if sv.cols is not None and ov.cols is not None \
                and len(sv.cols) == len(ov.cols):
            for f, (slh, olh) in enumerate(zip(sv.cols, ov.cols)):
                if not (olh[0] >= slh[0] and olh[1] <= slh[1]):
                    fails.append(
                        f"{key}[col {f}]: out [{olh[0]:g},{olh[1]:g}] ⊄ "
                        f"seed [{slh[0]:g},{slh[1]:g}]")
        elif not _contained(ov, sv):
            fails.append(f"{key}: out [{ov.lo:g},{ov.hi:g}] ⊄ "
                         f"seed [{sv.lo:g},{sv.hi:g}]")
    return ComboReport(f"{network}+{faults}", it.sites, fails,
                       dict(it.unknown))
