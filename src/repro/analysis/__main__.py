"""CLI: ``python -m repro.analysis [--only SECTION,...] [--waive RULE,...]``

Exit code 0 = every static invariant holds; 1 = violations (printed one
per line, prefixed by their section).
"""
from __future__ import annotations

import argparse
import sys

from .simcheck import run_simcheck


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="simcheck: static analysis of the jitted tick "
                    "program (DESIGN.md §8)")
    ap.add_argument("--only", default=None,
                    help="comma list of sections to run "
                         "(lint,layout,streams,recompile); default all")
    ap.add_argument("--waive", default=None,
                    help="comma list of jaxpr-lint rule ids to waive "
                         "(f64,callback,transfer,donation)")
    ap.add_argument("--sweep-points", type=int, default=8,
                    help="run_batch sweep width for the recompile "
                         "sentinel (default 8)")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    waive = set(args.waive.split(",")) if args.waive else None
    report = run_simcheck(only=only, waive=waive,
                          sweep_points=args.sweep_points)

    for sec, probs in report.sections.items():
        status = "clean" if not probs else f"{len(probs)} violation(s)"
        print(f"[simcheck] {sec}: {status}")
    for combo, digest in report.stream_digests.items():
        print(f"[simcheck]   stream topology {combo}: {digest}")
    if report.sentinel is not None:
        print(f"[simcheck]   compiles: warm="
              f"{report.sentinel.warm_compiles} "
              f"counting={report.sentinel.counting_compiles}")
    for p in report.problems:
        print(f"VIOLATION {p}")
    print(f"[simcheck] {'OK' if report.ok else 'FAILED'}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
