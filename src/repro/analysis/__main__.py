"""CLI: ``python -m repro.analysis [--only SECTION,...]``

Exit code 0 = every static invariant holds; 1 = violations (printed one
per line, prefixed by their section).  Rule waivers live in
``analysis/waivers.toml`` (DESIGN.md §8) — there is deliberately no
CLI waive flag: a flag silences forever and invisibly, a file row is
reviewed in the diff and expires.
"""
from __future__ import annotations

import argparse
import sys

from . import shardability
from .simcheck import run_simcheck


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="simcheck: static analysis of the jitted tick "
                    "program (DESIGN.md §8)")
    ap.add_argument("--only", default=None,
                    help="comma list of sections to run (lint,layout,"
                         "streams,recompile,intervals,shardability); "
                         "default all")
    ap.add_argument("--sweep-points", type=int, default=8,
                    help="run_batch sweep width for the recompile "
                         "sentinel (default 8)")
    ap.add_argument("--shard-report", default=None, metavar="PATH",
                    help="write the full shardability report (per-phase "
                         "tables + every cross-shard eqn) as JSON to "
                         "PATH (requires the shardability section)")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    report = run_simcheck(only=only, sweep_points=args.sweep_points)

    for sec, probs in report.sections.items():
        status = "clean" if not probs else f"{len(probs)} violation(s)"
        print(f"[simcheck] {sec}: {status}")
    for combo, digest in report.stream_digests.items():
        print(f"[simcheck]   stream topology {combo}: {digest}")
    if report.sentinel is not None:
        print(f"[simcheck]   compiles: warm="
              f"{report.sentinel.warm_compiles} "
              f"counting={report.sentinel.counting_compiles}")
    for combo, irep in report.interval_reports.items():
        print(f"[simcheck]   intervals {irep.summary()}")
    for combo, srep in report.shard_reports.items():
        print(f"[simcheck]   shardability {srep.summary()}")
    if args.shard_report:
        if not report.shard_reports:
            print("[simcheck] --shard-report given but the shardability "
                  "section did not run", file=sys.stderr)
            return 2
        shardability.write_report(
            list(report.shard_reports.values()), args.shard_report)
        print(f"[simcheck]   shardability report -> {args.shard_report}")
    for p in report.problems:
        print(f"VIOLATION {p}")
    print(f"[simcheck] {'OK' if report.ok else 'FAILED'}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
