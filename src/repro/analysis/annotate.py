"""Scatter-hazard declarations for the index-safety verifier (DESIGN.md §8).

The interval pass (:mod:`repro.analysis.intervals`) must prove every
``scatter`` site duplicate-free.  Two escape hatches exist, both spelled as
:func:`jax.named_scope` wrappers so they are pure metadata — the lowered
program, goldens, and stream digests are bit-identical with or without them:

* :func:`collide` — collisions are the *point* (segment_sum-style
  accumulation into shared slots).  The verifier accepts the site and lists
  it in the report as ``declared-collide``.
* :func:`disjoint` — the author asserts the index vector is duplicate-free
  but the abstract domain cannot prove it (e.g. the two-scatter spawn writer,
  whose slot list is distinct by construction of the free-slot compaction).
  The verifier accepts it as ``declared-disjoint``; under ``REPRO_CHECKED=1``
  the same sites carry :mod:`jax.experimental.checkify` runtime asserts, so
  CI exercises the declared invariant once per combo.

Scopes nest inside the tick-phase scopes emitted by ``engine.make_tick``,
so a site's name stack reads e.g. ``Dispatch/repro_collide:segment_sum``.
"""
from __future__ import annotations

import os

import jax

COLLIDE_PREFIX = "repro_collide:"
DISJOINT_PREFIX = "repro_disjoint:"


def collide(label: str):
    """Declare that scatters in this scope intentionally collide."""
    return jax.named_scope(COLLIDE_PREFIX + label)


def disjoint(label: str):
    """Declare that scatters in this scope are duplicate-free by
    construction (runtime-checked under ``REPRO_CHECKED=1``)."""
    return jax.named_scope(DISJOINT_PREFIX + label)


def checked_mode() -> bool:
    """True when ``REPRO_CHECKED=1``: trace checkify asserts into declared
    sites and run the program under ``checkify.checkify``.  Read at trace
    time; the engine folds it into the compile-cache key."""
    return os.environ.get("REPRO_CHECKED", "") == "1"
