"""Layout-access checker — PHASE_COLUMNS vs what the tick actually touches.

The mode-keyed pool layout (PR 4) is only as honest as its registry:
``PHASE_COLUMNS`` *declares* which columns each tick phase reads/writes,
and ``resolve_layout`` shrinks the stacked pool to the union of the
declared sets.  Nothing enforced the declarations — a phase could read a
column another phase happened to pull into the layout (attribution
drift, invisible until a mode stops carrying it), or keep claiming a
column it no longer touches (pool bytes nobody uses).

This checker replays ONE tick eagerly on a tiny diamond-graph sim with

* a **recording layout proxy** in place of the ``PoolLayout`` carried by
  ``Cloudlets`` — every ``layout.i(name)`` / ``layout.f(name)`` lookup
  (the single funnel all named reads AND ``with_cols`` writes go
  through) is logged, and ``i_fields``/``f_fields`` block reads (only
  ``pool.scatter_pool`` touches those) are logged as whole-row *spawn*
  writes;
* the engine's ``probe`` hook attributing each access to the phase
  being traced.

Rules (per mode combo, then unioned where noted):

* **undeclared-access** — a *named* access in a registry phase to a
  column outside that phase's declared set fails.  Spawn writes are
  exempt: a spawn initializes whole rows by design, mode-agnostically.
* **declared-but-never-touched** — a declared column no combo ever
  touches (named or spawn) in that phase fails; evaluated on the union
  across all combos because several declarations are mode-conditional
  (Dispatch reads ``arrival`` only on the uniform path, ``inst``
  pre-addressing only on the fabric path).
* **non-registry phases** (Response/Scaling/Trace) must stay inside the
  always-on core columns — they run in every mode, so touching a
  mode-keyed column would crash some layouts.
* **spawns** may only occur in the three phases that respawn rows
  (Generation, Derive, Disruption).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

from repro.core import SimCaps, SimParams, Simulation, diamond
from repro.core.engine import make_tick
from repro.core.types import (DynParams, PHASE_COLUMNS, Cloudlets,
                              resolve_layout)

# (network, faults, egress_shaping, telemetry) combos replayed.  The
# four golden combos plus the egress-shaping variant (the only consumer
# of the Transit/egress_shaping sub-entry) plus the telemetry combo
# (the only one tracing the Telemetry phase — full-mode so both its
# chaos and fabric sub-entries activate) plus the alerting combo
# (telemetry="alert" shorthand: stream + alerting="burn", the only one
# tracing the Alerting phase).
COMBOS: Tuple[Tuple[str, str, bool, object], ...] = (
    ("uniform", "none", False, False),
    ("uniform", "chaos", False, False),
    ("fabric", "none", False, False),
    ("fabric", "chaos", False, False),
    ("fabric", "chaos", True, False),
    ("fabric", "chaos", False, True),
    ("fabric", "chaos", False, "alert"),
)

# Registry sub-entries ("Phase/feature") activate with these flags.
_FEATURE_ON = {
    "chaos": lambda net, fl, eg, tel: fl == "chaos",
    "fabric": lambda net, fl, eg, tel: net == "fabric",
    "egress_shaping": lambda net, fl, eg, tel: eg,
}

_SPAWN_PHASES = ("Generation", "Derive", "Disruption")


class RecordingLayout:
    """Duck-typed ``PoolLayout`` stand-in that logs column accesses.

    Delegates every real lookup to the wrapped layout, so the replayed
    tick computes exactly what it would with the genuine layout;
    ``__contains__``/``columns`` stay unrecorded (a skip decision or a
    validation sweep is not an access).
    """

    def __init__(self, inner, log: "AccessLog"):
        # object.__setattr__ not needed — plain class, but the attribute
        # names must not collide with the recorded properties below.
        self._inner = inner
        self._log = log

    def i(self, name: str) -> int:
        self._log.touch(name, "named")
        return self._inner.i(name)

    def f(self, name: str) -> int:
        self._log.touch(name, "named")
        return self._inner.f(name)

    @property
    def i_fields(self):
        for n in self._inner.i_fields:
            self._log.touch(n, "spawn")
        return self._inner.i_fields

    @property
    def f_fields(self):
        for n in self._inner.f_fields:
            self._log.touch(n, "spawn")
        return self._inner.f_fields

    @property
    def columns(self):
        return self._inner.columns

    def __contains__(self, name: str) -> bool:
        return name in self._inner

    def init_ints(self):
        return self._inner.init_ints()

    def init_flts(self):
        return self._inner.init_flts()


@dataclasses.dataclass
class AccessLog:
    """phase → {(column, kind)} with the engine-probe phase cursor."""

    phase: str = "<init>"
    accesses: Dict[str, Set[Tuple[str, str]]] = \
        dataclasses.field(default_factory=dict)

    def probe(self, phase: str) -> None:
        self.phase = phase

    def touch(self, column: str, kind: str) -> None:
        self.accesses.setdefault(self.phase, set()).add((column, kind))


def _tiny_sim(network: str, faults: str, egress: bool,
              telemetry: bool | str = False) -> Simulation:
    caps = SimCaps(n_clients=8, max_requests=128, max_cloudlets=128,
                   max_instances=8, n_vms=2, d_max=2, max_replicas=2)
    alert_on = telemetry == "alert"
    tel_on = alert_on or telemetry in (True, "stream")
    # telemetry knobs shrunk so a 4-tick replay closes windows (Wt=2)
    # and a 4-tick lint program contains a real chunk flush (W=2 →
    # flush every 2 ticks); k=1 samples every request so the span path
    # traces its chaos/fabric column reads.  "alert" compiles the
    # Alerting stage on top (tiny lookbacks, enabled objectives, tight
    # hysteresis — the rule math traces whether or not anything fires).
    params = SimParams(dt=0.05, n_ticks=4, n_clients=6, spawn_rate=10.0,
                       wait_lo=0.1, wait_hi=0.3, seed=7,
                       scaling_policy=1,  # exercise the Scaling phase too
                       network=network, faults=faults,
                       egress_shaping=egress,
                       telemetry="stream" if tel_on else "none",
                       tel_window_ticks=2, tel_windows=2,
                       tel_span_k=1, tel_span_cap=64,
                       alerting="burn" if alert_on else "none",
                       slo_budget=0.05 if alert_on else 0.0,
                       slo_short_wins=1, slo_long_wins=2,
                       slo_for_ticks=1, slo_event_cap=16)
    return Simulation(diamond(mi=200.0), caps=caps, params=params)


def replay_accesses(network: str, faults: str, egress: bool,
                    telemetry: bool = False
                    ) -> Dict[str, Set[Tuple[str, str]]]:
    """Actual per-phase column accesses of one eagerly-executed tick."""
    sim = _tiny_sim(network, faults, egress, telemetry)
    log = AccessLog()
    tick = make_tick(sim.caps, sim.params, sim._has_edges, probe=log.probe)
    state = sim.init_state()
    cl = state.cloudlets
    state = state._replace(cloudlets=Cloudlets(
        cl.ints, cl.flts, RecordingLayout(cl.layout, log)))
    dyn = DynParams.from_params(sim.params)
    # Eager execution: lax.cond still traces both scaling branches, so
    # the Scaling phase records even on a tick where it is not due.
    tick(state, dyn, sim.app)
    return log.accesses


def declared_for(registry: dict, phase: str, network: str, faults: str,
                 egress: bool, telemetry: bool = False) -> Set[str]:
    """Declared column set of a registry phase under one mode combo
    (base entry + active ``Phase/feature`` sub-entries)."""
    cols = set(registry[phase])
    for key, sub in registry.items():
        if "/" not in key:
            continue
        base, feature = key.split("/", 1)
        if base == phase and _FEATURE_ON[feature](network, faults,
                                                  egress, telemetry):
            cols |= set(sub)
    return cols


def check_layout_access(phase_columns: dict | None = None) -> List[str]:
    """All layout-access violations across :data:`COMBOS` (empty = clean).

    ``phase_columns`` overrides the registry *for the diff only* — the
    seeded-violation self-tests pass a perturbed copy to prove each rule
    fires; production runs use the real ``PHASE_COLUMNS``.
    """
    registry = PHASE_COLUMNS if phase_columns is None else phase_columns
    base_phases = [p for p in registry if "/" not in p]
    core = set(resolve_layout(SimParams()).columns)
    problems: List[str] = []
    # union of actual touches per phase across combos (unused-rule input)
    touched: Dict[str, Set[str]] = {p: set() for p in base_phases}
    declared_any: Dict[str, Set[str]] = {p: set() for p in base_phases}

    for network, faults, egress, telemetry in COMBOS:
        combo = f"network={network} faults={faults}" \
            + (" egress_shaping" if egress else "") \
            + (" telemetry+alerting" if telemetry == "alert"
               else " telemetry" if telemetry else "")
        actual = replay_accesses(network, faults, egress, telemetry)
        for phase, accs in actual.items():
            spawns = {c for c, kind in accs if kind == "spawn"}
            named = {c for c, kind in accs if kind == "named"}
            if spawns and phase not in _SPAWN_PHASES:
                problems.append(
                    f"[{combo}] phase {phase!r} performs whole-row spawn "
                    f"writes — only {_SPAWN_PHASES} respawn rows")
            if phase in base_phases:
                decl = declared_for(registry, phase, network,
                                    faults, egress, telemetry)
                declared_any[phase] |= decl
                touched[phase] |= named | spawns
                undeclared = named - decl
                if undeclared:
                    problems.append(
                        f"[{combo}] phase {phase!r} accesses undeclared "
                        f"column(s) {sorted(undeclared)} — declare them "
                        f"in PHASE_COLUMNS[{phase!r}] (or a mode "
                        "sub-entry) so the layout resolver knows")
            else:
                off_core = named - core
                if off_core:
                    problems.append(
                        f"[{combo}] non-registry phase {phase!r} touches "
                        f"mode-keyed column(s) {sorted(off_core)} — it "
                        "runs in every mode, so these reads crash "
                        "layouts that don't carry them")

    for phase in base_phases:
        unused = declared_any[phase] - touched[phase]
        if unused:
            problems.append(
                f"phase {phase!r} declares column(s) {sorted(unused)} "
                "that no mode combo ever touches — stale declaration "
                "holding dead pool bytes")
    return problems
