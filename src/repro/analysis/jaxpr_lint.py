"""Jaxpr lint — walk the scan-body program for hot-loop hazards.

The fused tick's performance contract is structural: the whole run is
ONE ``lax.scan`` whose body stays f32/i32, device-resident, and
callback-free, scanned over a donated carry.  None of that is visible
in a passing test — an f64 upcast or a stray ``io_callback`` produces
the right numbers, slower.  This walker traverses the ClosedJaxpr of
the jitted run (descending through scan/cond/while/pjit sub-jaxprs) and
flags:

* ``f64`` — wide-dtype introduction (f64/i64/u64/c128 outvars, incl.
  widening ``convert_element_type``) inside the hot loop.  The engine
  is strong-typed f32/i32; wide values appear only if someone enables
  x64 and leaks a Python float through an op that then promotes.
* ``callback`` — any callback primitive (``pure_callback``,
  ``io_callback``, debug prints) inside the scan body: a host
  round-trip per tick.  Sites registered via :func:`declare_callback`
  (by host-function name) are exempt — the ONE legitimate tap is the
  telemetry exporter flush (obs/telemetry.py), which fires once per
  ring half, not per tick.
* ``transfer`` — explicit ``device_put`` transfers inside the scan
  body.
* ``donation`` — the solo run's carry is not donated (checked on the
  lowered module: every input-state buffer must carry a
  ``tf.aliasing_output`` attr / donated flag, else the pool doubles
  resident bytes).

Rules are waivable by id (``waive={"donation", ...}``) — see DESIGN.md
§8 for when that is legitimate.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Set

import jax
from jax.extend import core as jex_core

WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")
RULES = ("f64", "callback", "transfer", "donation")

# Host-function names allowed to appear as callback sites in the hot
# loop.  Populated at import time by the module that OWNS the callback
# (obs/telemetry.py declares its exporter tap) — an undeclared callback
# still fails the lint, so a stray io_callback can't hide behind the
# mechanism.
_DECLARED_CALLBACKS: Set[str] = set()


def declare_callback(name: str) -> None:
    """Allow-list a callback site by its host function's ``__name__``."""
    _DECLARED_CALLBACKS.add(name)


def _callback_site(eqn) -> str:
    """Host-function name behind a callback eqn (io_callback wraps the
    target in a _FlatCallback with a ``callback_func`` attribute)."""
    cb = eqn.params.get("callback")
    fn = getattr(cb, "callback_func", cb)
    return getattr(fn, "__name__", "")


def _sub_jaxprs(eqn) -> Iterable[tuple]:
    """(jaxpr, enters_loop) pairs nested in one equation's params."""
    loop = eqn.primitive.name in ("scan", "while")
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for x in vs:
            if isinstance(x, jex_core.ClosedJaxpr):
                yield x.jaxpr, loop
            elif isinstance(x, jex_core.Jaxpr):
                yield x, loop


def lint_jaxpr(jaxpr, in_loop: bool = False,
               waive: Optional[Set[str]] = None) -> List[str]:
    """Walk one (possibly closed) jaxpr; return violations.

    ``in_loop=True`` treats the given jaxpr itself as hot-loop code —
    used by tests that lint a tick body directly rather than the
    wrapping scan.
    """
    waive = waive or set()
    if hasattr(jaxpr, "jaxpr"):        # ClosedJaxpr → Jaxpr
        jaxpr = jaxpr.jaxpr
    problems: List[str] = []

    def walk(jx, hot: bool) -> None:
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if hot:
                if "f64" not in waive:
                    wide = [str(v.aval.dtype) for v in eqn.outvars
                            if getattr(v.aval, "dtype", None) is not None
                            and str(v.aval.dtype) in WIDE_DTYPES]
                    if wide:
                        problems.append(
                            f"f64: hot-loop {name!r} produces wide "
                            f"dtype(s) {wide} — the tick carry is "
                            "f32/i32; a widening here doubles scan "
                            "bandwidth")
                if "callback" not in waive and "callback" in name \
                        and _callback_site(eqn) not in _DECLARED_CALLBACKS:
                    problems.append(
                        f"callback: {name!r} inside the scan body — a "
                        "host round-trip every tick (declared sites: "
                        f"{sorted(_DECLARED_CALLBACKS) or 'none'})")
                if "transfer" not in waive and name == "device_put":
                    problems.append(
                        "transfer: device_put inside the scan body — "
                        "device↔host traffic in the hot loop")
            for sub, enters_loop in _sub_jaxprs(eqn):
                walk(sub, hot or enters_loop)

    walk(jaxpr, in_loop)
    return problems


def check_donation(lowered, waive: Optional[Set[str]] = None) -> List[str]:
    """Donation rule on a ``jax.jit(...).lower(...)`` result: the state
    argument (argnum 0) must be donated and XLA must have aliased at
    least one output onto it."""
    if waive and "donation" in waive:
        return []
    problems: List[str] = []
    # args_info mirrors ((args...), {kwargs}); argnum 0 is the state.
    positional = lowered.args_info[0]
    state_info = jax.tree_util.tree_leaves(positional[0])
    not_donated = sum(1 for a in state_info if not a.donated)
    if not_donated:
        problems.append(
            f"donation: {not_donated}/{len(state_info)} carry buffers "
            "not donated — pass donate_argnums=0 so the pool aliases "
            "the output instead of doubling resident bytes")
    elif "tf.aliasing_output" not in lowered.as_text():
        problems.append(
            "donation: carry marked donated but XLA aliased no output "
            "onto it (shape/dtype mismatch between input state and "
            "result?)")
    return problems


def lint_combo(network: str, faults: str,
               waive: Optional[Set[str]] = None,
               telemetry: str = "none") -> List[str]:
    """Full lint of one mode combo's solo run program (scan + donation).

    Uses the engine's own ``_make_run_fn`` so the linted program is the
    REAL one — with ``telemetry="stream"`` that is the chunked
    scan-of-scan including the declared exporter-tap io_callback (the
    allowlist mechanism is exercised, not bypassed)."""
    from repro.core.types import DynParams
    from .layout_check import _tiny_sim

    sim = _tiny_sim(network, faults, False, telemetry)
    state = sim.init_state()
    dyn = DynParams.from_params(sim.params)
    run_fn = sim._make_run_fn()

    closed = jax.make_jaxpr(run_fn)(state, dyn, sim.app)
    problems = lint_jaxpr(closed, waive=waive)
    lowered = jax.jit(run_fn, donate_argnums=0).lower(state, dyn, sim.app)
    problems += check_donation(lowered, waive=waive)
    return problems
