"""simcheck orchestrator — run every analyzer, one report, one exit code.

``python -m repro.analysis`` drives this module: jaxpr lint + donation
check per golden combo, the layout-access diff, the RNG-stream audit
(with per-combo topology digests), and the recompile sentinel.  Each
section returns a list of violation strings; the CLI exits non-zero if
any survive.  See DESIGN.md §8 for the rule catalog and waiver policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

from repro.core.types import DynParams

from . import jaxpr_lint, layout_check, recompile, streams

GOLDEN_COMBOS = (("uniform", "none"), ("uniform", "chaos"),
                 ("fabric", "none"), ("fabric", "chaos"))
# Fifth combo (PR 8): telemetry="stream" on the full mode.  The
# Telemetry phase consumes no tick RNG (its sample mask is a named
# init-time fold_in), so its stream digest must EQUAL fabric+chaos —
# checked below, pinning the observation-only contract.
TELEMETRY_COMBO = ("fabric", "chaos", "stream")
# Sixth combo (PR 9): alerting="burn" on top of streaming telemetry.
# The Alerting stage is pure arithmetic over sealed SLI windows — it
# must consume no tick RNG either, so its digest is pinned to the
# fabric+chaos digest exactly like the telemetry combo.
ALERTING_COMBO = ("fabric", "chaos", "alert")


def record_tick_streams(network: str, faults: str,
                        telemetry: bool | str = False
                        ) -> streams.StreamRecorder:
    """Replay one eager tick with stream recording; the state's rng is
    the registered root, so every wrapped derivation resolves a path."""
    sim = layout_check._tiny_sim(network, faults, False, telemetry)
    state = sim.init_state()
    dyn = DynParams.from_params(sim.params)
    with streams.recording() as rec:
        rec.register(state.rng, "tick")
        sim._tick(state, dyn, sim.app)
    return rec


def check_streams() -> Dict[str, object]:
    """Audit all six combos; returns {'problems': [...], 'digests': {...}}."""
    problems: List[str] = []
    digests: Dict[str, str] = {}
    for net, fl in GOLDEN_COMBOS:
        rec = record_tick_streams(net, fl)
        combo = f"{net}+{fl}"
        digests[combo] = streams.topology_digest(rec)
        for p in streams.audit_events(rec):
            problems.append(f"[{combo}] {p}")
        if not rec.events:
            problems.append(
                f"[{combo}] no stream derivations recorded — the engine "
                "bypassed analysis.streams entirely")
    net, fl, _ = TELEMETRY_COMBO
    rec = record_tick_streams(net, fl, telemetry=True)
    combo = f"{net}+{fl}+telemetry"
    digests[combo] = streams.topology_digest(rec)
    for p in streams.audit_events(rec):
        problems.append(f"[{combo}] {p}")
    if digests[combo] != digests[f"{net}+{fl}"]:
        problems.append(
            f"[{combo}] tick stream topology differs from {net}+{fl} — "
            "the Telemetry phase must not consume tick RNG (its sample "
            "mask is an init-time named fold_in)")
    net, fl, alert = ALERTING_COMBO
    rec = record_tick_streams(net, fl, telemetry=alert)
    combo = f"{net}+{fl}+alerting"
    digests[combo] = streams.topology_digest(rec)
    for p in streams.audit_events(rec):
        problems.append(f"[{combo}] {p}")
    if digests[combo] != digests[f"{net}+{fl}"]:
        problems.append(
            f"[{combo}] tick stream topology differs from {net}+{fl} — "
            "the Alerting phase must not consume tick RNG (burn-rate "
            "rules are pure arithmetic over sealed SLI windows)")
    return {"problems": problems, "digests": digests}


@dataclasses.dataclass
class SimcheckReport:
    sections: Dict[str, List[str]]
    stream_digests: Dict[str, str]
    sentinel: Optional[recompile.SentinelReport]

    @property
    def problems(self) -> List[str]:
        return [f"{sec}: {p}" for sec, ps in self.sections.items()
                for p in ps]

    @property
    def ok(self) -> bool:
        return not self.problems


def run_simcheck(only: Optional[Set[str]] = None,
                 waive: Optional[Set[str]] = None,
                 sweep_points: int = 8) -> SimcheckReport:
    """Run the requested analyzer sections (default: all).

    ``only`` limits to a subset of {'lint', 'layout', 'streams',
    'recompile'}; ``waive`` forwards jaxpr-lint rule waivers.
    """
    run = lambda name: only is None or name in only
    sections: Dict[str, List[str]] = {}
    digests: Dict[str, str] = {}
    sentinel = None

    if run("lint"):
        lint: List[str] = []
        for net, fl in GOLDEN_COMBOS:
            for p in jaxpr_lint.lint_combo(net, fl, waive=waive):
                lint.append(f"[{net}+{fl}] {p}")
        net, fl, tel = TELEMETRY_COMBO
        for p in jaxpr_lint.lint_combo(net, fl, waive=waive,
                                       telemetry=tel):
            lint.append(f"[{net}+{fl}+telemetry] {p}")
        net, fl, alert = ALERTING_COMBO
        for p in jaxpr_lint.lint_combo(net, fl, waive=waive,
                                       telemetry=alert):
            lint.append(f"[{net}+{fl}+alerting] {p}")
        sections["lint"] = lint
    if run("layout"):
        sections["layout"] = layout_check.check_layout_access()
    if run("streams"):
        res = check_streams()
        sections["streams"] = res["problems"]
        digests = res["digests"]
    if run("recompile"):
        sentinel = recompile.run_sentinel(n_points=sweep_points)
        sections["recompile"] = sentinel.problems

    return SimcheckReport(sections=sections, stream_digests=digests,
                          sentinel=sentinel)
