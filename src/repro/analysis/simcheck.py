"""simcheck orchestrator — run every analyzer, one report, one exit code.

``python -m repro.analysis`` drives this module: jaxpr lint + donation
check per golden combo, the layout-access diff, the RNG-stream audit
(with per-combo topology digests), the recompile sentinel, the
interval-based index-safety verifier, and the sharding-readiness
auditor.  Each section returns a list of violation strings; findings
from the rule-tagged sections (lint, intervals, shardability) are
filtered through ``analysis/waivers.toml`` first, and expired or
unmatched waivers are themselves violations.  The CLI exits non-zero
if anything survives.  See DESIGN.md §8 for the rule catalog and
waiver policy.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional, Set, Tuple

from repro.core.types import DynParams

from . import (intervals, jaxpr_lint, layout_check, recompile,
               shardability, streams)
from .waivers import apply_waivers, load_waivers

SHARD_BASELINE_PATH = pathlib.Path(__file__).with_name(
    "shard_baseline.json")

GOLDEN_COMBOS = (("uniform", "none"), ("uniform", "chaos"),
                 ("fabric", "none"), ("fabric", "chaos"))
# Fifth combo (PR 8): telemetry="stream" on the full mode.  The
# Telemetry phase consumes no tick RNG (its sample mask is a named
# init-time fold_in), so its stream digest must EQUAL fabric+chaos —
# checked below, pinning the observation-only contract.
TELEMETRY_COMBO = ("fabric", "chaos", "stream")
# Sixth combo (PR 9): alerting="burn" on top of streaming telemetry.
# The Alerting stage is pure arithmetic over sealed SLI windows — it
# must consume no tick RNG either, so its digest is pinned to the
# fabric+chaos digest exactly like the telemetry combo.
ALERTING_COMBO = ("fabric", "chaos", "alert")


def record_tick_streams(network: str, faults: str,
                        telemetry: bool | str = False
                        ) -> streams.StreamRecorder:
    """Replay one eager tick with stream recording; the state's rng is
    the registered root, so every wrapped derivation resolves a path."""
    sim = layout_check._tiny_sim(network, faults, False, telemetry)
    state = sim.init_state()
    dyn = DynParams.from_params(sim.params)
    with streams.recording() as rec:
        rec.register(state.rng, "tick")
        sim._tick(state, dyn, sim.app)
    return rec


def check_streams() -> Dict[str, object]:
    """Audit all six combos; returns {'problems': [...], 'digests': {...}}."""
    problems: List[str] = []
    digests: Dict[str, str] = {}
    for net, fl in GOLDEN_COMBOS:
        rec = record_tick_streams(net, fl)
        combo = f"{net}+{fl}"
        digests[combo] = streams.topology_digest(rec)
        for p in streams.audit_events(rec):
            problems.append(f"[{combo}] {p}")
        if not rec.events:
            problems.append(
                f"[{combo}] no stream derivations recorded — the engine "
                "bypassed analysis.streams entirely")
    net, fl, _ = TELEMETRY_COMBO
    rec = record_tick_streams(net, fl, telemetry=True)
    combo = f"{net}+{fl}+telemetry"
    digests[combo] = streams.topology_digest(rec)
    for p in streams.audit_events(rec):
        problems.append(f"[{combo}] {p}")
    if digests[combo] != digests[f"{net}+{fl}"]:
        problems.append(
            f"[{combo}] tick stream topology differs from {net}+{fl} — "
            "the Telemetry phase must not consume tick RNG (its sample "
            "mask is an init-time named fold_in)")
    net, fl, alert = ALERTING_COMBO
    rec = record_tick_streams(net, fl, telemetry=alert)
    combo = f"{net}+{fl}+alerting"
    digests[combo] = streams.topology_digest(rec)
    for p in streams.audit_events(rec):
        problems.append(f"[{combo}] {p}")
    if digests[combo] != digests[f"{net}+{fl}"]:
        problems.append(
            f"[{combo}] tick stream topology differs from {net}+{fl} — "
            "the Alerting phase must not consume tick RNG (burn-rate "
            "rules are pure arithmetic over sealed SLI windows)")
    return {"problems": problems, "digests": digests}


@dataclasses.dataclass
class SimcheckReport:
    sections: Dict[str, List[str]]
    stream_digests: Dict[str, str]
    sentinel: Optional[recompile.SentinelReport]
    interval_reports: Dict[str, intervals.ComboReport] = \
        dataclasses.field(default_factory=dict)
    shard_reports: Dict[str, shardability.ShardReport] = \
        dataclasses.field(default_factory=dict)

    @property
    def problems(self) -> List[str]:
        return [f"{sec}: {p}" for sec, ps in self.sections.items()
                for p in ps]

    @property
    def ok(self) -> bool:
        return not self.problems


# Sections whose findings carry a rule id and are therefore eligible
# for a dated waiver in analysis/waivers.toml.  layout/streams/
# recompile findings are structural and stay unwaivable.
WAIVABLE_SECTIONS = ("lint", "intervals", "shardability")


def _split_waived(waivable: List[Tuple[str, str, str]],
                  surviving: List[str]) -> Dict[str, List[str]]:
    """Regroup apply_waivers' surviving texts (an ordered subsequence
    of the waivable texts) back into their sections."""
    per_sec: Dict[str, List[str]] = {}
    si = 0
    for sec, _rule, text in waivable:
        if si < len(surviving) and surviving[si] == text:
            per_sec.setdefault(sec, []).append(text)
            si += 1
    return per_sec


def run_simcheck(only: Optional[Set[str]] = None,
                 sweep_points: int = 8) -> SimcheckReport:
    """Run the requested analyzer sections (default: all).

    ``only`` limits to a subset of {'lint', 'layout', 'streams',
    'recompile', 'intervals', 'shardability'}.  Rule waivers come from
    ``analysis/waivers.toml`` (DESIGN.md §8), not from arguments.
    """
    run = lambda name: only is None or name in only
    sections: Dict[str, List[str]] = {}
    digests: Dict[str, str] = {}
    sentinel = None
    # (section, rule, text) findings that waivers.toml may silence
    waivable: List[Tuple[str, str, str]] = []
    interval_reports: Dict[str, intervals.ComboReport] = {}
    shard_reports: Dict[str, shardability.ShardReport] = {}

    if run("lint"):
        lint_combos = [(*c, "none") for c in GOLDEN_COMBOS] \
            + [TELEMETRY_COMBO, ALERTING_COMBO]
        lint_tags = {"stream": "telemetry", "alert": "alerting"}
        for net, fl, tel in lint_combos:
            tag = f"+{lint_tags[tel]}" if tel in lint_tags else ""
            for p in jaxpr_lint.lint_combo(net, fl, telemetry=tel):
                # lint problems are "rule: detail" — the prefix is the
                # waivable rule id (f64, callback, transfer, donation)
                waivable.append(("lint", p.split(":", 1)[0],
                                 f"[{net}+{fl}{tag}] {p}"))
    if run("layout"):
        sections["layout"] = layout_check.check_layout_access()
    if run("streams"):
        res = check_streams()
        sections["streams"] = res["problems"]
        digests = res["digests"]
    if run("recompile"):
        sentinel = recompile.run_sentinel(n_points=sweep_points)
        sections["recompile"] = sentinel.problems
    if run("intervals"):
        for net, fl in GOLDEN_COMBOS:
            rep = intervals.verify_combo(net, fl)
            interval_reports[rep.combo] = rep
            for s in rep.violations:
                waivable.append(("intervals", s.rule or s.kind,
                                 f"[{rep.combo}] {s.line()}"))
            for f in rep.induction_fails:
                waivable.append((
                    "intervals", "induction",
                    f"[{rep.combo}] inductive bound regressed: {f} — "
                    "a tick output escapes its seeded state bound"))
            for prim, n in rep.unknown_prims.items():
                waivable.append((
                    "intervals", "unknown-prim",
                    f"[{rep.combo}] {n} eqn(s) use unmodeled primitive "
                    f"{prim!r} — add a transfer rule in intervals.py"))
    if run("shardability"):
        for net, fl in GOLDEN_COMBOS:
            rep = shardability.audit_combo(net, fl)
            shard_reports[rep.combo] = rep
        if SHARD_BASELINE_PATH.exists():
            baseline = json.loads(SHARD_BASELINE_PATH.read_text())
        else:
            baseline = {"combos": {}}
        for p in shardability.compare_to_baseline(
                list(shard_reports.values()), baseline):
            waivable.append(("shardability", "shardability", p))

    ran_waivable = [s for s in WAIVABLE_SECTIONS if run(s)]
    if ran_waivable:
        waivers = load_waivers()
        surviving, wproblems = apply_waivers(
            [(rule, text) for _, rule, text in waivable], waivers)
        per_sec = _split_waived(waivable, surviving)
        for sec in ran_waivable:
            sections[sec] = per_sec.get(sec, [])
        if only is not None and set(ran_waivable) != set(WAIVABLE_SECTIONS):
            # partial runs can't tell a stale waiver from one whose
            # section was skipped — only expiry stays fatal
            wproblems = [p for p in wproblems
                         if "matched no finding" not in p]
        sections["waivers"] = wproblems

    return SimcheckReport(sections=sections, stream_digests=digests,
                          sentinel=sentinel,
                          interval_reports=interval_reports,
                          shard_reports=shard_reports)
