"""Named RNG streams — auditable `split`/`fold_in` wrappers (simcheck).

The engine's RNG-stream topology is a correctness contract: every tick
phase consumes keys derived from ``state.rng`` along a fixed tree, and
`jax.random.split` is NOT prefix-stable — widening a split or reordering
a ``fold_in`` silently perturbs every downstream stream and breaks the
pinned golden digests (the hazard documented at the gray-failure fork in
``core/faults.py``).  Until this module, that discipline lived in
comments.

``split`` and ``fold_in`` here are drop-in wrappers over ``jax.random``:
outside an audit they ARE the underlying calls (one ``is None`` check at
trace time, nothing in the compiled program).  Inside a
:func:`recording` context every derivation is logged as a
:class:`StreamEvent` carrying the *named path* of the parent key and its
children, so the auditor can

* rebuild the stream-derivation tree of one traced tick,
* detect key reuse (two identical derivations off one parent — their
  children collide bit-for-bit) and path collisions (two streams bound
  to the same name),
* pin the whole topology under a golden digest
  (:func:`topology_digest`) so any reordering/widening fails a test
  instead of corrupting seeded experiments silently.

Call-site contract: every ``jax.random.split`` / ``fold_in`` on the tick
path (``core/engine.py``, ``core/faults.py``, ``core/generator.py``,
``core/scheduler.py``) goes through this module with a ``names=`` /
``name=`` label.  Leaf keys are consumed directly by samplers
(``normal``/``uniform``/...), which need no wrapping — reuse is only
ever *created* at a derivation site.  This module must not import
``repro.core`` (the cores import it).
"""
from __future__ import annotations

import contextlib
import hashlib
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax

# The active recorder (trace-time only; never touched by compiled code).
_RECORDER: Optional["StreamRecorder"] = None


@dataclass
class StreamEvent:
    """One derivation: ``parent --op(arg)--> children``."""

    parent: str               # named path of the parent key
    op: str                   # "split" | "fold_in"
    arg: object               # split width / fold_in data
    children: Tuple[str, ...]  # named paths of the derived keys


@dataclass
class StreamRecorder:
    """Trace-time log of every named derivation plus the key→path map.

    Key identity is Python object identity: the recorder pins every key
    object it has named (``_keepalive``) so a recycled ``id()`` can never
    misattribute a stream within one audit.
    """

    events: List[StreamEvent] = field(default_factory=list)
    unnamed: List[str] = field(default_factory=list)
    _paths: dict = field(default_factory=dict)      # id(key) -> path
    _keepalive: list = field(default_factory=list)

    def register(self, key, path: str) -> None:
        self._paths[id(key)] = path
        self._keepalive.append(key)

    def path_of(self, key) -> Optional[str]:
        return self._paths.get(id(key))

    def _parent_path(self, key, op: str, arg) -> str:
        path = self.path_of(key)
        if path is None:
            path = f"<unnamed#{len(self.unnamed)}>"
            self.unnamed.append(f"{op}({arg!r}) off an unregistered key — "
                                "wrap the site that derived it")
        return path


class _NamedKeys:
    """Recording view of a stacked ``jax.random.split`` result.

    Indexing (including negative indices, slices, unpacking) returns the
    underlying key rows while binding each accessed child to its declared
    name, so call sites keep the exact ``keys[i]`` shape of the raw API.
    """

    __slots__ = ("_keys", "_names", "_rec", "_parent")

    def __init__(self, keys, names: Tuple[str, ...], rec: StreamRecorder,
                 parent: str):
        self._keys = keys
        self._names = names
        self._rec = rec
        self._parent = parent

    def __len__(self) -> int:
        return len(self._names)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(len(self._names))[i]]
        k = self._keys[i]
        self._rec.register(k, f"{self._parent}/{self._names[i]}")
        return k

    def __iter__(self):
        return (self[i] for i in range(len(self._names)))


def split(key, num: int = 2, *, names: Sequence[str]):
    """`jax.random.split` with named children.

    ``names`` must have exactly ``num`` entries.  Returns the raw split
    result outside an audit; inside one, a :class:`_NamedKeys` view that
    binds children to ``<parent>/<name>`` as they are indexed.
    """
    names = tuple(names)
    if len(names) != num:
        raise ValueError(
            f"split(num={num}) needs exactly {num} names, got {names!r}")
    if len(set(names)) != len(names):
        raise ValueError(f"split names must be unique, got {names!r}")
    keys = jax.random.split(key, num)
    rec = _RECORDER
    if rec is None:
        return keys
    parent = rec._parent_path(key, "split", num)
    rec.events.append(StreamEvent(parent, "split", num,
                                  tuple(f"{parent}/{n}" for n in names)))
    return _NamedKeys(keys, names, rec, parent)


def fold_in(key, data, *, name: str):
    """`jax.random.fold_in` with a named child stream."""
    child = jax.random.fold_in(key, data)
    rec = _RECORDER
    if rec is None:
        return child
    parent = rec._parent_path(key, "fold_in", data)
    path = f"{parent}/{name}"
    rec.events.append(StreamEvent(parent, "fold_in", data, (path,)))
    rec.register(child, path)
    return child


@contextlib.contextmanager
def recording():
    """Audit context: every named derivation inside is logged.

    Not reentrant (the engine has exactly one audit driver); the recorder
    is detached even on error so a failed audit can't leak trace-time
    overhead into later runs.
    """
    global _RECORDER
    if _RECORDER is not None:
        raise RuntimeError("stream recording is already active")
    rec = StreamRecorder()
    _RECORDER = rec
    try:
        yield rec
    finally:
        _RECORDER = None


# ---------------------------------------------------------------------------
# Auditing: reuse/collision detection + the golden topology digest
# ---------------------------------------------------------------------------

def audit_events(rec: StreamRecorder) -> List[str]:
    """Stream-topology violations in one recorded trace.

    * **key reuse** — two derivations with identical (parent, op, arg):
      their children are bit-identical keys feeding different consumers;
    * **path collision** — two distinct streams bound to one name (the
      digest could not tell them apart);
    * **unnamed derivation** — a `split`/`fold_in` reached through a key
      no named site produced (an unwrapped call site upstream).
    """
    problems: List[str] = []
    seen_derivations: dict = {}
    seen_paths: dict = {}
    for i, ev in enumerate(rec.events):
        sig = (ev.parent, ev.op, repr(ev.arg))
        if sig in seen_derivations:
            problems.append(
                f"key reuse: {ev.op}({ev.arg!r}) applied to "
                f"{ev.parent!r} twice (events "
                f"{seen_derivations[sig]} and {i}) — the derived keys "
                "collide bit-for-bit")
        else:
            seen_derivations[sig] = i
        for child in ev.children:
            if child in seen_paths:
                problems.append(
                    f"stream path collision: {child!r} produced by events "
                    f"{seen_paths[child]} and {i}")
            else:
                seen_paths[child] = i
    for msg in rec.unnamed:
        problems.append(f"unnamed stream: {msg}")
    return problems


def topology_lines(rec: StreamRecorder) -> List[str]:
    """Canonical one-line-per-derivation serialization, in call order —
    call order IS part of the contract (split widths and fold_in
    positions are what prefix-instability is sensitive to)."""
    return [f"{ev.parent} --{ev.op}({ev.arg!r})--> [" +
            ", ".join(n.rsplit("/", 1)[-1] for n in ev.children) + "]"
            for ev in rec.events]


def topology_digest(rec: StreamRecorder) -> str:
    """Golden digest of the stream-derivation tree (16 hex chars)."""
    blob = "\n".join(topology_lines(rec)).encode()
    return hashlib.sha256(blob).hexdigest()[:16]
