"""Deterministic, step-indexed synthetic data pipeline.

Batches are pure functions of (seed, step), so checkpoint/restart and
elastic rescaling resume *exactly*: a restored run at step k regenerates
the same batch k every time, on any host topology (each host materializes
only its shard via the sharded-device-put path in launch/train.py).

The token stream mixes Zipf-distributed unigrams with a 45 % copy rule
(x_{t+1} = x_t) — structure a model provably exploits within tens of
steps (used by examples/train_lm.py and the loss-drop test).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 3.0
    copy_p: float = 0.45

    def batch(self, step: int) -> dict:
        """Global batch for a given step (deterministic)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        B, T, V = self.global_batch, self.seq_len, self.vocab
        # Zipf-ish marginal via inverse-CDF on u^a
        u = jax.random.uniform(k1, (B, T))
        ranks = jnp.floor((V - 1) * u ** self.zipf_a).astype(jnp.int32)
        # overlay copy structure: x[t] = x[t-1] on copy_p of positions
        mask = jax.random.uniform(k2, (B, T)) < self.copy_p
        shifted = jnp.roll(ranks, 1, axis=1)
        tokens = jnp.where(mask, shifted, ranks)
        labels = jnp.roll(tokens, -1, axis=1)
        labels = labels.at[:, -1].set(-1)        # no target for last pos
        return {"tokens": tokens, "labels": labels}


def batch_for(cfg, shape, step: int = 0, seed: int = 0) -> dict:
    """Concrete batch matching launch/specs.py input_specs (smoke/train)."""
    ds = SyntheticLM(vocab=max(cfg.vocab, 2), seq_len=shape.seq_len,
                     global_batch=shape.global_batch, seed=seed)
    batch = ds.batch(step)
    if cfg.family == "vlm":
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        B, T = shape.global_batch, shape.seq_len
        batch = {
            "embeds": jax.random.normal(key, (B, T, cfg.d_model),
                                        jnp.bfloat16),
            "positions": jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[None, None], (3, B, T)),
            "labels": batch["labels"],
        }
    elif cfg.family == "encdec":
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 2), step)
        batch["frames"] = jax.random.normal(
            key, (shape.global_batch, cfg.n_frames, cfg.d_model),
            jnp.bfloat16)
    return batch
