from .synthetic import SyntheticLM, batch_for  # noqa: F401
