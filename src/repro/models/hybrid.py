"""Jamba-style hybrid: periods of (1 attention + K-1 Mamba-2) layers with
MoE FFNs on alternating layers (arXiv:2403.19887).

The model scans over *periods* (stacked period parameters), each period
unrolling its K sub-layers — compile time O(period), run depth O(L).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attn_apply, attn_decode, attn_schema, kv_cache_schema
from .common import P, abstract, apply_mlp, initialize, logical_axes, \
    mlp_schema, rmsnorm, unembed
from .mamba2 import mamba_apply, mamba_decode, mamba_schema, \
    mamba_state_schema
from .moe import moe_apply, moe_schema
from .transformer import DecodeState, _stack_schema


class HybridLM:
    """1:(K-1) attention:mamba interleave, MoE on odd in-period layers."""

    def __init__(self, cfg: ArchConfig):
        assert cfg.attn_period >= 2 and cfg.n_layers % cfg.attn_period == 0
        self.cfg = cfg
        self.period = cfg.attn_period
        self.n_periods = cfg.n_layers // cfg.attn_period
        self.n_mamba = self.period - 1
        # FFN pattern inside a period: MoE on odd local indices
        self.n_moe = self.period // 2
        self.n_dense = self.period - self.n_moe

    # ---------------- schema -------------------------------------------
    def period_schema(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        norm = lambda: P((d,), ("embed",), init="ones", dtype=jnp.float32)
        return {
            "attn_norm": norm(),
            "attn": attn_schema(d, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                                cfg.qk_norm),
            "mamba_norm": _stack_schema({"n": norm()}, self.n_mamba)["n"],
            "mamba": _stack_schema(mamba_schema(cfg.mamba), self.n_mamba),
            "ffn_norm": _stack_schema({"n": norm()}, self.period)["n"],
            "dense": _stack_schema(mlp_schema(d, cfg.d_ff), self.n_dense),
            "moe": _stack_schema(moe_schema(d, cfg.moe), self.n_moe),
        }

    def schema(self):
        cfg = self.cfg
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       init="small_normal"),
            "periods": _stack_schema(self.period_schema(), self.n_periods),
            "final_norm": P((cfg.d_model,), ("embed",), init="ones",
                            dtype=jnp.float32),
            "head": P((cfg.d_model, cfg.vocab), ("embed", "vocab")),
        }

    def abstract_params(self):
        return abstract(self.schema())

    def init_params(self, rng):
        return initialize(self.schema(), rng)

    def param_logical_axes(self):
        return logical_axes(self.schema())

    # ---------------- forward ------------------------------------------
    def _ffn(self, pp, x, local_i):
        cfg = self.cfg
        h = rmsnorm(x, jax.tree_util.tree_map(
            lambda a: a[local_i], pp["ffn_norm"]))
        if local_i % 2 == 1:
            mp = jax.tree_util.tree_map(lambda a: a[local_i // 2], pp["moe"])
            return x + moe_apply(mp, h, cfg.moe)
        dp = jax.tree_util.tree_map(lambda a: a[local_i // 2], pp["dense"])
        return x + apply_mlp(dp, h)

    def _period(self, pp, x, positions, impl=None, interpret=False):
        cfg = self.cfg
        # local layer 0: attention mixer
        h = rmsnorm(x, pp["attn_norm"])
        x = x + attn_apply(pp["attn"], h, n_heads=cfg.n_heads,
                           n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                           qk_norm=cfg.qk_norm, positions=positions,
                           rope_theta=cfg.rope_theta, impl=impl)
        x = self._ffn(pp, x, 0)
        # local layers 1..K-1: mamba mixers
        for j in range(self.n_mamba):
            mp = jax.tree_util.tree_map(lambda a: a[j], pp["mamba"])
            mn = jax.tree_util.tree_map(lambda a: a[j], pp["mamba_norm"])
            h = rmsnorm(x, mn)
            x = x + mamba_apply(mp, h, cfg.mamba, chunk=cfg.ssd_chunk,
                                interpret=interpret)
            x = self._ffn(pp, x, j + 1)
        return x

    def hidden_states(self, params, tokens=None, embeds=None,
                      positions=None, impl=None, remat=True,
                      interpret=False, unroll=False):
        x = params["embed"][tokens] if embeds is None else embeds
        B, T = x.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                         (B, T))
        fn = functools.partial(self._period, positions=positions, impl=impl,
                               interpret=interpret)
        body = (lambda pp, h: fn(pp, h))
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(lambda h, pp: (body(pp, h), None), x,
                            params["periods"],
                            unroll=self.n_periods if unroll else 1)
        return rmsnorm(x, params["final_norm"])

    def logits(self, params, hidden):
        return unembed(hidden, params["head"])

    def loss_fn(self, params, batch, impl=None, remat=True,
                interpret=False, unroll=False):
        h = self.hidden_states(params, tokens=batch["tokens"], impl=impl,
                               remat=remat, interpret=interpret,
                               unroll=unroll)
        logits = unembed(h, params["head"])
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # ---------------- decode -------------------------------------------
    def init_decode_state(self, batch: int, seq: int, abstract_only=False):
        cfg = self.cfg
        kv = kv_cache_schema(batch, cfg.n_kv, seq, cfg.head_dim)
        ms = mamba_state_schema(batch, cfg.mamba)

        def stack(n, x):
            return jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)

        per = {
            "kv": kv,
            "mamba": jax.tree_util.tree_map(
                functools.partial(stack, self.n_mamba), ms),
        }
        stacked = jax.tree_util.tree_map(
            functools.partial(stack, self.n_periods), per)
        state = DecodeState(layers=stacked,
                            pos=jax.ShapeDtypeStruct((), jnp.int32))
        if abstract_only:
            return state
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), state)

    def decode_step(self, params, tokens, state: DecodeState,
                    unroll=False):
        cfg = self.cfg
        x = params["embed"][tokens]
        pos = state.pos

        def body(h, inp):
            pp, ls = inp
            hn = rmsnorm(h, pp["attn_norm"])
            kvc = ls["kv"]._replace(pos=pos)
            out, new_kv = attn_decode(
                pp["attn"], hn, kvc, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                head_dim=cfg.head_dim, qk_norm=cfg.qk_norm,
                rope_theta=cfg.rope_theta)
            new_kv = new_kv._replace(pos=jnp.zeros((), jnp.int32))
            h = h + out
            h = self._ffn(pp, h, 0)
            new_ms = []
            for j in range(self.n_mamba):
                mp = jax.tree_util.tree_map(lambda a: a[j], pp["mamba"])
                mn = jax.tree_util.tree_map(lambda a: a[j], pp["mamba_norm"])
                msj = jax.tree_util.tree_map(lambda a: a[j], ls["mamba"])
                hn = rmsnorm(h, mn)
                out, ms_new = mamba_decode(mp, hn, msj, cfg.mamba)
                h = h + out
                h = self._ffn(pp, h, j + 1)
                new_ms.append(ms_new)
            stacked_ms = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_ms)
            return h, {"kv": new_kv, "mamba": stacked_ms}

        x, new_layers = jax.lax.scan(body, x, (params["periods"],
                                               state.layers),
                                     unroll=self.n_periods if unroll else 1)
        h = rmsnorm(x, params["final_norm"])
        return unembed(h, params["head"]), DecodeState(layers=new_layers,
                                                       pos=pos + 1)
