"""Mamba-2 block (SSD) with training scan and O(1) decode state."""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels.ssd_scan import ssd, ssd_decode_step
from .common import P, rmsnorm


class MambaDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int      # d_inner // headdim
    headdim: int
    d_state: int
    n_groups: int
    d_conv: int       # short-conv width

    @staticmethod
    def make(d_model, headdim=64, d_state=128, n_groups=1, d_conv=4,
             expand=2):
        d_inner = expand * d_model
        return MambaDims(d_model, d_inner, d_inner // headdim, headdim,
                         d_state, n_groups, d_conv)

    @property
    def conv_channels(self):
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_schema(dims: MambaDims, dtype=jnp.bfloat16) -> Dict[str, P]:
    d, di = dims.d_model, dims.d_inner
    H, N, G = dims.n_heads, dims.d_state, dims.n_groups
    proj_out = 2 * di + 2 * G * N + H          # z, x, B, C, dt
    return {
        "in_proj": P((d, proj_out), ("embed", "mlp"), dtype=dtype),
        "conv_w": P((dims.d_conv, dims.conv_channels), (None, "mlp"),
                    init="small_normal", dtype=dtype),
        "conv_b": P((dims.conv_channels,), ("mlp",), init="zeros",
                    dtype=dtype),
        "dt_bias": P((H,), (None,), init="zeros", dtype=jnp.float32),
        "A_log": P((H,), (None,), init="alog", dtype=jnp.float32),
        "D": P((H,), (None,), init="ones", dtype=jnp.float32),
        "norm": P((di,), ("mlp",), init="ones", dtype=jnp.float32),
        "out_proj": P((di, d), ("mlp", "embed"), dtype=dtype),
    }


def _split_proj(zxbcdt, dims: MambaDims):
    di, G, N, H = dims.d_inner, dims.n_groups, dims.d_state, dims.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + dims.conv_channels]
    dt = zxbcdt[..., di + dims.conv_channels:]
    assert dt.shape[-1] == H
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time; xbc [B, T, C], w [K, C]."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def mamba_apply(p, x, dims: MambaDims, chunk: int = 128, impl=None,
                interpret: bool = False):
    """Training / prefill forward. x [B, T, d] → [B, T, d]."""
    B, T, _ = x.shape
    di, G, N, H, Pd = (dims.d_inner, dims.n_groups, dims.d_state,
                       dims.n_heads, dims.headdim)
    z, xbc, dt = _split_proj(x @ p["in_proj"], dims)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(B, T, H, Pd)
    Bm = xbc[..., di:di + G * N].reshape(B, T, G, N)
    Cm = xbc[..., di + G * N:].reshape(B, T, G, N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y = ssd(xs, dtv, A, Bm, Cm, p["D"], chunk=chunk, impl=impl,
            interpret=interpret)
    y = y.reshape(B, T, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"])
    return y @ p["out_proj"]


class MambaState(NamedTuple):
    """O(1) decode state: SSD state + short-conv tail."""

    h: jnp.ndarray         # [B, H, N, P] f32
    conv: jnp.ndarray      # [B, d_conv-1, conv_channels]


def mamba_state_schema(batch: int, dims: MambaDims, dtype=jnp.bfloat16):
    return MambaState(
        h=jax.ShapeDtypeStruct(
            (batch, dims.n_heads, dims.d_state, dims.headdim), jnp.float32),
        conv=jax.ShapeDtypeStruct(
            (batch, dims.d_conv - 1, dims.conv_channels), dtype),
    )


def mamba_decode(p, x, state: MambaState, dims: MambaDims):
    """One-token decode. x [B, 1, d] → ([B, 1, d], new state)."""
    B = x.shape[0]
    di, G, N, H, Pd = (dims.d_inner, dims.n_groups, dims.d_state,
                       dims.n_heads, dims.headdim)
    z, xbc, dt = _split_proj(x @ p["in_proj"], dims)
    window = jnp.concatenate([state.conv, xbc], axis=1)     # [B, K, C]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xbc_t = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)) \
        .astype(x.dtype)
    xs = xbc_t[:, :di].reshape(B, H, Pd)
    Bm = xbc_t[:, di:di + G * N].reshape(B, G, N)
    Cm = xbc_t[:, di + G * N:].reshape(B, G, N)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h_new, y = ssd_decode_step(state.h, xs, dtv, A, Bm, Cm, p["D"])
    y = y.reshape(B, 1, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"])
    return y @ p["out_proj"], MambaState(h=h_new, conv=window[:, 1:])
