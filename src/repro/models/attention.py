"""GQA attention block: qk-norm, RoPE/M-RoPE, flash/ref dispatch, KV cache."""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from ..kernels.flash_attention import attention as flash_attention
from .common import P, apply_mrope, apply_rope, rmsnorm


def attn_schema(d: int, n_heads: int, n_kv: int, head_dim: int,
                qk_norm: bool, dtype=jnp.bfloat16) -> Dict[str, P]:
    s = {
        "wq": P((d, n_heads * head_dim), ("embed", "heads"), dtype=dtype),
        "wk": P((d, n_kv * head_dim), ("embed", "kv_heads"), dtype=dtype),
        "wv": P((d, n_kv * head_dim), ("embed", "kv_heads"), dtype=dtype),
        "wo": P((n_heads * head_dim, d), ("heads", "embed"), dtype=dtype),
    }
    if qk_norm:
        s["q_norm"] = P((head_dim,), (None,), init="ones", dtype=jnp.float32)
        s["k_norm"] = P((head_dim,), (None,), init="ones", dtype=jnp.float32)
    return s


class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, Hkv, S, Dh]
    v: jnp.ndarray
    pos: jnp.ndarray     # scalar i32 — tokens already cached


class QuantKVCache(NamedTuple):
    """§Perf: int8 KV cache — decode is cache-read-bound, so halving the
    bytes per element (2→1 + 4/Dh scale) halves the dominant memory term.
    Per-position symmetric scales keep the quantization error local."""

    k: jnp.ndarray        # [B, Hkv, S, Dh] int8
    v: jnp.ndarray
    k_scale: jnp.ndarray  # [B, Hkv, S] f32
    v_scale: jnp.ndarray
    pos: jnp.ndarray


def _quant(x):
    """[..., Dh] bf16/f32 → (int8, f32 scale over the last dim)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    q = jnp.round(xf / jnp.maximum(scale[..., None], 1e-9))
    return q.astype(jnp.int8), scale


def _project(p, x, n_heads, n_kv, head_dim, qk_norm, positions,
             mrope_sections=None, rope_theta=1e6):
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, T, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, T, n_kv, head_dim)
    if qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if positions is not None:
        if mrope_sections is not None:
            q = apply_mrope(q, positions, mrope_sections, rope_theta)
            k = apply_mrope(k, positions, mrope_sections, rope_theta)
        else:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attn_apply(p, x, *, n_heads, n_kv, head_dim, qk_norm=False,
               positions=None, mrope_sections=None, rope_theta=1e6,
               causal=True, impl=None, kv: Optional[jnp.ndarray] = None,
               attn_impl: str = "grouped"):
    """Full-sequence attention (training / prefill).

    ``kv``: optional external K/V source sequence (cross-attention) given
    as an [B, Tkv, d] tensor — projected with this block's wk/wv.
    ``attn_impl``: sharding formulation (see ArchConfig.attn_impl).
    """
    B, T, _ = x.shape
    if kv is None:
        q, k, v = _project(p, x, n_heads, n_kv, head_dim, qk_norm,
                           positions, mrope_sections, rope_theta)
    else:
        q, _, _ = _project(p, x, n_heads, n_kv, head_dim, qk_norm,
                           positions, mrope_sections, rope_theta)
        Tk = kv.shape[1]
        k = (kv @ p["wk"]).reshape(B, Tk, n_kv, head_dim)
        v = (kv @ p["wv"]).reshape(B, Tk, n_kv, head_dim)
        if qk_norm:
            k = rmsnorm(k, p["k_norm"])
        causal = False
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if attn_impl in ("flat", "flat_seqshard") and n_kv < n_heads:
        # §Perf: the grouped einsum caps head sharding at n_kv; repeating
        # K/V to Hq flat heads restores n_heads-way parallelism.
        g = n_heads // n_kv
        kt = jnp.repeat(kt, g, axis=1)
        vt = jnp.repeat(vt, g, axis=1)
    if attn_impl == "flat_seqshard":
        # §Perf: context parallelism — shard the QUERY sequence over the
        # model axis; every head count divides, and the S² logits tensor
        # is 1/model-axis per device.  K/V stay replicated across model
        # (gathered once; small next to the S² compute).
        qt = jax.lax.with_sharding_constraint(
            qt, PartitionSpec("data", None, "model", None))
    out = flash_attention(qt, kt, vt, causal=causal, impl=impl)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, n_heads * head_dim)
    return out @ p["wo"]


def attn_decode(p, x, cache, *, n_heads, n_kv, head_dim,
                qk_norm=False, mrope_sections=None, rope_theta=1e6):
    """One-token decode against a fixed-capacity KV cache.

    x [B, 1, d].  The cache holds S slots; ``cache.pos`` tokens are valid.
    Accepts KVCache (bf16) or QuantKVCache (int8 + scales).
    Returns (out [B, 1, d], new cache).
    """
    B, T, _ = x.shape
    assert T == 1
    S = cache.k.shape[2]
    pos = cache.pos
    quant = isinstance(cache, QuantKVCache)
    positions = jnp.full((B, 1), pos, jnp.int32)
    if mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k, v = _project(p, x, n_heads, n_kv, head_dim, qk_norm,
                       positions, mrope_sections, rope_theta)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if quant:
        kq, ks = _quant(kt)
        vq, vs = _quant(vt)
        k_cache = jax.lax.dynamic_update_slice(cache.k, kq, (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, vq, (0, 0, pos, 0))
        k_sc = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, 0, pos))
        v_sc = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, 0, pos))
        k_read = k_cache.astype(jnp.float32) * k_sc[..., None]
        v_read = v_cache.astype(jnp.float32) * v_sc[..., None]
        new_cache = QuantKVCache(k=k_cache, v=v_cache, k_scale=k_sc,
                                 v_scale=v_sc, pos=pos + 1)
    else:
        k_cache = jax.lax.dynamic_update_slice(cache.k, kt, (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, vt, (0, 0, pos, 0))
        k_read = k_cache.astype(jnp.float32)
        v_read = v_cache.astype(jnp.float32)
        new_cache = KVCache(k=k_cache, v=v_cache, pos=pos + 1)

    qt = q.transpose(0, 2, 1, 3)                       # [B, H, 1, Dh]
    g = n_heads // n_kv
    qg = qt.reshape(B, n_kv, g, 1, head_dim).astype(jnp.float32)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg, k_read) \
        * head_dim ** -0.5
    valid = jnp.arange(S)[None, None, None, None, :] <= pos
    logits = jnp.where(valid, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v_read)
    out = out.reshape(B, n_heads, 1, head_dim).transpose(0, 2, 1, 3) \
        .reshape(B, 1, n_heads * head_dim).astype(x.dtype)
    return out @ p["wo"], new_cache


def kv_cache_schema(batch: int, n_kv: int, seq: int, head_dim: int,
                    dtype=jnp.bfloat16, quant: bool = False):
    """Abstract KV cache (dry-run input_specs for decode shapes)."""
    if quant:
        return QuantKVCache(
            k=jax.ShapeDtypeStruct((batch, n_kv, seq, head_dim), jnp.int8),
            v=jax.ShapeDtypeStruct((batch, n_kv, seq, head_dim), jnp.int8),
            k_scale=jax.ShapeDtypeStruct((batch, n_kv, seq), jnp.float32),
            v_scale=jax.ShapeDtypeStruct((batch, n_kv, seq), jnp.float32),
            pos=jax.ShapeDtypeStruct((), jnp.int32),
        )
    return KVCache(
        k=jax.ShapeDtypeStruct((batch, n_kv, seq, head_dim), dtype),
        v=jax.ShapeDtypeStruct((batch, n_kv, seq, head_dim), dtype),
        pos=jax.ShapeDtypeStruct((), jnp.int32),
    )
