"""Mixture-of-Experts MLP: top-k routing, sort-based dropless-ish dispatch.

Dispatch strategy (compile-friendly and EP-shardable): flatten the
(token, k) assignments, argsort by expert id, slice each expert's segment
into a fixed-capacity buffer, run one batched per-expert matmul
(``ecd,edf->ecf`` — MXU shaped, expert dim sharded over the ``model``
axis), and scatter-add the weighted outputs back.  Tokens beyond an
expert's capacity are dropped (their router weight simply contributes
nothing), with capacity_factor controlling the drop rate — the standard
Switch/GShard contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .common import P, apply_mlp, mlp_schema


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN width
    n_shared: int = 0          # shared-expert count (Qwen2-MoE style)
    d_shared: int = 0          # shared-expert FFN width (total)
    capacity_factor: float = 1.25
    norm_topk: bool = True


def moe_schema(d: int, cfg: MoECfg, dtype=jnp.bfloat16) -> Dict[str, P]:
    E, f = cfg.n_experts, cfg.d_expert
    s = {
        "router": P((d, E), ("embed", None), init="small_normal",
                    dtype=jnp.float32),
        "gate": P((E, d, f), ("experts", "embed", "mlp"), dtype=dtype),
        "up": P((E, d, f), ("experts", "embed", "mlp"), dtype=dtype),
        "down": P((E, f, d), ("experts", "mlp", "embed"), dtype=dtype),
    }
    if cfg.n_shared:
        s["shared"] = mlp_schema(d, cfg.d_shared, dtype)
        s["shared_gate"] = P((d, 1), ("embed", None), init="small_normal",
                             dtype=jnp.float32)
    return s


def moe_apply(p, x, cfg: MoECfg):
    """x [B, T, d] → [B, T, d]."""
    B, T, d = x.shape
    n_tok = B * T
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(n_tok, d)

    logits = xf.astype(jnp.float32) @ p["router"]          # [n, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                 # [n, K]
    if cfg.norm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- sort-based dispatch -----------------------------------------
    flat_e = top_e.reshape(-1)                             # [n·K]
    flat_t = jnp.repeat(jnp.arange(n_tok, dtype=jnp.int32), K)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sp = flat_e[order], flat_t[order], flat_p[order]

    cap = int(max(1, -(-n_tok * K * cfg.capacity_factor // E)))
    counts = jnp.bincount(se, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    # position of each sorted assignment within its expert segment
    pos_in_e = jnp.arange(n_tok * K, dtype=jnp.int32) - offsets[se]
    keep = pos_in_e < cap

    # gather tokens into [E, cap, d]
    slot = jnp.where(keep, se * cap + pos_in_e, E * cap)   # sentinel drop
    tok_of_slot = jnp.full((E * cap + 1,), 0, jnp.int32).at[slot].set(
        st_, mode="drop")
    w_of_slot = jnp.zeros((E * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sp, 0.0), mode="drop")
    live = jnp.zeros((E * cap + 1,), bool).at[slot].set(
        keep, mode="drop")
    tok_of_slot, w_of_slot, live = (a[:-1] for a in
                                    (tok_of_slot, w_of_slot, live))
    xe = jnp.where(live[:, None], xf[tok_of_slot], 0).reshape(E, cap, d)

    # ---- batched per-expert FFN (expert dim sharded on `model`) -------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"]).reshape(E * cap, d)

    # ---- weighted scatter-add back to tokens ---------------------------
    contrib = ye.astype(jnp.float32) * w_of_slot[:, None]
    out = jnp.zeros((n_tok, d), jnp.float32).at[
        jnp.where(live, tok_of_slot, n_tok)].add(contrib, mode="drop")

    if cfg.n_shared:
        sg = jax.nn.sigmoid(xf.astype(jnp.float32) @ p["shared_gate"])
        out = out + sg * apply_mlp(p["shared"], xf).astype(jnp.float32)
    return out.reshape(B, T, d).astype(x.dtype)
