"""Unified decoder-only LM covering dense / GQA / MoE / SSM / VLM families.

Layer structure: pre-norm mixer (attention or Mamba-2) + optional pre-norm
FFN (dense SwiGLU or MoE).  Layers are *scanned* over stacked parameters,
so compile time is O(1) in depth — essential for 40-cell dry-runs of
52-layer models on a CPU host.

The VLM/audio variants consume precomputed frontend embeddings (stub
frontend per the assignment); text decode goes through the embedding table.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (attn_apply, attn_decode, attn_schema,
                        kv_cache_schema)
from .common import (P, abstract, apply_mlp, initialize, logical_axes,
                     mlp_schema, rmsnorm, unembed)
from .mamba2 import (mamba_apply, mamba_decode, mamba_schema,
                     mamba_state_schema)
from .moe import moe_apply, moe_schema


def _stack_schema(schema, n: int):
    """Prepend a layer axis to every parameter of a per-layer schema."""
    return jax.tree_util.tree_map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale,
                    p.dtype),
        schema, is_leaf=lambda x: isinstance(x, P))


class DecodeState(NamedTuple):
    layers: Any              # stacked per-layer KVCache or MambaState
    pos: jnp.ndarray         # scalar i32


class LM:
    """Decoder-only language model (family chosen by ArchConfig)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.is_mamba = cfg.family == "ssm"
        self.is_moe = cfg.moe is not None
        self.takes_embeds = cfg.family in ("vlm",)

    # ---------------- schema -------------------------------------------
    def layer_schema(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {"mixer_norm": P((cfg.d_model,), ("embed",),
                                             init="ones", dtype=jnp.float32)}
        if self.is_mamba:
            s["mamba"] = mamba_schema(cfg.mamba)
        else:
            s["attn"] = attn_schema(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                    cfg.head_dim, cfg.qk_norm)
        if not self.is_mamba:
            s["mlp_norm"] = P((cfg.d_model,), ("embed",), init="ones",
                              dtype=jnp.float32)
            if self.is_moe:
                s["moe"] = moe_schema(cfg.d_model, cfg.moe)
            else:
                s["mlp"] = mlp_schema(cfg.d_model, cfg.d_ff)
        return s

    def schema(self) -> Dict[str, Any]:
        cfg = self.cfg
        s = {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       init="small_normal"),
            "layers": _stack_schema(self.layer_schema(), cfg.n_layers),
            "final_norm": P((cfg.d_model,), ("embed",), init="ones",
                            dtype=jnp.float32),
        }
        if not cfg.tie_embeddings:
            s["head"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        return s

    def abstract_params(self):
        return abstract(self.schema())

    def init_params(self, rng):
        return initialize(self.schema(), rng)

    def param_logical_axes(self):
        return logical_axes(self.schema())

    # ---------------- forward ------------------------------------------
    def _block(self, lp, x, positions, impl=None, interpret=False):
        cfg = self.cfg
        h = rmsnorm(x, lp["mixer_norm"])
        if self.is_mamba:
            x = x + mamba_apply(lp["mamba"], h, cfg.mamba,
                                chunk=cfg.ssd_chunk, interpret=interpret)
        else:
            x = x + attn_apply(
                lp["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                head_dim=cfg.head_dim, qk_norm=cfg.qk_norm,
                positions=positions, mrope_sections=cfg.mrope_sections,
                rope_theta=cfg.rope_theta, impl=impl,
                attn_impl=cfg.attn_impl)
            h2 = rmsnorm(x, lp["mlp_norm"])
            if self.is_moe:
                x = x + moe_apply(lp["moe"], h2, cfg.moe)
            else:
                x = x + apply_mlp(lp["mlp"], h2)
        return x

    def hidden_states(self, params, tokens=None, embeds=None,
                      positions=None, impl=None, remat=True,
                      interpret=False, unroll=False):
        cfg = self.cfg
        if embeds is None:
            x = params["embed"][tokens]
        else:
            x = embeds.astype(params["embed"].dtype)
        B, T = x.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32),
                                         (B, T))
            if cfg.mrope_sections is not None:
                positions = jnp.broadcast_to(positions[None], (3, B, T))

        block = functools.partial(self._block, positions=positions,
                                  impl=impl, interpret=interpret)
        fn = (lambda lp, h: block(lp, h))
        if remat:
            fn = jax.checkpoint(fn)

        def scan_body(h, lp):
            return fn(lp, h), None

        # unroll=True is used by the dry-run's cost-calibration compiles:
        # XLA's cost_analysis counts a while-loop body once, so per-layer
        # costs are measured on fully-unrolled 1- and 2-layer variants.
        x, _ = jax.lax.scan(scan_body, x, params["layers"],
                            unroll=self.cfg.n_layers if unroll else 1)
        return rmsnorm(x, params["final_norm"])

    def logits(self, params, hidden):
        head = params.get("head")
        if head is None:
            return unembed(hidden, params["embed"].T)
        return unembed(hidden, head)

    def loss_fn(self, params, batch, impl=None, remat=True,
                interpret=False, unroll=False):
        """Causal-LM cross entropy; labels < 0 are masked."""
        h = self.hidden_states(
            params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), impl=impl, remat=remat,
            interpret=interpret, unroll=unroll)
        logits = self.logits(params, h)            # f32 [B, T, V]
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # ---------------- decode -------------------------------------------
    def init_decode_state(self, batch: int, seq: int, abstract_only=False):
        cfg = self.cfg
        if self.is_mamba:
            one = mamba_state_schema(batch, cfg.mamba)
        else:
            one = kv_cache_schema(batch, cfg.n_kv, seq, cfg.head_dim,
                                  quant=cfg.kv_dtype == "int8")

        def stack(x):
            return jax.ShapeDtypeStruct((cfg.n_layers,) + x.shape, x.dtype)

        stacked = jax.tree_util.tree_map(stack, one)
        state = DecodeState(layers=stacked,
                            pos=jax.ShapeDtypeStruct((), jnp.int32))
        if abstract_only:
            return state
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), state)

    def decode_step(self, params, tokens, state: DecodeState,
                    unroll=False):
        """tokens [B, 1] → (logits [B, 1, V], new state)."""
        cfg = self.cfg
        x = params["embed"][tokens]

        def body(h, inp):
            lp, ls = inp
            hn = rmsnorm(h, lp["mixer_norm"])
            if self.is_mamba:
                out, new_ls = mamba_decode(lp["mamba"], hn, ls, cfg.mamba)
                h = h + out
            else:
                ls = ls._replace(pos=state.pos)
                out, new_ls = attn_decode(
                    lp["attn"], hn, ls, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                    head_dim=cfg.head_dim, qk_norm=cfg.qk_norm,
                    mrope_sections=cfg.mrope_sections,
                    rope_theta=cfg.rope_theta)
                new_ls = new_ls._replace(pos=jnp.zeros((), jnp.int32))
                h = h + out
                h2 = rmsnorm(h, lp["mlp_norm"])
                if self.is_moe:
                    h = h + moe_apply(lp["moe"], h2, cfg.moe)
                else:
                    h = h + apply_mlp(lp["mlp"], h2)
            return h, new_ls

        x, new_layers = jax.lax.scan(body, x, (params["layers"],
                                               state.layers),
                                     unroll=cfg.n_layers if unroll else 1)
        h = rmsnorm(x, params["final_norm"])
        return self.logits(params, h), DecodeState(layers=new_layers,
                                                   pos=state.pos + 1)
