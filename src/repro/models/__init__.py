"""Model zoo: build any assigned architecture from its ArchConfig."""
from __future__ import annotations


def build_model(cfg):
    # local imports: configs.base imports models.mamba2/moe for the dims
    # dataclasses, so the family modules must load lazily here.
    if cfg.family == "encdec":
        from .encdec import EncDec
        return EncDec(cfg)
    if cfg.family == "hybrid":
        from .hybrid import HybridLM
        return HybridLM(cfg)
    from .transformer import LM
    return LM(cfg)
