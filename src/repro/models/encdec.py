"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Encoder consumes precomputed frame embeddings (the assignment's stub
frontend), adds sinusoidal positions, and runs non-causal self-attention
blocks.  The decoder is a causal LM with cross-attention into the encoder
output.  Decode shapes lower the decoder step with a self-attn KV cache of
seq_len plus the (precomputed) cross-attention K/V.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (attn_apply, attn_decode, attn_schema,
                        kv_cache_schema)
from .common import P, abstract, apply_mlp, initialize, logical_axes, \
    mlp_schema, rmsnorm, sinusoid_positions, unembed
from .transformer import _stack_schema


class EncDecState(NamedTuple):
    self_kv: Any            # stacked per-layer KVCache over decoder seq
    cross_kv: Any           # stacked per-layer (k, v) over encoder frames
    pos: jnp.ndarray


class EncDec:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---------------- schema -------------------------------------------
    def _enc_layer(self):
        cfg = self.cfg
        d = cfg.d_model
        return {
            "norm1": P((d,), ("embed",), init="ones", dtype=jnp.float32),
            "attn": attn_schema(d, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                                cfg.qk_norm),
            "norm2": P((d,), ("embed",), init="ones", dtype=jnp.float32),
            "mlp": mlp_schema(d, cfg.d_ff),
        }

    def _dec_layer(self):
        cfg = self.cfg
        d = cfg.d_model
        return {
            "norm1": P((d,), ("embed",), init="ones", dtype=jnp.float32),
            "self_attn": attn_schema(d, cfg.n_heads, cfg.n_kv, cfg.head_dim,
                                     cfg.qk_norm),
            "norm2": P((d,), ("embed",), init="ones", dtype=jnp.float32),
            "cross_attn": attn_schema(d, cfg.n_heads, cfg.n_kv,
                                      cfg.head_dim, cfg.qk_norm),
            "norm3": P((d,), ("embed",), init="ones", dtype=jnp.float32),
            "mlp": mlp_schema(d, cfg.d_ff),
        }

    def schema(self):
        cfg = self.cfg
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       init="small_normal"),
            "enc_layers": _stack_schema(self._enc_layer(), cfg.n_enc_layers),
            "enc_norm": P((cfg.d_model,), ("embed",), init="ones",
                          dtype=jnp.float32),
            "dec_layers": _stack_schema(self._dec_layer(), cfg.n_layers),
            "dec_norm": P((cfg.d_model,), ("embed",), init="ones",
                          dtype=jnp.float32),
        }

    def abstract_params(self):
        return abstract(self.schema())

    def init_params(self, rng):
        return initialize(self.schema(), rng)

    def param_logical_axes(self):
        return logical_axes(self.schema())

    # ---------------- encoder ------------------------------------------
    def encode(self, params, frames, impl=None, remat=True, unroll=False):
        cfg = self.cfg
        T = frames.shape[1]
        x = frames.astype(jnp.bfloat16) + \
            sinusoid_positions(T, cfg.d_model).astype(jnp.bfloat16)[None]

        def block(lp, h):
            a = attn_apply(lp["attn"], rmsnorm(h, lp["norm1"]),
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                           head_dim=cfg.head_dim, causal=False,
                           positions=None, impl=impl)
            h = h + a
            return h + apply_mlp(lp["mlp"], rmsnorm(h, lp["norm2"]))

        fn = jax.checkpoint(block) if remat else block
        x, _ = jax.lax.scan(lambda h, lp: (fn(lp, h), None), x,
                            params["enc_layers"],
                            unroll=cfg.n_enc_layers if unroll else 1)
        return rmsnorm(x, params["enc_norm"])

    # ---------------- decoder ------------------------------------------
    def decode_train(self, params, tokens, enc_out, impl=None, remat=True,
                     unroll=False):
        cfg = self.cfg
        x = params["embed"][tokens]
        B, T = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        def block(lp, h):
            a = attn_apply(lp["self_attn"], rmsnorm(h, lp["norm1"]),
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                           head_dim=cfg.head_dim, causal=True,
                           positions=positions,
                           rope_theta=cfg.rope_theta, impl=impl)
            h = h + a
            c = attn_apply(lp["cross_attn"], rmsnorm(h, lp["norm2"]),
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                           head_dim=cfg.head_dim, positions=None,
                           kv=enc_out, impl=impl)
            h = h + c
            return h + apply_mlp(lp["mlp"], rmsnorm(h, lp["norm3"]))

        fn = jax.checkpoint(block) if remat else block
        x, _ = jax.lax.scan(lambda h, lp: (fn(lp, h), None), x,
                            params["dec_layers"],
                            unroll=cfg.n_layers if unroll else 1)
        return rmsnorm(x, params["dec_norm"])

    def loss_fn(self, params, batch, impl=None, remat=True,
                interpret=False, unroll=False):
        enc_out = self.encode(params, batch["frames"], impl=impl,
                              remat=remat, unroll=unroll)
        h = self.decode_train(params, batch["tokens"], enc_out, impl=impl,
                              remat=remat, unroll=unroll)
        logits = unembed(h, params["embed"].T)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # ---------------- serving ------------------------------------------
    def init_decode_state(self, batch: int, seq: int, abstract_only=False):
        cfg = self.cfg
        kv = kv_cache_schema(batch, cfg.n_kv, seq, cfg.head_dim)
        cross = {
            "k": jax.ShapeDtypeStruct(
                (batch, cfg.n_kv, cfg.n_frames, cfg.head_dim), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(
                (batch, cfg.n_kv, cfg.n_frames, cfg.head_dim), jnp.bfloat16),
        }

        def stack(x):
            return jax.ShapeDtypeStruct((cfg.n_layers,) + x.shape, x.dtype)

        state = EncDecState(
            self_kv=jax.tree_util.tree_map(stack, kv),
            cross_kv=jax.tree_util.tree_map(stack, cross),
            pos=jax.ShapeDtypeStruct((), jnp.int32))
        if abstract_only:
            return state
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), state)

    def decode_step(self, params, tokens, state: EncDecState,
                    unroll=False):
        cfg = self.cfg
        x = params["embed"][tokens]
        pos = state.pos

        def body(h, inp):
            lp, kvc, cross = inp
            kvc = kvc._replace(pos=pos)
            out, new_kv = attn_decode(
                lp["self_attn"], rmsnorm(h, lp["norm1"]),
                kvc, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)
            new_kv = new_kv._replace(pos=jnp.zeros((), jnp.int32))
            h = h + out
            # cross attention against precomputed encoder K/V
            B = h.shape[0]
            hq = rmsnorm(h, lp["norm2"])
            q = (hq @ lp["cross_attn"]["wq"]).reshape(
                B, 1, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            g = cfg.n_heads // cfg.n_kv
            qg = q.reshape(B, cfg.n_kv, g, 1, cfg.head_dim) \
                .astype(jnp.float32)
            logits = jnp.einsum("bkgqd,bksd->bkgqs", qg,
                                cross["k"].astype(jnp.float32)) \
                * cfg.head_dim ** -0.5
            w = jax.nn.softmax(logits, axis=-1)
            c = jnp.einsum("bkgqs,bksd->bkgqd", w,
                           cross["v"].astype(jnp.float32))
            c = c.reshape(B, cfg.n_heads, 1, cfg.head_dim) \
                .transpose(0, 2, 1, 3).reshape(B, 1,
                                               cfg.n_heads * cfg.head_dim)
            h = h + c.astype(h.dtype) @ lp["cross_attn"]["wo"]
            h = h + apply_mlp(lp["mlp"], rmsnorm(h, lp["norm3"]))
            return h, new_kv

        x, new_kv = jax.lax.scan(
            body, x, (params["dec_layers"], state.self_kv, state.cross_kv),
            unroll=cfg.n_layers if unroll else 1)
        h = rmsnorm(x, params["dec_norm"])
        logits = unembed(h, params["embed"].T)
        return logits, EncDecState(self_kv=new_kv, cross_kv=state.cross_kv,
                                   pos=pos + 1)
