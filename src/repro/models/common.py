"""Model-zoo foundations: declarative parameter schemas + shared layers.

Every parameter is declared once as a :class:`P` (shape, logical axes,
init) inside a schema tree; from that single source we derive
  * ``abstract(schema)``   — ShapeDtypeStructs for the dry-run (no alloc),
  * ``initialize(schema)`` — materialized arrays for smoke tests/training,
  * ``logical_axes(schema)`` — the logical-axis tree consumed by
    ``repro.dist.sharding`` to build NamedShardings.

Logical axis vocabulary (mapped to mesh axes in dist/sharding.py):
  batch seq embed heads kv_heads mlp experts vocab state conv frames
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter declaration."""

    shape: tuple
    axes: tuple              # logical axis name (or None) per dim
    init: str = "normal"     # normal | zeros | ones | small_normal | alog
    scale: float | None = None
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def tree_map_schema(f: Callable[[P], Any], schema) -> Any:
    return jax.tree_util.tree_map(
        f, schema, is_leaf=lambda x: isinstance(x, P))


def abstract(schema):
    return tree_map_schema(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), schema)


def logical_axes(schema):
    return tree_map_schema(lambda p: p.axes, schema)


def n_params(schema) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree_map_schema(lambda p: int(np.prod(p.shape)), schema))
    return int(sum(leaves))


def initialize(schema, rng) -> Any:
    flat, treedef = jax.tree_util.tree_flatten(
        schema, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(rng, len(flat))

    def one(p: P, key):
        if p.init == "zeros":
            return jnp.zeros(p.shape, p.dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, p.dtype)
        if p.init == "alog":       # mamba A_log: log of uniform [1, 16]
            u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(p.dtype)
        scale = p.scale if p.scale is not None else p.shape[-1] ** -0.5
        if p.init == "small_normal":
            scale = 0.02
        return (jax.random.normal(key, p.shape, jnp.float32)
                * scale).astype(p.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(p, k) for p, k in zip(flat, keys)])


# ===========================================================================
# Shared layers (pure functions over param dicts; f32 math, bf16 storage)
# ===========================================================================

def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu(x @ w_up + b_up)
    return h @ w_down + b_down


def mlp_schema(d: int, f: int, dtype=jnp.bfloat16) -> Dict[str, P]:
    return {
        "gate": P((d, f), ("embed", "mlp"), dtype=dtype),
        "up": P((d, f), ("embed", "mlp"), dtype=dtype),
        "down": P((f, d), ("mlp", "embed"), dtype=dtype),
    }


def apply_mlp(p, x):
    return swiglu(x, p["gate"], p["up"], p["down"])


# --------------------------------------------------------------------------
# RoPE (standard + multimodal M-RoPE of Qwen2-VL)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e6):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 1e6):
    """x [B, T, H, Dh]; positions [B, T] int32."""
    Dh = x.shape[-1]
    inv = jnp.asarray(rope_freqs(Dh, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * inv      # [B,T,Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 1e6):
    """Qwen2-VL M-RoPE: positions3 [3, B, T] (t/h/w); ``sections`` is the
    per-modality split of the Dh/2 frequency bands (e.g. (16, 24, 24))."""
    Dh = x.shape[-1]
    inv = jnp.asarray(rope_freqs(Dh, theta), jnp.float32)      # [Dh/2]
    ang_tmw = positions3.astype(jnp.float32)[..., None] * inv  # [3,B,T,Dh/2]
    sel = np.zeros((Dh // 2,), np.int32)
    off = 0
    for i, s in enumerate(sections):
        sel[off:off + s] = i
        off += s
    assert off == Dh // 2, (sections, Dh)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_tmw, 0, -1), jnp.asarray(sel)[None, None, :, None],
        axis=-1)[..., 0]                                       # [B,T,Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoid_positions(t: int, d: int):
    pos = np.arange(t)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / (10000 ** (dim / d))
    out = np.zeros((t, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def unembed(x, emb_or_head):
    """Logits in f32 (loss stability)."""
    return (x.astype(jnp.float32)
            @ emb_or_head.astype(jnp.float32))
