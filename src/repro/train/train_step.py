"""The jitted training step: loss → grads → (optional compression) → AdamW.

``make_train_step`` builds the function the multi-pod dry-run lowers: it
closes over the model and optimizer config, takes (params, opt_state,
batch) and returns updated state + metrics.  Gradient compression (int8 +
error feedback, dist/compression.py) is a static toggle modelling the
cross-pod bandwidth optimization — under SPMD the quantize/dequantize
brackets the gradient all-reduce so the cross-pod traffic is 1/4 width.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..dist.compression import compress_decompress
from .optimizer import AdamWCfg, AdamWState, adamw_update


def make_train_step(model, opt_cfg: AdamWCfg,
                    compress_grads: bool = False,
                    impl: Optional[str] = None,
                    remat: bool = True, unroll: bool = False) -> Callable:
    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, impl=impl, remat=remat,
                                    unroll=unroll)
        )(params)
        if compress_grads:
            grads = jax.tree_util.tree_map(compress_decompress, grads)
        new_params, new_state, stats = adamw_update(params, grads,
                                                    opt_state, opt_cfg)
        metrics = {"loss": loss, **stats}
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model, impl=None) -> Callable:
    def eval_step(params, batch):
        return model.loss_fn(params, batch, impl=impl, remat=False)

    return eval_step
