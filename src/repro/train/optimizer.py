"""AdamW implemented from scratch (no optax) with mixed precision.

Parameters are stored bf16; first/second moments are f32 and follow the
parameter sharding exactly (ZeRO-1: the optimizer state inherits the fsdp
layout because it is tree-mapped from the abstract params).  The update
runs in f32 and casts back to the parameter dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any            # f32 tree like params
    nu: Any            # f32 tree like params


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def abstract_opt_state(abstract_params) -> AdamWState:
    z = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
        abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z)


def lr_schedule(step, cfg: AdamWCfg):
    """Linear warmup → cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), grads), \
        gnorm


def adamw_update(params, grads, state: AdamWState, cfg: AdamWCfg):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * gf
        nu_n = b2 * nu + (1 - b2) * gf * gf
        mhat = mu_n / bc1
        vhat = nu_n / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu_n, nu_n

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), \
        {"lr": lr, "grad_norm": gnorm}
