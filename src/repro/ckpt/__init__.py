from .checkpoint import CheckpointManager, load_checkpoint, save_checkpoint  # noqa: F401
from .elastic import reshard_tree  # noqa: F401
