from .checkpoint import (CheckpointManager,  # noqa: F401
                         load_checkpoint, save_checkpoint)  # noqa: F401
from .elastic import reshard_tree  # noqa: F401
