"""Elastic rescale: re-lay a checkpointed state onto a different mesh.

After a node failure (or a capacity change) the job restarts with a new
``make_production_mesh`` (fewer/more pods).  Because checkpoints are
host-side full arrays and shardings are *derived* from the logical-axis
rules against whatever mesh is current, resharding is one
``jax.device_put`` per leaf — the divisibility guards in dist/sharding.py
re-resolve every rule for the new axis sizes (e.g. batch 256: 32-way on
2 pods → 16-way on 1 pod).
"""
from __future__ import annotations

import jax

from ..dist import sharding as shd


def reshard_tree(host_tree, mesh, logical_tree,
                 rules=shd.PARAM_RULES):
    """Place a host-side tree onto ``mesh`` per the logical-axis rules."""
    abstract = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), host_tree)
    shards = shd.tree_shardings(mesh, abstract, logical_tree, rules)
    return jax.tree_util.tree_map(jax.device_put, host_tree, shards)


def simulate_failure_and_rescale(state_tree, old_mesh, new_mesh,
                                 logical_tree):
    """Round-trip: gather from the (failing) old mesh, re-place on the new.

    In production the gather comes from the last checkpoint instead of the
    live mesh; the placement path is identical.
    """
    host = jax.device_get(state_tree)
    return reshard_tree(host, new_mesh, logical_tree)
