"""Checkpoint/restart without orbax: flat-key npz + manifest, async save.

Fault-tolerance contract (exercised in tests/test_checkpoint.py):
  * ``save_checkpoint`` writes params/opt-state/step atomically
    (tmp file + rename) so a crash mid-save never corrupts the latest
    checkpoint;
  * ``CheckpointManager`` keeps the last k checkpoints, saves on a
    background thread (compute continues), and ``restore_latest`` +
    the step-indexed data pipeline resume training bit-exactly;
  * restore accepts a *different* mesh via ckpt/elastic.py (elastic
    rescale after node failure: N pods → M pods).
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            out[key + "::bf16"] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def save_checkpoint(path, tree, step: int, extra: Optional[dict] = None):
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, str(path))
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    manifest = {"step": int(step), "file": path.name,
                "extra": extra or {}}
    mpath = path.parent / (path.stem + ".json")
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, str(mpath))


def load_checkpoint(path, like) -> Any:
    """Restore into the structure of ``like`` (tree of arrays/SDS)."""
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    flat = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for pathk, leaf in flat:
        key = jax.tree_util.keystr(pathk)
        if key + "::bf16" in data:
            arr = data[key + "::bf16"].view(jax.numpy.bfloat16)
        elif key in data:
            arr = data[key]
        else:
            raise KeyError(f"checkpoint missing {key}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"model shape {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Rolling async checkpointing (keep-last-k)."""

    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def _prune(self):
        ckpts = sorted(self.dir.glob("step_*.npz"))
        for old in ckpts[:-self.keep]:
            old.unlink(missing_ok=True)
            old.with_suffix(".json").unlink(missing_ok=True)

    def save(self, tree, step: int, blocking: bool = False):
        # materialize on host BEFORE handing to the thread (device buffers
        # may be donated by the next step)
        host_tree = jax.tree_util.tree_map(np.asarray, jax.device_get(tree))
        path = self.dir / f"step_{step:08d}.npz"

        def work():
            save_checkpoint(path, host_tree, step)
            self._prune()

        self.wait()
        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest(self) -> Tuple[Optional[pathlib.Path], int]:
        self.wait()
        ckpts = sorted(self.dir.glob("step_*.json"))
        if not ckpts:
            return None, -1
        manifest = json.loads(ckpts[-1].read_text())
        return self.dir / manifest["file"], manifest["step"]

    def restore_latest(self, like):
        path, step = self.latest()
        if path is None:
            return None, -1
        return load_checkpoint(path, like), step
