"""Input stand-ins + step builders for every (arch × shape) dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input (no device allocation); ``build_step`` pairs them with
the function the cell lowers:

  train_*   → full ``train_step`` (fwd + bwd + AdamW update)
  prefill_* → forward logits of the last position
  decode_*  → one-token ``serve_step`` against a seq_len KV cache/SSM state

and the matching NamedShardings from the logical-axis rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCfg
from ..dist import sharding as shd
from ..models import build_model
from ..models.attention import KVCache, QuantKVCache
from ..models.mamba2 import MambaState
from ..train.optimizer import AdamWCfg, abstract_opt_state
from ..train.train_step import make_train_step

i32 = jnp.int32
bf16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ===========================================================================
# Batch specs (train / prefill)
# ===========================================================================

def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        specs = {
            "embeds": _sds((B, S, cfg.d_model), bf16),
            "positions": _sds((3, B, S), i32),
            "labels": _sds((B, S), i32),
        }
    elif cfg.family == "encdec":
        specs = {
            "frames": _sds((B, cfg.n_frames, cfg.d_model), bf16),
            "tokens": _sds((B, S), i32),
            "labels": _sds((B, S), i32),
        }
    else:
        specs = {
            "tokens": _sds((B, S), i32),
            "labels": _sds((B, S), i32),
        }
    return specs


def batch_logical(cfg: ArchConfig, specs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        if k == "positions":
            out[k] = (None, "batch", "seq")
        elif k in ("embeds", "frames"):
            out[k] = ("batch", "seq", None)
        else:
            out[k] = ("batch", "seq")
    return out


# ===========================================================================
# Decode-state logical axes (mirrors each family's state structure)
# ===========================================================================

STATE_RULES = dict(shd.ACT_RULES)
STATE_RULES["seq"] = ("model",)        # KV cache may shard its time axis
STATE_RULES["heads"] = ("model",)

def _kv_axes(quant: bool = False):
    if quant:
        return QuantKVCache(
            k=("layers", "batch", "kv_heads", "seq", None),
            v=("layers", "batch", "kv_heads", "seq", None),
            k_scale=("layers", "batch", "kv_heads", "seq"),
            v_scale=("layers", "batch", "kv_heads", "seq"),
            pos=("layers",))
    return KVCache(k=("layers", "batch", "kv_heads", "seq", None),
                   v=("layers", "batch", "kv_heads", "seq", None),
                   pos=("layers",))


def _mamba_axes(extra_lead=()):
    lead = ("layers",) + extra_lead
    return MambaState(h=lead + ("batch", "heads", "state", None),
                      conv=lead + ("batch", None, "mlp"))


def decode_state_logical(model, cfg: ArchConfig):
    from ..models.encdec import EncDec, EncDecState
    from ..models.hybrid import HybridLM
    from ..models.transformer import DecodeState
    if isinstance(model, EncDec):
        return EncDecState(
            self_kv=_kv_axes(),
            cross_kv={"k": ("layers", "batch", "kv_heads", "frames", None),
                      "v": ("layers", "batch", "kv_heads", "frames", None)},
            pos=())
    if isinstance(model, HybridLM):
        return DecodeState(
            layers={"kv": _kv_axes(),
                    "mamba": _mamba_axes(extra_lead=("layers",))},
            pos=())
    if model.is_mamba:
        return DecodeState(layers=_mamba_axes(), pos=())
    return DecodeState(layers=_kv_axes(quant=cfg.kv_dtype == "int8"),
                       pos=())


# ===========================================================================
# Step builders
# ===========================================================================

@dataclasses.dataclass
class Cell:
    fn: Callable
    args: Tuple           # abstract arguments (ShapeDtypeStructs)
    in_shardings: Tuple
    donate: Tuple = ()


def build_cell(cfg: ArchConfig, shape: ShapeCfg, mesh,
               opt_cfg: AdamWCfg | None = None,
               unroll: bool = False) -> Cell:
    model = build_model(cfg)
    abstract_params = model.abstract_params()
    param_axes = model.param_logical_axes()
    p_shard = shd.tree_shardings(mesh, abstract_params, param_axes,
                                 shd.PARAM_RULES)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWCfg()
        opt_abs = abstract_opt_state(abstract_params)
        opt_axes = type(opt_abs)(step=(), mu=param_axes, nu=param_axes)
        o_shard = shd.tree_shardings(mesh, opt_abs, opt_axes,
                                     shd.PARAM_RULES)
        specs = input_specs(cfg, shape)
        b_axes = batch_logical(cfg, specs)
        b_shard = shd.tree_shardings(mesh, specs, b_axes, shd.ACT_RULES)
        fn = make_train_step(model, opt_cfg, unroll=unroll)
        return Cell(fn=fn, args=(abstract_params, opt_abs, specs),
                    in_shardings=(p_shard, o_shard, b_shard),
                    donate=(0, 1))

    if shape.kind == "prefill":
        specs = input_specs(cfg, shape)
        specs.pop("labels")
        b_axes = batch_logical(cfg, specs)
        b_shard = shd.tree_shardings(mesh, specs, b_axes, shd.ACT_RULES)

        def prefill_step(params, batch):
            if cfg.family == "encdec":
                enc = model.encode(params, batch["frames"], remat=False,
                                   unroll=unroll)
                h = model.decode_train(params, batch["tokens"], enc,
                                       remat=False, unroll=unroll)
                from ..models.common import unembed
                return unembed(h[:, -1:], params["embed"].T)
            h = model.hidden_states(
                params, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                positions=batch.get("positions"), remat=False,
                unroll=unroll)
            return model.logits(params, h[:, -1:])

        return Cell(fn=prefill_step, args=(abstract_params, specs),
                    in_shardings=(p_shard, b_shard))

    # decode: one new token against a seq_len-deep cache/state
    B = shape.global_batch
    state_abs = model.init_decode_state(B, shape.seq_len,
                                        abstract_only=True)
    state_axes = decode_state_logical(model, cfg)
    s_shard = shd.tree_shardings(mesh, state_abs, state_axes, STATE_RULES)
    tok = _sds((B, 1), i32)
    t_shard = shd.tree_shardings(mesh, {"t": tok}, {"t": ("batch", None)},
                                 shd.ACT_RULES)["t"]

    def serve_step(params, tokens, state):
        return model.decode_step(params, tokens, state, unroll=unroll)

    return Cell(fn=serve_step, args=(abstract_params, tok, state_abs),
                in_shardings=(p_shard, t_shard, s_shard),
                donate=(2,))
