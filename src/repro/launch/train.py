"""Training driver: real steps on CPU-sized presets, full archs via --arch.

Demonstrates the whole substrate end-to-end: synthetic data pipeline →
train_step (AdamW, remat, optional gradient compression) → rolling async
checkpoints → crash-resume (bit-exact thanks to the step-indexed pipeline).

  PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 2
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchConfig
from repro.data.synthetic import SyntheticLM
from repro.models import build_model
from repro.train.optimizer import AdamWCfg, adamw_init
from repro.train.train_step import make_train_step
from repro.ckpt.checkpoint import CheckpointManager

PRESETS = {
    # ~8M-param decoder (runs a few steps/s on one CPU core)
    "tiny": ArchConfig(name="tiny", family="dense", n_layers=4,
                       d_model=256, n_heads=4, n_kv=2, head_dim=64,
                       d_ff=1024, vocab=2048, tie_embeddings=True),
    # ~110M-param decoder (the "~100M model" example target)
    "100m": ArchConfig(name="100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv=4, head_dim=64,
                       d_ff=3072, vocab=32768, tie_embeddings=True),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.arch else PRESETS[args.preset]
    model = build_model(cfg)
    opt_cfg = AdamWCfg(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                       total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      compress_grads=args.compress_grads))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)

    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        restored, step = mgr.restore_latest({"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = step + 1
            print(f"resumed from step {step}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = data.batch(step)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save({"params": params, "opt": opt}, step)
    if mgr:
        mgr.save({"params": params, "opt": opt}, args.steps - 1,
                 blocking=True)
    return losses


if __name__ == "__main__":
    main()
