import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb runner: apply a named change to a cell, re-lower,
re-analyse, and print before/after roofline terms.

Each experiment is (name, arch, shape, config-overrides).  Baselines come
from the cached dry-run artifacts; the experiment re-runs the same
cost-calibrated extrapolation with the overridden ArchConfig.  Results are
cached under results/perf/ and summarized into EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.perf --exp phi3-prefill-flatseq
  PYTHONPATH=src python -m repro.launch.perf --list
"""
import argparse
import dataclasses as dc
import json

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import RESULTS as DRYRUN_RESULTS
from repro.launch.dryrun import cost_extrapolation
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

PERF_RESULTS = DRYRUN_RESULTS.parent / "perf"

# name → (arch, shape, overrides, hypothesis)
EXPERIMENTS = {
    # Cell 1: worst useful-compute ratio (0.008). 40 q-heads / 10 kv-heads:
    # the grouped einsum caps head sharding at 10 ∤ 16 → XLA replicates the
    # S² attention over the 16-way model axis (16× flops + bytes).
    "phi3-prefill-flat": (
        "phi3-medium-14b", "prefill_32k", {"attn_impl": "flat"},
        "flat-head einsum lifts the n_kv sharding cap; 40 ∤ 16 still, so "
        "expect little change alone — control for the seqshard run"),
    "phi3-prefill-flatseq": (
        "phi3-medium-14b", "prefill_32k", {"attn_impl": "flat_seqshard"},
        "context parallelism: shard the query sequence (32768 % 16 = 0) "
        "over the model axis → expect ~16× lower attention flops/bytes "
        "per device"),
    "phi3-train-flatseq": (
        "phi3-medium-14b", "train_4k", {"attn_impl": "flat_seqshard"},
        "same fix on the train cell (4096 % 16 = 0)"),
    "qwen3-train-flatseq": (
        "qwen3-0.6b", "train_4k", {"attn_impl": "flat_seqshard"},
        "paper-representative small arch; 16 q-heads shard after "
        "flattening AND the S² tensor shards on seq"),
    # Cell 2: most collective-bound (whisper train: coll term > mem term).
    "whisper-train-flatseq": (
        "whisper-base", "train_4k", {"attn_impl": "flat_seqshard"},
        "whisper-train collectives come with heavy activation resharding "
        "(SPMD warned about involuntary full remat); constraining "
        "attention layout should cut the all-gather volume"),
    # Cell 3: paper-representative serving cell (MoE decode).
    "qwen3moe-decode-flat": (
        "qwen3-moe-30b-a3b", "decode_32k", {"attn_impl": "flat"},
        "32 q-heads % 16 = 0 after flattening → decode attention shards "
        "on heads instead of replicating at kv=4"),
    "qwen3moe-decode-int8kv": (
        "qwen3-moe-30b-a3b", "decode_32k", {"kv_dtype": "int8"},
        "decode is KV-read-bound; int8 cache (+f32 per-position scale) "
        "halves bytes per element → expect ~1.9× lower memory term"),
    "granite-decode-int8kv": (
        "granite-20b", "decode_32k", {"kv_dtype": "int8"},
        "same lever on the MQA serving cell"),
}


def run_experiment(name: str, force: bool = False) -> dict:
    arch, shape_name, overrides, hypothesis = EXPERIMENTS[name]
    PERF_RESULTS.mkdir(parents=True, exist_ok=True)
    cache = PERF_RESULTS / f"{name}.json"
    if cache.exists() and not force:
        return json.loads(cache.read_text())

    base_rec = json.loads(
        (DRYRUN_RESULTS / f"{arch}__{shape_name}__pod1.json").read_text())
    base = base_rec["cost_extrapolated"]
    cfg = dc.replace(get_config(arch), **overrides)
    shape = next(s for s in SHAPES if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=False)
    after = cost_extrapolation(cfg, shape, mesh)

    def extr(ce, key):
        return ce["c1"][key] + (ce["units"] - 1) * max(
            ce["c2"][key] - ce["c1"][key], 0.0)

    rec = {"name": name, "arch": arch, "shape": shape_name,
           "overrides": overrides, "hypothesis": hypothesis}
    for key, denom in (("flops", PEAK_FLOPS), ("bytes_accessed", HBM_BW),
                       ("collective_bytes", ICI_BW)):
        b, a = extr(base, key), extr(after, key)
        rec[key] = {"before": b, "after": a,
                    "speedup": (b / a) if a > 0 else float("inf"),
                    "term_before_s": b / denom, "term_after_s": a / denom}
    cache.write_text(json.dumps(rec, indent=1))
    return rec


def show(rec: dict):
    print(f"\n=== {rec['name']} ({rec['arch']} × {rec['shape']}) ===")
    print(f"hypothesis: {rec['hypothesis']}")
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        r = rec[key]
        print(f"  {key:18s} {r['before']:.3e} → {r['after']:.3e}  "
              f"({r['speedup']:.2f}×)  term {r['term_before_s']:.4f}s → "
              f"{r['term_after_s']:.4f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", choices=sorted(EXPERIMENTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.list:
        for k, (a, s, o, h) in EXPERIMENTS.items():
            print(f"{k:28s} {a} × {s}: {o}")
        return
    names = sorted(EXPERIMENTS) if args.all else [args.exp]
    for n in names:
        if n:
            show(run_experiment(n, args.force))


if __name__ == "__main__":
    main()
