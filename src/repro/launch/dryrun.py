import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.  Never
set that flag globally (smoke tests and benches must see 1 device).

Per cell we record:
  * compile success on the single-pod (16×16) and multi-pod (2×16×16) mesh,
  * ``memory_analysis()`` — proves the cell fits (bytes per device),
  * ``cost_analysis()``   — FLOPs / bytes for §Roofline,
  * the collective-byte breakdown parsed from the partitioned HLO.

Results are cached as JSON under ``results/dryrun`` so reruns are
incremental (delete the file to force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-sample]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applies
from repro.launch.hlo_analysis import _COLLECTIVES, parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

def _units(cfg) -> int:
    """Repeated-unit count for cost extrapolation (layers or periods)."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers


def _variant(cfg, k: int):
    import dataclasses as dc
    if cfg.family == "hybrid":
        return dc.replace(cfg, n_layers=k * cfg.attn_period)
    if cfg.family == "encdec":
        return dc.replace(cfg, n_layers=k, n_enc_layers=k)
    return dc.replace(cfg, n_layers=k)


def _compile_costs(cfg, shape, mesh) -> dict:
    """flops/bytes/collectives of one compiled variant (unrolled scans)."""
    cell = build_cell(cfg, shape, mesh, unroll=True)
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate).lower(
            *cell.args).compile()
    costs = compiled.cost_analysis()
    if isinstance(costs, (list, tuple)):
        costs = costs[0]
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(costs.get("flops", 0.0)),
            "bytes_accessed": float(costs.get("bytes accessed", 0.0)),
            "collective_bytes": float(coll["total_bytes"]),
            "collectives": coll}


def cost_extrapolation(cfg, shape, mesh) -> dict:
    """XLA cost_analysis counts a while-loop body ONCE, so the scanned
    full model under-reports by ~n_layers×.  We compile fully-unrolled
    1- and 2-unit variants (identical shapes otherwise) and extrapolate
    linearly: total(U) = c1 + (U-1)·(c2-c1)."""
    u = _units(cfg)
    c1 = _compile_costs(_variant(cfg, 1), shape, mesh)
    c2 = _compile_costs(_variant(cfg, 2), shape, mesh)
    out = {}
    for k in ("flops", "bytes_accessed", "collective_bytes"):
        slope = c2[k] - c1[k]
        out[k] = c1[k] + (u - 1) * slope
        out[k + "_per_unit"] = slope
    out["units"] = u
    out["c1"] = {k: c1[k] for k in ("flops", "bytes_accessed",
                                    "collective_bytes")}
    out["c2"] = {k: c2[k] for k in ("flops", "bytes_accessed",
                                    "collective_bytes")}
    # per-op-type collective extrapolation (for the bottleneck narrative)
    per_op = {}
    for op in _COLLECTIVES:
        b1 = c1["collectives"][op]["bytes"]
        b2 = c2["collectives"][op]["bytes"]
        per_op[op] = b1 + (u - 1) * (b2 - b1)
    out["collective_bytes_by_op"] = per_op
    return out


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                force: bool = False) -> dict:
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    RESULTS.mkdir(parents=True, exist_ok=True)
    cache = RESULTS / f"{tag}.json"
    if cache.exists() and not force:
        return json.loads(cache.read_text())

    cfg = get_config(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    ok, why = shape_applies(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=why)
        cache.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes":
                    int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "code_bytes":
                    int(getattr(mem, "generated_code_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            }
        except Exception as e:                      # pragma: no cover
            mem_rec = {"error": str(e)}
        try:
            costs = compiled.cost_analysis()
            if isinstance(costs, (list, tuple)):
                costs = costs[0]
            cost_rec = {"flops": float(costs.get("flops", -1)),
                        "bytes_accessed": float(costs.get("bytes accessed",
                                                          -1))}
        except Exception as e:                      # pragma: no cover
            cost_rec = {"error": str(e)}
        coll = parse_collectives(compiled.as_text())
        # single-pod runs also calibrate true per-layer costs (§Roofline);
        # the multi-pod pass is the sharding proof and skips it.
        extra = {}
        if not multi_pod:
            extra = {"cost_extrapolated": cost_extrapolation(
                get_config(arch), shape, mesh)}
        rec.update(status="ok", lower_s=round(t1 - t0, 2),
                   compile_s=round(t2 - t1, 2), memory=mem_rec,
                   cost=cost_rec, collectives=coll, **extra)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    cache.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) on both meshes")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    def show(rec):
        line = f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} " \
               f"{rec['status']:8s}"
        if rec["status"] == "ok":
            line += (f" compile={rec['compile_s']:8.1f}s "
                     f"flops={rec['cost'].get('flops', -1):.3e} "
                     f"coll={rec['collectives']['total_bytes']:.3e}B")
        elif rec["status"] == "error":
            line += " " + rec["error"][:120]
        else:
            line += " " + rec.get("reason", "")[:80]
        print(line, flush=True)

    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    show(dryrun_cell(arch, shape.name, mp, args.force))
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    show(dryrun_cell(args.arch, args.shape, args.multi_pod, args.force))


if __name__ == "__main__":
    main()
