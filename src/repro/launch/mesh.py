"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run fakes 512 host devices *before* any
jax import; see dryrun.py).

  single pod : (16, 16)    axes ("data", "model")   — 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic-rescale tests build smaller ones)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
