"""Partitioned-HLO analysis helpers (no jax/device side effects).

Collective-byte accounting for §Roofline: sums the result bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
in a compiled module's HLO text.
"""
from __future__ import annotations

import re

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the partitioned HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*"
                     r"(\([^)]*\)|[a-z0-9]+\[[\d,]*\][^ ]*)\s+([a-z\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        # match e.g. all-reduce / all-gather-start / all-reduce-done
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        out[base]["count"] += 1
        out[base]["bytes"] += _shape_bytes(m.group(1))
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


