"""Serving driver: batched prefill + decode in waves.

A miniature batch server: up to ``batch-slots`` requests decode in
lock-step (shared position counter — the decode state tracks one global
position, matching the decode_* dry-run cells); each wave prefis its
prompts token-by-token, generates, then the next wave loads.  Per-slot
paged KV management is listed as future work in DESIGN.md.

  PYTHONPATH=src python -m repro.launch.serve --preset tiny --requests 8
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import PRESETS
from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    assert args.prompt_len + args.gen_len < args.max_seq

    cfg = get_config(args.arch) if args.arch else PRESETS[args.preset]
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    B = args.batch_slots
    decode = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(args.seed + 1)

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]
    outputs: List[List[int]] = []
    t0 = time.perf_counter()
    tokens_out = 0

    for wave_start in range(0, args.requests, B):
        wave = prompts[wave_start:wave_start + B]
        n = len(wave)
        state = model.init_decode_state(B, args.max_seq)
        cur = np.zeros((B, 1), np.int32)
        for s, p in enumerate(wave):
            cur[s, 0] = p[0]
        gen: List[List[int]] = [[] for _ in range(n)]
        for t in range(1, args.prompt_len + args.gen_len):
            key, sub = jax.random.split(key)
            logits, state = decode(params, jnp.asarray(cur), state)
            if args.temperature > 0:
                nxt = np.asarray(jax.random.categorical(
                    sub, logits[:, 0] / args.temperature, axis=-1), np.int32)
            else:
                nxt = np.asarray(jnp.argmax(logits[:, 0], -1), np.int32)
            tokens_out += n
            for s in range(n):
                if t < args.prompt_len:
                    cur[s, 0] = wave[s][t]          # forced prefill
                else:
                    cur[s, 0] = nxt[s]
                    gen[s].append(int(nxt[s]))
        outputs.extend(gen)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {tokens_out} decode tokens "
          f"in {dt:.2f}s ({tokens_out / dt:.1f} tok/s)")
    print("sample output:", outputs[0][:16])
    return outputs


if __name__ == "__main__":
    main()
