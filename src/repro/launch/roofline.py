"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Per (arch × shape) single-pod cell:
    compute   = HLO_FLOPs_per_device / 197e12          (v5e bf16 peak)
    memory    = HLO_bytes_per_device / 819e9           (HBM bandwidth)
    collective= collective_bytes_per_device / 50e9     (ICI per link)
plus MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (serve) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which exposes remat and
sharding-replication waste (see EXPERIMENTS.md §Roofline narrative).

All FLOP/byte figures use the loop-calibrated extrapolation recorded by
dryrun.py (XLA counts while bodies once; see cost_extrapolation there).
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applies
from repro.models import build_model
from repro.models.common import n_params

PEAK_FLOPS = 197e12        # TPU v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
CHIPS = 256                # single-pod mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _active_fraction(cfg) -> float:
    """Active-parameter fraction for MoE archs (6·N_active·D)."""
    if cfg.moe is None:
        return 1.0
    model = build_model(cfg)
    total = n_params(model.schema())
    m = cfg.moe
    routed_one = cfg.d_model * m.d_expert * 3
    if cfg.family == "hybrid":
        # half the period's FFNs are MoE; each picks top_k of n_experts
        inactive = (m.n_experts - m.top_k) * routed_one * (cfg.n_layers // 2)
    else:
        inactive = (m.n_experts - m.top_k) * routed_one * cfg.n_layers
    return max((total - inactive) / total, 1e-6)


def model_flops(cfg, shape) -> float:
    """6·N·D (train) or 2·N_active·D (forward/serve), global."""
    model = build_model(cfg)
    total = n_params(model.schema())
    active = total * _active_fraction(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def cell_roofline(arch: str, shape_name: str) -> dict | None:
    path = RESULTS / f"{arch}__{shape_name}__pod1.json"
    if not path.exists():
        return None
    rec = json.loads(path.read_text())
    if rec.get("status") != "ok":
        return {"arch": arch, "shape": shape_name,
                "status": rec.get("status"),
                "reason": rec.get("reason") or rec.get("error", "")[:200]}
    cfg = get_config(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    ce = rec["cost_extrapolated"]

    def extr(key):
        # re-extrapolate with a non-negative per-layer slope: for decode
        # cells the fixed (embed/head) part dominates and XLA may schedule
        # the 2-layer variant *cheaper* on some component — a layer cannot
        # have negative cost, so clamp.
        c1, c2 = ce["c1"][key], ce["c2"][key]
        return c1 + (ce["units"] - 1) * max(c2 - c1, 0.0)

    flops_dev = extr("flops")
    bytes_dev = extr("bytes_accessed")
    coll_dev = extr("collective_bytes")

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * CHIPS
    bound = max(terms.values())
    # roofline fraction: useful work per second at the bound vs peak
    frac = (mf / CHIPS / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name, "status": "ok",
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_global": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": frac,
        "coll_by_op": ce.get("collective_bytes_by_op", {}),
        "memory_temp_bytes": rec["memory"].get("temp_bytes", -1),
        "compile_s": rec.get("compile_s"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            cfg = get_config(arch)
            ok, why = shape_applies(cfg, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape.name,
                             "status": "skipped", "reason": why})
                continue
            r = cell_roofline(arch, shape.name)
            if r:
                rows.append(r)
    if args.json:
        print(json.dumps(rows, indent=1))
        return
    hdr = (f"{'arch':22s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dom':>5s} {'useful':>7s} {'roofl':>6s}")
    print(hdr)
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} -- {r.get('status')}: "
                  f"{r.get('reason','')[:60]}")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:9.4f} "
              f"{r['t_memory_s']:9.4f} {r['t_collective_s']:9.4f} "
              f"{r['dominant'][:5]:>5s} {r['useful_ratio']:7.3f} "
              f"{r['roofline_fraction']:6.3f}")


if __name__ == "__main__":
    main()
