"""File registry (paper §3.1, Fig 3): JSON APIs/services + YAML instances.

Users describe a cloud-native application with two documents and never
touch engine internals:

* ``app.json`` — APIs (name, weight, entry service) and services
  (name, labels, calls, cloudlet length stats), Fig 3a.
* ``instances.yaml`` — instance groups (prefix, labels, replicas, size,
  bandwidths, requests/limits), Fig 3b.

``register(...)`` parses both into a ready :class:`Simulation`.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict

import numpy as np
import yaml

from .app import InstanceTemplate
from .engine import Simulation
from .graph import ServiceGraph, build_graph
from .types import SimCaps, SimParams


def load_app_json(path_or_dict) -> Dict[str, Any]:
    if isinstance(path_or_dict, (str, pathlib.Path)):
        with open(path_or_dict) as f:
            return json.load(f)
    return dict(path_or_dict)


def load_instances_yaml(path_or_dict) -> Dict[str, Any]:
    if isinstance(path_or_dict, (str, pathlib.Path)):
        with open(path_or_dict) as f:
            return yaml.safe_load(f)
    return dict(path_or_dict)


def graph_from_spec(spec: Dict[str, Any],
                    default_mi: float = 500.0) -> ServiceGraph:
    """Build the service DAG from the Fig 3a JSON document.

    Network-fabric extension (DESIGN.md §6): a service may carry a
    ``"payloads": {callee: MB}`` map (per-call-edge RPC payload mean) and
    an API a ``"payload": MB`` scalar (client→entry request payload).

    Resilience extension (DESIGN.md §7): a service may carry a
    ``"retries": {callee: n}`` map (per-call-edge retry budget) and an API
    a ``"retries": n`` scalar (client→entry budget); unlisted edges use
    the run-wide ``SimParams.retry_budget``.  Timeout budgets mirror the
    retry resolver: a service ``"timeouts": {callee: seconds}`` map and an
    API ``"timeout": seconds`` scalar override the run-wide
    ``SimParams.retry_timeout_s`` per edge.
    """
    services = spec["services"]
    names = [s["name"] for s in services]
    calls = {s["name"]: list(s.get("calls", [])) for s in services}
    len_mean = {s["name"]: float(s.get("mi", default_mi)) for s in services}
    len_std = {s["name"]: float(s.get("mi_std", 0.1 * len_mean[s["name"]]))
               for s in services}
    apis = [(a["name"], a["entry"], float(a.get("weight", 1.0)))
            for a in spec["apis"]]
    payloads = {(s["name"], callee): float(mb)
                for s in services
                for callee, mb in s.get("payloads", {}).items()}
    api_payloads = {a["name"]: float(a["payload"])
                    for a in spec["apis"] if "payload" in a}
    retries = {(s["name"], callee): int(n)
               for s in services
               for callee, n in s.get("retries", {}).items()}
    api_retries = {a["name"]: int(a["retries"])
                   for a in spec["apis"] if "retries" in a}
    timeouts = {(s["name"], callee): float(sec)
                for s in services
                for callee, sec in s.get("timeouts", {}).items()}
    api_timeouts = {a["name"]: float(a["timeout"])
                    for a in spec["apis"] if "timeout" in a}
    return build_graph(names, calls, apis, len_mean, len_std,
                       payloads=payloads or None,
                       api_payloads=api_payloads or None,
                       retries=retries or None,
                       api_retries=api_retries or None,
                       timeouts=timeouts or None,
                       api_timeouts=api_timeouts or None)


def templates_from_spec(spec: Dict[str, Any],
                        graph: ServiceGraph) -> Dict[str, InstanceTemplate]:
    """Map Fig 3b instance groups onto services by label/prefix match."""
    templates: Dict[str, InstanceTemplate] = {}
    for item in spec.get("instances", []):
        labels = set(item.get("labels", [item.get("prefix", "")]))
        req = item.get("requests", {})
        lim = item.get("limits", {})
        tmpl = InstanceTemplate(
            mips=float(req.get("share", 1000.0)),
            limit_mips=float(lim.get("share", 2 * req.get("share", 1000.0))),
            ram=float(req.get("ram", 300.0)),
            limit_ram=float(lim.get("ram", 500.0)),
            bw=float(item.get("rec_bw", item.get("trans_bw", 100.0))),
            replicas=int(item.get("replicas", 1)),
            ram_per_cloudlet=float(item.get("ram_per_cloudlet", 1.0)),
            bytes_per_rpc=float(item.get("bytes_per_rpc", 0.01)),
        )
        for name in graph.names:
            if name in labels or any(name.startswith(l) for l in labels if l):
                templates[name] = tmpl
    return templates


def register(app_spec, instance_spec=None, caps: SimCaps | None = None,
             params: SimParams | None = None, vm_mips=None, vm_ram=None,
             host_egress_scale=None, host_ingress_scale=None,
             placement_policy=None, host_zone=None,
             host_cpu_scale=None) -> Simulation:
    """One-call entity registration (paper Fig 4 ``Register`` class).

    Failure-domain extension (DESIGN.md §7.1): the app document may carry
    a top-level ``"zones": [zone_id, ...]`` list (one entry per host) that
    maps hosts to correlated failure domains for zone-level chaos; the
    ``host_zone`` argument overrides it.  Default: one zone per host.

    SLO-objective extension (DESIGN.md §10): a service may declare
    ``"slo_ms": target`` and ``"slo_budget": fraction`` — the per-service
    latency target and error-budget fraction burn-rate alerting evaluates
    (``SimParams.alerting="burn"``); undeclared services fall back to the
    run-wide ``slo_ms`` / ``slo_budget`` params at evaluation time.
    """
    spec = load_app_json(app_spec)
    graph = graph_from_spec(spec)
    # spec-level bounds checks name the offending document entry; the
    # table-level recheck (app.validate_app) runs inside Simulation
    caps_eff = caps or SimCaps()
    for item in (load_instances_yaml(instance_spec).get("instances", [])
                 if instance_spec is not None else []):
        r = int(item.get("replicas", 1))
        if not 1 <= r <= caps_eff.max_replicas:
            who = item.get("labels", item.get("prefix", "?"))
            raise ValueError(
                f"instance group {who!r} declares replicas={r}; must lie "
                f"in [1, caps.max_replicas={caps_eff.max_replicas}]")
    if host_zone is None and "zones" in spec:
        host_zone = np.asarray(spec["zones"], np.int32)
        if host_zone.shape[0] != caps_eff.n_vms:
            raise ValueError(
                f'app document "zones" lists {host_zone.shape[0]} entries '
                f"but the cluster has caps.n_vms={caps_eff.n_vms} hosts")
    services = spec["services"]
    slo_ms = [float(s.get("slo_ms", -1.0)) for s in services]
    slo_budget = [float(s.get("slo_budget", -1.0)) for s in services]
    templates = {}
    if instance_spec is not None:
        inst_spec = load_instances_yaml(instance_spec)
        templates = templates_from_spec(inst_spec, graph)
    return Simulation(graph, caps=caps, params=params, templates=templates,
                      vm_mips=vm_mips, vm_ram=vm_ram,
                      host_egress_scale=host_egress_scale,
                      host_ingress_scale=host_ingress_scale,
                      placement_policy=placement_policy,
                      host_zone=host_zone,
                      host_cpu_scale=host_cpu_scale,
                      service_slo_ms=slo_ms,
                      service_slo_budget=slo_budget)
