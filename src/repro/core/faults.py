"""Fault injection & resilience — the Disruption tick phase (DESIGN.md §7).

The paper's headline claim is "comprehensive and dynamic modeling" with
QoS-based feedback, but a fair-weather engine cannot express availability,
error rate or recovery behavior — the QoS dimensions that make microservice
architectures interesting (uqSim, arXiv:1911.02122, validates exactly these
failure/queueing dynamics; resilience experiments à la Clue are the largest
untouched scenario family).  ``faults="chaos"`` inserts a **Disruption**
phase between Generation and Transit:

* **Injection** — a seeded, fully tensorized fault schedule: hosts crash
  and recover with MTBF/MTTR rates, instances are killed at a Poisson rate,
  host NICs degrade to a capacity fraction; every rate travels in
  :class:`DynParams`, so ``run_batch`` sweeps chaos intensity without
  recompiling.  A host going down flips its instances to ``INST_DOWN`` and
  fails their in-flight cloudlets in ONE masked pass over the stacked pool.
* **Resilience** — failed RPC attempts consult the per-service-edge retry
  policy (budget + per-attempt timeout); retries respawn through the
  existing two-scatter spawn path (``pool.scatter_pool``) with an attempt
  counter column, so a mass-kill wave frees and recycles slots in the same
  tick.  A per-edge circuit breaker (error-rate EMA trips open → fail-fast,
  half-open probe after a cooldown) is pure status masks — no control flow
  in the scan.  Exhausted retries propagate to the owning request as a
  *failed completion*.
* **Feedback** — :class:`FaultStats` (availability, error rate, retry
  amplification, observed MTTR) joins the QoS report; HS scale-out and
  migration place replicas only on up hosts.

``faults="none"`` (default) compiles the exact pre-faults program — pinned
bit-identical by the golden digests in tests/test_network.py, the same
pattern ``network="uniform"`` uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..analysis import streams
from . import network as netmod
from .app import AppStatic
from ..analysis.annotate import collide
from .pool import (assign_free_slots, scatter_pool, segment_rank,
                   segment_sum as _segsum)
from .types import (ALERT_FIRING, CL_EXEC, CL_FREE, CL_TRANSIT, CL_WAITING,
                    DynParams, FaultState, INST_DOWN, INST_DRAIN, INST_FREE,
                    INST_ON, SimCaps, SimParams, SimState)


def _p_rate(rate_per_s: jnp.ndarray, dt: jnp.ndarray) -> jnp.ndarray:
    """Per-tick event probability of a Poisson process with the given rate
    (exact exponential form — stable for any dt, 0 at rate 0)."""
    return 1.0 - jnp.exp(-dt * rate_per_s)


def _p_mean_time(mean_s: jnp.ndarray, dt: jnp.ndarray) -> jnp.ndarray:
    """Per-tick probability for a mean-time parameter (MTBF/MTTR);
    ``inf`` disables the transition."""
    return 1.0 - jnp.exp(-dt / jnp.maximum(mean_s, 1e-9))


def edge_payload_tables(app: AppStatic):
    """Flattened per-edge payload stats aligned with the cloudlet ``edge``
    id: call edges first ([S*d_max], row-major), then client→entry edges
    ([A]) — the layout §7 shares with the retry/breaker tables."""
    mean = jnp.concatenate([app.payload_mean.reshape(-1),
                            app.api_payload_mean])
    std = jnp.concatenate([app.payload_std.reshape(-1),
                           app.api_payload_std])
    return mean, std


# ``disruption(stop_after=...)`` stages, in phase order — the profiler
# (obs/profile.py) differences their prefix walls to attribute the
# phase's cost (ROADMAP item b).
DISRUPTION_STAGES = ("schedule", "doom", "respawn", "breaker")


def disruption(state: SimState, app: AppStatic, caps: SimCaps,
               params: SimParams, dyn: DynParams, rng: jnp.ndarray,
               rng_len: jnp.ndarray, rng_net=None,
               stop_after: str | None = None) -> SimState:
    """One Disruption tick: sample the fault schedule, fail doomed work,
    respawn retries, advance the circuit breakers (all masked tensor ops —
    the pool streams a constant number of times, DESIGN.md §2.2).

    ``stop_after`` truncates after the named stage
    (:data:`DISRUPTION_STAGES`); each cut writes that stage's outputs
    into the returned state so XLA cannot dead-code-eliminate the work
    being timed.  ``None`` (default) runs the full phase.
    """
    if stop_after is not None and stop_after not in DISRUPTION_STAGES:
        raise ValueError(
            f"disruption stop_after must be one of {DISRUPTION_STAGES}, "
            f"got {stop_after!r}")
    cl, inst, req = state.cloudlets, state.instances, state.requests
    fs, fst = state.fault, state.fstats
    i32, f32 = jnp.int32, jnp.float32
    H = fs.host_up.shape[0]
    I = inst.status.shape[0]
    C = cl.status.shape[0]
    E = fs.edge_err_ema.shape[0]
    R = req.api.shape[0]
    V = state.vms.mips.shape[0]
    t, dt = state.time, dyn.dt
    # Trace-time guard: the per-edge retry/breaker tables must cover every
    # edge id the app can emit (S*d_max call edges + one client→entry edge
    # per API) — an undersized table silently aliases breaker state via
    # clamped gathers.  zeros_state sizes E correctly when given
    # n_edges/n_apis; states built with a stale single-API default land
    # here.
    if int(app.n_edges) > E:
        raise ValueError(
            f"fault edge tables undersized: app emits edge ids up to "
            f"{int(app.n_edges) - 1} but FaultState holds {E} edges — "
            f"pass app=app (or n_edges/n_apis) to zeros_state")
    if int(app.host_zone.shape[0]) != H:
        raise ValueError(
            f"host_zone table must cover every host: app maps "
            f"{int(app.host_zone.shape[0])} hosts but the cluster has {H} — "
            f"pass n_hosts (or host_zone) to build_app")

    k_host, k_inst, k_nic = streams.split(
        rng, 3, names=("host", "inst", "nic"))
    # Gray-failure streams are folded off the tick key rather than widening
    # the split above: jax.random.split is NOT prefix-stable, so one extra
    # child would perturb every pre-existing chaos stream and break the
    # pinned chaos goldens.  The whole derivation tree is now pinned by
    # the stream-topology digest test (repro/analysis/streams.py).
    k_slow, k_sev, k_zone, k_zslow, k_part = streams.split(
        streams.fold_in(rng, 1, name="gray"), 5,
        names=("slow", "sev", "zone", "zslow", "part"))

    # --- correlated failure domains (zone draws, DESIGN.md §7.1) ---------
    # One uniform draw per *zone slot* ([H] slots bound Z); a firing draw
    # downs (or slows) every host mapped to that zone.  Unused slots are
    # masked out so the fired-zone counter stays meaningful.
    hz = app.host_zone
    zone_used = jnp.zeros((H,), bool).at[hz].set(True)
    zone_down = zone_used & (jax.random.uniform(k_zone, (H,))
                             < _p_rate(dyn.zone_fault_rate, dt))
    zone_slow = zone_used & (jax.random.uniform(k_zslow, (H,))
                             < _p_rate(dyn.zone_slow_rate, dt))

    # --- host crash / recovery (MTBF / MTTR) ---------------------------
    up = fs.host_up > 0
    u_h = jax.random.uniform(k_host, (H,))
    crash = up & ((u_h < _p_mean_time(dyn.host_mtbf_s, dt)) | zone_down[hz])
    recover = ~up & (u_h < _p_mean_time(dyn.host_mttr_s, dt))
    up_new = (up & ~crash) | recover

    # --- host fail-slow episodes (degraded MIPS, MTBF/MTTR style) --------
    slow = fs.host_slow > 0
    u_sl = jax.random.uniform(k_slow, (H,))
    slow_start = ~slow & up_new \
        & ((u_sl < _p_mean_time(dyn.host_slow_mtbf_s, dt)) | zone_slow[hz])
    slow_end = slow & (u_sl < _p_mean_time(dyn.host_slow_mttr_s, dt))
    # a crashing host ends its episode: it restarts healthy
    slow_new = ((slow & ~slow_end) | slow_start) & up_new

    # --- NIC degradation (capacity fraction while degraded) -------------
    ok = fs.nic_ok > 0
    u_n = jax.random.uniform(k_nic, (H,))
    degrade = ok & (u_n < _p_rate(dyn.nic_degrade_rate, dt))
    fix = ~ok & (u_n < _p_mean_time(dyn.nic_mttr_s, dt))
    ok_new = (ok & ~degrade) | fix
    # Brownout severity is sampled once per degradation from
    # U[factor − spread, factor + spread] ∩ [0, 1] and held for the whole
    # episode; Transit multiplies NIC capacity by the stored factor.
    sev = jnp.clip(dyn.nic_degrade_factor
                   + (2.0 * jax.random.uniform(k_sev, (H,)) - 1.0)
                   * dyn.nic_degrade_spread, 0.0, 1.0)
    nic_factor = jnp.where(degrade, sev,
                           jnp.where(fix, 1.0, fs.nic_factor))

    # --- partial partitions (zone-pair link cuts) ------------------------
    # Symmetric [Z, Z] mask updated on the strictly-upper triangle (one
    # draw per unordered pair) and mirrored; Transit zeroes the capacity
    # of cut transfers in the water-fill instead of crashing anything.
    cut = fs.zone_cut > 0
    u_p = jax.random.uniform(k_part, (H, H))
    upper = jnp.triu(jnp.ones((H, H), bool), 1)
    pair_used = upper & zone_used[:, None] & zone_used[None, :]
    p_open = pair_used & ~cut & (u_p < _p_rate(dyn.zone_partition_rate, dt))
    p_heal = cut & upper & (u_p < _p_mean_time(dyn.zone_partition_mttr_s, dt))
    cut_upper = (cut & upper & ~p_heal) | p_open
    zone_cut_new = (cut_upper | cut_upper.T).astype(i32)

    # fault-schedule outputs, written at every profiler cut so the stage
    # being timed stays live under DCE
    sched_fault = fs._replace(
        host_up=up_new.astype(i32), nic_ok=ok_new.astype(i32),
        host_slow=slow_new.astype(i32), nic_factor=nic_factor,
        zone_cut=zone_cut_new)
    if stop_after == "schedule":
        return state._replace(fault=sched_fault)

    # --- instance transitions -------------------------------------------
    host_safe = jnp.maximum(inst.host, 0)
    host_down = (inst.host >= 0) & ~up_new[host_safe]
    on = inst.status == INST_ON
    u_i = jax.random.uniform(k_inst, (I,))
    killed = on & (u_i < _p_rate(dyn.inst_kill_rate, dt))
    goes_down = on & (host_down | killed)
    # a draining pod on a crashed node is simply gone: free the slot and
    # release its VM share (its queue is wiped below anyway)
    drain_dies = (inst.status == INST_DRAIN) & host_down
    restarts = (inst.status == INST_DOWN) & ~host_down \
        & (u_i < _p_mean_time(dyn.inst_mttr_s, dt))

    status_new = jnp.where(goes_down, INST_DOWN, inst.status)
    status_new = jnp.where(drain_dies, INST_FREE, status_new)
    status_new = jnp.where(restarts, INST_ON, status_new)
    dead_now = goes_down | drain_dies

    rel_m = _segsum(jnp.where(drain_dies, inst.mips, 0.0), inst.vm, V)
    rel_r = _segsum(jnp.where(drain_dies, inst.ram, 0.0), inst.vm, V)
    vms = state.vms._replace(mips_used=state.vms.mips_used - rel_m,
                             ram_used=state.vms.ram_used - rel_r)

    # --- fail doomed in-flight work (one masked pass over the pool) ------
    active = cl.status != CL_FREE
    ci = jnp.maximum(cl.inst, 0)
    inst_dead = (cl.inst >= 0) & (dead_now[ci]
                                  | (status_new[ci] == INST_DOWN))
    # Per-attempt timeout: the per-edge registry value ("timeouts" spec
    # keys) when set, else the run-wide sweepable dyn.retry_timeout_s —
    # mirroring the per-edge retry-budget resolver below.
    e_safe = jnp.maximum(cl.edge, 0)
    tmo = jnp.where(app.edge_timeout[e_safe] >= 0,
                    app.edge_timeout[e_safe], dyn.retry_timeout_s)
    doomed = inst_dead | ((t - cl.arrival) > tmo)
    if "src_host" in cl.layout:
        # fabric mode only: an in-flight transfer whose source host died
        # loses its payload (uniform mode has no TRANSIT work by
        # construction, and no src_host column to read)
        doomed = doomed | ((cl.status == CL_TRANSIT) & (cl.src_host >= 0)
                           & ~up_new[jnp.maximum(cl.src_host, 0)])
    organic = active & doomed

    # circuit-breaker status masks (state machine documented in FaultState)
    open_m = fs.edge_open_until > t
    half_m = (fs.edge_open_until > 0) & ~open_m
    cl_open = (cl.edge >= 0) & open_m[e_safe]
    # fail-fast only calls spawned since the previous Disruption pass: an
    # open breaker blocks NEW calls, it never cancels established work
    fresh = cl.arrival >= t - dt
    failfast = active & ~organic & cl_open & fresh & (cl.status != CL_EXEC)

    failed = organic | failfast
    budget = jnp.where(app.edge_retry[e_safe] >= 0, app.edge_retry[e_safe],
                       dyn.retry_budget)
    can_retry = organic & (cl.attempt < budget) & ~cl_open
    # Per-tick retry admission budget (SimCaps.k_retry): the respawn wave
    # is a K-rank scatter like gen_spawn's k_fire, so its cost must not
    # scale with the whole pool; failures past the budget fail permanently
    # (a genuine mass-kill wave mostly fits — the auto budget is C/8).
    K_cap = caps.k_retry if caps.k_retry > 0 else min(C, max(256, C // 8))
    K_cap = min(K_cap, C)
    retry_rank = jnp.cumsum(can_retry.astype(i32)) - 1
    can_retry = can_retry & (retry_rank < K_cap)
    permanent = failed & ~can_retry

    # n_exec stays leak-free through a mass-kill wave: failures on still-up
    # instances (timeouts) decrement, dead instances reset to zero (all of
    # their executing cloudlets are in the failed set).
    exec_failed = failed & (cl.status == CL_EXEC)
    dec = _segsum(exec_failed.astype(i32),
                  jnp.where(exec_failed, cl.inst, -1), I)
    n_exec_new = jnp.where((status_new == INST_DOWN) | drain_dies, 0,
                           inst.n_exec - dec)

    instances = inst._replace(
        status=status_new,
        service=jnp.where(drain_dies, -1, inst.service),
        vm=jnp.where(drain_dies, -1, inst.vm),
        host=jnp.where(drain_dies, -1, inst.host),
        mips=jnp.where(drain_dies, 0.0, inst.mips),
        ram=jnp.where(drain_dies, 0.0, inst.ram),
        n_exec=n_exec_new,
        util_ema=jnp.where(goes_down | drain_dies, 0.0,
                           jnp.where(restarts, 0.5, inst.util_ema)),
    )

    # --- permanent failures propagate to the owning request --------------
    # finish is scatter-maxed with the failure time so the request's
    # response (finish - arrival) stays ≥ 0 when it completes as failed.
    # several cloudlets of one request can fail in the same wave —
    # accumulation into the shared request row is intended
    rdst = jnp.where(permanent & (cl.req >= 0), cl.req, R)
    with collide("request_fail_counts"):
        requests = req._replace(
            outstanding=req.outstanding.at[rdst].add(-1, mode="drop"),
            failed=req.failed.at[rdst].max(jnp.uint8(1), mode="drop"),
            finish=req.finish.at[rdst].max(t, mode="drop"),
        )

    # --- free failed slots (masked column writes, no per-field scatters) --
    cl2 = cl.with_cols(status=jnp.where(failed, CL_FREE, cl.status),
                       inst=jnp.where(failed, -1, cl.inst))

    state = state._replace(cloudlets=cl2, instances=instances, vms=vms,
                           requests=requests)
    if stop_after == "doom":
        return state._replace(fault=sched_fault)

    # --- respawn retries through the two-scatter spawn path ---------------
    # Every retry descriptor's own slot was just freed and the wave is
    # pre-capped to K_cap, so free ≥ wanted and the wave can never drop
    # (a dropped retry would strand its request's outstanding count).
    asg = assign_free_slots(cl2.status == CL_FREE, can_retry,
                            k_static=K_cap)
    Ka = asg.dst.shape[0]
    svc_new = cl.service[asg.src]
    req_new = cl.req[asg.src]
    edge_new = cl.edge[asg.src]
    att_new = cl.attempt[asg.src] + 1
    dep_new = cl.depth[asg.src]
    sin_new = cl.src_inst[asg.src]
    noise = jax.random.normal(rng_len, (Ka,), f32)
    length = jnp.maximum(app.len_mean[svc_new] + app.len_std[svc_new] * noise,
                         1.0)

    if rng_net is None:                  # uniform transport mode
        status_sp, inst_sp = CL_WAITING, -1
        src_host_sp, bytes_sp = -1, 0.0
        rr = state.rr
    else:                                # fabric mode: re-address + payload
        k_lb, k_pay = streams.split(rng_net, names=("lb", "payload"))
        tgt, rr = netmod.pick_replicas(svc_new, asg.live, state, caps,
                                       params, k_lb)
        pay_mean, pay_std = edge_payload_tables(app)
        eg = jnp.maximum(edge_new, 0)
        payload = netmod.sample_payload(pay_mean[eg], pay_std[eg], k_pay)
        # src host re-derived from the caller instance (it may have
        # migrated); the retried transfer contends like the original did
        sh = jnp.where(sin_new >= 0,
                       instances.host[jnp.maximum(sin_new, 0)], -1)
        dh = jnp.where(tgt >= 0, instances.host[jnp.maximum(tgt, 0)], -1)
        loop = (tgt >= 0) & (sh >= 0) & (sh == dh)
        in_transit = (tgt >= 0) & ~loop
        status_sp = jnp.where(in_transit, CL_TRANSIT, CL_WAITING)
        inst_sp = tgt
        src_host_sp = jnp.where(in_transit, sh, -1)
        bytes_sp = jnp.where(in_transit, payload, 0.0)

    cloudlets = scatter_pool(
        cl2, asg,
        status=status_sp, req=req_new, service=svc_new, inst=inst_sp,
        wait_ticks=0, depth=dep_new, src_host=src_host_sp,
        attempt=att_new, edge=edge_new, src_inst=sin_new,
        length=length, rem=length,
        arrival=jnp.full((Ka,), 0.0, f32) + t, start=-1.0,
        rem_bytes=bytes_sp)

    rds2 = jnp.where(asg.live, req_new, R)
    with collide("spawn_request_counts"):
        requests = requests._replace(
            spawned=requests.spawned.at[rds2].add(1, mode="drop"))
    if stop_after == "respawn":
        return state._replace(rr=rr, cloudlets=cloudlets,
                              requests=requests, fault=sched_fault)

    # --- circuit-breaker update (per edge, masks only) --------------------
    # Fail-fast failures are excluded from the EMA input: they are caused
    # by the breaker and would hold it open forever.
    org_e = _segsum(organic.astype(i32), jnp.where(organic, cl.edge, -1), E)
    succ_e = fs.edge_succ
    n_e = org_e + succ_e
    err = org_e.astype(f32) / jnp.maximum(n_e.astype(f32), 1.0)
    traffic = n_e > 0
    ema = jnp.where(traffic,
                    fs.edge_err_ema + dyn.cb_alpha * (err - fs.edge_err_ema),
                    fs.edge_err_ema)
    closed_m = fs.edge_open_until <= 0
    trip = closed_m & traffic & (ema > dyn.cb_err_thresh)
    reopen = half_m & (org_e > 0)
    close = half_m & (org_e == 0) & (succ_e > 0)
    open_until = jnp.where(trip | reopen, t + dyn.cb_cooldown_s,
                           jnp.where(close, 0.0, fs.edge_open_until))
    ema = jnp.where(close, 0.0, ema)   # clean slate after a healthy probe
    if stop_after == "breaker":
        fault = sched_fault._replace(edge_open_until=open_until,
                                     edge_err_ema=ema,
                                     edge_succ=jnp.zeros_like(succ_e))
        return state._replace(rr=rr, cloudlets=cloudlets,
                              requests=requests, fault=fault)

    # --- per-replica outlier ejection (breaker-aware LB, §7.1) ------------
    # Same three-state machine as the edge breaker, but per instance and
    # enforced in the dispatch rank table (policies.eject_view) — a sick
    # replica is routed around instead of the whole edge failing fast.
    S = state.sched.svc_replicas.shape[0]
    org_i = _segsum(organic.astype(i32), jnp.where(organic, cl.inst, -1), I)
    succ_i = fs.inst_succ
    n_i = org_i + succ_i
    traffic_i = n_i > 0
    err_i = org_i.astype(f32) / jnp.maximum(n_i.astype(f32), 1.0)
    iema = jnp.where(traffic_i,
                     fs.inst_err_ema
                     + dyn.cb_alpha * (err_i - fs.inst_err_ema),
                     fs.inst_err_ema)
    mean_lat = fs.inst_lat_sum / jnp.maximum(succ_i.astype(f32), 1.0)
    lema = jnp.where(succ_i > 0,
                     fs.inst_lat_ema + dyn.cb_alpha * (mean_lat
                                                       - fs.inst_lat_ema),
                     fs.inst_lat_ema)
    # latency outlier = EMA above eject_lat_factor × the service's mean
    # over its ON replicas with signal (≥ 2 so a lone replica never
    # outlies itself)
    on_i = instances.status == INST_ON
    isvc_safe = jnp.maximum(instances.service, 0)
    sig = on_i & (lema > 0) & (instances.service >= 0)
    lat_sum_s = _segsum(jnp.where(sig, lema, 0.0),
                        jnp.where(sig, instances.service, -1), S)
    lat_cnt_s = _segsum(sig.astype(i32), jnp.where(sig, instances.service,
                                                   -1), S)
    svc_lat = lat_sum_s / jnp.maximum(lat_cnt_s.astype(f32), 1.0)
    # Alert-driven tightening (DESIGN.md §10): while any burn alert FIRES
    # on a replica's service, its ejection thresholds multiply by
    # dyn.slo_eject_tighten (< 1 tightens) — outliers get evicted sooner
    # exactly when the service is burning its error budget.  Tighten = 1.0
    # (the default) multiplies exactly, so the sixth golden combo stays
    # bit-identical; the alert state the stage reads is one tick old
    # (Disruption precedes Execute/Alerting in the tick).
    if params.telemetry == "stream" and params.alerting == "burn":
        firing_s = (state.alerts.astate == ALERT_FIRING).any(axis=1)
        tighten = jnp.where(firing_s[isvc_safe] & (instances.service >= 0),
                            dyn.slo_eject_tighten, 1.0)
    else:
        tighten = 1.0
    eff_err_thresh = dyn.eject_err_thresh * tighten
    eff_lat_factor = dyn.eject_lat_factor * tighten
    lat_trip = (dyn.eject_lat_factor > 0) & (lat_cnt_s[isvc_safe] >= 2) \
        & (lema > eff_lat_factor * svc_lat[isvc_safe])
    ej_open = fs.inst_eject_until > t
    ej_half = (fs.inst_eject_until > 0) & ~ej_open
    ej_closed = fs.inst_eject_until <= 0
    want = ej_closed & on_i & traffic_i \
        & ((iema > eff_err_thresh) | lat_trip)
    # last-replica guard: keep at least one admissible (ON, not-ejected)
    # replica per service — cap this tick's ejections at admissible − 1
    n_adm = _segsum((on_i & ~ej_open).astype(i32),
                    jnp.where(instances.service >= 0, instances.service, -1),
                    S)
    eject_rank = segment_rank(isvc_safe, want, S)
    trip_i = want & (eject_rank < jnp.maximum(n_adm[isvc_safe] - 1, 0))
    probe_fail = ej_half & (org_i > 0)
    probe_ok = ej_half & (org_i == 0) & (succ_i > 0)
    eject_until = jnp.where(trip_i | probe_fail, t + dyn.eject_cooldown_s,
                            jnp.where(probe_ok, 0.0, fs.inst_eject_until))
    iema = jnp.where(probe_ok, 0.0, iema)
    lema = jnp.where(probe_ok, 0.0, lema)
    # dead / restarted pods shed their ejection history: a fresh pod is
    # re-admitted clean
    gone = dead_now | restarts
    eject_until = jnp.where(gone, 0.0, eject_until)
    iema = jnp.where(gone, 0.0, iema)
    lema = jnp.where(gone, 0.0, lema)

    fault = FaultState(host_up=up_new.astype(i32), nic_ok=ok_new.astype(i32),
                       edge_open_until=open_until, edge_err_ema=ema,
                       edge_succ=jnp.zeros_like(succ_e),
                       host_slow=slow_new.astype(i32),
                       nic_factor=nic_factor,
                       zone_cut=zone_cut_new,
                       inst_err_ema=iema, inst_lat_ema=lema,
                       inst_eject_until=eject_until,
                       inst_succ=jnp.zeros_like(succ_i),
                       inst_lat_sum=jnp.zeros_like(fs.inst_lat_sum))

    counters = state.counters._replace(
        spawned=state.counters.spawned + asg.n_assigned)
    fstats = fst._replace(
        host_crashes=fst.host_crashes + jnp.sum(crash.astype(i32)),
        host_recoveries=fst.host_recoveries + jnp.sum(recover.astype(i32)),
        inst_kills=fst.inst_kills + jnp.sum(killed.astype(i32)),
        failed_attempts=fst.failed_attempts + jnp.sum(failed.astype(i32)),
        retries=fst.retries + asg.n_assigned,
        failfast=fst.failfast + jnp.sum(failfast.astype(i32)),
        breaker_trips=fst.breaker_trips + jnp.sum(trip.astype(i32)),
        down_time_s=fst.down_time_s + dt * jnp.sum((~up_new).astype(f32)),
        ejections=fst.ejections + jnp.sum(trip_i.astype(i32)),
        readmissions=fst.readmissions + jnp.sum(probe_ok.astype(i32)),
        zone_faults=fst.zone_faults + jnp.sum(zone_down.astype(i32))
        + jnp.sum(zone_slow.astype(i32)),
        partitions=fst.partitions + jnp.sum(p_open.astype(i32)),
        slow_episodes=fst.slow_episodes + jnp.sum(slow_start.astype(i32)),
        slow_time_s=fst.slow_time_s + dt * jnp.sum(slow_new.astype(f32)),
    )
    return state._replace(rr=rr, cloudlets=cloudlets, requests=requests,
                          counters=counters, fault=fault, fstats=fstats)
