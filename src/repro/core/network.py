"""Network fabric: host NICs, payload transit, fair-share contention.

The paper's central challenge is "frequent inter-service communication",
but its transport model (and this repo's ``network="uniform"`` degenerate
mode) is a single load-independent latency per RPC hop.  The fabric mode
(DESIGN.md §6) makes the network a first-class tick phase:

* every instance is attached to a host NIC (``Instances.host``, co-located
  with its VM);
* every RPC carries a Gaussian payload sampled from the service edge it
  traverses (``AppStatic.payload_mean/std``) and is *addressed* to a
  replica at spawn time (client-side load balancing — the transfer needs a
  destination NIC before it can contend);
* in-flight transfers sit in the stacked cloudlet pool under ``CL_TRANSIT``
  with ``rem_bytes`` / ``src_host`` columns, and each tick the max-min fair
  water-filling kernel (``kernels/link_share``) splits every egress and
  ingress port among its transfers before ``dispatch`` admits the arrivals;
* intra-host hops take a loopback fast path (spawned directly into the
  waiting queue — no NIC occupancy, no transit tick).

The phase streams the cloudlet buffer a constant number of times and keeps
all statistics in small host-table scatters, preserving the one-pass tick
discipline of DESIGN.md §2.2.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import policies
from ..kernels.link_share import link_share
from .app import AppStatic
from .pool import segment_rank, segment_sum as _segsum
from .types import (CL_TRANSIT, CL_WAITING, DynParams, INST_ON, SimCaps,
                    SimParams, SimState)

# Payload floor (MB): Gaussian sampling may go non-positive; a transfer must
# carry at least one packet so it arrives in finite time.
MIN_PAYLOAD_MB = 1e-6

# NIC capacities are configured in Mbit/s; transfers account in MByte.
MBIT_PER_S_TO_MBYTE_PER_S = 1.0 / 8.0

# One-hot accounting matrices ([C, H] / [C, NB]) beat serialized scatters
# on CPU/TPU only while they fit comfortably in cache; past this element
# budget the O(C) segment_sum scatter takes over (counts are exact integers
# and NetStats carries no cross-implementation bit contract, so the switch
# is value-safe).
ONE_HOT_BUDGET = 1 << 22


def pick_replicas(svc: jnp.ndarray, live: jnp.ndarray, state: SimState,
                  caps: SimCaps, params: SimParams, rng: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Client-side load balancing at spawn time (fabric mode).

    Each new RPC in the spawn wave is addressed to a replica of its target
    service before it is sent — the transfer needs a destination NIC to
    contend on.  Uses the same policy selector as ``dispatch``; round-robin
    ranks FCFS within the wave via the prefix-sum ``segment_rank`` (no
    sort).  Returns ([K] target instance ids, -1 where no live replica
    exists; updated per-service round-robin cursors).
    """
    i32 = jnp.int32
    sched, inst = state.sched, state.instances
    S = sched.svc_replicas.shape[0]
    if params.faults == "chaos":
        # outlier ejection (§7.1): address around OPEN-ejected replicas —
        # the exact identity view when nothing is ejected
        iof, reps = policies.eject_view(sched, state.fault.inst_eject_until,
                                        state.time)
    else:
        iof, reps = sched.inst_of_rank, sched.svc_replicas
    svc_safe = jnp.where(live, svc, 0)
    replicas = reps[svc_safe]
    rep_safe = jnp.maximum(replicas, 1)

    # Shared three-policy rank selection (policies.lb_rank); round-robin
    # is offset by the FCFS rank within the spawn wave (prefix-sum
    # segment_rank — no sort), where dispatch uses slot order.
    offset = (segment_rank(svc_safe, live, S).astype(i32)
              if params.lb_policy == policies.LB_ROUND_ROBIN
              else jnp.zeros(svc.shape, i32))
    rank = policies.lb_rank(
        params.lb_policy, state.rr, svc_safe, rep_safe, offset, rng,
        iof, inst.status, inst.n_exec, inst.mips)

    target = iof[svc_safe, jnp.minimum(rank, caps.max_replicas - 1)]
    ok = live & (replicas > 0) & (target >= 0)
    tgt_safe = jnp.where(ok, target, 0)
    ok = ok & (inst.status[tgt_safe] == INST_ON)

    new_rr = state.rr
    if params.lb_policy == policies.LB_ROUND_ROBIN:
        # Advance cursors only for spawns that were actually addressed:
        # a failed address parks the cloudlet WAITING and dispatch's fresh
        # LB both serves it and steps the cursor — counting it here too
        # would double-step and skew replica fairness.
        counts = _segsum(ok.astype(i32), jnp.where(ok, svc, -1), S)
        new_rr = (state.rr + counts) % jnp.maximum(sched.svc_replicas, 1)
    return jnp.where(ok, target, -1), new_rr


def sample_payload(mean: jnp.ndarray, std: jnp.ndarray, rng: jnp.ndarray
                   ) -> jnp.ndarray:
    """Gaussian per-RPC payload (MB), floored at MIN_PAYLOAD_MB."""
    noise = jax.random.normal(rng, mean.shape, jnp.float32)
    return jnp.maximum(mean + std * noise, MIN_PAYLOAD_MB)


def inflight_mb(cl) -> jnp.ndarray:
    """Σ remaining MB of transfers on the fabric — the telemetry
    gauge behind the ``net_mb_inflight`` metric column (obs, §9)."""
    transit_m = cl.status == CL_TRANSIT
    return jnp.sum(jnp.where(transit_m, cl.rem_bytes,
                             jnp.zeros_like(cl.rem_bytes)))


def transit(state: SimState, caps: SimCaps, params: SimParams,
            dyn: DynParams, app: AppStatic | None = None) -> SimState:
    """One fabric tick: water-fill every NIC port, advance transfers,
    deliver arrivals into the waiting queue (Transit phase, DESIGN.md §6).

    NIC capacities must be positive: a zero-capacity port (swept
    ``nic_*_mbps=0`` or a zero host scale) yields zero rates, and its
    transfers legitimately never arrive — the run reports zero completions
    rather than inventing transport.

    ``app`` supplies the host→zone table for partial partitions under
    ``faults="chaos"`` (zone-pair link cuts, §7.1): a cut transfer gets
    zero capacity in the water-fill and stalls until the partition heals
    or its attempt times out — nothing crashes.
    """
    cl, inst, net = state.cloudlets, state.instances, state.net
    i32, f32 = jnp.int32, jnp.float32
    H = state.hosts.egress_scale.shape[0]
    NB = net.hist.shape[0]
    dt = dyn.dt

    active = cl.status == CL_TRANSIT
    inst_safe = jnp.maximum(cl.inst, 0)
    dst = jnp.where(active & (cl.inst >= 0), inst.host[inst_safe], -1)
    src = cl.src_host
    cap_e = (state.hosts.egress_scale * dyn.nic_egress_mbps
             * MBIT_PER_S_TO_MBYTE_PER_S)
    cap_i = (state.hosts.ingress_scale * dyn.nic_ingress_mbps
             * MBIT_PER_S_TO_MBYTE_PER_S)
    flowing = active & (dst >= 0)
    if params.faults == "chaos":
        # NIC degradation / brownout (Disruption schedule, §7): a degraded
        # host's ports run at the severity factor sampled when the episode
        # began (FaultState.nic_factor, 1.0 while healthy)
        nic = state.fault.nic_factor
        cap_e = cap_e * nic
        cap_i = cap_i * nic
        if app is not None:
            # partial partition: zero the capacity of transfers crossing a
            # cut zone pair (client ingress, src = -1, is never cut)
            hz = app.host_zone
            cut = (src >= 0) & (dst >= 0) \
                & (state.fault.zone_cut[hz[jnp.maximum(src, 0)],
                                        hz[jnp.maximum(dst, 0)]] > 0)
            flowing = flowing & ~cut

    rate = link_share(
        src, dst, flowing, cap_e, cap_i,
        iters=params.waterfill_iters,
        use_pallas=None if params.use_pallas_tick else False,
        interpret=params.pallas_interpret)

    if params.egress_shaping:
        # Per-instance egress shaping (§6 follow-up): an instance's
        # concurrent transfers share its own ``Instances.bw`` allowance on
        # top of the port-level water-fill — the clamp only ever lowers
        # rates, so NIC feasibility is preserved.  ``src_inst`` is a
        # chaos-phase column otherwise; this opt-in registers it via
        # PHASE_COLUMNS["Transit/egress_shaping"] (DESIGN.md §2.4).
        I = inst.status.shape[0]
        sin = cl.src_inst
        shaped = active & (sin >= 0)
        sin_safe = jnp.maximum(sin, 0)
        n_from = _segsum(shaped.astype(f32), jnp.where(shaped, sin, -1), I)
        share = (inst.bw[sin_safe] * MBIT_PER_S_TO_MBYTE_PER_S
                 / jnp.maximum(n_from[sin_safe], 1.0))
        rate = jnp.where(shaped, jnp.minimum(rate, share), rate)

    rem = cl.rem_bytes
    prog = rate * dt
    # Defensive: a transfer whose target instance vanished (drained between
    # spawn and now) has no NIC to arrive at — deliver it immediately and
    # let dispatch re-balance it.
    stranded = active & (dst < 0)
    arrived = (active & (rem <= prog) & (rate > 0)) | stranded
    t_arr = jnp.clip(state.time + rem / jnp.maximum(rate, 1e-9),
                     state.time, state.time + dt)
    t_arr = jnp.where(stranded, state.time, t_arr)
    moved = jnp.where(active, jnp.minimum(prog, rem), 0.0)
    new_rem = jnp.where(arrived, 0.0,
                        jnp.where(active, jnp.maximum(rem - prog, 0.0), rem))

    cloudlets = cl.with_cols(
        status=jnp.where(arrived, CL_WAITING, cl.status),
        rem_bytes=new_rem)

    # --- per-host accounting ---------------------------------------------
    # Utilization is goodput-based (bytes moved / port capacity): the
    # water-fill hands a lone transfer the whole port, so the allocated
    # rate would read as "saturated" even when only a header crossed.
    C = src.shape[0]
    if C * H <= ONE_HOT_BUDGET:     # one-hot masked sums vectorize
        hosts = jnp.arange(H, dtype=src.dtype)
        out_mb = jnp.sum(jnp.where((active & (src >= 0))[:, None]
                                   & (src[:, None] == hosts[None, :]),
                                   moved[:, None], 0.0), axis=0)
        in_mb = jnp.sum(jnp.where((active & (dst >= 0))[:, None]
                                  & (dst[:, None] == hosts[None, :]),
                                  moved[:, None], 0.0), axis=0)
    else:                           # huge pools × many hosts: O(C) scatter
        out_mb = _segsum(moved, jnp.where(active, src, -1), H)
        in_mb = _segsum(moved, jnp.where(active, dst, -1), H)
    util_e = out_mb / jnp.maximum(cap_e * dt, 1e-9)
    util_i = in_mb / jnp.maximum(cap_i * dt, 1e-9)

    # --- transit-time statistics (sub-tick arrival vs spawn time) -------
    # Stranded deliveries are excluded: their "duration" is time spent
    # addressed to a dead replica, not fabric crossing time, and would
    # pollute the percentiles during heavy scale-in churn.
    real = arrived & ~stranded
    dur = jnp.where(real, t_arr - cl.arrival, 0.0)
    bucket = jnp.clip((dur / params.net_hist_bin_s).astype(i32), 0, NB - 1)
    if C * NB <= ONE_HOT_BUDGET:
        bins = jnp.arange(NB, dtype=i32)
        hist = net.hist + jnp.sum(
            (real[:, None] & (bucket[:, None] == bins[None, :]))
            .astype(i32), axis=0)
    else:
        hist = net.hist + _segsum(
            jnp.ones((C,), i32), jnp.where(real, bucket, -1), NB)
    n_arr = jnp.sum(real.astype(i32))

    net = net._replace(
        bytes_out=net.bytes_out + out_mb,
        bytes_in=net.bytes_in + in_mb,
        egress_busy=net.egress_busy + util_e * dt,
        ingress_busy=net.ingress_busy + util_i * dt,
        transits=net.transits + n_arr,
        transit_sum=net.transit_sum + jnp.sum(dur),
        hist=hist)
    return state._replace(cloudlets=cloudlets, net=net)
