"""Free-slot allocation for fixed-capacity tensor pools.

The paper's Java engine calls ``new RpcCloudlet()``; the tensor engine
instead assigns the r-th new cloudlet to the r-th free slot of the active
buffer with two prefix sums and two scatters — O(pool + spawns), no sort.
Overflow is *counted*, never silently ignored (backpressure/drop semantics
are the caller's choice).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SlotAssignment(NamedTuple):
    dst: jnp.ndarray       # [K] i32 destination pool slot for rank r
    src: jnp.ndarray       # [K] i32 source descriptor index for rank r
    live: jnp.ndarray      # [K] bool rank is actually assigned
    n_assigned: jnp.ndarray  # scalar i32
    n_dropped: jnp.ndarray   # scalar i32 (valid descriptors with no slot)


def assign_free_slots(free_mask: jnp.ndarray, valid_mask: jnp.ndarray,
                      k_static: int | None = None) -> SlotAssignment:
    """Match the r-th valid descriptor to the r-th free pool slot.

    Parameters
    ----------
    free_mask : [C] bool — pool slots that may be written.
    valid_mask : [M] bool — descriptors that want a slot (flattened).
    k_static : static cap on assignments per call (default min(C, M)).
    """
    C = free_mask.shape[0]
    M = valid_mask.shape[0]
    K = min(C, M) if k_static is None else min(k_static, C, M)
    i32 = jnp.int32

    free_rank = jnp.cumsum(free_mask.astype(i32)) - 1      # [C]
    want_rank = jnp.cumsum(valid_mask.astype(i32)) - 1     # [M]
    n_free = free_rank[-1] + 1
    n_want = want_rank[-1] + 1
    n_assigned = jnp.minimum(jnp.minimum(n_free, n_want), K)

    # slot_of_rank[r] = index of the r-th free slot (ranks ≥ K dropped).
    slot_of_rank = jnp.zeros((K,), i32).at[
        jnp.where(free_mask & (free_rank < K), free_rank, K)
    ].set(jnp.arange(C, dtype=i32), mode="drop")
    # src_of_rank[r] = index of the r-th valid descriptor.
    src_of_rank = jnp.zeros((K,), i32).at[
        jnp.where(valid_mask & (want_rank < K), want_rank, K)
    ].set(jnp.arange(M, dtype=i32), mode="drop")

    ranks = jnp.arange(K, dtype=i32)
    live = ranks < n_assigned
    return SlotAssignment(dst=slot_of_rank, src=src_of_rank, live=live,
                          n_assigned=n_assigned,
                          n_dropped=n_want - n_assigned)


def scatter_new(pool_field: jnp.ndarray, asg: SlotAssignment,
                flat_values: jnp.ndarray) -> jnp.ndarray:
    """Write ``flat_values[asg.src[r]]`` into ``pool_field[asg.dst[r]]``.

    ``flat_values`` must be the RAW [M] descriptor array (same indexing as
    the ``valid_mask`` passed to :func:`assign_free_slots`) — never
    pre-gathered by ``asg.src`` (that would double-index).
    """
    C = pool_field.shape[0]
    dst = jnp.where(asg.live, asg.dst, C)  # sentinel C → dropped
    return pool_field.at[dst].set(flat_values[asg.src], mode="drop")


def scatter_ranked(pool_field: jnp.ndarray, asg: SlotAssignment,
                   rank_values: jnp.ndarray) -> jnp.ndarray:
    """Write rank-level values (already gathered via ``asg.src``, e.g.
    freshly sampled lengths of shape [K]) into the assigned slots."""
    C = pool_field.shape[0]
    dst = jnp.where(asg.live, asg.dst, C)
    return pool_field.at[dst].set(rank_values, mode="drop")


def scatter_const(pool_field: jnp.ndarray, asg: SlotAssignment,
                  value) -> jnp.ndarray:
    """Write a broadcast constant into every assigned slot."""
    C = pool_field.shape[0]
    dst = jnp.where(asg.live, asg.dst, C)
    val = jnp.broadcast_to(jnp.asarray(value, pool_field.dtype),
                           (asg.dst.shape[0],))
    return pool_field.at[dst].set(val, mode="drop")


def segment_rank(keys: jnp.ndarray, mask: jnp.ndarray,
                 num_segments: int) -> jnp.ndarray:
    """Rank of each masked element within its segment (FCFS by slot order).

    Sort-based (O(n log n)); used only on the capped space-shared dispatch
    path where intra-service ordering matters (paper §4.2 waiting queue
    admission).  Unmasked elements get rank = n (never admitted).
    """
    n = keys.shape[0]
    i32 = jnp.int32
    big = jnp.asarray(num_segments, i32)
    k = jnp.where(mask, keys.astype(i32), big)
    order = jnp.argsort(k, stable=True)  # stable → slot order within segment
    pos = jnp.zeros((n,), i32).at[order].set(jnp.arange(n, dtype=i32))
    # first position of each segment
    first = jnp.full((num_segments + 1,), n, i32).at[k].min(pos)
    rank = pos - first[k]
    return jnp.where(mask, rank, n)
