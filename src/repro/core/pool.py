"""Free-slot allocation for fixed-capacity tensor pools.

The paper's Java engine calls ``new RpcCloudlet()``; the tensor engine
instead assigns the r-th new cloudlet to the r-th free slot of the active
buffer with two prefix sums and two scatters — O(pool + spawns), no sort.
Overflow is *counted*, never silently ignored (backpressure/drop semantics
are the caller's choice).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from ..analysis.annotate import checked_mode, collide, disjoint


def segment_sum(data: jnp.ndarray, ids: jnp.ndarray, n: int,
                valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Scatter-add ``data`` into ``n`` segments, dropping -1/invalid ids —
    the drop-semantics workhorse of the tick phases (scheduler, network)."""
    if valid is None:
        valid = ids >= 0
    idx = jnp.where(valid, ids, n)
    with collide("segment_sum"):
        return jnp.zeros((n,), data.dtype).at[idx].add(
            jnp.where(valid, data, jnp.zeros_like(data)), mode="drop")


class SlotAssignment(NamedTuple):
    dst: jnp.ndarray       # [K] i32 destination pool slot for rank r
    src: jnp.ndarray       # [K] i32 source descriptor index for rank r
    live: jnp.ndarray      # [K] bool rank is actually assigned
    n_assigned: jnp.ndarray  # scalar i32
    n_dropped: jnp.ndarray   # scalar i32 (valid descriptors with no slot)


def assign_free_slots(free_mask: jnp.ndarray, valid_mask: jnp.ndarray,
                      k_static: int | None = None) -> SlotAssignment:
    """Match the r-th valid descriptor to the r-th free pool slot.

    Parameters
    ----------
    free_mask : [C] bool — pool slots that may be written.
    valid_mask : [M] bool — descriptors that want a slot (flattened).
    k_static : static cap on assignments per call (default min(C, M)).
    """
    C = free_mask.shape[0]
    M = valid_mask.shape[0]
    K = min(C, M) if k_static is None else min(k_static, C, M)
    i32 = jnp.int32

    free_rank = jnp.cumsum(free_mask.astype(i32)) - 1      # [C]
    want_rank = jnp.cumsum(valid_mask.astype(i32)) - 1     # [M]
    n_free = free_rank[-1] + 1
    n_want = want_rank[-1] + 1
    n_assigned = jnp.minimum(jnp.minimum(n_free, n_want), K)

    # slot_of_rank[r] = index of the r-th free slot (ranks ≥ K dropped).
    slot_of_rank = jnp.zeros((K,), i32).at[
        jnp.where(free_mask & (free_rank < K), free_rank, K)
    ].set(jnp.arange(C, dtype=i32), mode="drop")
    # src_of_rank[r] = index of the r-th valid descriptor.
    src_of_rank = jnp.zeros((K,), i32).at[
        jnp.where(valid_mask & (want_rank < K), want_rank, K)
    ].set(jnp.arange(M, dtype=i32), mode="drop")

    ranks = jnp.arange(K, dtype=i32)
    live = ranks < n_assigned
    return SlotAssignment(dst=slot_of_rank, src=src_of_rank, live=live,
                          n_assigned=n_assigned,
                          n_dropped=n_want - n_assigned)


def scatter_pool(cl, asg: SlotAssignment, **cols):
    """Fused spawn writer: one wave of new cloudlets lands in exactly TWO
    scatters — every i32 field of the stacked [C, NI] pool in one, every
    f32 field of the [C, NF] pool in the other.  All three spawn sites —
    root cloudlets (``gen_spawn``), successors (``derive``) and retry
    respawns (``faults.disruption``, §7) — go through here, so the pool
    write cost per tick is independent of how many columns exist.

    ``cl`` is the :class:`core.types.Cloudlets` buffer; column order and
    WIDTH come from its mode-keyed ``PoolLayout``, so the storage layout
    lives only in ``core.types``.  Columns are passed BY NAME, each a
    rank-level [K] array or a scalar to broadcast.  Every column of the
    active layout must be supplied — a spawn initializes whole rows —
    while registered columns outside the layout are accepted and skipped,
    so spawn sites stay mode-agnostic (the dead values fold away under
    jit).  Unregistered names raise.  Descriptor-level [M] arrays must be
    pre-gathered by ``asg.src``.  Returns the updated ``Cloudlets``.
    """
    from .types import CL_F_FIELDS, CL_I_FIELDS
    layout = cl.layout
    vocab = set(CL_I_FIELDS) | set(CL_F_FIELDS)
    missing = [n for n in layout.columns if n not in cols]
    unknown = sorted(set(cols) - vocab)
    if missing or unknown:
        raise TypeError(
            f"scatter_pool needs every column of the active layout "
            f"{layout.columns}; missing {sorted(missing)}, "
            f"unknown {unknown}")
    ints, flts = cl.ints, cl.flts
    C = ints.shape[0]
    K = asg.dst.shape[0]
    dst = jnp.where(asg.live, asg.dst, C)  # sentinel C → dropped

    def stacked(names, dtype):
        return jnp.stack(
            [jnp.broadcast_to(jnp.asarray(cols[n], dtype), (K,))
             for n in names], axis=1)

    if checked_mode():
        # The disjointness declared below is exactly what free-slot
        # compaction guarantees; REPRO_CHECKED=1 re-verifies it at runtime.
        from jax.experimental import checkify
        hits = jnp.zeros((C,), jnp.int32).at[dst].add(1, mode="drop")
        checkify.check(jnp.all(hits <= 1),
                       "scatter_pool: duplicate destination slot")
        checkify.check(
            jnp.all(jnp.where(asg.live, (asg.dst >= 0) & (asg.dst < C),
                              True)),
            "scatter_pool: live destination out of range")

    # Disjointness argument: live lanes carry slot_of_rank values — indices
    # of DISTINCT free slots by construction of the prefix-sum compaction —
    # and dead lanes carry the sentinel C, which mode="drop" discards.  The
    # interval domain cannot see this (the rank→slot gather erases the
    # rank tag), hence the declaration + the checked-mode assert above.
    with disjoint("scatter_pool"):
        return cl.replace(
            ints=ints.at[dst].set(stacked(layout.i_fields, ints.dtype),
                                  mode="drop"),
            flts=flts.at[dst].set(stacked(layout.f_fields, flts.dtype),
                                  mode="drop"))


def segment_rank(keys: jnp.ndarray, mask: jnp.ndarray,
                 num_segments: int, block: int = 128) -> jnp.ndarray:
    """Rank of each masked element within its segment (FCFS by slot order).

    Sort-free prefix ranking, used on the capped space-shared dispatch path
    (paper §4.2 waiting-queue admission).  The pool is cut into blocks of
    ``block`` lanes: intra-block ranks come from a strictly-lower-triangular
    equality count (O(n·block) elementwise work, no sort), block offsets
    from a per-segment count matrix cumsummed over blocks.  Unmasked
    elements get rank = n (never admitted).

    The count matrix is [n/block, num_segments+1]; when that exceeds a
    memory budget (huge instance counts × huge pools) the sort-based
    ranking — O(n) memory — takes over.
    """
    n = keys.shape[0]
    n_blocks = -(-n // max(min(block, n), 1))
    if n_blocks * (num_segments + 1) > (1 << 24):   # > 64 MB of i32 counts
        return segment_rank_sorted(keys, mask, num_segments)
    i32 = jnp.int32
    big = jnp.asarray(num_segments, i32)
    k = jnp.where(mask, keys.astype(i32), big)
    L = min(block, n)
    pad = -n % L
    if pad:
        k = jnp.concatenate([k, jnp.full((pad,), big, i32)])
        mask_p = jnp.concatenate([mask, jnp.zeros((pad,), bool)])
    else:
        mask_p = mask
    B = k.shape[0] // L
    kb = k.reshape(B, L)
    mb = mask_p.reshape(B, L)
    # intra-block rank: earlier masked lanes of the same segment
    same = (kb[:, :, None] == kb[:, None, :]) & mb[:, None, :]
    earlier = jnp.tril(jnp.ones((L, L), bool), k=-1)[None]
    intra = jnp.sum(same & earlier, axis=2).astype(i32)            # [B, L]
    # exclusive per-segment totals of all preceding blocks
    with collide("segment_rank"):
        cnt = jnp.zeros((B, num_segments + 1), i32).at[
            jnp.arange(B, dtype=i32)[:, None], kb].add(mb.astype(i32))
    base = jnp.cumsum(cnt, axis=0) - cnt                           # [B, S+1]
    rank = (base[jnp.arange(B)[:, None], kb] + intra).reshape(-1)[:n]
    return jnp.where(mask, rank, n)


def segment_rank_sorted(keys: jnp.ndarray, mask: jnp.ndarray,
                        num_segments: int) -> jnp.ndarray:
    """O(n log n) sort-based ranking: the reference oracle for
    :func:`segment_rank` and its O(n)-memory fallback for segment counts
    too large for the blocked count matrix."""
    n = keys.shape[0]
    i32 = jnp.int32
    big = jnp.asarray(num_segments, i32)
    k = jnp.where(mask, keys.astype(i32), big)
    order = jnp.argsort(k, stable=True)  # stable → slot order within segment
    pos = jnp.zeros((n,), i32).at[order].set(jnp.arange(n, dtype=i32))
    # first position of each segment
    with collide("segment_rank_sorted"):
        first = jnp.full((num_segments + 1,), n, i32).at[k].min(pos)
    rank = pos - first[k]
    return jnp.where(mask, rank, n)
