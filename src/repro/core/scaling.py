"""Service scaling policies (paper §5.3, Algorithms 4–5).

* NS — no scaling (paper §6.4 baseline).
* HS — horizontal (Alg 4): replicate the instance set of a hot service onto
  a VM with head-room; scale-in drains the newest replica of a cold service.
* VS — vertical (Alg 5): raise/lower the CPU share of hot/cold instances
  within the requests/limits band, releasing resources first and restoring
  on allocation failure (modelled by a per-VM fair-share clamp).
* HYBRID — HS until the replica cap, then VS (beyond-paper built-in).

The scaling event fires every ``scale_interval`` ticks (paper: "a service
scaling event is triggered at regular intervals").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import policies
from .app import AppStatic
from .types import (ALERT_FIRING, ALERT_PENDING, DynParams, INST_DRAIN,
                    INST_FREE, INST_ON, SimCaps, SimParams, SimState)
from ..analysis.annotate import collide


def _service_util(state: SimState, n_services: int) -> jnp.ndarray:
    """Mean utilization EMA over the ON replicas of each service."""
    inst = state.instances
    on = inst.status == INST_ON
    sid = jnp.where(on, inst.service, -1)
    idx = jnp.where(sid >= 0, sid, n_services)
    with collide("service_util"):
        tot = jnp.zeros((n_services,), jnp.float32).at[idx].add(
            jnp.where(on, inst.util_ema, 0.0), mode="drop")
        cnt = jnp.zeros((n_services,), jnp.float32).at[idx].add(
            on.astype(jnp.float32), mode="drop")
    return tot / jnp.maximum(cnt, 1.0)


# ===========================================================================
# Horizontal scaling (Algorithm 4)
# ===========================================================================

def horizontal(state: SimState, app: AppStatic, caps: SimCaps,
               dyn: DynParams, params: SimParams | None = None) -> SimState:
    S = app.n_services
    util = _service_util(state, S)
    want_out = ((util > dyn.hs_util_hi)
                & (state.sched.svc_replicas >= 1)
                & (state.sched.svc_replicas < caps.max_replicas))
    want_in = (util < dyn.hs_util_lo) & (state.sched.svc_replicas > 1)

    if params is not None and params.telemetry == "stream" \
            and params.alerting == "burn":
        # Burn-rate-gated control plane (DESIGN.md §10): with
        # dyn.hs_mode == HS_SLO_BURN, scale-out triggers on a FIRING burn
        # alert for the service (any rule) once its stabilization window
        # expired — not on the util EMA — and scale-in is additionally
        # vetoed while an alert is pending or firing.  dyn.hs_mode is a
        # traced selector, so one run_batch sweep compares both control
        # planes; with hs_mode == HS_UTIL the where() selects the exact
        # util-gated masks and the program stays bit-identical.
        al = state.alerts
        firing = (al.astate == ALERT_FIRING).any(axis=1)
        active = firing | (al.astate == ALERT_PENDING).any(axis=1)
        burn = dyn.hs_mode == policies.HS_SLO_BURN
        want_out_burn = (firing & (state.time >= al.hold_until)
                         & (state.sched.svc_replicas >= 1)
                         & (state.sched.svc_replicas < caps.max_replicas))
        want_out = jnp.where(burn, want_out_burn, want_out)
        want_in = jnp.where(burn, want_in & ~active, want_in)
        # stabilization clock arms on the scale-out ATTEMPT (commit may
        # still fail on capacity) — fixed hold beats re-firing every tick
        state = state._replace(alerts=al._replace(
            hold_until=jnp.where(burn & want_out,
                                 state.time + dyn.slo_stabilize_s,
                                 al.hold_until)))

    def body(s, st: SimState) -> SimState:
        st = jax.lax.cond(want_out[s], lambda x: _scale_out(x, s, app),
                          lambda x: x, st)
        st = jax.lax.cond(want_in[s], lambda x: _scale_in(x, s),
                          lambda x: x, st)
        return st

    return jax.lax.fori_loop(0, S, body, state)


def _scale_out(state: SimState, s, app: AppStatic) -> SimState:
    """Alg 4: create a replica; bind on success, undo (no-op) on failure."""
    inst, vms, sched = state.instances, state.vms, state.sched
    slot = jnp.argmax(inst.status == INST_FREE)
    has_slot = inst.status[slot] == INST_FREE
    # paper Alg 3 line 3: VM queue sorted by descending available resources.
    # Down hosts (fault injection, §7) are excluded — replicas respawn only
    # onto live nodes (host id = vm id; all-up in faults="none" mode).
    free = jnp.where(state.fault.host_up > 0, vms.mips - vms.mips_used,
                     -jnp.inf)
    vm = jnp.argmax(free)
    need_mips = app.tmpl_mips[s]
    need_ram = app.tmpl_ram[s]
    fits = (free[vm] >= need_mips) & (vms.ram[vm] - vms.ram_used[vm]
                                      >= need_ram)
    do = has_slot & fits

    def commit(st: SimState) -> SimState:
        i = st.instances._replace(
            status=st.instances.status.at[slot].set(INST_ON),
            service=st.instances.service.at[slot].set(s),
            vm=st.instances.vm.at[slot].set(vm),
            host=st.instances.host.at[slot].set(vm),
            mips=st.instances.mips.at[slot].set(need_mips),
            limit_mips=st.instances.limit_mips.at[slot].set(
                app.tmpl_limit_mips[s]),
            request_mips=st.instances.request_mips.at[slot].set(need_mips),
            ram=st.instances.ram.at[slot].set(need_ram),
            limit_ram=st.instances.limit_ram.at[slot].set(
                app.tmpl_limit_ram[s]),
            bw=st.instances.bw.at[slot].set(app.tmpl_bw[s]),
            util_ema=st.instances.util_ema.at[slot].set(0.5),
        )
        v = st.vms._replace(
            mips_used=st.vms.mips_used.at[vm].add(need_mips),
            ram_used=st.vms.ram_used.at[vm].add(need_ram))
        rank = st.sched.svc_replicas[s]
        R = st.sched.inst_of_rank.shape[1]
        # clamp is a no-op (want_out requires svc_replicas < max_replicas)
        # but makes svc_replicas ∈ [0, max_replicas] a local invariant the
        # index-safety verifier can carry through the fori loop
        sc = st.sched._replace(
            inst_of_rank=st.sched.inst_of_rank.at[s, rank].set(slot),
            svc_replicas=st.sched.svc_replicas.at[s].set(
                jnp.minimum(st.sched.svc_replicas[s] + 1, R)))
        c = st.counters._replace(scale_out=st.counters.scale_out + 1)
        return st._replace(instances=i, vms=v, sched=sc, counters=c)

    return jax.lax.cond(do, commit, lambda st: st, state)


def _scale_in(state: SimState, s) -> SimState:
    """Drain the newest ON replica; the slot frees once its queue empties.

    Only ON replicas are eligible: flipping an ``INST_DOWN`` replica (chaos
    mode, §7) to DRAIN would steal its restart path and let the VM share be
    released twice (``drain_dies`` in the Disruption phase + ``drain_done``
    in execute).  When the newest ON replica is not the newest rank, the
    last rank's entry moves into the vacated rank so the dispatch table
    stays compact (rank order is not load-bearing).  Rank 0 is never
    drained; with no ON replica beyond it, scale-in skips.
    """
    sched, inst = state.sched, state.instances
    R = sched.inst_of_rank.shape[1]
    idx = jnp.arange(R)
    slots = sched.inst_of_rank[s]
    nrep = sched.svc_replicas[s]
    on = ((idx < nrep) & (slots >= 0)
          & (inst.status[jnp.maximum(slots, 0)] == INST_ON))
    any_on = on.any()
    rank = jnp.where(any_on, R - 1 - jnp.argmax(on[::-1]), -1)
    slot = slots[jnp.maximum(rank, 0)]
    ok = any_on & (rank >= 1)

    def commit(st: SimState) -> SimState:
        i = st.instances._replace(
            status=st.instances.status.at[slot].set(INST_DRAIN))
        # clamps are no-ops (ok requires rank ≥ 1, hence svc_replicas ≥ 2)
        # but keep `last` and the new count provably in range
        last = jnp.clip(st.sched.svc_replicas[s] - 1, 0, R - 1)
        iof = st.sched.inst_of_rank.at[s, rank].set(
            jnp.where(rank == last, -1, st.sched.inst_of_rank[s, last]))
        sc = st.sched._replace(
            inst_of_rank=iof.at[s, last].set(-1),
            svc_replicas=st.sched.svc_replicas.at[s].set(
                jnp.maximum(st.sched.svc_replicas[s] - 1, 0)))
        c = st.counters._replace(scale_in=st.counters.scale_in + 1)
        return st._replace(instances=i, sched=sc, counters=c)

    return jax.lax.cond(ok, commit, lambda st: st, state)


# ===========================================================================
# Vertical scaling (Algorithm 5) — vectorized with per-VM fair-share clamp
# ===========================================================================

def vertical(state: SimState, app: AppStatic, caps: SimCaps,
             dyn: DynParams) -> SimState:
    inst, vms = state.instances, state.vms
    V = vms.mips.shape[0]
    on = inst.status == INST_ON

    want_up = on & (inst.util_ema > dyn.vs_util_hi) & \
        (inst.mips < inst.limit_mips)
    want_down = on & (inst.util_ema < dyn.vs_util_lo) & \
        (inst.mips > inst.request_mips)

    target = jnp.where(
        want_up, jnp.minimum(inst.mips * dyn.vs_up_factor,
                             inst.limit_mips),
        jnp.where(want_down,
                  jnp.maximum(inst.mips * dyn.vs_down_factor,
                              inst.request_mips),
                  inst.mips))
    delta = target - inst.mips
    dec = jnp.minimum(delta, 0.0)
    inc = jnp.maximum(delta, 0.0)

    vm_idx = jnp.where(inst.vm >= 0, inst.vm, V)
    dec_per_vm = jnp.zeros((V,), jnp.float32).at[vm_idx].add(dec, mode="drop")
    inc_per_vm = jnp.zeros((V,), jnp.float32).at[vm_idx].add(inc, mode="drop")
    # Alg 5: release first, then try to allocate the new request; scale the
    # grant down per-VM when the combined asks exceed head-room ("restore
    # instance on failure" becomes a partial/zero grant).
    headroom = vms.mips - (vms.mips_used + dec_per_vm)
    grant = jnp.clip(headroom / jnp.maximum(inc_per_vm, 1e-9), 0.0, 1.0)
    inc_granted = inc * grant[jnp.minimum(vm_idx, V - 1)]

    new_mips = inst.mips + dec + inc_granted
    applied = dec + inc_granted
    vms = vms._replace(mips_used=vms.mips_used + jnp.zeros(
        (V,), jnp.float32).at[vm_idx].add(applied, mode="drop"))
    i32 = jnp.int32
    counters = state.counters._replace(
        scale_up=state.counters.scale_up
        + jnp.sum((want_up & (inc_granted > 0)).astype(i32)),
        scale_down=state.counters.scale_down
        + jnp.sum(want_down.astype(i32)))
    return state._replace(
        instances=inst._replace(mips=new_mips), vms=vms, counters=counters)


# ===========================================================================

def scaling_event(state: SimState, app: AppStatic, caps: SimCaps,
                  params: SimParams, dyn: DynParams) -> SimState:
    """Dispatch to the configured policy (paper §6.4: NS / HS / VS)."""
    if params.scaling_policy == policies.SCALE_NONE:
        return state
    if params.scaling_policy == policies.SCALE_HORIZONTAL:
        return horizontal(state, app, caps, dyn, params)
    if params.scaling_policy == policies.SCALE_VERTICAL:
        return vertical(state, app, caps, dyn)
    if params.scaling_policy == policies.SCALE_HYBRID:
        state = horizontal(state, app, caps, dyn, params)
        return vertical(state, app, caps, dyn)
    raise ValueError(f"unknown scaling policy {params.scaling_policy}")
