"""Cloudlet scheduler phases (paper §4.2) + derivative spawning (§4.1.2).

Every tick runs, in order:

  ``gen_spawn``   — new requests fire root cloudlets at API entry services
  ``disruption``  — (chaos mode, core/faults.py) hosts crash/recover,
                    instances die, doomed work fails, retries respawn,
                    circuit breakers advance
  ``transit``     — (fabric mode, core/network.py) in-flight payloads share
                    host NICs max-min fairly; arrivals join the waiting queue
  ``dispatch``    — waiting→execution transition with load balancing
  ``execute``     — time-shared progress + finish detection + usage history
  ``derive``      — finished cloudlets spawn successors along the DAG
  ``complete``    — requests whose last cloudlet finished get a response

The waiting/execution/finished "queues" of the paper are status masks on
the active cloudlet buffer; the finished queue is folded into per-request
and per-service aggregates (DESIGN.md §2).

One-pass tick discipline (DESIGN.md §2.2): spawn waves write the stacked
cloudlet pool with two row scatters (``scatter_pool``), and the execution
phase folds progress plus every finish-side reduction into a single fused
op (``cloudlet_finish`` — Pallas kernel on TPU, stacked-scatter jnp
reference elsewhere), so the ``max_cloudlets`` buffer streams through
memory a constant number of times per tick regardless of how many
statistics are maintained.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..analysis import streams
from . import network as netmod
from . import policies
from ..kernels.cloudlet_step import cloudlet_finish_pool as _cloudlet_finish_op
from .app import AppStatic
from .pool import (assign_free_slots, scatter_pool, segment_rank,
                   segment_sum as _segsum)
from ..analysis.annotate import collide
from .types import (CL_EXEC, CL_FREE, CL_TRANSIT, CL_WAITING,
                    DynParams, INST_DRAIN, INST_FREE, INST_ON, SimCaps,
                    SimParams, SimState)


# ===========================================================================
# Generation: new requests + root cloudlets (paper Alg 1 + "Dispatching")
# ===========================================================================

class GenResult(NamedTuple):
    n_new_requests: jnp.ndarray


def gen_spawn(state: SimState, app: AppStatic, caps: SimCaps,
              fired: jnp.ndarray, api: jnp.ndarray,
              wait_proposal: jnp.ndarray, rng: jnp.ndarray, dyn: DynParams,
              params: SimParams | None = None, net_rng=None
              ) -> Tuple[SimState, GenResult]:
    """Allocate request slots for fired clients and spawn root cloudlets.

    With ``net_rng`` set (network fabric mode, DESIGN.md §6) each root
    cloudlet is addressed to a replica and enters TRANSIT carrying the
    API's request payload — the client is external, so the transfer
    contends only on the destination host's ingress NIC (src_host = -1).
    """
    req, cl, ctr = state.requests, state.cloudlets, state.counters
    R = req.api.shape[0]
    i32, f32 = jnp.int32, jnp.float32
    Nc = fired.shape[0]
    K = caps.k_fire if caps.k_fire > 0 else Nc
    K = min(K, Nc)
    E = app.api_entry.shape[1]

    rank = jnp.cumsum(fired.astype(i32)) - 1
    # Admission: per-tick budget AND the generator's numLimit (Alg 1) —
    # both enforced per client so a burst tick cannot overshoot the limit.
    in_budget = fired & (rank < K) & (req.count + rank < dyn.num_limit)
    slot = req.count + rank
    has_slot = in_budget & (slot < R)
    n_accept = jnp.sum(has_slot.astype(i32))
    n_pool_drop = jnp.sum((in_budget & ~has_slot).astype(i32))

    # Client wait update: accepted/pool-dropped clients rest; over-budget
    # clients retry next tick (backpressure); others count down.
    new_wait = jnp.where(
        in_budget, wait_proposal,
        jnp.where(fired, 0, jnp.maximum(state.clients.wait - 1, 0)))

    # ---- write accepted requests -------------------------------------
    # The request pool is append-only, so a fresh slot still holds its
    # zeros_state values (outstanding=spawned=critical_len=0, response=-1,
    # finish=0) — only api and arrival need writing.  finish then grows
    # purely via the execute-phase scatter-max (tfin ≥ arrival always).
    dst = jnp.where(has_slot, slot, R)
    requests = req._replace(
        count=req.count + n_accept,
        api=req.api.at[dst].set(api, mode="drop"),
        arrival=req.arrival.at[dst].set(
            jnp.full((Nc,), 0.0, f32) + state.time, mode="drop"),
    )

    # ---- root cloudlet descriptors [K, E] ------------------------------
    # Compact accepted clients into rank order.
    client_of_rank = jnp.zeros((K,), i32).at[
        jnp.where(has_slot & (rank < K), rank, K)
    ].set(jnp.arange(Nc, dtype=i32), mode="drop")
    ranks = jnp.arange(K, dtype=i32)
    r_live = ranks < n_accept
    api_r = api[client_of_rank]                      # [K]
    req_slot_r = req.count + ranks                   # [K]

    svc_d = app.api_entry[api_r]                     # [K, E]
    n_ent = app.api_n_entry[api_r]                   # [K]
    valid = (r_live[:, None] & (jnp.arange(E)[None, :] < n_ent[:, None])
             & (svc_d >= 0)).reshape(-1)
    svc_flat = svc_d.reshape(-1)
    req_flat = jnp.broadcast_to(req_slot_r[:, None], (K, E)).reshape(-1)

    asg = assign_free_slots(cl.status == CL_FREE, valid)
    Ka = asg.dst.shape[0]
    svc_new = svc_flat[asg.src]          # rank-level gather (for sampling)
    # clamp is a no-op on live lanes (has_slot ⇒ slot < R, and only
    # has_slot descriptors are compacted into live ranks) but makes
    # req ∈ [-1, R-1] a pool-column invariant the verifier can carry
    req_new = jnp.minimum(req_flat[asg.src], R - 1)
    api_flat = jnp.broadcast_to(api_r[:, None], (K, E)).reshape(-1)
    api_new = api_flat[asg.src]
    # client→entry edge id: after the S*d_max call edges (resilience, §7)
    edge_new = app.n_services * app.succ.shape[1] + api_new
    noise = jax.random.normal(rng, (Ka,), f32)
    length = jnp.maximum(app.len_mean[svc_new] + app.len_std[svc_new] * noise,
                         1.0)

    if net_rng is None:                  # uniform mode (degenerate network)
        status_new, inst_new = CL_WAITING, -1
        src_host_new, bytes_new = -1, 0.0
        rr = state.rr
    else:                                # fabric mode: address + payload
        k_lb, k_pay = streams.split(net_rng, names=("lb", "payload"))
        tgt, rr = netmod.pick_replicas(svc_new, asg.live, state, caps,
                                       params, k_lb)
        payload = netmod.sample_payload(app.api_payload_mean[api_new],
                                        app.api_payload_std[api_new], k_pay)
        # No live replica yet → park in the waiting queue (dispatch
        # re-balances); clients are external, so no loopback fast path.
        status_new = jnp.where(tgt >= 0, CL_TRANSIT, CL_WAITING)
        inst_new = tgt
        src_host_new = -1
        bytes_new = jnp.where(tgt >= 0, payload, 0.0)

    # Fused spawn write: every i32 field in one scatter, every f32 field
    # in the other (columns outside this mode's layout are skipped).
    cloudlets = scatter_pool(
        cl, asg,
        status=status_new, req=req_new, service=svc_new, inst=inst_new,
        wait_ticks=0, depth=0, src_host=src_host_new,
        attempt=0, edge=edge_new, src_inst=-1,
        length=length, rem=length,
        arrival=jnp.full((Ka,), 0.0, f32) + state.time, start=-1.0,
        rem_bytes=bytes_new)

    # direct scatter-adds: no [R]-sized temporaries on the spawn path.
    # A request with several entry cloudlets hits its counters repeatedly —
    # accumulation is the point.
    rdst = jnp.where(asg.live, req_new, R)
    with collide("spawn_request_counts"):
        requests = requests._replace(
            outstanding=requests.outstanding.at[rdst].add(1, mode="drop"),
            spawned=requests.spawned.at[rdst].add(1, mode="drop"),
        )
    counters = ctr._replace(
        spawned=ctr.spawned + asg.n_assigned,
        dropped_cloudlets=ctr.dropped_cloudlets + asg.n_dropped,
        dropped_requests=ctr.dropped_requests + n_pool_drop,
    )
    state = state._replace(
        rr=rr, clients=state.clients._replace(wait=new_wait),
        requests=requests, cloudlets=cloudlets, counters=counters)
    return state, GenResult(n_new_requests=n_accept)


# ===========================================================================
# Dispatch: waiting → execution with load balancing (paper §4.2)
# ===========================================================================

def dispatch(state: SimState, app: AppStatic, caps: SimCaps,
             params: SimParams, dyn: DynParams, rng: jnp.ndarray,
             network: bool = False) -> SimState:
    cl, inst, sched = state.cloudlets, state.instances, state.sched
    C = cl.status.shape[0]
    I = inst.status.shape[0]
    S = app.n_services
    i32 = jnp.int32

    if network:
        # Fabric mode: transport is modeled by the Transit phase — a
        # waiting cloudlet has already crossed the network (or took the
        # loopback fast path) and is usually pre-addressed to a replica.
        waiting = cl.status == CL_WAITING
    else:
        # An RPC hop must traverse the network before it may be scheduled
        # (net_latency models client→service and service→service
        # transport) — the load-independent degenerate mode.
        waiting = (cl.status == CL_WAITING) & \
            (state.time + 1e-6 >= cl.arrival + dyn.net_latency)
    if params.faults == "chaos":
        # outlier ejection (§7.1): dispatch around OPEN-ejected replicas —
        # the exact identity view when nothing is ejected
        iof, reps = policies.eject_view(sched, state.fault.inst_eject_until,
                                        state.time)
    else:
        iof, reps = sched.inst_of_rank, sched.svc_replicas
    svc = jnp.where(waiting, cl.service, 0)
    replicas = reps[svc]                                    # [C]
    has_rep = waiting & (replicas > 0)
    rep_safe = jnp.maximum(replicas, 1)

    # Shared three-policy rank selection (policies.lb_rank) — dispatch
    # offsets round-robin by slot order; the fabric's spawn-time
    # addressing (network.pick_replicas) uses the same helper with an
    # FCFS wave-rank offset.
    rank = policies.lb_rank(
        params.lb_policy, state.rr, svc, rep_safe,
        jnp.arange(C, dtype=i32), rng,
        iof, inst.status, inst.n_exec, inst.mips)

    target = iof[svc, jnp.minimum(rank, caps.max_replicas - 1)]
    ok = has_rep & (target >= 0)
    tgt_safe = jnp.where(ok, target, 0)
    ok = ok & (inst.status[tgt_safe] == INST_ON)

    if network:
        # Honor the spawn-time address when the replica is still ON and
        # still serves this cloudlet's service (the slot may have been
        # freed and re-bound by scale-in/out while the payload was in
        # flight); otherwise fall through to the fresh load-balancing
        # decision computed above.
        pre = cl.inst
        pre_safe = jnp.maximum(pre, 0)
        use_pre = (waiting & (pre >= 0)
                   & (inst.status[pre_safe] == INST_ON)
                   & (inst.service[pre_safe] == cl.service))
        if params.faults == "chaos":
            # a replica ejected while the payload was in flight is not
            # honored either — re-balance to a healthy one
            use_pre = use_pre & ~(
                state.fault.inst_eject_until[pre_safe] > state.time)
        target = jnp.where(use_pre, pre, target)
        ok = ok | use_pre
        tgt_safe = jnp.where(ok, target, 0)

    if params.max_concurrent > 0:
        # Space-shared admission: FCFS rank within the target instance
        # must fit in the remaining concurrency budget (paper: unselected
        # cloudlets re-enter the waiting queue).  Prefix-sum ranking —
        # no sort on the hot path.
        intra = segment_rank(jnp.where(ok, target, I), ok, I + 1)
        cap_left = jnp.maximum(dyn.max_concurrent - inst.n_exec, 0)
        admit = ok & (intra < cap_left[tgt_safe])
    else:
        admit = ok

    # One pool-sized scatter: admissions per instance.  It both maintains
    # the incremental n_exec counter (execute no longer re-counts the
    # execution queue) and, reduced over the small instance table, yields
    # the per-service dispatch counts for the round-robin cursors.
    admit_per_inst = _segsum(admit.astype(i32),
                             jnp.where(admit, target, -1), I)
    if network:
        # Pre-addressed cloudlets already advanced the cursor at spawn
        # (pick_replicas); counting them again here would step the cursor
        # twice per RPC and pin round-robin traffic to one replica.
        lb_admit = admit & ~use_pre
        lb_per_inst = _segsum(lb_admit.astype(i32),
                              jnp.where(lb_admit, target, -1), I)
        disp_per_svc = _segsum(lb_per_inst, inst.service, S)
    else:
        disp_per_svc = _segsum(admit_per_inst, inst.service, S)
    rr = (state.rr + disp_per_svc) % jnp.maximum(sched.svc_replicas, 1)

    cloudlets = cl.with_cols(
        status=jnp.where(admit, CL_EXEC, cl.status),
        inst=jnp.where(admit, target, cl.inst),
        start=jnp.where(admit & (cl.start < 0), state.time, cl.start),
        wait_ticks=cl.wait_ticks + (waiting & ~admit).astype(i32),
    )
    instances = inst._replace(n_exec=inst.n_exec + admit_per_inst)
    return state._replace(rr=rr, cloudlets=cloudlets, instances=instances)


# ===========================================================================
# Execute: time-shared progress, finish detection, usage history
# ===========================================================================

class FinishInfo(NamedTuple):
    fin: jnp.ndarray       # [C] bool finished this tick
    tfin: jnp.ndarray      # [C] f32 sub-tick finish timestamp
    pre_service: jnp.ndarray  # [C] i32 service ids before slot clearing
    pre_req: jnp.ndarray
    pre_depth: jnp.ndarray
    pre_inst: jnp.ndarray


def execute(state: SimState, app: AppStatic, caps: SimCaps,
            params: SimParams, dyn: DynParams
            ) -> Tuple[SimState, FinishInfo]:
    cl, inst, vms = state.cloudlets, state.instances, state.vms
    I = inst.status.shape[0]
    S = app.n_services
    i32, f32 = jnp.int32, jnp.float32
    dt = dyn.dt

    status_c, rem_c, inst_c = cl.status, cl.rem, cl.inst
    execm = status_c == CL_EXEC

    # n_exec is maintained incrementally (dispatch adds admissions, the
    # finish counts below subtract) — no per-tick re-count over the pool.
    n_exec = inst.n_exec
    if params.share_policy == policies.SHARE_SRPT:
        w = jnp.where(execm, 1.0 / (rem_c + 1.0), 0.0)
        wsum = _segsum(w, jnp.where(execm, inst_c, -1), I)
    else:  # equal time slice: the weight sum IS the execution count
        w = execm.astype(f32)
        wsum = n_exec.astype(f32)
    inst_safe = jnp.where(execm, inst_c, 0)
    # Hardware heterogeneity: instances run at their host's CPU speed
    # (hosts.cpu_scale, 1.0 everywhere by default — an exact multiply);
    # the scheduler/placement still accounts the full allocation.
    mips_eff = inst.mips * state.hosts.cpu_scale[jnp.maximum(inst.host, 0)]
    if params.faults == "chaos":
        # fail-slow hosts (§7.1): a slow host's instances run at a fraction
        # of their allocation — the scheduling weights are untouched, only
        # the effective rate degrades (allocation-based util still reads
        # against inst.mips, so a slow host shows depressed utilization)
        hs = jnp.maximum(inst.host, 0)
        is_slow = (inst.host >= 0) & (state.fault.host_slow[hs] > 0)
        mips_eff = jnp.where(is_slow, mips_eff * dyn.host_slow_factor,
                             mips_eff)
    rate = jnp.where(execm,
                     mips_eff[inst_safe] * w
                     / jnp.maximum(wsum[inst_safe], 1e-9), 0.0)  # MI/s

    # --- fused finish reduction: progress + every per-finish aggregate
    # (Pallas kernel on TPU / interpret, stacked-scatter jnp elsewhere);
    # the per-request arrays are updated in place, so the (often much
    # larger) request pool is never re-streamed here.  The pool-level
    # wrapper slices the kernel's input columns out of the stacked blocks
    # through the mode-keyed PoolLayout ---
    req = state.requests
    out = _cloudlet_finish_op(
        cl, rate, state.time, dt,
        req.finish, req.critical_len, req.outstanding,
        n_inst=I,
        use_pallas=None if params.use_pallas_tick else False,
        interpret=params.pallas_interpret)
    fin, tfin = out.fin, out.tfin
    used_mips = out.inst_acc[:I, 0]
    fin_per_inst = out.inst_acc[:I, 1].astype(i32)

    svc_of_inst = inst.service
    util = jnp.where(inst.mips > 0, used_mips / jnp.maximum(inst.mips, 1e-9),
                     0.0)
    # Usage accounting (paper §5.2): idle floor on every ON instance plus a
    # resize surcharge on vertically-scaled instances.  The scaling signal
    # (util EMA) stays based on raw consumption.
    on = inst.status == INST_ON
    acct_mips = (used_mips * (1.0 + jnp.where(
        inst.mips > inst.request_mips, dyn.vs_overhead_frac, 0.0))
        + dyn.idle_mips_frac * jnp.where(on, inst.mips, 0.0))
    a = dyn.util_ema
    util_ema = jnp.where(inst.status != INST_FREE,
                         a * util + (1 - a) * inst.util_ema, 0.0)
    used_ram = jnp.where(svc_of_inst >= 0,
                         app.ram_per_cl[jnp.maximum(svc_of_inst, 0)]
                         * n_exec.astype(f32), 0.0)

    # --- per-service usage history / node-delay estimates ---------------
    # The cloudlet-axis statistics were accumulated per instance by the
    # fused op; fold them (plus usage) into services with ONE stacked
    # scatter over the small instance table.
    st = state.svc_stats
    svc_rows = jnp.concatenate(
        [(acct_mips * dt)[:, None], out.inst_acc[:I, 1:5]], axis=1)
    sidx = jnp.where(svc_of_inst >= 0, svc_of_inst, S)
    with collide("svc_acc"):
        svc_acc = jnp.zeros((S + 1, 5), f32).at[sidx].add(
            jnp.where((svc_of_inst >= 0)[:, None], svc_rows, 0.0),
            mode="drop")
    svc_stats = st._replace(
        usage_sum=st.usage_sum + svc_acc[:S, 0],
        finished=st.finished + svc_acc[:S, 1].astype(i32),
        delay_sum=st.delay_sum + svc_acc[:S, 2],
        exec_sum=st.exec_sum + svc_acc[:S, 3],
        wait_sum=st.wait_sum + svc_acc[:S, 4],
    )

    # --- request aggregates (already folded in by the fused op) ----------
    requests = req._replace(outstanding=out.req_out, finish=out.req_finish,
                            critical_len=out.req_crit)

    info = FinishInfo(fin=fin, tfin=tfin, pre_service=cl.service,
                      pre_req=cl.req, pre_depth=cl.depth, pre_inst=inst_c)

    # --- clear finished slots (the "finished queue" is the aggregates) --
    cloudlets = cl.with_cols(
        status=jnp.where(fin, CL_FREE, status_c),
        rem=out.new_rem,
        inst=jnp.where(fin, -1, inst_c),
    )

    # --- drained instances release their VM share (HS scale-in) ---------
    n_exec_after = n_exec - fin_per_inst
    drain_done = (inst.status == INST_DRAIN) & (n_exec_after == 0)
    V = vms.mips.shape[0]
    rel_mips = _segsum(jnp.where(drain_done, inst.mips, 0.0), inst.vm, V)
    rel_ram = _segsum(jnp.where(drain_done, inst.ram, 0.0), inst.vm, V)
    vms = vms._replace(mips_used=vms.mips_used - rel_mips,
                       ram_used=vms.ram_used - rel_ram)

    instances = inst._replace(
        status=jnp.where(drain_done, INST_FREE, inst.status),
        service=jnp.where(drain_done, -1, inst.service),
        vm=jnp.where(drain_done, -1, inst.vm),
        host=jnp.where(drain_done, -1, inst.host),
        mips=jnp.where(drain_done, 0.0, inst.mips),
        ram=jnp.where(drain_done, 0.0, inst.ram),
        n_exec=n_exec_after,
        used_mips=used_mips,
        used_ram=used_ram,
        util_ema=jnp.where(drain_done, 0.0, util_ema),
        usage_sum=inst.usage_sum + acct_mips * dt,
        busy_ticks=inst.busy_ticks + (n_exec > 0).astype(i32),
    )

    counters = state.counters._replace(
        finished=state.counters.finished + jnp.sum(fin.astype(i32)))

    # --- per-edge / per-replica success counts (resilience §7, chaos mode
    # only): the next Disruption pass folds them into the breaker and
    # outlier-ejection error/latency EMAs --------------------------------
    fault = state.fault
    if params.faults == "chaos":
        E = fault.edge_succ.shape[0]
        fault = fault._replace(
            edge_succ=fault.edge_succ + _segsum(
                fin.astype(i32), jnp.where(fin, cl.edge, -1), E),
            inst_succ=fault.inst_succ + fin_per_inst,
            inst_lat_sum=fault.inst_lat_sum + out.inst_acc[:I, 2])

    return state._replace(cloudlets=cloudlets, instances=instances, vms=vms,
                          requests=requests, svc_stats=svc_stats,
                          counters=counters, fault=fault), info


# ===========================================================================
# Derive: finished cloudlets spawn successors (paper §4.1.2 "Derivative")
# ===========================================================================

def derive(state: SimState, app: AppStatic, caps: SimCaps,
           info: FinishInfo, rng: jnp.ndarray,
           params: SimParams | None = None, net_rng=None) -> SimState:
    cl, req, ctr = state.cloudlets, state.requests, state.counters
    C = cl.status.shape[0]
    R = req.api.shape[0]
    I = state.instances.status.shape[0]
    D = app.succ.shape[1]
    i32, f32 = jnp.int32, jnp.float32

    # maximum() is a no-op (fin ⇒ the slot held a real service id) but
    # pins parent_svc ∈ [0, S-1] for the succ-table row gather below
    parent_svc = jnp.where(info.fin, jnp.maximum(info.pre_service, 0), 0)
    child = app.succ[parent_svc]                      # [C, D]
    valid = (info.fin[:, None] & (child >= 0)).reshape(-1)
    svc_flat = child.reshape(-1)
    req_flat = jnp.broadcast_to(info.pre_req[:, None], (C, D)).reshape(-1)
    dep_flat = jnp.broadcast_to((info.pre_depth + 1)[:, None],
                                (C, D)).reshape(-1)
    tf_flat = jnp.broadcast_to(info.tfin[:, None], (C, D)).reshape(-1)
    pin_flat = jnp.broadcast_to(info.pre_inst[:, None], (C, D)).reshape(-1)

    asg = assign_free_slots(cl.status == CL_FREE, valid, k_static=C)
    Ka = asg.dst.shape[0]
    svc_new = svc_flat[asg.src]          # rank-level gathers
    req_new = req_flat[asg.src]
    # clamp is a no-op (build validation rejects call-graph cycles, so a
    # parent at depth S-1 has exhausted every service and can have no
    # successors) but keeps the depth column inside its declared
    # [0, S-1] bound
    dep_new = jnp.minimum(dep_flat[asg.src], app.succ.shape[0] - 1)
    tf_new = tf_flat[asg.src]
    # Edge id: row = parent service, column = successor slot (§7).
    psvc_new = jnp.broadcast_to(parent_svc[:, None],
                                (C, D)).reshape(-1)[asg.src]
    slot_new = (asg.src % D).astype(i32)
    edge_new = psvc_new * D + slot_new
    pin_new = pin_flat[asg.src]
    noise = jax.random.normal(rng, (Ka,), f32)
    length = jnp.maximum(app.len_mean[svc_new] + app.len_std[svc_new] * noise,
                         1.0)

    if net_rng is None:                  # uniform mode (degenerate network)
        status_new, inst_new = CL_WAITING, -1
        src_host_new, bytes_new = -1, 0.0
        rr = state.rr
    else:                                # fabric mode: address + payload
        k_lb, k_pay = streams.split(net_rng, names=("lb", "payload"))
        tgt, rr = netmod.pick_replicas(svc_new, asg.live, state, caps,
                                       params, k_lb)
        payload = netmod.sample_payload(app.payload_mean[psvc_new, slot_new],
                                        app.payload_std[psvc_new, slot_new],
                                        k_pay)
        src_host = jnp.where(pin_new >= 0,
                             state.instances.host[jnp.maximum(pin_new, 0)],
                             -1)
        dst_host = jnp.where(tgt >= 0,
                             state.instances.host[jnp.maximum(tgt, 0)], -1)
        # Loopback fast path: co-located hops never touch a NIC — they
        # land directly in the waiting queue at the parent's finish time.
        loop = (tgt >= 0) & (src_host >= 0) & (src_host == dst_host)
        in_transit = (tgt >= 0) & ~loop
        status_new = jnp.where(in_transit, CL_TRANSIT, CL_WAITING)
        inst_new = tgt
        src_host_new = jnp.where(in_transit, src_host, -1)
        bytes_new = jnp.where(in_transit, payload, 0.0)

    # Fused spawn write: two scatters for the whole successor wave.
    cloudlets = scatter_pool(
        cl, asg,
        status=status_new, req=req_new, service=svc_new, inst=inst_new,
        wait_ticks=0, depth=dep_new, src_host=src_host_new,
        attempt=0, edge=edge_new, src_inst=pin_new,
        length=length, rem=length, arrival=tf_new, start=-1.0,
        rem_bytes=bytes_new)

    # several successors of one parent share a request — intended collisions
    rdst = jnp.where(asg.live, req_new, R)
    with collide("spawn_request_counts"):
        requests = req._replace(
            outstanding=req.outstanding.at[rdst].add(1, mode="drop"),
            spawned=req.spawned.at[rdst].add(1, mode="drop"))

    # Outbound-RPC bandwidth (linear usage model, paper §5.2).
    live_pinst = jnp.where(asg.live, pin_flat[asg.src], -1)
    psvc = jnp.where(asg.live, jnp.maximum(
        state.instances.service[jnp.maximum(live_pinst, 0)], 0), 0)
    bw = _segsum(app.bytes_per_rpc[psvc] * asg.live.astype(f32),
                 live_pinst, I)
    instances = state.instances._replace(used_bw=bw)

    counters = ctr._replace(
        spawned=ctr.spawned + asg.n_assigned,
        dropped_cloudlets=ctr.dropped_cloudlets + asg.n_dropped)
    return state._replace(rr=rr, cloudlets=cloudlets, requests=requests,
                          instances=instances, counters=counters)


# ===========================================================================
# Complete: close requests whose dependency tree drained (paper §4.3.2)
# ===========================================================================

def complete(state: SimState, dyn: DynParams, faults: bool = False
             ) -> Tuple[SimState, jnp.ndarray]:
    req, ctr = state.requests, state.counters
    i32 = jnp.int32
    done = ((req.outstanding == 0) & (req.spawned > 0) & (req.response < 0)
            & (req.arrival >= 0))
    resp = jnp.where(done, req.finish - req.arrival, req.response)
    n_done = jnp.sum(done.astype(i32))
    viol = done & (resp * 1000.0 > dyn.slo_ms)
    if faults:
        # a failed completion is an SLO violation regardless of how fast
        # it failed — else breaker fail-fasts would IMPROVE the SLO rate
        viol = viol | (done & (req.failed > 0))
    counters = ctr._replace(
        completed=ctr.completed + n_done,
        resp_sum=ctr.resp_sum + jnp.sum(jnp.where(done, resp, 0.0)),
        slo_violations=ctr.slo_violations + jnp.sum(viol.astype(i32)),
    )
    state = state._replace(requests=req._replace(response=resp),
                           counters=counters)
    if faults:
        # a request whose failed flag is set completes as a FAILED
        # completion — counted exactly once, at its single `done` tick
        n_fail = jnp.sum((done & (req.failed > 0)).astype(i32))
        state = state._replace(fstats=state.fstats._replace(
            failed_requests=state.fstats.failed_requests + n_fail))
    return state, n_done
