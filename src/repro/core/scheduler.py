"""Cloudlet scheduler phases (paper §4.2) + derivative spawning (§4.1.2).

Every tick runs, in order:

  ``gen_spawn``   — new requests fire root cloudlets at API entry services
  ``dispatch``    — waiting→execution transition with load balancing
  ``execute``     — time-shared progress + finish detection + usage history
  ``derive``      — finished cloudlets spawn successors along the DAG
  ``complete``    — requests whose last cloudlet finished get a response

The waiting/execution/finished "queues" of the paper are status masks on
the active cloudlet buffer; the finished queue is folded into per-request
and per-service aggregates (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import policies
from ..kernels.cloudlet_step import cloudlet_step as _cloudlet_step_op
from .app import AppStatic
from .pool import (assign_free_slots, scatter_const, scatter_new,
                   scatter_ranked, segment_rank)
from .types import (CL_EXEC, CL_FREE, CL_WAITING, DynParams, INST_DRAIN,
                    INST_FREE, INST_ON, SimCaps, SimParams, SimState)


def _segsum(data, ids, n, valid=None):
    """Scatter-add with -1/invalid ids dropped."""
    if valid is None:
        valid = ids >= 0
    idx = jnp.where(valid, ids, n)
    return jnp.zeros((n,), data.dtype).at[idx].add(
        jnp.where(valid, data, jnp.zeros_like(data)), mode="drop")


# ===========================================================================
# Generation: new requests + root cloudlets (paper Alg 1 + "Dispatching")
# ===========================================================================

class GenResult(NamedTuple):
    n_new_requests: jnp.ndarray


def gen_spawn(state: SimState, app: AppStatic, caps: SimCaps,
              fired: jnp.ndarray, api: jnp.ndarray,
              wait_proposal: jnp.ndarray, rng: jnp.ndarray
              ) -> Tuple[SimState, GenResult]:
    """Allocate request slots for fired clients and spawn root cloudlets."""
    req, cl, ctr = state.requests, state.cloudlets, state.counters
    R = req.api.shape[0]
    C = cl.status.shape[0]
    i32, f32 = jnp.int32, jnp.float32
    Nc = fired.shape[0]
    K = caps.k_fire if caps.k_fire > 0 else Nc
    K = min(K, Nc)
    E = app.api_entry.shape[1]

    rank = jnp.cumsum(fired.astype(i32)) - 1
    in_budget = fired & (rank < K)
    slot = req.count + rank
    has_slot = in_budget & (slot < R)
    n_accept = jnp.sum(has_slot.astype(i32))
    n_pool_drop = jnp.sum((in_budget & ~has_slot).astype(i32))

    # Client wait update: accepted/pool-dropped clients rest; over-budget
    # clients retry next tick (backpressure); others count down.
    new_wait = jnp.where(
        in_budget, wait_proposal,
        jnp.where(fired, 0, jnp.maximum(state.clients.wait - 1, 0)))

    # ---- write accepted requests -------------------------------------
    dst = jnp.where(has_slot, slot, R)
    requests = req._replace(
        count=req.count + n_accept,
        api=req.api.at[dst].set(api, mode="drop"),
        arrival=req.arrival.at[dst].set(
            jnp.full((Nc,), 0.0, f32) + state.time, mode="drop"),
        outstanding=req.outstanding.at[dst].set(jnp.zeros((Nc,), i32),
                                                mode="drop"),
        spawned=req.spawned.at[dst].set(jnp.zeros((Nc,), i32), mode="drop"),
        finish=req.finish.at[dst].set(jnp.full((Nc,), 0.0, f32) + state.time,
                                      mode="drop"),
        response=req.response.at[dst].set(jnp.full((Nc,), -1.0, f32),
                                          mode="drop"),
        critical_len=req.critical_len.at[dst].set(jnp.zeros((Nc,), i32),
                                                  mode="drop"),
    )

    # ---- root cloudlet descriptors [K, E] ------------------------------
    # Compact accepted clients into rank order.
    client_of_rank = jnp.zeros((K,), i32).at[
        jnp.where(has_slot & (rank < K), rank, K)
    ].set(jnp.arange(Nc, dtype=i32), mode="drop")
    ranks = jnp.arange(K, dtype=i32)
    r_live = ranks < n_accept
    api_r = api[client_of_rank]                      # [K]
    req_slot_r = req.count + ranks                   # [K]

    svc_d = app.api_entry[api_r]                     # [K, E]
    n_ent = app.api_n_entry[api_r]                   # [K]
    valid = (r_live[:, None] & (jnp.arange(E)[None, :] < n_ent[:, None])
             & (svc_d >= 0)).reshape(-1)
    svc_flat = svc_d.reshape(-1)
    req_flat = jnp.broadcast_to(req_slot_r[:, None], (K, E)).reshape(-1)

    asg = assign_free_slots(cl.status == CL_FREE, valid)
    Ka = asg.dst.shape[0]
    svc_new = svc_flat[asg.src]          # rank-level gather (for sampling)
    req_new = req_flat[asg.src]
    noise = jax.random.normal(rng, (Ka,), f32)
    length = jnp.maximum(app.len_mean[svc_new] + app.len_std[svc_new] * noise,
                         1.0)

    cloudlets = cl._replace(
        status=scatter_const(cl.status, asg, CL_WAITING),
        req=scatter_new(cl.req, asg, req_flat),
        service=scatter_new(cl.service, asg, svc_flat),
        inst=scatter_const(cl.inst, asg, -1),
        length=scatter_ranked(cl.length, asg, length),
        rem=scatter_ranked(cl.rem, asg, length),
        arrival=scatter_ranked(cl.arrival, asg,
                               jnp.full((Ka,), 0.0, f32) + state.time),
        start=scatter_const(cl.start, asg, -1.0),
        wait_ticks=scatter_const(cl.wait_ticks, asg, 0),
        depth=scatter_const(cl.depth, asg, 0),
    )

    spawn_per_req = _segsum(jnp.where(asg.live, 1, 0).astype(i32),
                            jnp.where(asg.live, req_new, -1), R)
    requests = requests._replace(
        outstanding=requests.outstanding + spawn_per_req,
        spawned=requests.spawned + spawn_per_req,
    )
    counters = ctr._replace(
        spawned=ctr.spawned + asg.n_assigned,
        dropped_cloudlets=ctr.dropped_cloudlets + asg.n_dropped,
        dropped_requests=ctr.dropped_requests + n_pool_drop,
    )
    state = state._replace(
        clients=state.clients._replace(wait=new_wait),
        requests=requests, cloudlets=cloudlets, counters=counters)
    return state, GenResult(n_new_requests=n_accept)


# ===========================================================================
# Dispatch: waiting → execution with load balancing (paper §4.2)
# ===========================================================================

def dispatch(state: SimState, app: AppStatic, caps: SimCaps,
             params: SimParams, dyn: DynParams, rng: jnp.ndarray) -> SimState:
    cl, inst, sched = state.cloudlets, state.instances, state.sched
    C = cl.status.shape[0]
    I = inst.status.shape[0]
    S = app.n_services
    i32 = jnp.int32

    # An RPC hop must traverse the network before it may be scheduled
    # (net_latency models client→service and service→service transport).
    waiting = (cl.status == CL_WAITING) & \
        (state.time + 1e-6 >= cl.arrival + dyn.net_latency)
    svc = jnp.where(waiting, cl.service, 0)
    replicas = sched.svc_replicas[svc]                      # [C]
    has_rep = waiting & (replicas > 0)
    rep_safe = jnp.maximum(replicas, 1)

    if params.lb_policy == policies.LB_ROUND_ROBIN:
        rank = (state.rr[svc] + jnp.arange(C, dtype=i32)) % rep_safe
    elif params.lb_policy == policies.LB_RANDOM:
        rank = jax.random.randint(rng, (C,), 0, 1 << 30) % rep_safe
    else:  # LB_LEAST_LOADED: per service, replica with max idle mips
        iof = sched.inst_of_rank                            # [S, R_max]
        valid = iof >= 0
        iof_safe = jnp.where(valid, iof, 0)
        load = inst.n_exec[iof_safe] / jnp.maximum(inst.mips[iof_safe], 1e-6)
        load = jnp.where(valid & (inst.status[iof_safe] == INST_ON),
                         load, jnp.inf)
        best = jnp.argmin(load, axis=1).astype(i32)         # [S]
        rank = best[svc]

    target = sched.inst_of_rank[svc, jnp.minimum(rank, caps.max_replicas - 1)]
    ok = has_rep & (target >= 0)
    tgt_safe = jnp.where(ok, target, 0)
    ok = ok & (inst.status[tgt_safe] == INST_ON)

    if params.max_concurrent > 0:
        # Space-shared admission: FCFS rank within the target instance
        # must fit in the remaining concurrency budget (paper: unselected
        # cloudlets re-enter the waiting queue).
        intra = segment_rank(jnp.where(ok, target, I), ok, I + 1)
        cap_left = jnp.maximum(dyn.max_concurrent - inst.n_exec, 0)
        admit = ok & (intra < cap_left[tgt_safe])
    else:
        admit = ok

    new_status = jnp.where(admit, CL_EXEC, cl.status)
    new_inst = jnp.where(admit, target, cl.inst)
    new_start = jnp.where(admit & (cl.start < 0), state.time, cl.start)
    new_wait_t = cl.wait_ticks + (waiting & ~admit).astype(i32)

    disp_per_svc = _segsum(admit.astype(i32),
                           jnp.where(admit, cl.service, -1), S)
    rr = (state.rr + disp_per_svc) % jnp.maximum(sched.svc_replicas, 1)

    return state._replace(
        rr=rr,
        cloudlets=cl._replace(status=new_status, inst=new_inst,
                              start=new_start, wait_ticks=new_wait_t),
    )


# ===========================================================================
# Execute: time-shared progress, finish detection, usage history
# ===========================================================================

class FinishInfo(NamedTuple):
    fin: jnp.ndarray       # [C] bool finished this tick
    tfin: jnp.ndarray      # [C] f32 sub-tick finish timestamp
    pre_service: jnp.ndarray  # [C] i32 service ids before slot clearing
    pre_req: jnp.ndarray
    pre_depth: jnp.ndarray
    pre_inst: jnp.ndarray


def execute(state: SimState, app: AppStatic, caps: SimCaps,
            params: SimParams, dyn: DynParams
            ) -> Tuple[SimState, FinishInfo]:
    cl, inst, vms = state.cloudlets, state.instances, state.vms
    I = inst.status.shape[0]
    S = app.n_services
    i32, f32 = jnp.int32, jnp.float32
    dt = dyn.dt

    execm = cl.status == CL_EXEC
    cid = jnp.where(execm, cl.inst, -1)
    n_exec = _segsum(jnp.ones_like(cl.status), cid, I)

    if params.share_policy == policies.SHARE_SRPT:
        w = jnp.where(execm, 1.0 / (cl.rem + 1.0), 0.0)
    else:
        w = execm.astype(f32)
    wsum = _segsum(w, cid, I)
    inst_safe = jnp.where(execm, cl.inst, 0)
    rate = jnp.where(execm,
                     inst.mips[inst_safe] * w
                     / jnp.maximum(wsum[inst_safe], 1e-9), 0.0)  # MI/s

    if params.use_pallas_tick:
        # fused TPU kernel (kernels/cloudlet_step): one VMEM pass computes
        # progress, sub-tick finishes, consumption, and per-instance usage
        new_rem, fin, tfin, consumed, used_mips = _cloudlet_step_op(
            cl.status, cl.rem, cl.inst, rate, state.time, dt, I)
        new_rem = jnp.where(execm, new_rem, cl.rem)
    else:
        prog = rate * dt
        fin = execm & (cl.rem <= prog) & (rate > 0)
        tfin = jnp.where(
            fin, jnp.clip(state.time + cl.rem / jnp.maximum(rate, 1e-9),
                          state.time, state.time + dt), 0.0)
        consumed = jnp.minimum(prog, cl.rem)
        new_rem = jnp.maximum(cl.rem - prog, 0.0)
        used_mips = _segsum(consumed / dt, cid, I)
    svc_of_inst = inst.service
    util = jnp.where(inst.mips > 0, used_mips / jnp.maximum(inst.mips, 1e-9),
                     0.0)
    # Usage accounting (paper §5.2): idle floor on every ON instance plus a
    # resize surcharge on vertically-scaled instances.  The scaling signal
    # (util EMA) stays based on raw consumption.
    on = inst.status == INST_ON
    acct_mips = (used_mips * (1.0 + jnp.where(
        inst.mips > inst.request_mips, dyn.vs_overhead_frac, 0.0))
        + dyn.idle_mips_frac * jnp.where(on, inst.mips, 0.0))
    a = dyn.util_ema
    util_ema = jnp.where(inst.status != INST_FREE,
                         a * util + (1 - a) * inst.util_ema, 0.0)
    used_ram = jnp.where(svc_of_inst >= 0,
                         app.ram_per_cl[jnp.maximum(svc_of_inst, 0)]
                         * n_exec, 0.0)

    # --- per-service usage history / node-delay estimates ---------------
    st = state.svc_stats
    fsvc = jnp.where(fin, cl.service, -1)
    sojourn = jnp.where(fin, tfin - cl.arrival, 0.0)
    exec_t = jnp.where(fin, tfin - jnp.maximum(cl.start, cl.arrival), 0.0)
    wait_t = jnp.where(fin, jnp.maximum(cl.start, cl.arrival) - cl.arrival,
                       0.0)
    svc_stats = st._replace(
        usage_sum=st.usage_sum + _segsum(acct_mips * dt, svc_of_inst, S),
        finished=st.finished + _segsum(jnp.ones_like(cl.status), fsvc, S),
        delay_sum=st.delay_sum + _segsum(sojourn, fsvc, S),
        exec_sum=st.exec_sum + _segsum(exec_t, fsvc, S),
        wait_sum=st.wait_sum + _segsum(wait_t, fsvc, S),
    )

    # --- request aggregates ---------------------------------------------
    req = state.requests
    R = req.api.shape[0]
    frq = jnp.where(fin, cl.req, -1)
    fin_per_req = _segsum(jnp.ones_like(cl.status), frq, R)
    rdst = jnp.where(fin, cl.req, R)
    finish = req.finish.at[rdst].max(tfin, mode="drop")
    crit = req.critical_len.at[rdst].max(cl.depth + 1, mode="drop")
    requests = req._replace(outstanding=req.outstanding - fin_per_req,
                            finish=finish, critical_len=crit)

    info = FinishInfo(fin=fin, tfin=tfin, pre_service=cl.service,
                      pre_req=cl.req, pre_depth=cl.depth, pre_inst=cl.inst)

    # --- clear finished slots (the "finished queue" is the aggregates) --
    cloudlets = cl._replace(
        status=jnp.where(fin, CL_FREE, cl.status),
        rem=new_rem,
        inst=jnp.where(fin, -1, cl.inst),
    )

    # --- drained instances release their VM share (HS scale-in) ---------
    n_exec_after = n_exec - _segsum(jnp.ones_like(cl.status),
                                    jnp.where(fin, cl.inst, -1), I)
    drain_done = (inst.status == INST_DRAIN) & (n_exec_after == 0)
    V = vms.mips.shape[0]
    rel_mips = _segsum(jnp.where(drain_done, inst.mips, 0.0), inst.vm, V)
    rel_ram = _segsum(jnp.where(drain_done, inst.ram, 0.0), inst.vm, V)
    vms = vms._replace(mips_used=vms.mips_used - rel_mips,
                       ram_used=vms.ram_used - rel_ram)

    instances = inst._replace(
        status=jnp.where(drain_done, INST_FREE, inst.status),
        service=jnp.where(drain_done, -1, inst.service),
        vm=jnp.where(drain_done, -1, inst.vm),
        mips=jnp.where(drain_done, 0.0, inst.mips),
        ram=jnp.where(drain_done, 0.0, inst.ram),
        n_exec=n_exec_after,
        used_mips=used_mips,
        used_ram=used_ram,
        util_ema=jnp.where(drain_done, 0.0, util_ema),
        usage_sum=inst.usage_sum + acct_mips * dt,
        busy_ticks=inst.busy_ticks + (n_exec > 0).astype(i32),
    )

    counters = state.counters._replace(
        finished=state.counters.finished + jnp.sum(fin.astype(i32)))
    return state._replace(cloudlets=cloudlets, instances=instances, vms=vms,
                          requests=requests, svc_stats=svc_stats,
                          counters=counters), info


# ===========================================================================
# Derive: finished cloudlets spawn successors (paper §4.1.2 "Derivative")
# ===========================================================================

def derive(state: SimState, app: AppStatic, caps: SimCaps,
           info: FinishInfo, rng: jnp.ndarray) -> SimState:
    cl, req, ctr = state.cloudlets, state.requests, state.counters
    C = cl.status.shape[0]
    R = req.api.shape[0]
    I = state.instances.status.shape[0]
    D = app.succ.shape[1]
    i32, f32 = jnp.int32, jnp.float32

    parent_svc = jnp.where(info.fin, info.pre_service, 0)
    child = app.succ[parent_svc]                      # [C, D]
    valid = (info.fin[:, None] & (child >= 0)).reshape(-1)
    svc_flat = child.reshape(-1)
    req_flat = jnp.broadcast_to(info.pre_req[:, None], (C, D)).reshape(-1)
    dep_flat = jnp.broadcast_to((info.pre_depth + 1)[:, None],
                                (C, D)).reshape(-1)
    tf_flat = jnp.broadcast_to(info.tfin[:, None], (C, D)).reshape(-1)
    pin_flat = jnp.broadcast_to(info.pre_inst[:, None], (C, D)).reshape(-1)

    asg = assign_free_slots(cl.status == CL_FREE, valid, k_static=C)
    Ka = asg.dst.shape[0]
    svc_new = svc_flat[asg.src]          # rank-level gather (for sampling)
    noise = jax.random.normal(rng, (Ka,), f32)
    length = jnp.maximum(app.len_mean[svc_new] + app.len_std[svc_new] * noise,
                         1.0)

    cloudlets = cl._replace(
        status=scatter_const(cl.status, asg, CL_WAITING),
        req=scatter_new(cl.req, asg, req_flat),
        service=scatter_new(cl.service, asg, svc_flat),
        inst=scatter_const(cl.inst, asg, -1),
        length=scatter_ranked(cl.length, asg, length),
        rem=scatter_ranked(cl.rem, asg, length),
        arrival=scatter_new(cl.arrival, asg, tf_flat),
        start=scatter_const(cl.start, asg, -1.0),
        wait_ticks=scatter_const(cl.wait_ticks, asg, 0),
        depth=scatter_new(cl.depth, asg, dep_flat),
    )

    live_req = jnp.where(asg.live, req_flat[asg.src], -1)
    spawn_per_req = _segsum(jnp.where(asg.live, 1, 0).astype(i32),
                            live_req, R)
    requests = req._replace(outstanding=req.outstanding + spawn_per_req,
                            spawned=req.spawned + spawn_per_req)

    # Outbound-RPC bandwidth (linear usage model, paper §5.2).
    live_pinst = jnp.where(asg.live, pin_flat[asg.src], -1)
    psvc = jnp.where(asg.live, jnp.maximum(
        state.instances.service[jnp.maximum(live_pinst, 0)], 0), 0)
    bw = _segsum(app.bytes_per_rpc[psvc] * asg.live.astype(f32),
                 live_pinst, I)
    instances = state.instances._replace(used_bw=bw)

    counters = ctr._replace(
        spawned=ctr.spawned + asg.n_assigned,
        dropped_cloudlets=ctr.dropped_cloudlets + asg.n_dropped)
    return state._replace(cloudlets=cloudlets, requests=requests,
                          instances=instances, counters=counters)


# ===========================================================================
# Complete: close requests whose dependency tree drained (paper §4.3.2)
# ===========================================================================

def complete(state: SimState, dyn: DynParams) -> Tuple[SimState, jnp.ndarray]:
    req, ctr = state.requests, state.counters
    i32 = jnp.int32
    done = ((req.outstanding == 0) & (req.spawned > 0) & (req.response < 0)
            & (req.arrival >= 0))
    resp = jnp.where(done, req.finish - req.arrival, req.response)
    n_done = jnp.sum(done.astype(i32))
    counters = ctr._replace(
        completed=ctr.completed + n_done,
        resp_sum=ctr.resp_sum + jnp.sum(jnp.where(done, resp, 0.0)),
        slo_violations=ctr.slo_violations + jnp.sum(
            (done & (resp * 1000.0 > dyn.slo_ms)).astype(i32)),
    )
    return state._replace(requests=req._replace(response=resp),
                          counters=counters), n_done
