"""Core data structures for the CloudNativeSim tensor-DES engine.

The paper's Java object graph (Request / RpcCloudlet / Instance / VM /
Service) is re-expressed as fixed-shape tensor pools so that one simulator
tick is a fused dataflow update and the whole run is a single
``jax.lax.scan``.  See DESIGN.md §2 for the adaptation rationale.

Conventions
-----------
* All pools use int32 / float32 (JAX default x64-disabled).
* ``-1`` is the universal "null id" (no instance, no service, padding).
* Pools are *fixed capacity*; requests are append-only, cloudlets use an
  active-set buffer with free-slot recycling (finished cloudlets fold their
  statistics into per-request / per-instance aggregates and free the slot —
  the paper's "finished queue" is an aggregate, not an archive).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import streams

# --------------------------------------------------------------------------
# Cloudlet status codes (paper §4.2: waiting / execution / finished queues).
# --------------------------------------------------------------------------
CL_FREE = 0       # slot unused (or folded into the "finished" aggregate)
CL_WAITING = 1    # in the waiting queue
CL_EXEC = 2       # in the execution queue
CL_TRANSIT = 3    # RPC payload in flight on the network fabric (§6)

# Instance status codes.
INST_FREE = 0     # slot unused
INST_ON = 1       # active, receiving cloudlets
INST_DRAIN = 2    # scale-in requested: no new cloudlets, frees when empty
INST_DOWN = 3     # crashed (host down or pod killed): no dispatch, in-flight
#                   work failed; restarts via MTTR once its host is up (§7)


@dataclasses.dataclass(frozen=True)
class SimCaps:
    """Static pool capacities (hashable → safe to close over in jit)."""

    n_clients: int = 128          # Nc upper bound (client pool size)
    max_requests: int = 4096      # append-only request pool
    max_cloudlets: int = 8192     # ACTIVE cloudlet buffer (waiting+exec)
    max_instances: int = 64       # instance pool (incl. head-room for HS)
    n_vms: int = 8
    d_max: int = 4                # max out-degree of any service node
    max_replicas: int = 8         # per-service replica cap (HS)
    k_fire: int = 0               # max requests admitted per tick (0 = Nc);
                                  # over-budget clients retry next tick
    net_hist_buckets: int = 64    # transit-time histogram resolution (§6)
    k_retry: int = 0              # max retry respawns per Disruption tick
                                  # (0 = auto: min(C, max(256, C/8)));
                                  # over-budget failures fail permanently —
                                  # a per-tick retry admission budget (§7)

    def validate(self) -> None:
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            lo = 0 if f.name in ("k_fire", "k_retry") else 1
            if not isinstance(v, int) or v < lo:
                raise ValueError(
                    f"SimCaps.{f.name} must be an int ≥ {lo}, got {v!r}")


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Static scalar parameters of a simulation run (closed over in jit)."""

    # --- time -----------------------------------------------------------
    dt: float = 0.1               # seconds per tick
    n_ticks: int = 1000

    # --- request generator (paper Alg 1) --------------------------------
    n_clients: int = 100          # N_c, final number of clients
    spawn_rate: float = 1.0       # v, clients per second
    wait_lo: float = 5.0          # p0 (seconds)
    wait_hi: float = 15.0         # p1 (seconds)
    num_limit: int = 2 ** 31 - 1  # numLimit (max generated requests)

    # --- scheduling (paper §4.2) ----------------------------------------
    lb_policy: int = 0            # policies.LB_* (round-robin default)
    share_policy: int = 0         # policies.SHARE_* (equal time slice)
    max_concurrent: int = 0       # 0 = pure time sharing (unbounded)
    net_latency_s: float = 0.0    # per-RPC-hop network latency (seconds)

    # --- network fabric (DESIGN.md §6) -----------------------------------
    network: str = "uniform"      # "uniform": load-independent net_latency_s
                                  # per hop (the legacy degenerate mode);
                                  # "fabric": payloads transit host NICs with
                                  # max-min fair bandwidth contention
    nic_egress_mbps: float = 1000.0   # per-host NIC egress capacity
    nic_ingress_mbps: float = 1000.0  # per-host NIC ingress capacity
    waterfill_iters: int = 2      # water-filling freeze rounds (static:
                                  # exact max-min for ≤ this many bottleneck
                                  # levels, conservative — never
                                  # oversubscribing — beyond; raise for
                                  # deep multi-bottleneck fabrics)
    net_hist_bin_s: float = 0.01  # transit-time histogram bin width (s)

    # --- scaling (paper §5.3) -------------------------------------------
    scaling_policy: int = 0       # policies.SCALE_* (NS default)
    scale_interval: int = 50      # ticks between scaling events
    hs_util_hi: float = 0.8       # HS scale-out threshold (service avg util)
    hs_util_lo: float = 0.2       # HS scale-in threshold
    vs_util_hi: float = 0.8       # VS scale-up threshold (instance util)
    vs_util_lo: float = 0.2
    vs_up_factor: float = 1.5
    vs_down_factor: float = 0.75
    util_ema: float = 0.2         # EMA coefficient for utilization signal

    # --- migration (paper §5.1) -----------------------------------------
    migration_enabled: bool = False
    mig_vm_util_hi: float = 0.9

    # --- fault injection & resilience (DESIGN.md §7) ---------------------
    faults: str = "none"          # "none": the fault-free engine (exact
                                  # pre-faults program, bit-pinned);
                                  # "chaos": Disruption tick phase — host
                                  # crash/recovery, instance kills, NIC
                                  # degradation, retries, circuit breakers
    host_mtbf_s: float = float("inf")   # mean time between host crashes
    host_mttr_s: float = 30.0           # mean host recovery time
    inst_kill_rate: float = 0.0         # instance kills per second per pod
    inst_mttr_s: float = 15.0           # mean pod restart time (host up)
    nic_degrade_rate: float = 0.0       # NIC degradations per second per host
    nic_mttr_s: float = 30.0            # mean NIC recovery time
    nic_degrade_factor: float = 1.0     # capacity multiplier while degraded
    retry_budget: int = 2         # default retries per RPC (per-edge
                                  # overrides via the registry "retries" key)
    retry_timeout_s: float = float("inf")  # per-attempt timeout (age of the
                                  # attempt before it counts as failed)
    cb_err_thresh: float = 2.0    # breaker trip threshold on the per-edge
                                  # error-rate EMA (> 1 = breaker disabled)
    cb_alpha: float = 0.3         # error-rate EMA coefficient
    cb_cooldown_s: float = 10.0   # open → half-open cooldown
    egress_shaping: bool = False  # clamp per-instance Transit egress by
                                  # Instances.bw (fabric mode, §6)

    # --- gray failure (fail-slow / blast radius, DESIGN.md §7.1) ---------
    host_slow_mtbf_s: float = float("inf")  # mean time between fail-slow
                                  # episodes per host (inf = never)
    host_slow_mttr_s: float = 30.0          # mean fail-slow episode length
    host_slow_factor: float = 0.25          # MIPS multiplier while slow
    nic_degrade_spread: float = 0.0         # NIC brownout severity spread:
                                  # each degradation samples its factor from
                                  # U[factor − spread, factor + spread]∩[0,1]
    zone_fault_rate: float = 0.0  # zone crash draws per second per zone —
                                  # one draw downs EVERY host of the zone
                                  # (hosts recover individually, host_mttr_s)
    zone_slow_rate: float = 0.0   # zone fail-slow draws per second per zone
    zone_partition_rate: float = 0.0   # partial-partition draws per second
                                  # per zone PAIR (cuts their link capacity)
    zone_partition_mttr_s: float = 30.0  # mean partition length
    eject_err_thresh: float = 2.0 # outlier-ejection trip threshold on the
                                  # per-replica error EMA (> 1 = disabled)
    eject_lat_factor: float = 0.0 # latency outlier trip: replica latency
                                  # EMA > factor × its service's mean
                                  # (0 = latency ejection disabled)
    eject_cooldown_s: float = 10.0  # ejected → probe (half-open) cooldown

    # --- usage accounting (paper §5.2 linear model) ----------------------
    idle_mips_frac: float = 0.0   # idle floor: instances consume a small
                                  # fraction of their allocation when ON
    vs_overhead_frac: float = 0.0 # resize churn: vertically-scaled
                                  # instances pay a usage surcharge

    # --- observability (DESIGN.md §9) ------------------------------------
    telemetry: str = "none"       # "none": zero telemetry state, program
                                  # bit-identical to the pre-obs engine;
                                  # "stream": per-window metric rows ring
                                  # out through a double-buffered
                                  # io_callback tap + sampled span tracing
    tel_window_ticks: int = 16    # ticks per metric-row window
    tel_windows: int = 8          # metric ring capacity W (even; one
                                  # io_callback flush per W/2 windows)
    tel_span_k: int = 100         # trace 1 request in k (seeded Bernoulli)
    tel_span_cap: int = 1024      # span ring capacity (overflow drops
                                  # are counted exactly, never overwrite)
    tel_span_tick_cap: int = 0    # per-tick span staging budget (0 = the
                                  # ring capacity; sampled finishers past
                                  # it drop — counted, never silent)
    tel_tag: float = 0.0          # row tag (traced; run_batch auto-tags
                                  # sweep points when left at 0)

    # --- SLO objectives & burn-rate alerting (DESIGN.md §10) -------------
    alerting: str = "none"        # "none": no alert state, program
                                  # bit-identical to the alert-free engine;
                                  # "burn": Alerting tick stage — per-service
                                  # multi-window burn-rate rules + alert
                                  # state machine (requires telemetry="stream")
    hs_mode: str = "util"         # horizontal scale-out gate: "util"
                                  # (threshold on the utilization EMA) or
                                  # "slo_burn" (firing burn alerts + a
                                  # stabilization window); TRACED — sweep
                                  # points select per-point, no recompile
    slo_budget: float = 0.0       # run-wide error-budget fraction (allowed
                                  # share of slow completions per service);
                                  # 0 disables every objective without a
                                  # per-service override (traced)
    slo_fast_burn: float = 14.4   # fast-rule burn threshold (Google SRE
                                  # page rule: 14.4× budget burn; traced)
    slo_slow_burn: float = 6.0    # slow-rule burn threshold (traced)
    slo_short_wins: int = 3       # short lookback, in CLOSED telemetry
                                  # windows (static: sizes the rule masks)
    slo_long_wins: int = 12       # long lookback = SLI ring length (static)
    slo_for_ticks: int = 5        # hysteresis: rule must hold this many
                                  # consecutive ticks before pending→firing
    slo_stabilize_s: float = 30.0 # burn-mode scale-out stabilization window
                                  # per service (traced)
    slo_eject_tighten: float = 1.0  # outlier-ejection threshold multiplier
                                  # applied while a latency alert fires on
                                  # the replica's service (traced; 1 = off)
    slo_event_cap: int = 256      # alert-transition ring capacity (overflow
                                  # drops are counted exactly)

    # --- backend ---------------------------------------------------------
    use_pallas_tick: bool = False # fused cloudlet_step TPU kernel for the
                                  # execution phase (CPU runs the jnp ref)
    pallas_interpret: bool = False  # force the Pallas kernel in interpret
                                  # mode (CPU validation / perf tracking)

    # --- QoS -------------------------------------------------------------
    slo_ms: float = 1000.0        # SLO threshold on response time (ms)
    mi_per_milicore: float = 0.001  # milicores = used_mips / mi_per_milicore

    seed: int = 0


# Horizontal scale-out gates (dyn.hs_mode encodes the index; traced so one
# run_batch sweep compares control planes without recompiling).
HS_MODES = ("util", "slo_burn")

# Burn-rate rules evaluated per service (axis 1 of AlertState.astate) and
# the alert state machine's states. Names are the exported label values.
ALERT_RULES = ("SLOFastBurn", "SLOSlowBurn")
ALERT_STATES = ("inactive", "pending", "firing", "resolved")
ALERT_INACTIVE, ALERT_PENDING, ALERT_FIRING, ALERT_RESOLVED = 0, 1, 2, 3


class DynParams(NamedTuple):
    """Traced scalar parameters — passed as a jit *argument* so sweeping
    loads/thresholds (benchmarks, calibration) never recompiles the tick.

    Static knobs that change the program structure (policy selectors,
    pool sizes, n_ticks) stay in SimParams/SimCaps and are closed over.
    """

    dt: jnp.ndarray
    n_clients: jnp.ndarray
    spawn_rate: jnp.ndarray
    wait_lo: jnp.ndarray
    wait_hi: jnp.ndarray
    num_limit: jnp.ndarray
    max_concurrent: jnp.ndarray
    scale_interval: jnp.ndarray
    hs_util_hi: jnp.ndarray
    hs_util_lo: jnp.ndarray
    vs_util_hi: jnp.ndarray
    vs_util_lo: jnp.ndarray
    vs_up_factor: jnp.ndarray
    vs_down_factor: jnp.ndarray
    util_ema: jnp.ndarray
    mig_vm_util_hi: jnp.ndarray
    slo_ms: jnp.ndarray
    net_latency: jnp.ndarray
    idle_mips_frac: jnp.ndarray
    vs_overhead_frac: jnp.ndarray
    nic_egress_mbps: jnp.ndarray
    nic_ingress_mbps: jnp.ndarray
    host_mtbf_s: jnp.ndarray
    host_mttr_s: jnp.ndarray
    inst_kill_rate: jnp.ndarray
    inst_mttr_s: jnp.ndarray
    nic_degrade_rate: jnp.ndarray
    nic_mttr_s: jnp.ndarray
    nic_degrade_factor: jnp.ndarray
    retry_budget: jnp.ndarray
    retry_timeout_s: jnp.ndarray
    cb_err_thresh: jnp.ndarray
    cb_alpha: jnp.ndarray
    cb_cooldown_s: jnp.ndarray
    host_slow_mtbf_s: jnp.ndarray
    host_slow_mttr_s: jnp.ndarray
    host_slow_factor: jnp.ndarray
    nic_degrade_spread: jnp.ndarray
    zone_fault_rate: jnp.ndarray
    zone_slow_rate: jnp.ndarray
    zone_partition_rate: jnp.ndarray
    zone_partition_mttr_s: jnp.ndarray
    eject_err_thresh: jnp.ndarray
    eject_lat_factor: jnp.ndarray
    eject_cooldown_s: jnp.ndarray
    hs_mode: jnp.ndarray
    slo_budget: jnp.ndarray
    slo_fast_burn: jnp.ndarray
    slo_slow_burn: jnp.ndarray
    slo_stabilize_s: jnp.ndarray
    slo_eject_tighten: jnp.ndarray
    tel_tag: jnp.ndarray

    @staticmethod
    def from_params(p: "SimParams") -> "DynParams":
        f = lambda v: jnp.asarray(v, jnp.float32)
        i = lambda v: jnp.asarray(v, jnp.int32)
        return DynParams(
            dt=f(p.dt), n_clients=i(p.n_clients), spawn_rate=f(p.spawn_rate),
            wait_lo=f(p.wait_lo), wait_hi=f(p.wait_hi),
            num_limit=i(p.num_limit), max_concurrent=i(p.max_concurrent),
            scale_interval=i(p.scale_interval),
            hs_util_hi=f(p.hs_util_hi), hs_util_lo=f(p.hs_util_lo),
            vs_util_hi=f(p.vs_util_hi), vs_util_lo=f(p.vs_util_lo),
            vs_up_factor=f(p.vs_up_factor), vs_down_factor=f(p.vs_down_factor),
            util_ema=f(p.util_ema), mig_vm_util_hi=f(p.mig_vm_util_hi),
            slo_ms=f(p.slo_ms), net_latency=f(p.net_latency_s),
            idle_mips_frac=f(p.idle_mips_frac),
            vs_overhead_frac=f(p.vs_overhead_frac),
            nic_egress_mbps=f(p.nic_egress_mbps),
            nic_ingress_mbps=f(p.nic_ingress_mbps),
            host_mtbf_s=f(p.host_mtbf_s), host_mttr_s=f(p.host_mttr_s),
            inst_kill_rate=f(p.inst_kill_rate), inst_mttr_s=f(p.inst_mttr_s),
            nic_degrade_rate=f(p.nic_degrade_rate),
            nic_mttr_s=f(p.nic_mttr_s),
            nic_degrade_factor=f(p.nic_degrade_factor),
            retry_budget=i(p.retry_budget),
            retry_timeout_s=f(p.retry_timeout_s),
            cb_err_thresh=f(p.cb_err_thresh), cb_alpha=f(p.cb_alpha),
            cb_cooldown_s=f(p.cb_cooldown_s),
            host_slow_mtbf_s=f(p.host_slow_mtbf_s),
            host_slow_mttr_s=f(p.host_slow_mttr_s),
            host_slow_factor=f(p.host_slow_factor),
            nic_degrade_spread=f(p.nic_degrade_spread),
            zone_fault_rate=f(p.zone_fault_rate),
            zone_slow_rate=f(p.zone_slow_rate),
            zone_partition_rate=f(p.zone_partition_rate),
            zone_partition_mttr_s=f(p.zone_partition_mttr_s),
            eject_err_thresh=f(p.eject_err_thresh),
            eject_lat_factor=f(p.eject_lat_factor),
            eject_cooldown_s=f(p.eject_cooldown_s),
            hs_mode=i(HS_MODES.index(p.hs_mode)),
            slo_budget=f(p.slo_budget),
            slo_fast_burn=f(p.slo_fast_burn),
            slo_slow_burn=f(p.slo_slow_burn),
            slo_stabilize_s=f(p.slo_stabilize_s),
            slo_eject_tighten=f(p.slo_eject_tighten),
            tel_tag=f(p.tel_tag))


class Clients(NamedTuple):
    """Locust-style closed-loop client pool (paper Alg 1)."""

    wait: jnp.ndarray        # [Nc] i32 ticks until next request (0 = fire)


class Requests(NamedTuple):
    """Append-only request pool (paper §4.3)."""

    count: jnp.ndarray        # scalar i32, number of allocated requests
    api: jnp.ndarray          # [R] i32
    arrival: jnp.ndarray      # [R] f32 seconds
    outstanding: jnp.ndarray  # [R] i32 cloudlets in flight
    spawned: jnp.ndarray      # [R] i32 total cloudlets ever spawned
    finish: jnp.ndarray       # [R] f32 max cloudlet finish time so far
    response: jnp.ndarray     # [R] f32 final response (s), -1 while open
    critical_len: jnp.ndarray # [R] i32 nodes on the critical (longest) chain
    failed: jnp.ndarray       # [R] u8 1 = a cloudlet of this request failed
    #                           permanently (retries exhausted / fail-fast);
    #                           the request completes as a failed completion.
    #                           A Disruption-phase column: shape [0] in
    #                           faults="none" mode (mode-keyed registry) —
    #                           uint8 so even chaos runs pay one byte per
    #                           request on the scan carry, not a word


# --------------------------------------------------------------------------
# Mode-keyed pool column registry (DESIGN.md §2.2).
#
# The stacked cloudlet pool stores all i32 fields as one [C, NI] array and
# all f32 fields as one [C, NF] array, so spawning writes the whole pool
# with TWO row scatters instead of one scatter per field.  WHICH columns
# exist is mode-dependent: each tick phase declares the columns it needs,
# and `resolve_layout` unions the declarations of the phases a SimParams
# actually enables into a static `PoolLayout`.  A default
# network="uniform"/faults="none" run therefore carries only the core
# columns — the fabric (src_host/rem_bytes) and resilience
# (attempt/edge/src_inst) columns never ride the scan carry unless their
# phase is compiled in.
# --------------------------------------------------------------------------

# Full column vocabulary, in storage order: (name, block, init value).
# The init value is what a free slot holds (`zeros_state`) — spawn waves
# always initialize whole rows, so only free slots ever show it.
POOL_COLUMNS = (
    ("status", "i", 0),        # CL_*
    ("req", "i", -1),          # owning request
    ("service", "i", -1),      # service node
    ("inst", "i", -1),         # assigned instance (-1 = unassigned)
    ("wait_ticks", "i", 0),    # ticks spent in the waiting queue
    ("depth", "i", 0),         # hops from the root cloudlet
    ("src_host", "i", -1),     # transfer source host (-1 = client / none)
    ("attempt", "i", 0),       # retry attempt counter (0 = first try, §7)
    ("edge", "i", -1),         # service-graph edge this RPC traverses:
    #                            parent_svc * d_max + slot for call edges,
    #                            S * d_max + api for client→entry edges
    #                            (retry policy / circuit breaker key, §7)
    ("src_inst", "i", -1),     # caller instance (-1 = external client)
    ("length", "f", 0.0),      # total MI (Gaussian, paper §4.1.2)
    ("rem", "f", 0.0),         # remaining MI
    ("arrival", "f", 0.0),     # seconds (of the current attempt)
    ("start", "f", -1.0),      # first-execution time (-1 = not yet)
    ("rem_bytes", "f", 0.0),   # MB still in flight (TRANSIT status, §6)
)
CL_I_FIELDS = tuple(n for n, b, _ in POOL_COLUMNS if b == "i")
CL_F_FIELDS = tuple(n for n, b, _ in POOL_COLUMNS if b == "f")
_COL_BLOCK = {n: b for n, b, _ in POOL_COLUMNS}
_COL_INIT = {n: v for n, _, v in POOL_COLUMNS}

# Declared per-column invariant bounds, keyed like POOL_COLUMNS.  These are
# the *inductive* invariants the index-safety verifier (analysis/intervals.py,
# DESIGN.md §8) seeds the tick jaxpr with and re-checks on the tick's output
# state: every value a column can hold at a tick boundary lies in
# ``fn(caps, app) -> (lo, hi)``.  Id-like columns are what make pool
# gathers/scatters provable; unbounded counters use ``inf``.
_INF = float("inf")
POOL_COLUMN_BOUNDS = {
    "status":     lambda caps, app: (CL_FREE, CL_TRANSIT),
    "req":        lambda caps, app: (-1, caps.max_requests - 1),
    "service":    lambda caps, app: (-1, app.n_services - 1),
    "inst":       lambda caps, app: (-1, caps.max_instances - 1),
    "wait_ticks": lambda caps, app: (0, _INF),
    # acyclicity (validate_app) caps any call chain at n_services hops
    "depth":      lambda caps, app: (0, max(app.n_services - 1, 0)),
    "src_host":   lambda caps, app: (-1, app.n_hosts - 1),
    "attempt":    lambda caps, app: (0, _INF),
    "edge":       lambda caps, app: (
        -1, edge_table_size(app.n_services, caps.d_max, app.n_apis) - 1),
    "src_inst":   lambda caps, app: (-1, caps.max_instances - 1),
    "length":     lambda caps, app: (0.0, _INF),
    "rem":        lambda caps, app: (-_INF, _INF),
    "arrival":    lambda caps, app: (0.0, _INF),
    "start":      lambda caps, app: (-1.0, _INF),
    "rem_bytes":  lambda caps, app: (-_INF, _INF),
}

# Tick phase → columns it reads/writes (the registry the layout is keyed
# on).  The first four phases exist in every mode; Transit only under
# network="fabric", Disruption only under faults="chaos", and the
# egress-shaping clamp (a Transit sub-feature) only when opted in.
PHASE_COLUMNS = {
    "Generation": ("status", "req", "service", "inst", "wait_ticks",
                   "depth", "length", "rem", "arrival", "start"),
    "Dispatch":   ("status", "service", "inst", "wait_ticks", "arrival",
                   "start"),
    "Execute":    ("status", "req", "service", "inst", "depth", "rem",
                   "arrival", "start"),
    # Chaos-mode Execute additionally folds per-edge success counts for
    # the breaker EMA off cl.edge — drift simcheck's layout-access
    # checker caught (the column was only declared under Disruption; the
    # resolved layout is unchanged, the *attribution* was wrong).
    "Execute/chaos": ("edge",),
    "Derive":     ("status", "req", "service", "inst", "depth", "length",
                   "rem", "arrival", "start"),
    "Transit":    ("status", "inst", "arrival", "src_host", "rem_bytes"),
    "Transit/egress_shaping": ("src_inst",),
    "Disruption": ("status", "req", "service", "inst", "depth", "attempt",
                   "edge", "src_inst", "length", "rem", "arrival", "start"),
    # Fabric-mode retry respawns re-derive the retried hop's source host
    # (same checker catch as Execute/chaos: the column was riding on
    # Transit's declaration; resolved layouts are unchanged).
    "Disruption/fabric": ("src_host",),
    # Telemetry (telemetry="stream", DESIGN.md §9) reads finished rows
    # into the span ring and samples end-of-tick gauges; it only ever
    # RE-reads columns other phases already pulled into the layout, so
    # every resolved layout is unchanged and telemetry="none" stays
    # bit-identical by construction.
    "Telemetry": ("status", "req", "service", "wait_ticks", "arrival",
                  "start"),
    "Telemetry/chaos": ("edge", "attempt"),
    "Telemetry/fabric": ("src_host", "rem_bytes"),
    # Alerting (alerting="burn", DESIGN.md §10) folds finished-hop sojourn
    # times into the per-service SLI accumulators; like Telemetry it is
    # observation-only — `arrival` rides on Execute's declaration, so no
    # resolved layout grows.
    "Alerting": ("arrival",),
}


@dataclasses.dataclass(frozen=True)
class PoolLayout:
    """Static name → column-index map of the stacked cloudlet pool.

    Resolved once per mode combination (`resolve_layout`) and carried as
    pytree *aux data* on :class:`Cloudlets`, so it is hashable, closed
    over in jit, and keys the compile cache together with the structural
    SimParams knobs that produced it.
    """

    i_fields: Tuple[str, ...]
    f_fields: Tuple[str, ...]

    def i(self, name: str) -> int:
        """Index of an i32 column in the [C, NI] block."""
        try:
            return self.i_fields.index(name)
        except ValueError:
            raise KeyError(
                f"pool column {name!r} is not part of this mode's layout "
                f"(i32 columns: {self.i_fields})") from None

    def f(self, name: str) -> int:
        """Index of an f32 column in the [C, NF] block."""
        try:
            return self.f_fields.index(name)
        except ValueError:
            raise KeyError(
                f"pool column {name!r} is not part of this mode's layout "
                f"(f32 columns: {self.f_fields})") from None

    def __contains__(self, name: str) -> bool:
        return name in self.i_fields or name in self.f_fields

    @property
    def columns(self) -> Tuple[str, ...]:
        return self.i_fields + self.f_fields

    def init_ints(self) -> np.ndarray:
        return np.array([_COL_INIT[n] for n in self.i_fields], np.int32)

    def init_flts(self) -> np.ndarray:
        return np.array([_COL_INIT[n] for n in self.f_fields], np.float32)


@functools.lru_cache(maxsize=None)
def _layout_for(network: str, faults: str, egress_shaping: bool,
                telemetry: bool = False, alerting: bool = False) -> PoolLayout:
    phases = ["Generation", "Dispatch", "Execute", "Derive"]
    if faults == "chaos":
        phases.append("Disruption")
        phases.append("Execute/chaos")
    if network == "fabric":
        phases.append("Transit")
        if faults == "chaos":
            phases.append("Disruption/fabric")
        if egress_shaping:
            phases.append("Transit/egress_shaping")
    if telemetry:
        # observation-only: the Telemetry declarations are a subset of the
        # union above in every mode, so the resolved layout never grows
        phases.append("Telemetry")
        if faults == "chaos":
            phases.append("Telemetry/chaos")
        if network == "fabric":
            phases.append("Telemetry/fabric")
    if alerting:
        phases.append("Alerting")
    need = set()
    for p in phases:
        cols = set(PHASE_COLUMNS[p])
        if p.startswith("Telemetry") or p == "Alerting":
            extra = cols - need
            if extra:
                raise ValueError(
                    f"PHASE_COLUMNS[{p!r}] declares column(s) "
                    f"{sorted(extra)} that no simulating phase carries in "
                    "this mode — telemetry/alerting is observation-only "
                    "and must not grow the pool layout")
        need |= cols
    return PoolLayout(
        i_fields=tuple(n for n in CL_I_FIELDS if n in need),
        f_fields=tuple(n for n in CL_F_FIELDS if n in need))


def resolve_layout(params: "SimParams") -> PoolLayout:
    """The static pool layout a SimParams' enabled phases require."""
    return _layout_for(params.network, params.faults,
                       params.network == "fabric" and params.egress_shaping,
                       params.telemetry == "stream",
                       params.telemetry == "stream"
                       and params.alerting == "burn")


FULL_LAYOUT = _layout_for("fabric", "chaos", True)   # every column


@jax.tree_util.register_pytree_node_class
class Cloudlets:
    """Active-set RpcCloudlet buffer (paper §4.1.2, §4.2), stored as two
    stacked column blocks so one spawn wave is two scatters.

    The column set is the mode-keyed :class:`PoolLayout` (aux data, not a
    leaf): named accessors (``cl.status`` …) and the column writers
    (`with_cols`, `pool.scatter_pool`) resolve indices through it, so no
    caller hard-codes a position and absent columns cost nothing.
    Writers accept any registered column name and silently skip columns
    outside the active layout — spawn sites stay mode-agnostic; reading
    an absent column raises ``KeyError`` (reads are always mode-gated).
    """

    __slots__ = ("ints", "flts", "layout")

    def __init__(self, ints: jnp.ndarray, flts: jnp.ndarray,
                 layout: PoolLayout = FULL_LAYOUT):
        self.ints = ints        # [C, len(layout.i_fields)] i32
        self.flts = flts        # [C, len(layout.f_fields)] f32
        self.layout = layout

    # --- pytree protocol (layout is static aux data) -------------------
    def tree_flatten(self):
        return (self.ints, self.flts), self.layout

    @classmethod
    def tree_unflatten(cls, layout, children):
        return cls(children[0], children[1], layout)

    def replace(self, ints=None, flts=None) -> "Cloudlets":
        return Cloudlets(self.ints if ints is None else ints,
                         self.flts if flts is None else flts, self.layout)

    # --- named column views --------------------------------------------
    def col(self, name: str) -> jnp.ndarray:
        if _COL_BLOCK.get(name) == "i":
            return self.ints[:, self.layout.i(name)]
        if _COL_BLOCK.get(name) == "f":
            return self.flts[:, self.layout.f(name)]
        raise KeyError(f"unknown pool column {name!r}")

    @property
    def status(self) -> jnp.ndarray:
        return self.col("status")

    @property
    def req(self) -> jnp.ndarray:
        return self.col("req")

    @property
    def service(self) -> jnp.ndarray:
        return self.col("service")

    @property
    def inst(self) -> jnp.ndarray:
        return self.col("inst")

    @property
    def wait_ticks(self) -> jnp.ndarray:
        return self.col("wait_ticks")

    @property
    def depth(self) -> jnp.ndarray:
        return self.col("depth")

    @property
    def src_host(self) -> jnp.ndarray:
        return self.col("src_host")

    @property
    def attempt(self) -> jnp.ndarray:
        return self.col("attempt")

    @property
    def edge(self) -> jnp.ndarray:
        return self.col("edge")

    @property
    def src_inst(self) -> jnp.ndarray:
        return self.col("src_inst")

    @property
    def length(self) -> jnp.ndarray:
        return self.col("length")

    @property
    def rem(self) -> jnp.ndarray:
        return self.col("rem")

    @property
    def arrival(self) -> jnp.ndarray:
        return self.col("arrival")

    @property
    def start(self) -> jnp.ndarray:
        return self.col("start")

    @property
    def rem_bytes(self) -> jnp.ndarray:
        return self.col("rem_bytes")

    def with_cols(self, **cols) -> "Cloudlets":
        """Replace whole [C] field columns by name (dispatch/execute path);
        consecutive column writes fuse into one pass under jit.  Registered
        columns outside the active layout are skipped (mode-agnostic
        callers); unregistered names raise."""
        ints, flts = self.ints, self.flts
        L = self.layout
        for name, v in cols.items():
            if name not in _COL_BLOCK:
                raise TypeError(f"unknown pool column {name!r}")
            if name not in L:
                continue
            if _COL_BLOCK[name] == "i":
                ints = ints.at[:, L.i(name)].set(jnp.asarray(v, ints.dtype))
            else:
                flts = flts.at[:, L.f(name)].set(jnp.asarray(v, flts.dtype))
        return Cloudlets(ints, flts, L)


class Instances(NamedTuple):
    """Instance pool (pods/containers; paper §3.3)."""

    status: jnp.ndarray      # [I] i32 INST_*
    service: jnp.ndarray     # [I] i32 (-1 on free slots)
    vm: jnp.ndarray          # [I] i32
    host: jnp.ndarray        # [I] i32 physical host (NIC attachment, §6);
    #                          co-located with the VM, moves on migration
    mips: jnp.ndarray        # [I] f32 current CPU allocation (MI/s)
    limit_mips: jnp.ndarray  # [I] f32 vertical-scaling cap ("limits.share")
    request_mips: jnp.ndarray# [I] f32 baseline request ("requests.share")
    ram: jnp.ndarray         # [I] f32 current RAM allocation (MB)
    limit_ram: jnp.ndarray   # [I] f32
    bw: jnp.ndarray          # [I] f32 bandwidth (Mbps)
    n_exec: jnp.ndarray      # [I] i32 executing cloudlets this tick
    used_mips: jnp.ndarray   # [I] f32 consumed this tick
    used_ram: jnp.ndarray    # [I] f32 linear cloudlet→RAM model (paper §5.2)
    used_bw: jnp.ndarray     # [I] f32 linear spawn→BW model
    util_ema: jnp.ndarray    # [I] f32 smoothed utilization (scaling signal)
    usage_sum: jnp.ndarray   # [I] f32 ∫ used_mips dt  (usage history)
    busy_ticks: jnp.ndarray  # [I] i32 ticks with n_exec > 0


class VMs(NamedTuple):
    mips: jnp.ndarray        # [V] f32 capacity
    mips_used: jnp.ndarray   # [V] f32 allocated to instances
    ram: jnp.ndarray         # [V] f32
    ram_used: jnp.ndarray    # [V] f32


class Hosts(NamedTuple):
    """Per-host hardware description (fabric §6, heterogeneity §7.1).

    One host per VM slot (host id = vm id).  Effective port capacity is
    ``scale * dyn.nic_{egress,ingress}_mbps`` so heterogeneous clusters keep
    their shape while sweeps scale the whole fabric through one traced
    scalar.  ``cpu_scale`` is the CPU analogue: instances execute at
    ``cpu_scale[host] ×`` their allocated MIPS, so a slow hardware class
    (old CPUs, throttled nodes) degrades *speed* while the placement
    bin-packing still sees the full requested milicores — the
    resource-model asymmetry real schedulers suffer (default 1.0
    everywhere, which multiplies out exactly).
    """

    egress_scale: jnp.ndarray    # [H] f32 NIC egress capacity multiplier
    ingress_scale: jnp.ndarray   # [H] f32 NIC ingress capacity multiplier
    cpu_scale: jnp.ndarray       # [H] f32 execution-rate multiplier


class NetStats(NamedTuple):
    """Network-fabric usage history (bytes moved, link utilization,
    transit-time distribution) — all zeros in ``network="uniform"`` mode."""

    bytes_out: jnp.ndarray     # [H] f32 MB egressed per host
    bytes_in: jnp.ndarray      # [H] f32 MB ingressed per host
    egress_busy: jnp.ndarray   # [H] f32 ∫ egress utilization dt (seconds)
    ingress_busy: jnp.ndarray  # [H] f32 ∫ ingress utilization dt
    transits: jnp.ndarray      # scalar i32 completed transfers
    transit_sum: jnp.ndarray   # scalar f32 Σ transit durations (s)
    hist: jnp.ndarray          # [NB] i32 transit-time histogram
    #                            (bin = net_hist_bin_s; last bin = overflow)


class FaultState(NamedTuple):
    """Fault-injection & resilience state (Disruption phase, DESIGN.md §7).

    ``host_up`` / ``nic_ok`` are [H] in every mode (placement and scaling
    read them unconditionally); every other table is a chaos-only column —
    zero-width in ``faults="none"`` mode so the fault-free scan carry pays
    nothing for the resilience machinery.

    The circuit breaker per service edge is a pure status mask over
    ``edge_open_until``: CLOSED while ``open_until <= 0``, OPEN while
    ``time < open_until`` (new calls fail fast), HALF-OPEN once the cooldown
    passes (``0 < open_until <= time`` — probe traffic flows; the first
    observed failure re-opens, the first all-success tick closes).
    Outlier ejection (``inst_eject_until``) mirrors the same three states
    per replica: an OPEN replica is compacted out of the dispatch rank
    table (`policies.eject_view`), a HALF-OPEN one receives probe traffic.
    """

    host_up: jnp.ndarray         # [H] i32 1 = host up
    nic_ok: jnp.ndarray          # [H] i32 1 = NIC healthy (degradation)
    edge_open_until: jnp.ndarray # [E] f32 breaker clock (see above)
    edge_err_ema: jnp.ndarray    # [E] f32 error-rate EMA per edge
    edge_succ: jnp.ndarray       # [E] i32 successes since the last breaker
    #                              update (written by execute, consumed and
    #                              reset by the next Disruption phase)
    host_slow: jnp.ndarray       # [H] i32 1 = fail-slow episode active
    #                              (Execute degrades MIPS by host_slow_factor)
    nic_factor: jnp.ndarray      # [H] f32 NIC capacity multiplier Transit
    #                              applies (1.0 healthy; sampled per brownout
    #                              from the severity distribution)
    zone_cut: jnp.ndarray        # [H, H] i32 symmetric zone-pair partition
    #                              mask (zone ids index it; Z ≤ H so the
    #                              host count bounds the table)
    inst_err_ema: jnp.ndarray    # [I] f32 per-replica error-rate EMA
    inst_lat_ema: jnp.ndarray    # [I] f32 per-replica mean-sojourn EMA (s)
    inst_eject_until: jnp.ndarray# [I] f32 ejection clock (breaker states)
    inst_succ: jnp.ndarray       # [I] i32 successes since the last ejection
    #                              update (execute-written, like edge_succ)
    inst_lat_sum: jnp.ndarray    # [I] f32 Σ sojourn of those successes


class FaultStats(NamedTuple):
    """Cumulative resilience/availability history (joins QoSReport, §7)."""

    host_crashes: jnp.ndarray    # i32 injected host-down events
    host_recoveries: jnp.ndarray # i32 host recoveries (observed-MTTR denom.)
    inst_kills: jnp.ndarray      # i32 injected instance kills
    failed_attempts: jnp.ndarray # i32 cloudlet attempts that failed
    retries: jnp.ndarray         # i32 retry attempts respawned
    failfast: jnp.ndarray        # i32 attempts failed fast by an open breaker
    failed_requests: jnp.ndarray # i32 requests completed as failed
    breaker_trips: jnp.ndarray   # i32 closed → open transitions
    down_time_s: jnp.ndarray     # f32 Σ host-down seconds (MTTR numerator)
    ejections: jnp.ndarray       # i32 replica outlier ejections
    readmissions: jnp.ndarray    # i32 ejected replicas re-admitted clean
    zone_faults: jnp.ndarray     # i32 zone-correlated crash/slow draws fired
    partitions: jnp.ndarray      # i32 zone-pair partitions opened
    slow_episodes: jnp.ndarray   # i32 host fail-slow episodes started
    slow_time_s: jnp.ndarray     # f32 Σ host-slow seconds


# --------------------------------------------------------------------------
# Telemetry schemas (DESIGN.md §9).  Declared here, next to POOL_COLUMNS,
# because zeros_state sizes the TelemetryState buffers off them; the
# host-side renderers (repro/obs) re-export these tuples.
# --------------------------------------------------------------------------

# One metric row per closed window, in ring-storage order.
TEL_METRIC_COLUMNS = (
    "window",            # window index (monotone, 0-based)
    "time_s",            # sim time at window close
    "tag",               # sweep-point tag (dyn.tel_tag)
    "completed",         # requests completed in the window (sum)
    "generated",         # requests generated in the window (sum)
    "n_waiting",         # gauges sampled at window close ↓
    "n_exec",
    "n_transit",
    "used_mips",
    "active_instances",
    "net_mb_inflight",   # Σ rem_bytes in TRANSIT (fabric mode; else 0)
    "failed_attempts",   # cumulative FaultStats at close (0 faults off)
    "retries",           # cumulative FaultStats at close
    "spans",             # spans recorded so far (cumulative)
    "span_drops",        # spans dropped at ring capacity (cumulative)
)
# Window-summed accumulators (prefix of the row's sum section).
TEL_ACC_COLUMNS = ("completed", "generated")
# One span per sampled finished cloudlet (hop), split by block dtype.
TEL_SPAN_I_COLUMNS = ("req", "service", "inst", "host", "src_host",
                      "edge", "attempt", "wait_ticks")
TEL_SPAN_F_COLUMNS = ("arrival", "start", "finish")


class TelemetryState(NamedTuple):
    """Device-side observability state (telemetry="stream", DESIGN.md §9).

    Mode-keyed like :class:`FaultState`: every buffer is zero-width under
    ``telemetry="none"`` so the default scan carry pays nothing.  The
    metric ring is double-buffered — ticks write rows into half the ring
    while the io_callback tap flushes the other, just-completed half.
    The span ring is append-until-full: overflow never overwrites, it
    increments the exact drop counter instead.
    """

    ring: jnp.ndarray        # [W, K] f32 metric rows (K = TEL_METRIC_…)
    acc: jnp.ndarray         # [len(TEL_ACC_COLUMNS)] f32 open-window sums
    win: jnp.ndarray         # [1] i32 windows closed so far
    span_i: jnp.ndarray      # [SP, NSI] i32 span ints
    span_f: jnp.ndarray      # [SP, NSF] f32 span timestamps
    span_n: jnp.ndarray      # [1] i32 spans recorded (≤ SP)
    span_drops: jnp.ndarray  # [1] i32 spans dropped at capacity
    sample: jnp.ndarray      # [R] u8 1 = request is traced (seeded 1-in-k)


def validate_telemetry(params: "SimParams") -> None:
    if params.telemetry not in ("none", "stream"):
        raise ValueError(
            f"SimParams.telemetry must be 'none' or 'stream', "
            f"got {params.telemetry!r}")
    if params.telemetry == "stream":
        if params.tel_windows < 2 or params.tel_windows % 2:
            raise ValueError(
                "SimParams.tel_windows must be an even int ≥ 2 (the ring "
                f"flushes in halves), got {params.tel_windows!r}")
        for f in ("tel_window_ticks", "tel_span_k", "tel_span_cap"):
            v = getattr(params, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"SimParams.{f} must be an int ≥ 1, got {v!r}")
        v = params.tel_span_tick_cap
        if not isinstance(v, int) or v < 0:
            raise ValueError(
                "SimParams.tel_span_tick_cap must be an int ≥ 0 "
                f"(0 = uncapped), got {v!r}")


class AlertState(NamedTuple):
    """Per-service SLO burn-rate alerting state (alerting="burn",
    DESIGN.md §10).

    Mode-keyed like :class:`TelemetryState`: every buffer is zero-width
    unless ``telemetry="stream"`` AND ``alerting="burn"``, so the default
    carry pays nothing and the sixth golden combo (alerting compiled in,
    objectives disabled) stays bit-identical by construction.  Axes:
    ``S`` services, ``NR = len(ALERT_RULES)`` burn rules, ``L`` closed SLI
    windows (``slo_long_wins``), ``AP`` event-ring rows (``slo_event_cap``).
    The transition ring is append-until-full with exact drop counting —
    the span-ring discipline.
    """

    sli_win: jnp.ndarray      # [L, S, 2] f32 closed windows of (good, bad)
    sli_acc: jnp.ndarray      # [S, 2] f32 open-window (good, bad) sums
    win: jnp.ndarray          # [1] i32 SLI windows closed so far
    astate: jnp.ndarray       # [S, NR] i32 ALERT_INACTIVE..ALERT_RESOLVED
    pending: jnp.ndarray      # [S, NR] i32 consecutive ticks condition held
    fires: jnp.ndarray        # [S, NR] i32 pending→firing transitions
    resolves: jnp.ndarray     # [S, NR] i32 firing→resolved transitions
    firing_ticks: jnp.ndarray # [S, NR] i32 ticks spent firing
    hold_until: jnp.ndarray   # [S] f32 burn-mode scale-out stabilization
    ev_time: jnp.ndarray      # [AP] f32 transition timestamps
    ev_service: jnp.ndarray   # [AP] i32
    ev_rule: jnp.ndarray      # [AP] i32 index into ALERT_RULES
    ev_state: jnp.ndarray     # [AP] i32 new state (index into ALERT_STATES)
    ev_n: jnp.ndarray         # [1] i32 transitions recorded (≤ AP)
    ev_drops: jnp.ndarray     # [1] i32 transitions dropped at capacity


def validate_alerting(params: "SimParams") -> None:
    if params.alerting not in ("none", "burn"):
        raise ValueError(
            f"SimParams.alerting must be 'none' or 'burn', "
            f"got {params.alerting!r}")
    if params.hs_mode not in HS_MODES:
        raise ValueError(
            f"SimParams.hs_mode must be one of {HS_MODES}, "
            f"got {params.hs_mode!r}")
    if params.alerting == "burn":
        if params.telemetry != "stream":
            raise ValueError(
                "alerting='burn' evaluates rules on the telemetry window "
                "cadence and requires telemetry='stream'")
        for f in ("slo_short_wins", "slo_long_wins", "slo_for_ticks",
                  "slo_event_cap"):
            v = getattr(params, f)
            if not isinstance(v, int) or v < 1:
                raise ValueError(
                    f"SimParams.{f} must be an int ≥ 1, got {v!r}")
        if params.slo_long_wins < params.slo_short_wins:
            raise ValueError(
                "SimParams.slo_long_wins must be ≥ slo_short_wins "
                f"(got {params.slo_long_wins} < {params.slo_short_wins})")
        if not params.slo_eject_tighten > 0:
            raise ValueError(
                "SimParams.slo_eject_tighten must be > 0 (1 disables "
                f"tightening), got {params.slo_eject_tighten!r}")
    elif params.hs_mode == "slo_burn":
        raise ValueError(
            "hs_mode='slo_burn' gates scale-out on firing burn alerts and "
            "requires alerting='burn'")


class SchedState(NamedTuple):
    """Service→replica dispatch tables, maintained incrementally.

    ``inst_of_rank[s, r]`` is the instance slot of the r-th replica of
    service ``s`` (-1 beyond ``svc_replicas[s]``).  Placement fills it,
    HS scale-out/in mutates it, dispatch reads it every tick.
    """

    inst_of_rank: jnp.ndarray   # [S, R_max] i32
    svc_replicas: jnp.ndarray   # [S] i32


class SvcStats(NamedTuple):
    """Per-service usage history (paper §5.2) and node-delay estimates
    (feeds the critical-path analysis of §4.3.2)."""

    usage_sum: jnp.ndarray   # [S] f32 ∫ used_mips dt over replicas
    finished: jnp.ndarray    # [S] i32 cloudlets completed
    delay_sum: jnp.ndarray   # [S] f32 Σ (finish - arrival) sojourn
    exec_sum: jnp.ndarray    # [S] f32 Σ execution time
    wait_sum: jnp.ndarray    # [S] f32 Σ waiting time


class Counters(NamedTuple):
    spawned: jnp.ndarray         # i32 cloudlets ever created
    finished: jnp.ndarray        # i32 cloudlets ever finished
    dropped_cloudlets: jnp.ndarray
    dropped_requests: jnp.ndarray
    completed: jnp.ndarray       # i32 completed requests
    resp_sum: jnp.ndarray        # f32 Σ response
    slo_violations: jnp.ndarray  # i32
    migrations: jnp.ndarray      # i32
    scale_out: jnp.ndarray       # i32 HS scale-out events
    scale_in: jnp.ndarray        # i32 HS scale-in events
    scale_up: jnp.ndarray        # i32 VS scale-up events
    scale_down: jnp.ndarray      # i32 VS scale-down events


class SimState(NamedTuple):
    tick: jnp.ndarray       # i32
    time: jnp.ndarray       # f32 seconds
    rng: jnp.ndarray        # PRNG key
    rr: jnp.ndarray         # [S] i32 round-robin cursor per service
    clients: Clients
    requests: Requests
    cloudlets: Cloudlets
    instances: Instances
    vms: VMs
    hosts: Hosts
    net: NetStats
    sched: SchedState
    svc_stats: SvcStats
    counters: Counters
    fault: FaultState
    fstats: FaultStats
    telemetry: TelemetryState
    alerts: AlertState


class TickTrace(NamedTuple):
    """Per-tick scalar outputs of the scan (QoS time series)."""

    completed: jnp.ndarray      # requests completed this tick
    generated: jnp.ndarray      # requests generated this tick
    n_waiting: jnp.ndarray      # cloudlets in waiting queue
    n_exec: jnp.ndarray         # cloudlets in execution queue
    n_transit: jnp.ndarray      # transfers in flight on the fabric (§6)
    used_mips: jnp.ndarray      # Σ instance used mips
    active_instances: jnp.ndarray
    active_clients: jnp.ndarray


def edge_table_size(n_services: int, d_max: int, n_apis: int) -> int:
    """Length of every per-service-edge table — retry/timeout/payload on
    :class:`AppStatic` AND the FaultState breaker tables: ``S * d_max``
    call edges plus one client→entry edge per API (ids
    ``S*d_max .. S*d_max + n_apis - 1``).  ONE resolver shared by
    ``build_app`` and ``zeros_state`` so the two can never disagree."""
    return n_services * d_max + max(n_apis, 1)


def zeros_state(caps: SimCaps, params: SimParams, rng, n_services: int = 1,
                n_edges: int | None = None, n_apis: int = 1,
                app=None) -> SimState:
    """Build the initial (empty) simulation state.

    Pass ``app`` (an :class:`AppStatic`) to size the per-service-edge
    resilience tables (retry policy / circuit breaker, §7) from the app's
    own edge tables — the same ``edge_table_size`` resolver that built its
    retry/timeout/payload columns, so the FaultState tables can't be
    undersized.  Without an app, sizing falls back to the caps-derived
    bound with ``n_edges``/``n_apis`` overrides (legacy path; the engine's
    trace-time check still rejects mismatched states).

    The cloudlet pool is built to the mode-keyed :class:`PoolLayout` the
    params resolve to — exactly the columns the enabled tick phases
    declared, nothing more.  In ``faults="none"`` mode every chaos-only
    FaultState table (the [E] breaker tables, fail-slow / ejection /
    partition state) is zero-width.
    """
    caps.validate()
    validate_telemetry(params)
    validate_alerting(params)
    f32 = jnp.float32
    i32 = jnp.int32
    Nc, R, C, I, V = (caps.n_clients, caps.max_requests, caps.max_cloudlets,
                      caps.max_instances, caps.n_vms)
    if app is not None:
        n_services = int(app.n_services)
        n_edges = int(app.n_edges)
    S = n_services
    chaos = params.faults == "chaos"
    E = n_edges if n_edges is not None \
        else edge_table_size(n_services, caps.d_max, n_apis)
    if not chaos:
        E = 0     # chaos-only columns: zero-width off the Disruption phase
    layout = resolve_layout(params)
    return SimState(
        tick=jnp.zeros((), i32),
        time=jnp.zeros((), f32),
        rng=rng,
        rr=jnp.zeros((S,), i32),
        clients=Clients(wait=jnp.zeros((Nc,), i32)),
        requests=Requests(
            count=jnp.zeros((), i32),
            api=jnp.full((R,), -1, i32),
            arrival=jnp.full((R,), -1.0, f32),
            outstanding=jnp.zeros((R,), i32),
            spawned=jnp.zeros((R,), i32),
            finish=jnp.zeros((R,), f32),
            response=jnp.full((R,), -1.0, f32),
            critical_len=jnp.zeros((R,), i32),
            # the failed flag is a Disruption-phase column: zero-width in
            # faults="none" mode so it never rides the scan carry there
            failed=jnp.zeros((R if chaos else 0,), jnp.uint8),
        ),
        cloudlets=Cloudlets(
            ints=jnp.tile(jnp.asarray(layout.init_ints()[None, :]), (C, 1)),
            flts=jnp.tile(jnp.asarray(layout.init_flts()[None, :]), (C, 1)),
            layout=layout,
        ),
        instances=Instances(
            status=jnp.zeros((I,), i32),
            service=jnp.full((I,), -1, i32),
            vm=jnp.full((I,), -1, i32),
            host=jnp.full((I,), -1, i32),
            mips=jnp.zeros((I,), f32),
            limit_mips=jnp.zeros((I,), f32),
            request_mips=jnp.zeros((I,), f32),
            ram=jnp.zeros((I,), f32),
            limit_ram=jnp.zeros((I,), f32),
            bw=jnp.zeros((I,), f32),
            n_exec=jnp.zeros((I,), i32),
            used_mips=jnp.zeros((I,), f32),
            used_ram=jnp.zeros((I,), f32),
            used_bw=jnp.zeros((I,), f32),
            util_ema=jnp.zeros((I,), f32),
            usage_sum=jnp.zeros((I,), f32),
            busy_ticks=jnp.zeros((I,), i32),
        ),
        vms=VMs(
            mips=jnp.zeros((V,), f32),
            mips_used=jnp.zeros((V,), f32),
            ram=jnp.zeros((V,), f32),
            ram_used=jnp.zeros((V,), f32),
        ),
        hosts=Hosts(
            egress_scale=jnp.ones((V,), f32),
            ingress_scale=jnp.ones((V,), f32),
            cpu_scale=jnp.ones((V,), f32),
        ),
        net=NetStats(
            bytes_out=jnp.zeros((V,), f32),
            bytes_in=jnp.zeros((V,), f32),
            egress_busy=jnp.zeros((V,), f32),
            ingress_busy=jnp.zeros((V,), f32),
            transits=jnp.zeros((), i32),
            transit_sum=jnp.zeros((), f32),
            hist=jnp.zeros((caps.net_hist_buckets,), i32),
        ),
        sched=SchedState(
            inst_of_rank=jnp.full((S, caps.max_replicas), -1, i32),
            svc_replicas=jnp.zeros((S,), i32),
        ),
        svc_stats=SvcStats(
            usage_sum=jnp.zeros((S,), f32),
            finished=jnp.zeros((S,), i32),
            delay_sum=jnp.zeros((S,), f32),
            exec_sum=jnp.zeros((S,), f32),
            wait_sum=jnp.zeros((S,), f32),
        ),
        counters=Counters(*([jnp.zeros((), i32)] * 5 + [jnp.zeros((), f32)]
                            + [jnp.zeros((), i32)] * 6)),
        fault=FaultState(
            host_up=jnp.ones((V,), i32),
            nic_ok=jnp.ones((V,), i32),
            edge_open_until=jnp.zeros((E,), f32),
            edge_err_ema=jnp.zeros((E,), f32),
            edge_succ=jnp.zeros((E,), i32),
            host_slow=jnp.zeros((V if chaos else 0,), i32),
            nic_factor=jnp.ones((V if chaos else 0,), f32),
            zone_cut=jnp.zeros((V, V) if chaos else (0, 0), i32),
            inst_err_ema=jnp.zeros((I if chaos else 0,), f32),
            inst_lat_ema=jnp.zeros((I if chaos else 0,), f32),
            inst_eject_until=jnp.zeros((I if chaos else 0,), f32),
            inst_succ=jnp.zeros((I if chaos else 0,), i32),
            inst_lat_sum=jnp.zeros((I if chaos else 0,), f32),
        ),
        fstats=FaultStats(*([jnp.zeros((), i32)] * 8
                            + [jnp.zeros((), f32)]
                            + [jnp.zeros((), i32)] * 5
                            + [jnp.zeros((), f32)])),
        telemetry=_zeros_telemetry(params, R, rng),
        alerts=_zeros_alerts(params, S),
    )


def _zeros_telemetry(params: SimParams, R: int, rng) -> TelemetryState:
    """Initial telemetry state: zero-width under ``telemetry="none"``
    (the FaultState pattern — the default carry pays nothing), sized from
    the tel_* knobs under ``"stream"``.

    The 1-in-k span sample mask is drawn once here from a child key
    *folded off* the root rng under the named label ``"tel_sample"``:
    ``fold_in`` leaves the parent key untouched, so ``state.rng`` — and
    with it every simulation stream — is bit-identical with telemetry on
    or off (the golden-matrix fifth combo), and the RNG auditor sees a
    named derivation if the init path is ever recorded.
    """
    f32, i32 = jnp.float32, jnp.int32
    on = params.telemetry == "stream"
    K = len(TEL_METRIC_COLUMNS)
    NA = len(TEL_ACC_COLUMNS)
    NSI = len(TEL_SPAN_I_COLUMNS)
    NSF = len(TEL_SPAN_F_COLUMNS)
    W = params.tel_windows if on else 0
    SP = params.tel_span_cap if on else 0
    if on:
        k_sample = streams.fold_in(rng, 0, name="tel_sample")
        sample = (jax.random.uniform(k_sample, (R,))
                  < 1.0 / params.tel_span_k).astype(jnp.uint8)
    else:
        sample = jnp.zeros((0,), jnp.uint8)
    return TelemetryState(
        ring=jnp.zeros((W, K), f32),
        acc=jnp.zeros((NA if on else 0,), f32),
        win=jnp.zeros((1 if on else 0,), i32),
        span_i=jnp.zeros((SP, NSI), i32),
        span_f=jnp.zeros((SP, NSF), f32),
        span_n=jnp.zeros((1 if on else 0,), i32),
        span_drops=jnp.zeros((1 if on else 0,), i32),
        sample=sample,
    )


def _zeros_alerts(params: SimParams, S: int) -> AlertState:
    """Initial alert state: zero-width unless the Alerting stage is
    compiled in (``telemetry="stream"`` AND ``alerting="burn"``) — the
    :func:`_zeros_telemetry` pattern.  Draws no RNG: alert evaluation is
    fully deterministic recording-rule math."""
    f32, i32 = jnp.float32, jnp.int32
    on = params.telemetry == "stream" and params.alerting == "burn"
    NR = len(ALERT_RULES)
    Sa = S if on else 0
    L = params.slo_long_wins if on else 0
    AP = params.slo_event_cap if on else 0
    one = 1 if on else 0
    return AlertState(
        sli_win=jnp.zeros((L, Sa, 2), f32),
        sli_acc=jnp.zeros((Sa, 2), f32),
        win=jnp.zeros((one,), i32),
        astate=jnp.zeros((Sa, NR), i32),
        pending=jnp.zeros((Sa, NR), i32),
        fires=jnp.zeros((Sa, NR), i32),
        resolves=jnp.zeros((Sa, NR), i32),
        firing_ticks=jnp.zeros((Sa, NR), i32),
        hold_until=jnp.zeros((Sa,), f32),
        ev_time=jnp.zeros((AP,), f32),
        ev_service=jnp.zeros((AP,), i32),
        ev_rule=jnp.zeros((AP,), i32),
        ev_state=jnp.zeros((AP,), i32),
        ev_n=jnp.zeros((one,), i32),
        ev_drops=jnp.zeros((one,), i32),
    )


def np_or_jnp(x):
    """Normalize config arrays to numpy (static side) for hashing safety."""
    return np.asarray(x)
