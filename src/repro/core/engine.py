"""Simulation engine: one fused tick per paper event cycle, scanned over time.

``make_tick`` assembles the event phases of paper §3.2 —
Generation → Dispatching → Scheduling → Derivative → Scaling & Migration —
into a single jitted state transition, and ``Simulation`` wraps
``jax.lax.scan`` over it with per-tick QoS traces.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import streams
from . import faults as faultsmod
from . import network as netmod
from . import policies
from . import scheduler
from .app import AppStatic, InstanceTemplate, build_app, validate_app
from .generator import client_phase
from .graph import ServiceGraph
from .placement import initial_allocation, migrate
from .scaling import scaling_event
from .types import (CL_EXEC, CL_TRANSIT, CL_WAITING, DynParams, INST_ON,
                    SimCaps, SimParams, SimState, TickTrace,
                    validate_alerting, validate_telemetry, zeros_state)

# make_tick's phase sequence — ``stop_after`` prefixes must name one.
TICK_PHASES = ("Generation", "Disruption", "Transit", "Dispatch",
               "Execute", "Alerting", "Derive", "Response", "Scaling")


def make_tick(caps: SimCaps, params: SimParams,
              has_edges: bool = True, scaling: str = "cond",
              probe: Optional[Callable[[str], None]] = None,
              stop_after: Optional[str] = None) -> Callable:
    """Build the jit-able tick function (paper event cycle, vectorized).

    ``params`` supplies the *static* knobs (policy selectors — they choose
    program structure); the swept scalars (``dyn``) and the application
    description (``app``) are traced arguments, so load/threshold sweeps
    and re-parameterized graphs (calibration) reuse one compilation.

    ``scaling`` selects how the periodic scaling/migration event is
    embedded: ``"cond"`` (a per-tick ``lax.cond``, the solo-run default),
    ``"always"`` / ``"never"`` (unconditional variants — ``run_batch``
    hoists the cadence decision OUT of its vmap, where a traced cond
    would otherwise degenerate into executing the scaling body every
    tick for every sweep point).

    ``params.network`` is static: ``"uniform"`` builds exactly the legacy
    load-independent-latency program; ``"fabric"`` inserts the Transit
    phase (core/network.py) between Generation/Derivative spawns and
    Dispatching, so RPC payloads contend on host NICs (DESIGN.md §6).

    ``params.faults`` is static: ``"none"`` builds exactly the fault-free
    program; ``"chaos"`` inserts the Disruption phase (core/faults.py)
    between Generation and Transit — host crash/recovery, instance kills,
    NIC degradation, retries and circuit breakers (DESIGN.md §7).

    ``probe`` is a trace-time hook for simcheck's layout-access checker
    (repro/analysis): called with each phase name just before that
    phase's ops trace, it lets the checker attribute recorded column
    accesses to `PHASE_COLUMNS` entries.  ``None`` (the default) adds
    nothing to the traced program.

    ``params.telemetry`` is static: ``"stream"`` adds the Telemetry
    recording ops (span capture after Execute, window close after
    Trace — repro/obs, DESIGN.md §9); ``"none"`` builds the exact
    pre-observability program (the telemetry buffers are zero-width).

    ``stop_after`` truncates the tick right after the named phase
    (``"Execute"``, or a Disruption stage like ``"Disruption/respawn"``)
    and returns a zero trace — the obs profiler's prefix programs
    (obs/profile.py) difference their walls to attribute per-phase cost.
    ``None`` (the default) builds the full tick.
    """
    if params.network not in ("uniform", "fabric"):
        raise ValueError(
            f"SimParams.network must be 'uniform' or 'fabric', "
            f"got {params.network!r}")
    if params.faults not in ("none", "chaos"):
        raise ValueError(
            f"SimParams.faults must be 'none' or 'chaos', "
            f"got {params.faults!r}")
    validate_telemetry(params)
    validate_alerting(params)
    network = params.network == "fabric"
    faults_on = params.faults == "chaos"
    telemetry = params.telemetry == "stream"
    alerting = telemetry and params.alerting == "burn"
    if telemetry:
        from ..obs import telemetry as telmod
    if alerting:
        from ..obs import slo as slomod
    if stop_after is not None \
            and stop_after.split("/", 1)[0] not in TICK_PHASES:
        raise ValueError(
            f"stop_after must name a tick phase {TICK_PHASES} "
            f"(optionally 'Disruption/<stage>'), got {stop_after!r}")

    # Stream names for the tick's single wide split; positions are the
    # contract (split is NOT prefix-stable), names are the audit labels.
    key_names = ("carry", "gen", "spawn", "lb", "derive") \
        + (("net_gen", "net_derive") if network else ()) \
        + (("faults", "retry_len", "retry_net") if faults_on else ())

    def tick(state: SimState, dyn: DynParams, app: AppStatic
             ) -> Tuple[SimState, TickTrace]:
        # rng split counts are mode-static; the first five (seven with the
        # fabric) match the fault-free program exactly, so faults="none"
        # stays bit-identical to the pre-faults engine.
        n_keys = (7 if network else 5) + (3 if faults_on else 0)
        keys = streams.split(state.rng, n_keys, names=key_names)
        rng, k_gen, k_gen2, k_lb, k_der = (keys[0], keys[1], keys[2],
                                           keys[3], keys[4])
        k_net_g, k_net_d = (keys[5], keys[6]) if network else (None, None)
        state = state._replace(rng=rng)

        def early(st: SimState) -> Tuple[SimState, TickTrace]:
            # profiler prefix cut: advance the clock, zero the trace
            i0 = jnp.zeros((), jnp.int32)
            tr = TickTrace(completed=i0, generated=i0, n_waiting=i0,
                           n_exec=i0, n_transit=i0,
                           used_mips=jnp.zeros((), jnp.float32),
                           active_instances=i0, active_clients=i0)
            return st._replace(tick=st.tick + 1,
                               time=st.time + dyn.dt), tr

        # --- Generation (paper Alg 1) ---------------------------------
        # Each phase body runs under a jax.named_scope so every eqn in
        # the lowered program carries its tick phase — pure metadata
        # (digests identical), consumed by the analysis passes (§8).
        if probe:
            probe("Generation")
        with jax.named_scope("Generation"):
            gen = client_phase(state.clients.wait, state.time,
                               state.requests.count, app.api_cdf, dyn, k_gen)
            state, gen_res = scheduler.gen_spawn(
                state, app, caps, gen.fired, gen.api, gen.wait_proposal,
                k_gen2, dyn, params=params, net_rng=k_net_g)
        if stop_after == "Generation":
            return early(state)

        # --- Disruption (chaos mode: faults, retries, breakers) ----------
        if faults_on:
            if probe:
                probe("Disruption")
            stage = (stop_after.split("/", 1)[1]
                     if stop_after and stop_after.startswith("Disruption/")
                     else None)
            with jax.named_scope("Disruption"):
                state = faultsmod.disruption(
                    state, app, caps, params, dyn, keys[-3], keys[-2],
                    keys[-1] if network else None, stop_after=stage)
        if stop_after and stop_after.startswith("Disruption"):
            return early(state)

        # --- Transit (fabric mode: NIC fair-share water-filling) --------
        if network:
            if probe:
                probe("Transit")
            with jax.named_scope("Transit"):
                state = netmod.transit(state, caps, params, dyn, app)
        if stop_after == "Transit":
            return early(state)

        # --- Dispatching (waiting → execution, load-balanced) ----------
        if probe:
            probe("Dispatch")
        with jax.named_scope("Dispatch"):
            state = scheduler.dispatch(state, app, caps, params, dyn, k_lb,
                                       network=network)
        if stop_after == "Dispatch":
            return early(state)

        # --- Scheduling (time-shared execution + finish) ----------------
        if probe:
            probe("Execute")
        with jax.named_scope("Execute"):
            state, fin_info = scheduler.execute(state, app, caps, params,
                                                dyn)
        if stop_after == "Execute":
            return early(state)

        # --- Telemetry: span capture (execute cleared only status/rem/
        # inst, and Derive has not yet respawned over the freed slots) ---
        if telemetry:
            if probe:
                probe("Telemetry")
            with jax.named_scope("Telemetry"):
                state = telmod.record_spans(state, fin_info, params)

        # --- Alerting (SLO burn-rate rules + alert state machine) --------
        if alerting:
            if probe:
                probe("Alerting")
            with jax.named_scope("Alerting"):
                state = slomod.alert_step(state, fin_info, params, dyn, app)
        if stop_after == "Alerting":
            return early(state)

        # --- Derivative (spawn successors along the service chain) ------
        if has_edges:  # static: edge-free graphs skip the spawn machinery
            if probe:
                probe("Derive")
            with jax.named_scope("Derive"):
                state = scheduler.derive(state, app, caps, fin_info, k_der,
                                         params=params, net_rng=k_net_d)
        if stop_after == "Derive":
            return early(state)

        # --- Response (critical-path completion, paper §4.3.2) ----------
        if probe:
            probe("Response")
        with jax.named_scope("Response"):
            state, n_done = scheduler.complete(state, dyn, faults=faults_on)
        if stop_after == "Response":
            return early(state)

        # --- Scaling & Migration (paper §5) ------------------------------
        if probe:
            probe("Scaling")
        if (params.scaling_policy or params.migration_enabled) \
                and scaling != "never":

            def do_scale(st: SimState) -> SimState:
                st = scaling_event(st, app, caps, params, dyn)
                if params.migration_enabled:
                    st = migrate(st, app, caps, dyn)
                return st

            with jax.named_scope("Scaling"):
                if scaling == "always":
                    state = do_scale(state)
                else:
                    due = (state.tick % dyn.scale_interval) == \
                        (dyn.scale_interval - 1)
                    state = jax.lax.cond(due, do_scale, lambda st: st,
                                         state)
        if stop_after == "Scaling":
            return early(state)

        if probe:
            probe("Trace")
        with jax.named_scope("Trace"):
            trace = TickTrace(
                completed=n_done,
                generated=gen_res.n_new_requests,
                n_waiting=jnp.sum((state.cloudlets.status == CL_WAITING)
                                  .astype(jnp.int32)),
                n_exec=jnp.sum((state.cloudlets.status == CL_EXEC)
                               .astype(jnp.int32)),
                n_transit=jnp.sum((state.cloudlets.status == CL_TRANSIT)
                                  .astype(jnp.int32)),
                used_mips=jnp.sum(state.instances.used_mips),
                active_instances=jnp.sum((state.instances.status == INST_ON)
                                         .astype(jnp.int32)),
                active_clients=gen.n_active,
            )

        # --- Telemetry: window accumulate/close (observation-only) ------
        if telemetry:
            if probe:
                probe("Telemetry")
            with jax.named_scope("Telemetry"):
                state = telmod.close_window(state, params, dyn, trace)

        state = state._replace(tick=state.tick + 1, time=state.time + dyn.dt)
        return state, trace

    return tick


@dataclasses.dataclass
class SimResult:
    state: SimState
    trace: TickTrace
    wall_time_s: float
    compile_time_s: float

    def trace_np(self) -> dict:
        return {k: np.asarray(v) for k, v in self.trace._asdict().items()}


def batch_item(result: SimResult, b: int) -> SimResult:
    """Slice one sweep point out of a :meth:`Simulation.run_batch` result
    (wall/compile times are those of the whole batch)."""
    take = lambda x: x[b]
    return SimResult(state=jax.tree_util.tree_map(take, result.state),
                     trace=jax.tree_util.tree_map(take, result.trace),
                     wall_time_s=result.wall_time_s,
                     compile_time_s=result.compile_time_s)


def stack_dyn(dyns) -> DynParams:
    """Stack per-point :class:`DynParams` into the batched pytree
    ``run_batch`` consumes (leading axis = sweep point)."""
    dyns = list(dyns)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dyns)


class Simulation:
    """User-facing façade (paper Fig 4 ``Application`` + ``Register``).

    >>> sim = Simulation(graph, caps=SimCaps(...), params=SimParams(...))
    >>> result = sim.run()
    """

    def __init__(self, graph: ServiceGraph,
                 caps: SimCaps | None = None,
                 params: SimParams | None = None,
                 templates: dict[str, InstanceTemplate] | None = None,
                 default_template: InstanceTemplate | None = None,
                 vm_mips: np.ndarray | None = None,
                 vm_ram: np.ndarray | None = None,
                 api_entries=None,
                 host_egress_scale: np.ndarray | None = None,
                 host_ingress_scale: np.ndarray | None = None,
                 placement_policy: int | None = None,
                 host_zone: np.ndarray | None = None,
                 host_cpu_scale: np.ndarray | None = None,
                 service_slo_ms: np.ndarray | None = None,
                 service_slo_budget: np.ndarray | None = None):
        self.graph = graph
        self.caps = caps or SimCaps()
        self.params = params or SimParams()
        V = self.caps.n_vms
        # host→zone table (failure domains for zone-correlated chaos, §7.1);
        # defaults to one zone per host inside build_app.  The per-service
        # SLO tables feed burn-rate alerting (DESIGN.md §10); -1 entries
        # fall back to the run-wide dyn.slo_ms / dyn.slo_budget.
        self.app = build_app(graph, templates, default_template, api_entries,
                             n_hosts=V, host_zone=host_zone,
                             slo_target_ms=service_slo_ms,
                             slo_budget=service_slo_budget)
        # fail on out-of-range ids NOW, with the offending entry named,
        # instead of silently corrupting goldens at run time (§8)
        validate_app(self.app, self.caps)
        self.vm_mips = np.asarray(
            vm_mips if vm_mips is not None
            else np.full(V, 32_000.0), np.float32)
        self.vm_ram = np.asarray(
            vm_ram if vm_ram is not None
            else np.full(V, 65_536.0), np.float32)
        if len(self.vm_mips) != V or len(self.vm_ram) != V:
            raise ValueError("vm_mips/vm_ram must have n_vms entries")
        # One NIC-attached host per VM slot (network fabric, DESIGN.md §6);
        # the scales shape a heterogeneous fabric while the traced
        # nic_{egress,ingress}_mbps scalars stay sweepable.
        self.host_egress_scale = np.asarray(
            host_egress_scale if host_egress_scale is not None
            else np.ones(V), np.float32)
        self.host_ingress_scale = np.asarray(
            host_ingress_scale if host_ingress_scale is not None
            else np.ones(V), np.float32)
        # CPU-speed analogue of the NIC scales: instances on host h run at
        # cpu_scale[h] × their allocated MIPS (heterogeneous-hardware
        # studies, e.g. examples/hetero_study.py); placement still sees
        # the full requested milicores.
        self.host_cpu_scale = np.asarray(
            host_cpu_scale if host_cpu_scale is not None
            else np.ones(V), np.float32)
        if len(self.host_egress_scale) != V \
                or len(self.host_ingress_scale) != V \
                or len(self.host_cpu_scale) != V:
            raise ValueError("host NIC/CPU scales must have n_vms entries")
        self.placement_policy = (policies.PLACE_MOST_AVAILABLE
                                 if placement_policy is None
                                 else placement_policy)
        self._has_edges = bool(np.asarray(graph.n_succ).sum() > 0)
        self._tick = make_tick(self.caps, self.params, self._has_edges)

    # ------------------------------------------------------------------
    def init_state(self, seed: Optional[int] = None) -> SimState:
        rng = jax.random.PRNGKey(self.params.seed if seed is None else seed)
        state = zeros_state(self.caps, self.params, rng, app=self.app)
        inst, iof, reps = initial_allocation(
            np.asarray(self.app.tmpl_replicas),
            np.asarray(self.app.tmpl_mips),
            np.asarray(self.app.tmpl_limit_mips),
            np.asarray(self.app.tmpl_ram),
            np.asarray(self.app.tmpl_limit_ram),
            np.asarray(self.app.tmpl_bw),
            self.vm_mips, self.vm_ram, self.caps,
            policy=self.placement_policy)
        instances = state.instances._replace(
            **{k: jnp.asarray(v) for k, v in inst.items()})
        vm_used_m = np.zeros_like(self.vm_mips)
        vm_used_r = np.zeros_like(self.vm_ram)
        for i in range(self.caps.max_instances):
            v = inst["vm"][i]
            if v >= 0:
                vm_used_m[v] += inst["mips"][i]
                vm_used_r[v] += inst["ram"][i]
        vms = state.vms._replace(
            mips=jnp.asarray(self.vm_mips), ram=jnp.asarray(self.vm_ram),
            mips_used=jnp.asarray(vm_used_m), ram_used=jnp.asarray(vm_used_r))
        sched = state.sched._replace(inst_of_rank=jnp.asarray(iof),
                                     svc_replicas=jnp.asarray(reps))
        hosts = state.hosts._replace(
            egress_scale=jnp.asarray(self.host_egress_scale),
            ingress_scale=jnp.asarray(self.host_ingress_scale),
            cpu_scale=jnp.asarray(self.host_cpu_scale))
        return state._replace(instances=instances, vms=vms, sched=sched,
                              hosts=hosts)

    # ------------------------------------------------------------------
    # One compiled executable per (static knobs × pytree shapes); swept
    # scalars (dyn) and graph parameterizations (app) are traced arguments.
    _compiled_cache: dict = {}

    @staticmethod
    def _shape_key(tree) -> tuple:
        return tuple((tuple(x.shape), str(x.dtype))
                     for x in jax.tree_util.tree_leaves(tree))

    # every SimParams knob that selects program structure (anything NOT
    # carried by the traced DynParams sweep) — cache keys and run_batch
    # validation both derive from this list.  seed is deliberately absent:
    # it only feeds init_state's PRNGKey, so seed-only changes reuse the
    # compiled executable.
    _STATIC_FIELDS = ("lb_policy", "share_policy", "scaling_policy",
                      "migration_enabled", "n_ticks", "use_pallas_tick",
                      "pallas_interpret", "network", "waterfill_iters",
                      "net_hist_bin_s", "faults", "egress_shaping",
                      "telemetry", "tel_window_ticks", "tel_windows",
                      "tel_span_k", "tel_span_cap", "tel_span_tick_cap",
                      "alerting",
                      "slo_short_wins", "slo_long_wins", "slo_for_ticks",
                      "slo_event_cap")
    # NOTE: hs_mode is deliberately NOT static — it rides DynParams as an
    # integer selector so one run_batch sweep compares util-threshold vs
    # burn-rate control planes without recompiling.

    def _static_key(self) -> tuple:
        p = self.params
        return (self.caps, self._has_edges, p.max_concurrent > 0,
                tuple(getattr(p, f) for f in self._STATIC_FIELDS))

    def _make_run_fn(self) -> Callable:
        """The solo-run program: a plain tick scan, or — telemetry on —
        the chunked scan-of-scan whose chunk boundaries flush half the
        metric ring through the io_callback tap (obs/telemetry.py).
        Exposed so simcheck's jaxpr lint walks the REAL hot-loop program
        (incl. the declared callback site), not a stand-in."""
        tick = self._tick
        n_ticks = self.params.n_ticks
        if self.params.telemetry != "stream":

            def run_fn(st: SimState, dp: DynParams, app: AppStatic):
                return jax.lax.scan(lambda s, _: tick(s, dp, app), st,
                                    None, length=n_ticks)

            return run_fn
        from ..obs import telemetry as telmod
        params = self.params

        def run_fn(st: SimState, dp: DynParams, app: AppStatic):
            return telmod.chunked_scan(lambda s, _: tick(s, dp, app),
                                       st, params, n_ticks)

        return run_fn

    def _get_compiled(self, state: SimState, dyn: DynParams):
        from ..analysis.annotate import checked_mode
        checked = checked_mode()
        key = (self._static_key(), checked,
               self._shape_key((state, dyn, self.app)))
        hit = Simulation._compiled_cache.get(key)
        if hit is not None:
            return hit, 0.0
        t0 = _time.perf_counter()
        run_fn = self._make_run_fn()

        if checked:
            # REPRO_CHECKED=1: functionalize the declared-invariant asserts
            # (annotate.disjoint sites) into a checkify error carried
            # through the scan; run() throws on the first violated one.
            # No donation — checkify's error prefix changes the arity.
            from jax.experimental import checkify
            run_fn = checkify.checkify(run_fn,
                                       errors=checkify.user_checks)
            compiled = jax.jit(run_fn).lower(state, dyn, self.app).compile()
        else:
            # The input state is consumed: run() builds a fresh one per
            # call, so the [C,*] pool blocks alias the output instead of
            # doubling resident bytes.  (Batch paths can't donate — their
            # [B,...] outputs don't match the unbatched input shapes.)
            # simcheck's jaxpr lint enforces this stays donated.
            compiled = (jax.jit(run_fn, donate_argnums=0)
                        .lower(state, dyn, self.app).compile())
        dt = _time.perf_counter() - t0
        Simulation._compiled_cache[key] = compiled
        return compiled, dt

    @staticmethod
    def _unalias(state: SimState) -> SimState:
        """Copy state leaves that share a device buffer with an earlier
        leaf.  zeros_state's identical constant fills can alias one
        buffer, and donating the same buffer twice is an XLA error."""
        leaves, treedef = jax.tree_util.tree_flatten(state)
        seen: set = set()
        out = []
        for x in leaves:
            try:
                ptr = x.unsafe_buffer_pointer()
            except Exception:
                ptr = None
            if ptr is not None and ptr in seen:
                x = jnp.array(x, copy=True)
            elif ptr is not None:
                seen.add(ptr)
            out.append(x)
        return jax.tree_util.tree_unflatten(treedef, out)

    def run(self, seed: Optional[int] = None) -> SimResult:
        """Compile (AOT, timed separately) and execute the full scan."""
        state = self._unalias(self.init_state(seed))
        dyn = DynParams.from_params(self.params)
        compiled, compile_s = self._get_compiled(state, dyn)
        t1 = _time.perf_counter()
        out = compiled(state, dyn, self.app)
        from ..analysis.annotate import checked_mode
        if checked_mode():
            err, (out_state, trace) = out
            err.throw()
        else:
            out_state, trace = out
        out_state = jax.block_until_ready(out_state)
        t2 = _time.perf_counter()
        if self.params.telemetry == "stream":
            from ..obs import telemetry as telmod
            telmod.drain_to_exporter(out_state, self.params)
            if self.params.alerting == "burn":
                from ..obs import slo as slomod
                slomod.drain_to_exporter(out_state, self.params,
                                         tags=np.asarray(dyn.tel_tag))
        return SimResult(state=out_state, trace=trace,
                         wall_time_s=t2 - t1, compile_time_s=compile_s)

    # ------------------------------------------------------------------
    def _get_compiled_batch(self, state: SimState, dyn_b: DynParams,
                            app_b: AppStatic | None = None):
        # The scaling cadence decision must live OUTSIDE the vmap: a
        # traced cond under vmap becomes a select that executes the whole
        # scaling body every tick for every sweep point.  When the sweep
        # shares one scale_interval (checked on the concrete values) the
        # batched program scans ticks at the outer level and conds between
        # vmapped scaling/plain tick variants; otherwise it falls back to
        # the per-point cond.
        has_scaling = bool(self.params.scaling_policy
                           or self.params.migration_enabled)
        si = np.asarray(dyn_b.scale_interval)
        hoist = has_scaling and bool((si == si.flat[0]).all())
        batched_app = app_b is not None
        app_arg = app_b if batched_app else self.app
        key = ("batch", hoist, batched_app, self._static_key(),
               self._shape_key((state, dyn_b, app_arg)))
        hit = Simulation._compiled_cache.get(key)
        if hit is not None:
            return hit, 0.0
        t0 = _time.perf_counter()
        n_ticks = self.params.n_ticks
        B = np.asarray(dyn_b.dt).shape[0]
        # app axis: batched sweeps vmap over (dyn, app); plain sweeps close
        # over the one shared app (in_axes None keeps it unbatched)
        app_ax = 0 if batched_app else None
        tel_on = self.params.telemetry == "stream"
        params = self.params
        if tel_on:
            # the flush must NOT sit under a traced cond (vmap-of-cond
            # rejects IO effects): both batch paths chunk their scans and
            # flush unconditionally between chunks — under vmap the tap
            # fires once per sweep point per chunk, rows tagged by lane
            from ..obs import telemetry as telmod

        if hoist:
            tick_on = make_tick(self.caps, self.params, self._has_edges,
                                scaling="always")
            tick_off = make_tick(self.caps, self.params, self._has_edges,
                                 scaling="never")

            def run_fn(st: SimState, dp_b: DynParams, app: AppStatic):
                st_b = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (B,) + x.shape), st)
                interval = dp_b.scale_interval[0]
                on = jax.vmap(lambda s, d, a: tick_on(s, d, a),
                              in_axes=(0, 0, app_ax))
                off = jax.vmap(lambda s, d, a: tick_off(s, d, a),
                               in_axes=(0, 0, app_ax))

                def body(carry, _):
                    due = (carry.tick[0] % interval) == (interval - 1)
                    return jax.lax.cond(due, lambda s: on(s, dp_b, app),
                                        lambda s: off(s, dp_b, app), carry)

                if tel_on:
                    flush_b = jax.vmap(lambda s: telmod.flush(s, params))
                    states, traces = telmod.chunked_scan(
                        body, st_b, params, n_ticks, flush_fn=flush_b)
                else:
                    states, traces = jax.lax.scan(body, st_b, None,
                                                  length=n_ticks)
                # traces come out [T, B]; match the scan-inside-vmap layout
                return states, jax.tree_util.tree_map(
                    lambda x: jnp.swapaxes(x, 0, 1), traces)
        else:
            tick = self._tick

            def run_fn(st: SimState, dp_b: DynParams, app: AppStatic):
                def one(dp: DynParams, app_p: AppStatic):
                    tick_fn = lambda s, _: tick(s, dp, app_p)
                    if tel_on:
                        return telmod.chunked_scan(tick_fn, st, params,
                                                   n_ticks)
                    return jax.lax.scan(tick_fn, st, None, length=n_ticks)
                return jax.vmap(one, in_axes=(0, app_ax))(dp_b, app)

        compiled = jax.jit(run_fn).lower(state, dyn_b, app_arg).compile()
        dt = _time.perf_counter() - t0
        Simulation._compiled_cache[key] = compiled
        return compiled, dt

    def _check_static_point(self, p: SimParams, b: int) -> None:
        """A sweep point may only vary the DynParams-traced scalars: the
        compiled program keeps ``self.params``' structure, so a mismatch in
        a structural knob would silently run the wrong program."""
        bad = [f for f in self._STATIC_FIELDS
               if getattr(p, f) != getattr(self.params, f)]
        if (p.max_concurrent > 0) != (self.params.max_concurrent > 0):
            bad.append("max_concurrent (capped vs uncapped)")
        if bad:
            raise ValueError(
                f"run_batch sweep point {b} differs from the Simulation's "
                f"params in structural knob(s) {bad}; these select program "
                "structure and cannot be swept — build a separate "
                "Simulation instead")
        if p.seed != self.params.seed:
            raise ValueError(
                f"run_batch sweep point {b} has a different seed; every "
                "point starts from the same initial state — pass seed= to "
                "run_batch (or run separate simulations) instead")

    def run_batch(self, dyn_batch, seed: Optional[int] = None,
                  apps=None) -> SimResult:
        """Run a whole parameter sweep as ONE compile + ONE device dispatch.

        ``dyn_batch`` is either a batched :class:`DynParams` (every leaf
        carries a leading sweep axis) or a sequence of per-point
        :class:`DynParams` / :class:`SimParams` which is stacked here.
        Every sweep point starts from the same initial state (same seed),
        so point ``b`` of the result equals ``run()`` with that point's
        dyn values.  Structure-changing knobs (policy selectors, pool
        sizes, ``n_ticks``) are static — sweep those with separate
        Simulations.

        ``apps`` optionally supplies one :class:`AppStatic` per sweep
        point (every leaf must match ``self.app``'s shape — e.g. re-zoned
        ``host_zone`` tables for a blast-radius study, or re-parameterized
        length/payload models for calibration); the whole sweep still
        compiles and dispatches once, vmapped over (dyn, app).
        """
        if not isinstance(dyn_batch, DynParams):
            points = list(dyn_batch)
            for b, d in enumerate(points):
                if isinstance(d, SimParams):
                    self._check_static_point(d, b)
            dyn_batch = stack_dyn(
                d if isinstance(d, DynParams) else DynParams.from_params(d)
                for d in points)
        B = int(np.asarray(dyn_batch.dt).shape[0])
        app_b = None
        if apps is not None:
            apps = list(apps)
            if len(apps) != B:
                raise ValueError(
                    f"apps must supply one AppStatic per sweep point: got "
                    f"{len(apps)} apps for {B} points")
            ref = self._shape_key(self.app)
            for b, a in enumerate(apps):
                if self._shape_key(a) != ref:
                    raise ValueError(
                        f"apps[{b}] has different array shapes than the "
                        "Simulation's app; shape-changing graphs need a "
                        "separate Simulation")
            app_b = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *apps)
        if self.params.telemetry == "stream":
            # auto-tag streamed rows by sweep point unless the caller
            # already assigned tags (tag is a traced DynParams scalar)
            tags = np.asarray(dyn_batch.tel_tag)
            if np.all(tags == 0.0):
                dyn_batch = dyn_batch._replace(
                    tel_tag=jnp.arange(B, dtype=jnp.float32))
        state = self.init_state(seed)
        compiled, compile_s = self._get_compiled_batch(state, dyn_batch,
                                                       app_b)
        t1 = _time.perf_counter()
        out_state, trace = compiled(state, dyn_batch,
                                    app_b if app_b is not None else self.app)
        out_state = jax.block_until_ready(out_state)
        t2 = _time.perf_counter()
        if self.params.telemetry == "stream":
            from ..obs import telemetry as telmod
            telmod.drain_to_exporter(out_state, self.params)
            if self.params.alerting == "burn":
                from ..obs import slo as slomod
                slomod.drain_to_exporter(out_state, self.params,
                                         tags=np.asarray(dyn_batch.tel_tag))
        return SimResult(state=out_state, trace=trace,
                         wall_time_s=t2 - t1, compile_time_s=compile_s)

    # Convenience accessors -------------------------------------------
    def responses(self, result: SimResult) -> np.ndarray:
        r = np.asarray(result.state.requests.response)
        return r[r >= 0]
