"""Simulation engine: one fused tick per paper event cycle, scanned over time.

``make_tick`` assembles the event phases of paper §3.2 —
Generation → Dispatching → Scheduling → Derivative → Scaling & Migration —
into a single jitted state transition, and ``Simulation`` wraps
``jax.lax.scan`` over it with per-tick QoS traces.
"""
from __future__ import annotations

import dataclasses
import time as _time
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import scheduler
from .app import AppStatic, InstanceTemplate, build_app
from .generator import client_phase
from .graph import ServiceGraph
from .placement import initial_allocation, migrate
from .scaling import scaling_event
from .types import (CL_EXEC, CL_WAITING, DynParams, INST_ON, SimCaps,
                    SimParams, SimState, TickTrace, zeros_state)


def make_tick(caps: SimCaps, params: SimParams,
              has_edges: bool = True) -> Callable:
    """Build the jit-able tick function (paper event cycle, vectorized).

    ``params`` supplies the *static* knobs (policy selectors — they choose
    program structure); the swept scalars (``dyn``) and the application
    description (``app``) are traced arguments, so load/threshold sweeps
    and re-parameterized graphs (calibration) reuse one compilation.
    """

    def tick(state: SimState, dyn: DynParams, app: AppStatic
             ) -> Tuple[SimState, TickTrace]:
        rng, k_gen, k_gen2, k_lb, k_der = jax.random.split(state.rng, 5)
        state = state._replace(rng=rng)

        # --- Generation (paper Alg 1) ---------------------------------
        gen = client_phase(state.clients.wait, state.time,
                           state.requests.count, app.api_cdf, dyn, k_gen)
        state, gen_res = scheduler.gen_spawn(
            state, app, caps, gen.fired, gen.api, gen.wait_proposal, k_gen2)

        # --- Dispatching (waiting → execution, load-balanced) ----------
        state = scheduler.dispatch(state, app, caps, params, dyn, k_lb)

        # --- Scheduling (time-shared execution + finish) ----------------
        state, fin_info = scheduler.execute(state, app, caps, params, dyn)

        # --- Derivative (spawn successors along the service chain) ------
        if has_edges:  # static: edge-free graphs skip the spawn machinery
            state = scheduler.derive(state, app, caps, fin_info, k_der)

        # --- Response (critical-path completion, paper §4.3.2) ----------
        state, n_done = scheduler.complete(state, dyn)

        # --- Scaling & Migration (paper §5) ------------------------------
        if params.scaling_policy or params.migration_enabled:
            due = (state.tick % dyn.scale_interval) == (dyn.scale_interval - 1)

            def do_scale(st: SimState) -> SimState:
                st = scaling_event(st, app, caps, params, dyn)
                if params.migration_enabled:
                    st = migrate(st, app, caps, dyn)
                return st

            state = jax.lax.cond(due, do_scale, lambda st: st, state)

        trace = TickTrace(
            completed=n_done,
            generated=gen_res.n_new_requests,
            n_waiting=jnp.sum((state.cloudlets.status == CL_WAITING)
                              .astype(jnp.int32)),
            n_exec=jnp.sum((state.cloudlets.status == CL_EXEC)
                           .astype(jnp.int32)),
            used_mips=jnp.sum(state.instances.used_mips),
            active_instances=jnp.sum((state.instances.status == INST_ON)
                                     .astype(jnp.int32)),
            active_clients=gen.n_active,
        )
        state = state._replace(tick=state.tick + 1, time=state.time + dyn.dt)
        return state, trace

    return tick


@dataclasses.dataclass
class SimResult:
    state: SimState
    trace: TickTrace
    wall_time_s: float
    compile_time_s: float

    def trace_np(self) -> dict:
        return {k: np.asarray(v) for k, v in self.trace._asdict().items()}


class Simulation:
    """User-facing façade (paper Fig 4 ``Application`` + ``Register``).

    >>> sim = Simulation(graph, caps=SimCaps(...), params=SimParams(...))
    >>> result = sim.run()
    """

    def __init__(self, graph: ServiceGraph,
                 caps: SimCaps | None = None,
                 params: SimParams | None = None,
                 templates: dict[str, InstanceTemplate] | None = None,
                 default_template: InstanceTemplate | None = None,
                 vm_mips: np.ndarray | None = None,
                 vm_ram: np.ndarray | None = None,
                 api_entries=None):
        self.graph = graph
        self.caps = caps or SimCaps()
        self.params = params or SimParams()
        self.app = build_app(graph, templates, default_template, api_entries)
        V = self.caps.n_vms
        self.vm_mips = np.asarray(
            vm_mips if vm_mips is not None
            else np.full(V, 32_000.0), np.float32)
        self.vm_ram = np.asarray(
            vm_ram if vm_ram is not None
            else np.full(V, 65_536.0), np.float32)
        if len(self.vm_mips) != V or len(self.vm_ram) != V:
            raise ValueError("vm_mips/vm_ram must have n_vms entries")
        self._has_edges = bool(np.asarray(graph.n_succ).sum() > 0)
        self._tick = make_tick(self.caps, self.params, self._has_edges)

    # ------------------------------------------------------------------
    def init_state(self, seed: Optional[int] = None) -> SimState:
        rng = jax.random.PRNGKey(self.params.seed if seed is None else seed)
        state = zeros_state(self.caps, self.params, rng,
                            n_services=self.graph.n_services)
        inst, iof, reps = initial_allocation(
            np.asarray(self.app.tmpl_replicas),
            np.asarray(self.app.tmpl_mips),
            np.asarray(self.app.tmpl_limit_mips),
            np.asarray(self.app.tmpl_ram),
            np.asarray(self.app.tmpl_limit_ram),
            np.asarray(self.app.tmpl_bw),
            self.vm_mips, self.vm_ram, self.caps)
        instances = state.instances._replace(
            **{k: jnp.asarray(v) for k, v in inst.items()})
        vm_used_m = np.zeros_like(self.vm_mips)
        vm_used_r = np.zeros_like(self.vm_ram)
        for i in range(self.caps.max_instances):
            v = inst["vm"][i]
            if v >= 0:
                vm_used_m[v] += inst["mips"][i]
                vm_used_r[v] += inst["ram"][i]
        vms = state.vms._replace(
            mips=jnp.asarray(self.vm_mips), ram=jnp.asarray(self.vm_ram),
            mips_used=jnp.asarray(vm_used_m), ram_used=jnp.asarray(vm_used_r))
        sched = state.sched._replace(inst_of_rank=jnp.asarray(iof),
                                     svc_replicas=jnp.asarray(reps))
        return state._replace(instances=instances, vms=vms, sched=sched)

    # ------------------------------------------------------------------
    # One compiled executable per (static knobs × pytree shapes); swept
    # scalars (dyn) and graph parameterizations (app) are traced arguments.
    _compiled_cache: dict = {}

    @staticmethod
    def _shape_key(tree) -> tuple:
        return tuple((tuple(x.shape), str(x.dtype))
                     for x in jax.tree_util.tree_leaves(tree))

    def _get_compiled(self, state: SimState, dyn: DynParams):
        key = (self.caps, self.params.lb_policy, self.params.share_policy,
               self.params.scaling_policy, self.params.max_concurrent > 0,
               self.params.migration_enabled, self.params.n_ticks,
               self._has_edges,
               self._shape_key((state, dyn, self.app)))
        hit = Simulation._compiled_cache.get(key)
        if hit is not None:
            return hit, 0.0
        t0 = _time.perf_counter()
        tick = self._tick
        n_ticks = self.params.n_ticks

        def run_fn(st: SimState, dp: DynParams, app: AppStatic):
            return jax.lax.scan(lambda s, _: tick(s, dp, app), st, None,
                                length=n_ticks)

        compiled = jax.jit(run_fn).lower(state, dyn, self.app).compile()
        dt = _time.perf_counter() - t0
        Simulation._compiled_cache[key] = compiled
        return compiled, dt

    def run(self, seed: Optional[int] = None) -> SimResult:
        """Compile (AOT, timed separately) and execute the full scan."""
        state = self.init_state(seed)
        dyn = DynParams.from_params(self.params)
        compiled, compile_s = self._get_compiled(state, dyn)
        t1 = _time.perf_counter()
        out_state, trace = compiled(state, dyn, self.app)
        out_state = jax.block_until_ready(out_state)
        t2 = _time.perf_counter()
        return SimResult(state=out_state, trace=trace,
                         wall_time_s=t2 - t1, compile_time_s=compile_s)

    # Convenience accessors -------------------------------------------
    def responses(self, result: SimResult) -> np.ndarray:
        r = np.asarray(result.state.requests.response)
        return r[r >= 0]
