"""QoS metrics extraction — the paper's Exporter/Reporter (§3.1, Fig 4).

Produces request-based metrics (response-time stats, QPS, SLO violation
rate), instance-based metrics (utilization, milicores) and service-based
metrics (per-node delays, the input of the critical-path analysis).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

from .engine import SimResult, Simulation
from .types import INST_ON, SimParams


@dataclasses.dataclass
class QoSReport:
    # request-based
    generated_requests: int
    completed_requests: int
    dropped_requests: int
    avg_response_ms: float
    p50_response_ms: float
    p95_response_ms: float
    p99_response_ms: float
    max_response_ms: float
    slo_violation_rate: float
    qps_mean: float
    qps_peak: float
    # cloudlet-based
    cloudlets_spawned: int
    cloudlets_finished: int
    cloudlets_dropped: int
    # instance-based
    active_instances: int
    avg_milicores: float          # paper Fig 11 metric
    avg_utilization: float
    # scaling activity
    scale_out: int
    scale_in: int
    scale_up: int
    scale_down: int
    migrations: int
    # engine
    wall_time_s: float
    compile_time_s: float
    # network fabric (zeros in network="uniform" mode, DESIGN.md §6)
    net_transits: int = 0             # completed transfers
    net_bytes_mb: float = 0.0         # total MB moved on the fabric
    avg_transit_ms: float = 0.0
    transit_p50_ms: float = 0.0       # percentiles from the histogram:
    transit_p95_ms: float = 0.0       # bucket upper edge, CAPPED at the
    transit_p99_ms: float = 0.0       # histogram range (buckets × bin)
    avg_egress_util: float = 0.0      # time-mean NIC utilization over hosts
    avg_ingress_util: float = 0.0
    # availability QoS (all inert in faults="none" mode, DESIGN.md §7)
    availability: float = 1.0         # 1 − failed / completed requests
    error_rate: float = 0.0           # failed attempts / spawned cloudlets
    failed_requests: int = 0
    retries: int = 0                  # retry attempts respawned
    retry_amplification: float = 1.0  # spawned / first-attempt spawns
    failfast_failures: int = 0        # attempts rejected by open breakers
    breaker_trips: int = 0
    host_crashes: int = 0
    observed_mttr_s: float = 0.0      # host down-time / recoveries
    # gray failure / blast radius (DESIGN.md §7.1)
    ejections: int = 0                # replica outlier ejections
    readmissions: int = 0             # ejected replicas re-admitted clean
    zone_faults: int = 0              # zone-correlated crash/slow draws
    partitions: int = 0               # zone-pair partitions opened
    slow_episodes: int = 0            # host fail-slow episodes
    slow_time_s: float = 0.0          # Σ host-slow seconds
    # observability (all-zero unless telemetry="stream", DESIGN.md §9)
    tel_windows: int = 0              # metric windows closed
    tel_spans: int = 0                # spans recorded (sampled requests)
    tel_span_drops: int = 0           # spans dropped at ring capacity
    # SLO alerting (all-zero unless alerting="burn", DESIGN.md §10)
    alert_fires: int = 0              # pending→firing transitions
    alert_resolves: int = 0           # firing→resolved transitions
    alert_firing_time_s: float = 0.0  # Σ (service, rule) seconds firing
    alert_event_drops: int = 0        # transitions dropped at ring capacity

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


def transit_percentile_ms(hist: np.ndarray, bin_s: float, p: float) -> float:
    """p-th percentile of the transit-time distribution from its histogram.

    Reported at the bucket's upper edge — conservative *within* the
    histogram range.  Durations beyond ``len(hist) * bin_s`` land in the
    overflow (last) bucket, so a percentile falling there reads as the
    range cap and under-states a heavily saturated tail; widen
    ``SimCaps.net_hist_buckets`` / ``SimParams.net_hist_bin_s`` when the
    cap is hit (``transit_p99_ms == net_hist_buckets * bin * 1000``)."""
    hist = np.asarray(hist, np.int64)
    n = int(hist.sum())
    if n == 0:
        return 0.0
    cdf = np.cumsum(hist)
    b = int(np.searchsorted(cdf, np.ceil(p / 100.0 * n), side="left"))
    return (b + 1) * bin_s * 1000.0


def summarize(sim: Simulation, result: SimResult,
              window_s: Optional[float] = None,
              params: Optional[SimParams] = None) -> QoSReport:
    """Fold the final state + per-tick traces into a QoS report.

    ``params`` overrides ``sim.params`` for sweep points produced by
    :meth:`Simulation.run_batch` (pass the point's SimParams together
    with the ``batch_item`` slice).
    """
    st = result.state
    params = params or sim.params
    resp_all = np.asarray(st.requests.response)
    # the failed flag is a chaos-mode column (zero-width under
    # faults="none", where nothing ever fails)
    failed_col = np.asarray(st.requests.failed)
    req_failed = (failed_col > 0) if failed_col.size \
        else np.zeros(resp_all.shape, bool)
    # response-time statistics cover SUCCESSFUL completions only (a failed
    # completion's "response" is its time-to-failure); identical to the
    # pre-faults report in faults="none" mode, where nothing ever fails
    resp = resp_all[(resp_all >= 0) & ~req_failed] * 1000.0      # → ms
    trace = result.trace_np()

    dt = params.dt
    qps_series = trace["completed"] / dt
    # steady-state window: after the client ramp (paper Fig 9 highlights
    # the N_c/v boundary), unless the caller overrides.
    ramp_ticks = int(min(params.n_clients / max(params.spawn_rate, 1e-9) / dt,
                         len(qps_series) - 1))
    steady = qps_series[ramp_ticks:] if len(qps_series) > ramp_ticks + 1 \
        else qps_series

    inst_status = np.asarray(st.instances.status)
    on = inst_status == INST_ON
    usage_sum = np.asarray(st.instances.usage_sum)
    busy = np.asarray(st.instances.busy_ticks)
    sim_time = float(st.time)
    # milicores: time-averaged used MIPS converted via mi_per_milicore.
    avg_used = usage_sum / max(sim_time, 1e-9)
    milicores = avg_used * params.mi_per_milicore * 1000.0
    mips = np.asarray(st.instances.mips)
    util = np.where(mips > 0, avg_used / np.maximum(mips, 1e-9), 0.0)

    def pct(p):
        return float(np.percentile(resp, p)) if len(resp) else 0.0

    # --- network fabric (all-zero in uniform mode) -----------------------
    net = st.net
    transits = int(net.transits)
    # every transfer has a destination NIC, so the ingress sum is the
    # total MB moved (client uploads have no egress side)
    bytes_mb = float(np.asarray(net.bytes_in).sum())
    bin_s = params.net_hist_bin_s
    tp = lambda p: transit_percentile_ms(np.asarray(net.hist), bin_s, p)

    # --- availability / resilience (all-zero in faults="none" mode) ------
    fst = st.fstats
    n_failed_req = int(fst.failed_requests)
    spawned = int(st.counters.spawned)
    retries = int(fst.retries)
    recoveries = int(fst.host_recoveries)

    # --- observability (zero-width buffers under telemetry="none") -------
    tel = st.telemetry
    tel_windows = int(np.asarray(tel.win).reshape(-1)[0]) \
        if tel.win.size else 0
    tel_spans = int(np.asarray(tel.span_n).reshape(-1)[0]) \
        if tel.span_n.size else 0
    tel_span_drops = int(np.asarray(tel.span_drops).reshape(-1)[0]) \
        if tel.span_drops.size else 0

    # --- SLO alerting (zero-width buffers unless alerting="burn") --------
    al = st.alerts
    alert_fires = int(np.asarray(al.fires).sum()) if al.fires.size else 0
    alert_resolves = int(np.asarray(al.resolves).sum()) \
        if al.resolves.size else 0
    alert_firing_time_s = float(np.asarray(al.firing_ticks).sum()
                                * params.dt) if al.firing_ticks.size else 0.0
    alert_event_drops = int(np.asarray(al.ev_drops).reshape(-1)[0]) \
        if al.ev_drops.size else 0

    completed = int(st.counters.completed)
    return QoSReport(
        generated_requests=int(st.requests.count),
        completed_requests=completed,
        dropped_requests=int(st.counters.dropped_requests),
        avg_response_ms=float(resp.mean()) if len(resp) else 0.0,
        p50_response_ms=pct(50), p95_response_ms=pct(95),
        p99_response_ms=pct(99),
        max_response_ms=float(resp.max()) if len(resp) else 0.0,
        slo_violation_rate=float(st.counters.slo_violations)
        / max(completed, 1),
        qps_mean=float(steady.mean()) if len(steady) else 0.0,
        qps_peak=float(qps_series.max()) if len(qps_series) else 0.0,
        cloudlets_spawned=int(st.counters.spawned),
        cloudlets_finished=int(st.counters.finished),
        cloudlets_dropped=int(st.counters.dropped_cloudlets),
        active_instances=int(on.sum()),
        avg_milicores=float(milicores[on].mean()) if on.any() else 0.0,
        avg_utilization=float(util[on].mean()) if on.any() else 0.0,
        scale_out=int(st.counters.scale_out),
        scale_in=int(st.counters.scale_in),
        scale_up=int(st.counters.scale_up),
        scale_down=int(st.counters.scale_down),
        migrations=int(st.counters.migrations),
        wall_time_s=result.wall_time_s,
        compile_time_s=result.compile_time_s,
        net_transits=transits,
        net_bytes_mb=bytes_mb,
        avg_transit_ms=float(net.transit_sum) / max(transits, 1) * 1000.0,
        transit_p50_ms=tp(50), transit_p95_ms=tp(95), transit_p99_ms=tp(99),
        avg_egress_util=float(np.asarray(net.egress_busy).mean())
        / max(sim_time, 1e-9),
        avg_ingress_util=float(np.asarray(net.ingress_busy).mean())
        / max(sim_time, 1e-9),
        availability=1.0 - n_failed_req / max(completed, 1),
        error_rate=int(fst.failed_attempts) / max(spawned, 1),
        failed_requests=n_failed_req,
        retries=retries,
        retry_amplification=spawned / max(spawned - retries, 1),
        failfast_failures=int(fst.failfast),
        breaker_trips=int(fst.breaker_trips),
        host_crashes=int(fst.host_crashes),
        observed_mttr_s=float(fst.down_time_s) / max(recoveries, 1),
        ejections=int(fst.ejections),
        readmissions=int(fst.readmissions),
        zone_faults=int(fst.zone_faults),
        partitions=int(fst.partitions),
        slow_episodes=int(fst.slow_episodes),
        slow_time_s=float(fst.slow_time_s),
        tel_windows=tel_windows,
        tel_spans=tel_spans,
        tel_span_drops=tel_span_drops,
        alert_fires=alert_fires,
        alert_resolves=alert_resolves,
        alert_firing_time_s=alert_firing_time_s,
        alert_event_drops=alert_event_drops,
    )


def node_delays(result: SimResult) -> np.ndarray:
    """Mean sojourn (wait + exec) per service — the per-node ``delay(n)``
    of paper Eq 5, measured from the simulation."""
    st = result.state.svc_stats
    fin = np.asarray(st.finished).astype(np.float64)
    return np.asarray(st.delay_sum) / np.maximum(fin, 1.0)


def report_text(rep: QoSReport) -> str:
    """Human-readable Reporter output (paper: 'displayed in system logs')."""
    lines = ["=== CloudNativeSim QoS report ==="]
    for f in dataclasses.fields(rep):
        lines.append(f"  {f.name:22s} {getattr(rep, f.name)}")
    return "\n".join(lines)
