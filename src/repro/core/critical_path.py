"""Response-time analysis via the critical path (paper §4.3.2, Alg 2).

The paper iterates over every path of a service chain and keeps the
max-delay one (Eqs 5–6).  Enumerating paths is exponential in DAG width; we
compute the same quantity with max-plus linear algebra over the adjacency
matrix (kernels/tropical — DESIGN.md §2.3):

    D* = tropical_closure(A),   A[i,j] = delay(j) if i→j else -inf
    responseTime(api) = delay(entry) + max_j D*[entry, j]

which equals  max_{p ∈ P} Σ_{n ∈ p} delay(n)  (Eq 5/6) for every chain.
The critical path itself is recovered by greedy argmax backtracking.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from ..kernels.tropical import NEG_INF, tropical_closure
from .graph import ServiceGraph


def delay_matrix(graph: ServiceGraph, delays: np.ndarray) -> jnp.ndarray:
    """A[i, j] = delay(j) on edges of the service DAG, -inf elsewhere."""
    S = graph.n_services
    adj = graph.adjacency()
    d = np.asarray(delays, dtype=np.float32)
    a = np.where(adj, d[None, :], np.float32(NEG_INF))
    return jnp.asarray(a)


def response_times(graph: ServiceGraph, delays: np.ndarray,
                   use_pallas: bool | None = None,
                   interpret: bool = False) -> np.ndarray:
    """Critical-path response time per API (Alg 2 output), in delay units."""
    a = delay_matrix(graph, delays)
    d_star = tropical_closure(a, depth=graph.depth,
                              use_pallas=use_pallas, interpret=interpret)
    d_star = np.asarray(d_star)
    d = np.asarray(delays, dtype=np.float64)
    out = np.zeros(graph.n_apis, dtype=np.float64)
    for api in range(graph.n_apis):
        entry = int(graph.api_entry[api])
        best = d_star[entry].max()          # includes the 0-length self path
        out[api] = d[entry] + max(best, 0.0)
    return out


def response_times_batched(graph: ServiceGraph, delays_bt: np.ndarray,
                           use_pallas: bool | None = None,
                           interpret: bool = False) -> np.ndarray:
    """Batched Alg 2 over [B, S] delay snapshots (e.g. per time window).

    This is the fleet-scale shape the tropical kernel is built for:
    [B, S, S] closures in one call.
    """
    delays_bt = np.asarray(delays_bt, dtype=np.float32)
    B, S = delays_bt.shape
    adj = graph.adjacency()
    a = np.where(adj[None, :, :], delays_bt[:, None, :], np.float32(NEG_INF))
    d_star = tropical_closure(jnp.asarray(a), depth=graph.depth,
                              use_pallas=use_pallas, interpret=interpret)
    d_star = np.asarray(d_star)
    out = np.zeros((B, graph.n_apis), dtype=np.float64)
    for api in range(graph.n_apis):
        entry = int(graph.api_entry[api])
        best = d_star[:, entry, :].max(axis=-1)
        out[:, api] = delays_bt[:, entry] + np.maximum(best, 0.0)
    return out


def critical_path(graph: ServiceGraph, delays: np.ndarray, api: int
                  ) -> Tuple[float, List[int]]:
    """Alg 2 faithful form: returns (responseTime, CP node list).

    Longest-path DP in topological order with backtracking — host-side,
    used for reporting and for cross-validating the tropical closure.
    """
    S = graph.n_services
    d = np.asarray(delays, dtype=np.float64)
    entry = int(graph.api_entry[api])
    best = np.full(S, -np.inf)
    parent = np.full(S, -1, dtype=np.int64)
    best[entry] = d[entry]
    order = np.argsort(graph.levels, kind="stable")
    for u in order:
        if best[u] == -np.inf:
            continue
        for v in graph.succ[u]:
            if v < 0:
                continue
            cand = best[u] + d[v]
            if cand > best[v]:
                best[v] = cand
                parent[v] = u
    leaf = int(np.argmax(np.where(np.isfinite(best), best, -np.inf)))
    rt = float(best[leaf])
    path = [leaf]
    while parent[path[-1]] >= 0:
        path.append(int(parent[path[-1]]))
    return rt, path[::-1]


def path_delay(path: Sequence[int], delays: np.ndarray) -> float:
    """Eq 5: D_p = Σ_{n ∈ p} delay(n)."""
    d = np.asarray(delays, dtype=np.float64)
    return float(sum(d[n] for n in path))
