"""Request Generator (paper §4.3.1, Algorithm 1, Eqs 1–4).

Vectorized Locust-style closed-loop client model: ``N_c`` clients ramp up at
``v`` clients/second; each client fires a request at a weighted-random API,
then sleeps uniform ``[p0, p1]`` seconds.  The closed forms the paper derives
(Eqs 1, 3, 4) are provided as `*_analytic` functions and are asserted against
the simulated trace in tests and `benchmarks/bench_generator.py` (Fig 9).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import streams
from .types import DynParams, SimParams


class GenOut(NamedTuple):
    fired: jnp.ndarray       # [Nc] bool — client fired this tick
    api: jnp.ndarray         # [Nc] i32 — chosen API (valid where fired)
    n_active: jnp.ndarray    # scalar i32 — active clients (Eq 1)
    wait_proposal: jnp.ndarray  # [Nc] i32 — next wait if the fire is accepted


def client_phase(wait: jnp.ndarray, time: jnp.ndarray, req_count: jnp.ndarray,
                 api_weight_cdf: jnp.ndarray, dyn: DynParams,
                 rng: jnp.ndarray) -> GenOut:
    """One generation tick (paper Alg 1 lines 4–17, vectorized).

    Fire decisions + proposed wait resets; the engine commits them after
    admission (backpressure may defer a fire to the next tick).
    """
    Nc = wait.shape[0]
    idx = jnp.arange(Nc)
    # Eq 1: N(t) = min(Nc, v * t)   (ramp at spawn rate v).
    n_active = jnp.minimum(
        dyn.n_clients,
        jnp.floor(dyn.spawn_rate * time).astype(jnp.int32) + 1,
    )
    active = idx < n_active
    under_limit = req_count < dyn.num_limit
    fired = active & (wait <= 0) & under_limit

    k_api, k_wait = streams.split(rng, names=("api", "wait"))
    # Weighted API selection (Alg 1 line 9): inverse-CDF on the weight set.
    u = jax.random.uniform(k_api, (Nc,))
    api = jnp.searchsorted(api_weight_cdf, u).astype(jnp.int32)
    api = jnp.minimum(api, api_weight_cdf.shape[0] - 1)

    # Alg 1 line 13: wait ~ U[p0, p1] (converted to ticks, ≥ 1).
    wait_s = dyn.wait_lo + (dyn.wait_hi - dyn.wait_lo) \
        * jax.random.uniform(k_wait, (Nc,))
    wait_ticks = jnp.maximum(jnp.round(wait_s / dyn.dt), 1).astype(jnp.int32)
    return GenOut(fired=fired, api=api, n_active=n_active,
                  wait_proposal=wait_ticks)


# --------------------------------------------------------------------------
# Closed forms (paper Eqs 1, 3, 4) — used to validate the generator (Fig 9).
# --------------------------------------------------------------------------

def n_clients_analytic(t: np.ndarray, params: SimParams) -> np.ndarray:
    """Eq 1: N(t) = min(N_c, v·t)."""
    return np.minimum(params.n_clients, params.spawn_rate * np.asarray(t))


def qps_analytic(t: np.ndarray, params: SimParams) -> np.ndarray:
    """Eq 3: λ(t) = N(t) · 2/(p0+p1)."""
    return (n_clients_analytic(t, params) * 2.0
            / (params.wait_lo + params.wait_hi))


def total_requests_analytic(t: np.ndarray, params: SimParams) -> np.ndarray:
    """Eq 4: piecewise ∫λ — quadratic during ramp-up, linear afterwards."""
    t = np.asarray(t, dtype=np.float64)
    Nc, v = params.n_clients, params.spawn_rate
    psum = params.wait_lo + params.wait_hi
    t_ramp = Nc / v
    ramp = v / psum * t ** 2
    steady = 2.0 * Nc / psum * t - Nc ** 2 / (v * psum)
    return np.where(t <= t_ramp, ramp, steady)


def api_weight_cdf(weights: np.ndarray) -> jnp.ndarray:
    w = np.asarray(weights, dtype=np.float64)
    cdf = np.cumsum(w / w.sum())
    cdf[-1] = 1.0
    return jnp.asarray(cdf, dtype=jnp.float32)
