"""Service-dependency graph (paper §4.1.1, Figs 6–7).

A :class:`ServiceGraph` is the static description of a cloud-native
application: named services, their call edges (a DAG), the APIs that enter
the graph, and per-service cloudlet statistics.  It is built host-side with
numpy (it is configuration, not state) and exposes the padded successor /
predecessor tables ("bidirectional service hierarchy", paper Fig 7) that the
jitted engine consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

# Default per-edge RPC payload (MB) when a graph carries no payload spec —
# small enough that generous NICs reproduce near-uniform behavior.
DEFAULT_PAYLOAD_MB = 0.01


@dataclasses.dataclass
class ServiceGraph:
    """Static DAG of services + API entry points.

    Attributes
    ----------
    names : service names, index = service id.
    succ : [S, d_max] int32 successor table, padded with -1 (forward table
        of paper Fig 7).
    pred : [S, d_max_in] int32 predecessor table (reverse table of Fig 7).
    n_succ / n_pred : [S] int32 degrees.
    api_names : API labels, index = api id.
    api_entry : [A] int32 entry service per API.
    api_weight : [A] float32 selection weight (paper Fig 3a "weight").
    len_mean / len_std : [S] float32 Gaussian cloudlet length in MI
        (paper §4.1.2 — lengths are sampled per cloudlet).
    levels : [S] int32 topological level of each service.
    payload_mean / payload_std : [S, d_max] float32 Gaussian RPC payload
        (MB, request+response lumped) per call edge, aligned with ``succ``
        (network fabric, DESIGN.md §6; -0 rows beyond n_succ are inert).
    api_payload_mean / api_payload_std : [A] float32 client→entry payload.
    edge_retry : [S, d_max] int32 per-call-edge retry budget (-1 = use the
        run-wide ``SimParams.retry_budget`` — resilience, DESIGN.md §7).
    api_retry : [A] int32 client→entry retry budget (-1 = run-wide default).
    edge_timeout : [S, d_max] float32 per-call-edge attempt timeout in
        seconds (-1 = use the run-wide ``SimParams.retry_timeout_s``) —
        timeout budgets match the per-edge retry budgets, DESIGN.md §7.
    api_timeout : [A] float32 client→entry timeout (-1 = run-wide default).
    """

    names: List[str]
    succ: np.ndarray
    pred: np.ndarray
    n_succ: np.ndarray
    n_pred: np.ndarray
    api_names: List[str]
    api_entry: np.ndarray
    api_weight: np.ndarray
    len_mean: np.ndarray
    len_std: np.ndarray
    levels: np.ndarray
    payload_mean: np.ndarray = None
    payload_std: np.ndarray = None
    api_payload_mean: np.ndarray = None
    api_payload_std: np.ndarray = None
    edge_retry: np.ndarray = None
    api_retry: np.ndarray = None
    edge_timeout: np.ndarray = None
    api_timeout: np.ndarray = None

    def __post_init__(self):
        """Fill default payload/retry tables for graphs built before the
        network fabric / resilience subsystems existed (payloads default to
        DEFAULT_PAYLOAD_MB, retry budgets to -1 = run-wide default)."""
        S, D = self.succ.shape if self.succ.size else (len(self.names), 1)
        A = len(self.api_names)
        if self.payload_mean is None:
            self.payload_mean = np.full((S, D), DEFAULT_PAYLOAD_MB,
                                        np.float32)
        if self.payload_std is None:
            self.payload_std = 0.1 * np.asarray(self.payload_mean,
                                                np.float32)
        if self.api_payload_mean is None:
            self.api_payload_mean = np.full((A,), DEFAULT_PAYLOAD_MB,
                                            np.float32)
        if self.api_payload_std is None:
            self.api_payload_std = 0.1 * np.asarray(self.api_payload_mean,
                                                    np.float32)
        if self.edge_retry is None:
            self.edge_retry = np.full((S, D), -1, np.int32)
        if self.api_retry is None:
            self.api_retry = np.full((A,), -1, np.int32)
        if self.edge_timeout is None:
            self.edge_timeout = np.full((S, D), -1.0, np.float32)
        if self.api_timeout is None:
            self.api_timeout = np.full((A,), -1.0, np.float32)

    # ------------------------------------------------------------------
    @property
    def n_services(self) -> int:
        return len(self.names)

    @property
    def n_apis(self) -> int:
        return len(self.api_names)

    @property
    def d_max(self) -> int:
        return int(self.succ.shape[1])

    @property
    def depth(self) -> int:
        return int(self.levels.max()) + 1 if self.n_services else 0

    def service_id(self, name: str) -> int:
        return self.names.index(name)

    # ------------------------------------------------------------------
    def adjacency(self) -> np.ndarray:
        """Dense [S, S] bool adjacency matrix (i calls j)."""
        S = self.n_services
        adj = np.zeros((S, S), dtype=bool)
        for i in range(S):
            for j in self.succ[i]:
                if j >= 0:
                    adj[i, int(j)] = True
        return adj

    def chains_from(self, root: int, limit: int = 4096) -> List[List[int]]:
        """Enumerate root→leaf chains (paper §4.1.1 "service chains").

        Used by analysis/tests only; the engine never enumerates paths —
        it uses the tropical longest-path formulation (critical_path.py).
        """
        chains: List[List[int]] = []

        def dfs(node: int, path: List[int]):
            if len(chains) >= limit:
                return
            succs = [int(s) for s in self.succ[node] if s >= 0]
            if not succs:
                chains.append(path)
                return
            for s in succs:
                dfs(s, path + [s])

        dfs(root, [root])
        return chains

    def validate(self) -> None:
        """Reject cyclic graphs (paper: service calls are acyclic)."""
        S = self.n_services
        indeg = self.n_pred.copy()
        queue = [i for i in range(S) if indeg[i] == 0]
        seen = 0
        while queue:
            u = queue.pop()
            seen += 1
            for v in self.succ[u]:
                if v >= 0:
                    indeg[int(v)] -= 1
                    if indeg[int(v)] == 0:
                        queue.append(int(v))
        if seen != S:
            raise ValueError("service graph contains a cycle — not a DAG")


def build_graph(
    services: Sequence[str],
    calls: Dict[str, Sequence[str]],
    apis: Sequence[Tuple[str, str, float]],
    len_mean: Dict[str, float],
    len_std: Dict[str, float] | None = None,
    d_max: int | None = None,
    payloads: Dict[Tuple[str, str], float] | None = None,
    payload_stds: Dict[Tuple[str, str], float] | None = None,
    api_payloads: Dict[str, float] | None = None,
    default_payload_mb: float = DEFAULT_PAYLOAD_MB,
    retries: Dict[Tuple[str, str], int] | None = None,
    api_retries: Dict[str, int] | None = None,
    timeouts: Dict[Tuple[str, str], float] | None = None,
    api_timeouts: Dict[str, float] | None = None,
) -> ServiceGraph:
    """Construct a :class:`ServiceGraph`.

    Parameters
    ----------
    services : ordered service names.
    calls : service name → called service names (DAG edges).
    apis : (api_name, entry_service, weight) triples.
    len_mean / len_std : per-service Gaussian cloudlet length (MI).
    d_max : pad successor tables to this out-degree (default: observed max).
    payloads / payload_stds : (caller, callee) → RPC payload mean/std in MB
        (network fabric; unlisted edges get ``default_payload_mb`` /
        10% of the mean).
    api_payloads : api name → client→entry payload mean in MB.
    retries / api_retries : per-edge retry budgets (resilience, §7);
        unlisted edges fall back to the run-wide ``SimParams.retry_budget``.
    timeouts / api_timeouts : per-edge attempt timeouts in seconds (§7);
        unlisted edges fall back to the run-wide
        ``SimParams.retry_timeout_s``, so timeout budgets can match the
        per-edge retry budgets.
    """
    names = list(services)
    index = {n: i for i, n in enumerate(names)}
    S = len(names)
    succ_lists: List[List[int]] = [[] for _ in range(S)]
    pred_lists: List[List[int]] = [[] for _ in range(S)]
    for src, dsts in calls.items():
        for dst in dsts:
            if src not in index or dst not in index:
                raise KeyError(f"unknown service in edge {src}->{dst}")
            succ_lists[index[src]].append(index[dst])
            pred_lists[index[dst]].append(index[src])

    obs_out = max([len(l) for l in succ_lists], default=1) or 1
    obs_in = max([len(l) for l in pred_lists], default=1) or 1
    d_out = max(d_max or 0, obs_out)
    d_in = max(d_max or 0, obs_in)

    succ = np.full((S, d_out), -1, dtype=np.int32)
    pred = np.full((S, d_in), -1, dtype=np.int32)
    for i, l in enumerate(succ_lists):
        succ[i, : len(l)] = l
    for i, l in enumerate(pred_lists):
        pred[i, : len(l)] = l

    n_succ = np.array([len(l) for l in succ_lists], dtype=np.int32)
    n_pred = np.array([len(l) for l in pred_lists], dtype=np.int32)

    api_names = [a[0] for a in apis]
    api_entry = np.array([index[a[1]] for a in apis], dtype=np.int32)
    api_weight = np.array([a[2] for a in apis], dtype=np.float32)
    if api_weight.sum() <= 0:
        raise ValueError("API weights must sum to a positive value")

    mean = np.array([len_mean[n] for n in names], dtype=np.float32)
    if len_std is None:
        std = 0.1 * mean
    else:
        std = np.array([len_std.get(n, 0.1 * len_mean[n]) for n in names],
                       dtype=np.float32)

    def edge_slot(src: str, dst: str, what: str) -> Tuple[int, int]:
        """Resolve a (caller, callee) name pair to its successor-table
        (row, slot) — shared by every per-edge table (payloads, retries)."""
        if src not in index or dst not in index:
            raise KeyError(f"unknown service in {what} edge {src}->{dst}")
        try:
            d = succ_lists[index[src]].index(index[dst])
        except ValueError:
            raise KeyError(
                f"{what} declared for non-edge {src}->{dst}: add {dst!r} "
                f"to {src!r}'s calls first") from None
        return index[src], d

    # Per-edge payload tables, aligned with the padded succ table.
    payloads = payloads or {}
    payload_stds = payload_stds or {}
    payload_mean = np.full((S, d_out), default_payload_mb, np.float32)
    payload_std = 0.1 * payload_mean
    for (src, dst), mb in payloads.items():
        s, d = edge_slot(src, dst, "payload")
        payload_mean[s, d] = mb
        payload_std[s, d] = payload_stds.get((src, dst), 0.1 * mb)
    api_payloads = api_payloads or {}
    api_payload_mean = np.array(
        [float(api_payloads.get(a[0], default_payload_mb)) for a in apis],
        np.float32)
    api_payload_std = 0.1 * api_payload_mean

    # Per-edge retry budgets, aligned with the padded succ table (§7).
    edge_retry = np.full((S, d_out), -1, np.int32)
    for (src, dst), n in (retries or {}).items():
        s, d = edge_slot(src, dst, "retry budget")
        edge_retry[s, d] = int(n)
    api_retry = np.array(
        [int((api_retries or {}).get(a[0], -1)) for a in apis], np.int32)

    # Per-edge attempt timeouts, same resolver/layout as the retry table.
    edge_timeout = np.full((S, d_out), -1.0, np.float32)
    for (src, dst), sec in (timeouts or {}).items():
        s, d = edge_slot(src, dst, "timeout")
        edge_timeout[s, d] = float(sec)
    api_timeout = np.array(
        [float((api_timeouts or {}).get(a[0], -1.0)) for a in apis],
        np.float32)

    # Topological levels (longest distance from any root).
    levels = np.zeros(S, dtype=np.int32)
    indeg = n_pred.copy()
    queue = [i for i in range(S) if indeg[i] == 0]
    order = []
    while queue:
        u = queue.pop()
        order.append(u)
        for v in succ[u]:
            if v >= 0:
                levels[v] = max(levels[v], levels[u] + 1)
                indeg[v] -= 1
                if indeg[v] == 0:
                    queue.append(int(v))
    graph = ServiceGraph(
        names=names, succ=succ, pred=pred, n_succ=n_succ, n_pred=n_pred,
        api_names=api_names, api_entry=api_entry, api_weight=api_weight,
        len_mean=mean, len_std=std, levels=levels,
        payload_mean=payload_mean, payload_std=payload_std,
        api_payload_mean=api_payload_mean, api_payload_std=api_payload_std,
        edge_retry=edge_retry, api_retry=api_retry,
        edge_timeout=edge_timeout, api_timeout=api_timeout,
    )
    graph.validate()
    return graph


def linear_chain(n: int, mi: float = 1000.0,
                 name: str = "svc") -> ServiceGraph:
    """n-service pipeline svc0 → svc1 → … (test/benchmark helper)."""
    names = [f"{name}{i}" for i in range(n)]
    calls = {names[i]: [names[i + 1]] for i in range(n - 1)}
    return build_graph(names, calls, [("GET /chain", names[0], 1.0)],
                       {nm: mi for nm in names})


def star(n_leaves: int, mi: float = 1000.0) -> ServiceGraph:
    """Fan-out: gateway → n_leaves parallel services (capacity tests)."""
    names = ["gateway"] + [f"leaf{i}" for i in range(n_leaves)]
    calls = {"gateway": names[1:]}
    return build_graph(names, calls, [("GET /fanout", "gateway", 1.0)],
                       {nm: mi for nm in names}, d_max=n_leaves)


def diamond(mi: float = 1000.0) -> ServiceGraph:
    """Paper Fig 6: A → {B, C} → D."""
    return build_graph(
        ["A", "B", "C", "D"],
        {"A": ["B", "C"], "B": ["D"], "C": ["D"]},
        [("GET /demo", "A", 1.0)],
        {"A": mi, "B": mi, "C": 2 * mi, "D": mi},
    )
