"""Static application description consumed by the jitted tick function.

``AppStatic`` bundles the service graph tables (paper Fig 7), the API entry
mapping, the Gaussian cloudlet-length model (paper §4.1.2) and the
per-service instance templates (paper Fig 3b YAML: requests/limits) as jnp
arrays that the engine closes over.  It is configuration — never mutated.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np

from .generator import api_weight_cdf
from .graph import ServiceGraph


@dataclasses.dataclass(frozen=True)
class InstanceTemplate:
    """Per-service instance spec (paper Fig 3b)."""

    mips: float = 1000.0          # requests.share → initial CPU (MI/s)
    limit_mips: float = 2000.0    # limits.share   → VS ceiling
    ram: float = 300.0            # requests.ram (MB)
    limit_ram: float = 500.0
    bw: float = 100.0             # rec/trans bandwidth (Mbps)
    replicas: int = 1
    ram_per_cloudlet: float = 1.0   # linear usage model (paper §5.2)
    bytes_per_rpc: float = 0.01     # MB per inter-service call


class AppStatic(NamedTuple):
    succ: jnp.ndarray           # [S, d_max] i32
    n_succ: jnp.ndarray         # [S] i32
    len_mean: jnp.ndarray       # [S] f32 (MI)
    len_std: jnp.ndarray        # [S] f32
    api_entry: jnp.ndarray      # [A, E_max] i32 (-1 pad)
    api_n_entry: jnp.ndarray    # [A] i32
    api_cdf: jnp.ndarray        # [A] f32
    tmpl_mips: jnp.ndarray      # [S] f32
    tmpl_limit_mips: jnp.ndarray
    tmpl_ram: jnp.ndarray
    tmpl_limit_ram: jnp.ndarray
    tmpl_bw: jnp.ndarray
    tmpl_replicas: jnp.ndarray  # [S] i32
    ram_per_cl: jnp.ndarray     # [S] f32
    bytes_per_rpc: jnp.ndarray  # [S] f32
    payload_mean: jnp.ndarray   # [S, d_max] f32 per-edge RPC payload (MB)
    payload_std: jnp.ndarray    # [S, d_max] f32
    api_payload_mean: jnp.ndarray  # [A] f32 client→entry payload (MB)
    api_payload_std: jnp.ndarray   # [A] f32
    edge_retry: jnp.ndarray     # [S*d_max + A] i32 per-edge retry budget,
    #                             -1 = run-wide default; indexed by the
    #                             cloudlet ``edge`` id (resilience, §7)
    edge_timeout: jnp.ndarray   # [S*d_max + A] f32 per-edge attempt
    #                             timeout (s), -1 = run-wide default
    #                             (SimParams.retry_timeout_s); same edge-id
    #                             layout as edge_retry
    host_zone: jnp.ndarray      # [H] i32 failure-domain (zone) id per host
    #                             — zone-correlated fault draws hit every
    #                             host sharing an id (DESIGN.md §7.1);
    #                             default: each host its own zone
    slo_target_ms: jnp.ndarray  # [S] f32 per-service SLO latency target
    #                             (ms) for burn-rate SLIs, -1 = run-wide
    #                             default (dyn.slo_ms); DESIGN.md §10
    slo_budget: jnp.ndarray     # [S] f32 per-service error-budget
    #                             fraction, -1 = run-wide default
    #                             (dyn.slo_budget); budget ≤ 0 after
    #                             fallback disables the objective

    @property
    def n_services(self) -> int:
        return self.succ.shape[0]

    @property
    def n_apis(self) -> int:
        return self.api_cdf.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_retry.shape[0]

    @property
    def n_hosts(self) -> int:
        return self.host_zone.shape[0]


def validate_app(app: AppStatic, caps) -> None:
    """Build-time bounds validation (DESIGN.md §8).

    Every id table the jitted tick indexes with is range-checked HERE,
    before tracing, with errors that name the offending entry — the
    PR-4 bug class (an undersized edge table silently corrupting
    goldens) becomes a build failure.  The index-safety verifier's
    seed intervals (``analysis/intervals.py``) assume exactly these
    bounds, so a validated app makes its proofs sound.
    """
    from .types import edge_table_size
    S, A, H = app.n_services, app.n_apis, app.n_hosts
    D = int(app.succ.shape[1]) if app.succ.ndim == 2 else 0
    problems: list[str] = []

    succ = np.asarray(app.succ).reshape(S, -1)
    if succ.size and (succ.min() < -1 or succ.max() >= S):
        problems.append(
            f"succ table ids must lie in [-1, {S - 1}]: got "
            f"[{succ.min()}, {succ.max()}]")
    if D > caps.d_max:
        problems.append(
            f"service out-degree {D} exceeds caps.d_max={caps.d_max}; "
            f"the per-edge retry/breaker tables would be undersized — "
            f"raise SimCaps.d_max to at least {D}")
    if app.n_edges != S * D + max(A, 1):
        problems.append(
            f"edge tables have {app.n_edges} rows but the edge-id space "
            f"is S*d_max+A = {S}*{D}+{max(A, 1)} = {S * D + max(A, 1)}; "
            f"edge ids past the table would read out of bounds")
    if D <= caps.d_max and app.n_edges > edge_table_size(S, caps.d_max, A):
        problems.append(
            f"edge tables ({app.n_edges} rows) exceed the caps-derived "
            f"bound edge_table_size({S}, {caps.d_max}, {A}) = "
            f"{edge_table_size(S, caps.d_max, A)}")

    entry = np.asarray(app.api_entry).reshape(A, -1)
    if entry.size and (entry.min() < -1 or entry.max() >= S):
        problems.append(
            f"api_entry service ids must lie in [-1, {S - 1}]: got "
            f"[{entry.min()}, {entry.max()}]")
    for a in range(A):
        if entry.size and not (entry[a] >= 0).any():
            problems.append(f"API {a} has no entry service")

    reps = np.asarray(app.tmpl_replicas)
    if reps.size and (reps.min() < 1 or reps.max() > caps.max_replicas):
        bad = int(np.argmax((reps < 1) | (reps > caps.max_replicas)))
        problems.append(
            f"service {bad} declares {int(reps[bad])} replicas; replica "
            f"counts must lie in [1, caps.max_replicas={caps.max_replicas}]")
    if reps.size and int(reps.sum()) > caps.max_instances:
        problems.append(
            f"total initial replicas {int(reps.sum())} exceed "
            f"caps.max_instances={caps.max_instances}; raise the cap or "
            f"trim the templates")

    hz = np.asarray(app.host_zone)
    if hz.size and (hz.min() < 0 or hz.max() >= H):
        problems.append(
            f"host_zone ids must lie in [0, {H}): got "
            f"[{hz.min()}, {hz.max()}]")

    # Reject call-graph cycles reachable from an API entry: derivative
    # spawning would loop forever, and acyclicity is what caps chain
    # depth at S-1 hops — the depth column's declared bound
    # (types.POOL_COLUMN_BOUNDS) behind scheduler.derive's depth clamp.
    # Only meaningful once both id tables are in range (checked above).
    ids_ok = ((not succ.size or (succ.min() >= -1 and succ.max() < S))
              and (not entry.size
                   or (entry.min() >= -1 and entry.max() < S)))
    if succ.size and entry.size and ids_ok:
        depth = np.full((S,), -1, np.int64)
        roots = entry[entry >= 0]
        depth[roots] = 0
        cyclic = False
        for _ in range(S + 1):
            changed = False
            for s in range(S):
                if depth[s] < 0:
                    continue
                for c in succ[s]:
                    if c >= 0 and depth[c] < depth[s] + 1:
                        depth[c] = depth[s] + 1
                        changed = True
            if not changed:
                break
        else:
            cyclic = True
        if cyclic:
            problems.append(
                "service call graph has a cycle reachable from an API "
                "entry — derivative spawning would never terminate")

    if problems:
        raise ValueError(
            "application failed build-time bounds validation:\n  - "
            + "\n  - ".join(problems))


def build_app(graph: ServiceGraph,
              templates: dict[str, InstanceTemplate] | None = None,
              default_template: InstanceTemplate | None = None,
              api_entries: Sequence[Sequence[str]] | None = None,
              n_hosts: int = 0,
              host_zone: Sequence[int] | None = None,
              slo_target_ms: Sequence[float] | None = None,
              slo_budget: Sequence[float] | None = None) -> AppStatic:
    """Assemble :class:`AppStatic` from a graph + instance templates.

    ``api_entries`` optionally overrides the per-API entry services with a
    *list* per API (fan-out at the entry, used by capacity benchmarks);
    default is the single entry service recorded in the graph.

    ``host_zone`` maps each of the cluster's ``n_hosts`` hosts to a
    failure domain for zone-correlated chaos (registry ``zones:`` key);
    default is one zone per host (no correlation).

    ``slo_target_ms`` / ``slo_budget`` declare per-service SLO objectives
    for burn-rate alerting (registry per-service ``slo_ms`` /
    ``slo_budget`` keys); -1 entries fall back to the run-wide traced
    defaults at evaluation time.
    """
    default_template = default_template or InstanceTemplate()
    templates = templates or {}
    S = graph.n_services
    A = graph.n_apis

    if host_zone is None:
        hz = np.arange(n_hosts, dtype=np.int32)
    else:
        hz = np.asarray(host_zone, dtype=np.int32).reshape(-1)
        n_hosts = n_hosts or hz.shape[0]
        if hz.shape[0] != n_hosts:
            raise ValueError(
                f"host_zone must list one zone per host: got {hz.shape[0]} "
                f"entries for {n_hosts} hosts")
        if hz.size and (hz.min() < 0 or hz.max() >= n_hosts):
            raise ValueError(
                f"host_zone ids must lie in [0, {n_hosts}): got "
                f"[{hz.min()}, {hz.max()}]")

    def svc_table(name: str, vals) -> np.ndarray:
        if vals is None:
            return np.full((S,), -1.0, dtype=np.float32)
        arr = np.asarray(vals, dtype=np.float32).reshape(-1)
        if arr.shape[0] != S:
            raise ValueError(
                f"{name} must list one value per service: got "
                f"{arr.shape[0]} entries for {S} services")
        return arr

    slo_t = svc_table("slo_target_ms", slo_target_ms)
    slo_b = svc_table("slo_budget", slo_budget)

    def tarr(field: str, dtype=np.float32) -> np.ndarray:
        return np.array(
            [getattr(templates.get(n, default_template), field)
             for n in graph.names], dtype=dtype)

    if api_entries is None:
        e_max = 1
        entry = graph.api_entry.reshape(A, 1).astype(np.int32)
        n_entry = np.ones((A,), dtype=np.int32)
    else:
        e_max = max(len(e) for e in api_entries)
        entry = np.full((A, e_max), -1, dtype=np.int32)
        n_entry = np.zeros((A,), dtype=np.int32)
        for a, names in enumerate(api_entries):
            ids = [graph.service_id(n) for n in names]
            entry[a, : len(ids)] = ids
            n_entry[a] = len(ids)

    return AppStatic(
        succ=jnp.asarray(graph.succ),
        n_succ=jnp.asarray(graph.n_succ),
        len_mean=jnp.asarray(graph.len_mean),
        len_std=jnp.asarray(graph.len_std),
        api_entry=jnp.asarray(entry),
        api_n_entry=jnp.asarray(n_entry),
        api_cdf=api_weight_cdf(graph.api_weight),
        tmpl_mips=jnp.asarray(tarr("mips")),
        tmpl_limit_mips=jnp.asarray(tarr("limit_mips")),
        tmpl_ram=jnp.asarray(tarr("ram")),
        tmpl_limit_ram=jnp.asarray(tarr("limit_ram")),
        tmpl_bw=jnp.asarray(tarr("bw")),
        tmpl_replicas=jnp.asarray(tarr("replicas", np.int32)),
        ram_per_cl=jnp.asarray(tarr("ram_per_cloudlet")),
        bytes_per_rpc=jnp.asarray(tarr("bytes_per_rpc")),
        payload_mean=jnp.asarray(graph.payload_mean),
        payload_std=jnp.asarray(graph.payload_std),
        api_payload_mean=jnp.asarray(graph.api_payload_mean),
        api_payload_std=jnp.asarray(graph.api_payload_std),
        edge_retry=jnp.concatenate(
            [jnp.asarray(graph.edge_retry, jnp.int32).reshape(-1),
             jnp.asarray(graph.api_retry, jnp.int32)]),
        edge_timeout=jnp.concatenate(
            [jnp.asarray(graph.edge_timeout, jnp.float32).reshape(-1),
             jnp.asarray(graph.api_timeout, jnp.float32)]),
        host_zone=jnp.asarray(hz),
        slo_target_ms=jnp.asarray(slo_t),
        slo_budget=jnp.asarray(slo_b),
    )
