"""Service placement & migration (paper §5.1, Algorithm 3).

Initial allocation runs host-side (numpy) at simulation build time — it is
configuration, not simulation state.  Runtime migration (overloaded VM →
cooler VM) is jitted and runs inside the tick loop.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import policies
from .app import AppStatic
from .types import DynParams, INST_ON, SimCaps, SimState


class PlacementError(RuntimeError):
    pass


def initial_allocation(app_replicas: np.ndarray, tmpl_mips: np.ndarray,
                       tmpl_limit_mips: np.ndarray, tmpl_ram: np.ndarray,
                       tmpl_limit_ram: np.ndarray, tmpl_bw: np.ndarray,
                       vm_mips: np.ndarray, vm_ram: np.ndarray,
                       caps: SimCaps,
                       policy: int = policies.PLACE_MOST_AVAILABLE,
                       ) -> Tuple[dict, np.ndarray, np.ndarray]:
    """Paper Algorithm 3: deploy every service's replicas onto VMs.

    VMs are kept in a priority order by available CPU ("sortedQueue …
    descending available PE resources"); each instance goes to the head VM
    that fits.  Returns (instance field dict, inst_of_rank, svc_replicas).
    """
    S = len(app_replicas)
    I, V = caps.max_instances, caps.n_vms
    if len(vm_mips) != V:
        raise PlacementError(f"expected {V} VMs, got {len(vm_mips)}")

    inst = {
        "status": np.zeros(I, np.int32),
        "service": np.full(I, -1, np.int32),
        "vm": np.full(I, -1, np.int32),
        "host": np.full(I, -1, np.int32),
        "mips": np.zeros(I, np.float32),
        "limit_mips": np.zeros(I, np.float32),
        "request_mips": np.zeros(I, np.float32),
        "ram": np.zeros(I, np.float32),
        "limit_ram": np.zeros(I, np.float32),
        "bw": np.zeros(I, np.float32),
    }
    vm_used_mips = np.zeros(V, np.float64)
    vm_used_ram = np.zeros(V, np.float64)
    inst_of_rank = np.full((S, caps.max_replicas), -1, np.int32)
    svc_replicas = np.zeros(S, np.int32)

    slot = 0
    for s in range(S):
        n_rep = int(app_replicas[s])
        if n_rep > caps.max_replicas:
            raise PlacementError(
                f"service {s}: {n_rep} replicas > "
                f"max_replicas={caps.max_replicas}")
        for r in range(n_rep):
            if slot >= I:
                raise PlacementError(
                    "instance pool exhausted during placement")
            free_mips = vm_mips - vm_used_mips
            free_ram = vm_ram - vm_used_ram
            if policy == policies.PLACE_FIRST_FIT:
                order = np.arange(V)
            elif policy == policies.PLACE_BEST_FIT:
                order = np.argsort(free_mips)            # tightest fit first
            elif policy == policies.PLACE_SPREAD:
                order = np.roll(np.arange(V), -slot)     # cycle hosts
            else:  # PLACE_MOST_AVAILABLE (paper default)
                order = np.argsort(-free_mips)
            placed = False
            for v in order:
                if (free_mips[v] >= tmpl_mips[s]
                        and free_ram[v] >= tmpl_ram[s]):
                    inst["status"][slot] = INST_ON
                    inst["service"][slot] = s
                    inst["vm"][slot] = v
                    inst["host"][slot] = v     # NIC attachment = VM's node
                    inst["mips"][slot] = tmpl_mips[s]
                    inst["limit_mips"][slot] = tmpl_limit_mips[s]
                    inst["request_mips"][slot] = tmpl_mips[s]
                    inst["ram"][slot] = tmpl_ram[s]
                    inst["limit_ram"][slot] = tmpl_limit_ram[s]
                    inst["bw"][slot] = tmpl_bw[s]
                    vm_used_mips[v] += tmpl_mips[s]
                    vm_used_ram[v] += tmpl_ram[s]
                    inst_of_rank[s, r] = slot
                    svc_replicas[s] += 1
                    slot += 1
                    placed = True
                    break
            if not placed:
                raise PlacementError(
                    f"service {s} replica {r}: no VM fits "
                    f"(mips={tmpl_mips[s]}, ram={tmpl_ram[s]})")
    return inst, inst_of_rank, svc_replicas


def migrate(state: SimState, app: AppStatic, caps: SimCaps,
            dyn: DynParams) -> SimState:
    """One migration step (paper §5.1): if the hottest VM exceeds the
    utilization threshold, move its smallest instance to the coolest VM."""
    inst, vms = state.instances, state.vms
    util = vms.mips_used / jnp.maximum(vms.mips, 1e-9)
    hot = jnp.argmax(util)
    need = util[hot] > dyn.mig_vm_util_hi

    on_hot = (inst.status == INST_ON) & (inst.vm == hot)
    cand_mips = jnp.where(on_hot, inst.mips, jnp.inf)
    mover = jnp.argmin(cand_mips)
    movable = need & on_hot[mover]

    # never migrate onto the source VM or a down host (fault injection §7;
    # host id = vm id, all-up in faults="none" mode)
    free = jnp.where((jnp.arange(vms.mips.shape[0]) == hot)
                     | (state.fault.host_up <= 0), -jnp.inf,
                     vms.mips - vms.mips_used)
    tgt = jnp.argmax(free)
    fits = (free[tgt] >= inst.mips[mover]) & \
           (vms.ram[tgt] - vms.ram_used[tgt] >= inst.ram[mover])
    # anti-ping-pong hysteresis: only move if the target ends up strictly
    # cooler than the source was (else the next event would bounce back)
    tgt_util_after = (vms.mips_used[tgt] + inst.mips[mover]) \
        / jnp.maximum(vms.mips[tgt], 1e-9)
    do = movable & fits & (tgt_util_after < util[hot] - 1e-6)

    dm = jnp.where(do, inst.mips[mover], 0.0)
    dr = jnp.where(do, inst.ram[mover], 0.0)
    vms = vms._replace(
        mips_used=vms.mips_used.at[hot].add(-dm).at[tgt].add(dm),
        ram_used=vms.ram_used.at[hot].add(-dr).at[tgt].add(dr),
    )
    new_vm = jnp.where(do, tgt, inst.vm[mover])
    inst = inst._replace(
        vm=inst.vm.at[mover].set(new_vm),
        host=inst.host.at[mover].set(new_vm))  # the NIC moves with the VM
    counters = state.counters._replace(
        migrations=state.counters.migrations + do.astype(jnp.int32))
    return state._replace(instances=inst, vms=vms, counters=counters)
