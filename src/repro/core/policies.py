"""Policy interfaces and built-in policy identifiers (paper §3.2, §5).

CloudNativeSim exposes *new policy interfaces* for cloud-native scheduling:
load balancing (cloudlet→instance), CPU sharing (time-slice weighting),
service scaling (NS/HS/VS) and placement (service→VM).  Built-ins are
selected with the integer ids below (kept static so the engine stays
jit-compilable); custom policies plug in as pure callables with the
signatures documented in each Protocol.
"""
from __future__ import annotations

from typing import Protocol

import jax
import jax.numpy as jnp

from ..analysis.annotate import checked_mode, disjoint

from .types import INST_ON

# --- load balancing (paper §4.2: "maximum idle resources or random") ------
LB_ROUND_ROBIN = 0
LB_RANDOM = 1
LB_LEAST_LOADED = 2

# --- CPU sharing (paper §4.2: equal vs unequal time slices) ---------------
SHARE_EQUAL = 0        # equal time slice multiplexing
SHARE_SRPT = 1         # unequal: weight ∝ 1/remaining (best-effort short-job)

# --- scaling (paper §5.3 / §6.4: NS, HS, VS) -------------------------------
SCALE_NONE = 0
SCALE_HORIZONTAL = 1
SCALE_VERTICAL = 2
SCALE_HYBRID = 3       # HS first, VS when replica cap reached (beyond-paper)

# --- HS scale-out gate (dyn.hs_mode; traced per-sweep-point selector) ------
HS_UTIL = 0            # threshold on the service utilization EMA (Alg 4)
HS_SLO_BURN = 1        # firing SLO burn-rate alert + stabilization window
#                        (alerting="burn" control loop, DESIGN.md §10)

# --- placement (paper §5.1 Alg 3) ------------------------------------------
PLACE_MOST_AVAILABLE = 0   # sorted queue by descending free PEs (paper)
PLACE_FIRST_FIT = 1
PLACE_BEST_FIT = 2
PLACE_SPREAD = 3           # k8s-style topology spread: cycle the VM list so
#                            consecutive instances land on different hosts —
#                            creates cross-host RPC edges for the network
#                            fabric (DESIGN.md §6) instead of piling onto
#                            the largest node

LB_NAMES = {LB_ROUND_ROBIN: "round_robin", LB_RANDOM: "random",
            LB_LEAST_LOADED: "least_loaded"}
SCALE_NAMES = {SCALE_NONE: "NS", SCALE_HORIZONTAL: "HS",
               SCALE_VERTICAL: "VS", SCALE_HYBRID: "HYBRID"}


def lb_rank(lb_policy: int, rr: jnp.ndarray, svc: jnp.ndarray,
            rep_safe: jnp.ndarray, offset: jnp.ndarray, rng,
            inst_of_rank: jnp.ndarray, inst_status: jnp.ndarray,
            inst_n_exec: jnp.ndarray, inst_mips: jnp.ndarray
            ) -> jnp.ndarray:
    """Replica-rank selection shared by ``scheduler.dispatch`` (slot-order
    ``offset``) and the fabric's spawn-time addressing
    (``network.pick_replicas``, FCFS wave-rank ``offset``) — one source of
    truth for the three built-in LB policies.

    ``svc`` must be pre-sanitized (masked lanes pointing at a valid id);
    returns the per-lane replica rank (callers map it through
    ``inst_of_rank`` and apply their own validity masks).
    """
    i32 = jnp.int32
    if lb_policy == LB_ROUND_ROBIN:
        return (rr[svc] + offset) % rep_safe
    if lb_policy == LB_RANDOM:
        return jax.random.randint(rng, svc.shape, 0, 1 << 30) % rep_safe
    # LB_LEAST_LOADED: per service, the replica with the lowest
    # executing-per-mips load among its ON instances.
    valid = inst_of_rank >= 0
    iof_safe = jnp.where(valid, inst_of_rank, 0)
    load = inst_n_exec[iof_safe] / jnp.maximum(inst_mips[iof_safe], 1e-6)
    load = jnp.where(valid & (inst_status[iof_safe] == INST_ON),
                     load, jnp.inf)
    return jnp.argmin(load, axis=1).astype(i32)[svc]


def eject_view(sched, eject_until: jnp.ndarray, time: jnp.ndarray
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Breaker-aware outlier-ejection view of the dispatch rank table
    (DESIGN.md §7.1): returns ``(inst_of_rank, svc_replicas)`` with every
    OPEN-ejected replica (``time < eject_until``) compacted out, so the
    LB policies route around a sick replica instead of the edge breaker
    failing the whole edge.  HALF-OPEN replicas (cooldown elapsed) stay in
    the rotation as probe targets.

    When nothing is ejected the compaction is the exact identity — the
    keep mask reduces to the in-rank mask, positions equal ranks, and the
    returned tables are value-identical to ``sched``'s, which keeps the
    fault-free and default-chaos goldens bit-pinned.
    """
    i32 = jnp.int32
    iof = sched.inst_of_rank                      # [S, R]
    S, Rm = iof.shape
    idx = jnp.arange(Rm, dtype=i32)[None, :]
    in_rank = idx < sched.svc_replicas[:, None]
    ejected = eject_until[jnp.maximum(iof, 0)] > time
    keep = in_rank & ~ejected
    pos = jnp.cumsum(keep.astype(i32), axis=1) - 1
    n_ok = jnp.where(keep, pos + 1, 0).max(axis=1)
    rows = jnp.broadcast_to(jnp.arange(S, dtype=i32)[:, None], (S, Rm))
    cols = jnp.where(keep, pos, Rm)               # Rm = out of bounds → drop
    if checked_mode():
        from jax.experimental import checkify
        hits = jnp.zeros((S, Rm), i32).at[rows, cols].add(1, mode="drop")
        checkify.check(jnp.all(hits <= 1),
                       "eject_view: duplicate compaction target")
    # Disjointness: within a row the kept positions are a prefix ranking
    # (cumsum of the keep mask), so (row, pos) pairs never repeat; the
    # 2-D prefix pattern is outside the 1-D rank tag, hence the
    # declaration + checked-mode assert.
    with disjoint("eject_view"):
        iof_eff = jnp.full((S, Rm), -1, i32).at[rows, cols].set(
            iof, mode="drop")
    return iof_eff, n_ok


class LoadBalancer(Protocol):
    """Custom load-balancing hook.

    Called once per tick with the per-instance load view; must return, for
    every service, the *rank offset* added to the round-robin cursor.  See
    ``scheduler.dispatch`` for how ranks map to replicas.
    """

    def __call__(self, inst_service: jnp.ndarray, inst_load: jnp.ndarray,
                 rng: jnp.ndarray) -> jnp.ndarray: ...


class ScalingPolicy(Protocol):
    """Custom scaling hook (paper §5.3 "users can customize auto-scaling").

    Receives the utilization EMA per instance and the service mapping;
    returns per-service desired replica delta (int) and per-instance mips
    multiplier (float).  Built-ins: HS returns ±1 deltas, VS returns
    up/down factors.
    """

    def __call__(self, util_ema: jnp.ndarray, inst_service: jnp.ndarray,
                 inst_status: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray]: ...
