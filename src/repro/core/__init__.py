"""CloudNativeSim core — the paper's contribution as a composable JAX module.

Public API:
    Simulation, SimCaps, SimParams   — build & run a simulation
    build_graph / ServiceGraph       — service-dependency DAG (paper §4.1.1)
    register                          — file registry (paper §3.1)
    summarize / QoSReport             — QoS feedback (paper §3.1)
    critical_path / response_times    — Alg 2 analysis (paper §4.3.2)
    policies                          — built-in policy ids + interfaces
"""
from . import policies  # noqa: F401
from .app import AppStatic, InstanceTemplate, build_app  # noqa: F401
from .critical_path import (critical_path, path_delay,  # noqa: F401
                            response_times,  # noqa: F401
                            response_times_batched)  # noqa: F401
from .engine import (SimResult, Simulation, batch_item,  # noqa: F401
                     make_tick, stack_dyn)  # noqa: F401
from .generator import (n_clients_analytic, qps_analytic,  # noqa: F401
                        total_requests_analytic)  # noqa: F401
from .graph import (ServiceGraph, build_graph, diamond,  # noqa: F401
                    linear_chain, star)  # noqa: F401
from .qos import QoSReport, node_delays, report_text, summarize  # noqa: F401
from .registry import register  # noqa: F401
from .types import (DynParams, PoolLayout, SimCaps, SimParams,  # noqa: F401
                    SimState, resolve_layout)  # noqa: F401
